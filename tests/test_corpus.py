"""Corpus-level synthesis tests: the scenario zoo registry, joint
clustering across scenarios, the shared terminal table, and the
single-batched-PGD-solve contract."""
import numpy as np
import pytest

from repro.core import proxy_search
from repro.core.events import CommEvent, ComputeEvent
from repro.core.synthesize import synthesize, synthesize_corpus
from repro.core.trace_ir import TraceStore


def _store(vectors, comm_axis="x", n_ranks=4):
    comm = CommEvent("psum", (8,), "float32", (comm_axis,))
    tr = []
    for v in vectors:
        tr += [ComputeEvent(tuple(v)), comm]
    return TraceStore.from_rank_traces([list(tr) for _ in range(n_ranks)],
                                       {comm_axis: n_ranks})


_V1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
_V2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
_V3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)


def test_corpus_shares_terminals_across_scenarios():
    """A compute behaviour two scenarios share becomes ONE corpus terminal
    (joint clustering + corpus table), and identical comm events unify."""
    corp = synthesize_corpus([
        ("a", _store([_V1, _V2])),
        ("b", _store([_V1, _V3])),       # shares V1 and the psum with a
    ])
    assert corp.stats["n_scenarios"] == 2
    assert corp.stats["n_solver_calls"] == 1
    # V1 cluster + psum shared; V2/V3 private → 4 corpus terminals, 2 shared
    assert corp.stats["n_corpus_terminals"] == 4
    assert corp.stats["n_shared_terminals"] == 2
    # the shared compute terminal got the same fit in both scenarios
    fa = {e.key(): corp.results["a"].fits[g]
          for g, e in enumerate(corp.results["a"].merged.table.events)
          if not isinstance(e, CommEvent)}
    fb = {e.key(): corp.results["b"].fits[g]
          for g, e in enumerate(corp.results["b"].merged.table.events)
          if not isinstance(e, CommEvent)}
    shared = set(fa) & set(fb)
    assert len(shared) == 1
    k = shared.pop()
    assert fa[k] is fb[k]                # literally the same FitResult


def test_corpus_single_batched_solve(monkeypatch):
    """The whole corpus fits in exactly one fit_batch dispatch."""
    calls = []
    orig = proxy_search.fit_batch

    def counting(targets, *a, **kw):
        calls.append(np.atleast_2d(targets).shape[0])
        return orig(targets, *a, **kw)

    monkeypatch.setattr(proxy_search, "fit_batch", counting)
    corp = synthesize_corpus([
        ("a", _store([_V1, _V2])),
        ("b", _store([_V1, _V3])),
        ("c", _store([_V2, _V3])),
    ])
    assert len(calls) == 1               # one dispatch for three scenarios
    assert calls[0] == 3                 # V1, V2, V3 clusters
    assert corp.stats["n_compute_terminals"] == 3


def test_corpus_fidelity_matches_per_scenario_loop():
    stores = {"a": _store([_V1, _V2]), "b": _store([_V3, _V1])}
    corp = synthesize_corpus(list(stores.items()))
    for sname, st in stores.items():
        res = synthesize(store=st, name=f"loop_{sname}", solver="pgd")
        f_loop = res.fidelity(sample_ranks=None)
        f_corp = corp.results[sname].fidelity(sample_ranks=None)
        assert f_loop.comm_lossless and f_corp.comm_lossless
        np.testing.assert_array_equal(f_corp.delta, f_loop.delta)


def test_corpus_report_structure():
    corp = synthesize_corpus([("a", _store([_V1])), ("b", _store([_V2]))])
    rep = corp.report(sample_ranks=None)
    assert set(rep["scenarios"]) == {"a", "b"}
    for row in rep["scenarios"].values():
        assert row["comm_lossless"]
        assert row["compression_ratio"] > 1
    assert rep["all_comm_lossless"]
    assert rep["n_solver_calls"] == 1
    assert corp.stats["corpus_compression_ratio"] > 1


def test_corpus_proxies_execute():
    corp = synthesize_corpus([("a", _store([_V1])), ("b", _store([_V2]))])
    for res in corp.results.values():
        out = res.proxy.run_local(ranks=[0])
        assert np.isfinite(np.float32(out["s"]))


# ---------------------------------------------------------------------------
# scenario zoo registry (real model-zoo builders)
# ---------------------------------------------------------------------------


def test_zoo_covers_all_families():
    from repro.configs.registry import ARCH_IDS, SCENARIOS

    fams = {s.family for s in SCENARIOS.values()}
    assert fams == {"transformer", "flash", "ssm", "moe", "encdec"}
    for s in SCENARIOS.values():
        assert s.arch_id in ARCH_IDS


@pytest.mark.parametrize("name", ["transformer-dp", "encdec-pipeline"])
def test_zoo_builders_trace_and_synthesize(name):
    """Cheap end-to-end: build a reduced zoo scenario and synthesize it."""
    from repro.configs.registry import build_scenario

    st = build_scenario(name, n_ranks=4, steps=2)
    assert st.n_ranks == 4 and st.n_events > 0
    assert st.metrics.shape[1] == 6
    assert np.all(st.metrics >= 0) and np.any(st.metrics > 0)
    res = synthesize(store=st, name=name.replace("-", "_"))
    fid = res.fidelity(sample_ranks=None)
    assert fid.comm_lossless
    assert res.stats["compression_ratio"] > 1


def test_zoo_corpus_two_scenarios():
    """The registry path through synthesize_corpus (CI smoke shape)."""
    corp = synthesize_corpus(["transformer-dp", "ssm-decode"],
                             n_ranks=4, steps=2)
    assert corp.stats["n_scenarios"] == 2
    assert corp.stats["n_solver_calls"] == 1
    rep = corp.report(sample_ranks=None)
    assert rep["all_comm_lossless"]
