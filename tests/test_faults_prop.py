"""Property tests for chaos repair: random seeded fault schedules over
random append/remove sequences — after ``repair()``, store state equals
from-scratch synthesis of the surviving scenario set.

Split per the repo convention: the seeded deterministic schedule corpus
always runs; only the hypothesis-randomized exploration skips when
hypothesis is absent (the gating condition is the optional dependency)."""
import numpy as np
import pytest

from repro.core import faults
from repro.core.corpus_store import CorpusStore, IngestBatchError
from repro.core.events import CommEvent, ComputeEvent
from repro.core.trace_ir import TraceStore

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic schedule corpus in this module still runs")

_VECS = [(2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.),
         (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0),
         (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0),
         (1.3e7, 2.2e4, 5.1e6, 3.3e2, 1.0, 0.)]


def _scenario(i: int) -> TraceStore:
    comm = CommEvent("psum", (8,), "float32", ("x",))
    vs = [_VECS[i % len(_VECS)], _VECS[(i + 1) % len(_VECS)]]
    tr = []
    for v in vs:
        tr += [ComputeEvent(tuple(float(x) + i for x in v)), comm]
    return TraceStore.from_rank_traces([list(tr) for _ in range(4)],
                                       {"x": 4})


#: fault kinds a single-process schedule can recover from in-process
#: (worker_death needs a pool; slow_lock only delays)
_KINDS = ("crash_before", "crash_after", "torn_write", "io_error")


def _reopen_and_repair(root) -> CorpusStore:
    """What a restarted appender process does after a crash: reopen
    from disk and repair if fsck finds damage.  Read faults can fire
    during the reopen itself; each retry burns a spec's budget, so the
    loop is bounded by the plan's total fault count."""
    while True:
        try:
            cs = CorpusStore(root)
            if not cs.verify().clean:
                cs.repair()
            return cs
        except (faults.InjectedCrash, OSError):
            continue


def _check_schedule(seed: int, ops: list[tuple[str, int]]) -> None:
    """Drive a random append/remove sequence under a seeded fault plan;
    whatever faults fire, the repaired store must equal a from-scratch
    store over the survivors (names, hashes, cluster derivation)."""
    import tempfile
    from pathlib import Path
    root = Path(tempfile.mkdtemp()) / "corpus"

    plan = faults.FaultPlan.random(seed, n_faults=3, kinds=_KINDS)
    with faults.active_plan(plan):
        cs = _reopen_and_repair(root)
        for op, i in ops:
            name = f"s{i}"
            try:
                if op == "add" and name not in cs:
                    cs.add_scenario(name, _scenario(i))
                elif op == "remove" and name in cs:
                    cs.remove_scenario(name)
            except (faults.InjectedCrash, OSError, IngestBatchError):
                # a "crashed" handle is dead: recover as a restarted
                # appender would
                cs = _reopen_and_repair(root)

    cs = CorpusStore(root)
    if not cs.verify().clean:
        cs.repair()
    rep = cs.verify()
    assert rep.clean, rep.summary()

    # the oracle: survivors == a from-scratch store over the same set
    fresh_root = root.parent / "fresh"
    fresh = CorpusStore(fresh_root)
    for n in cs.names:
        i = int(n[1:])
        fresh.add_scenario(n, _scenario(i))
    assert fresh.names == cs.names
    for n in cs.names:
        assert fresh.content_hash(n) == cs.content_hash(n)
    ids_a, reps_a = cs.cluster_assignments()
    ids_b, reps_b = fresh.cluster_assignments()
    assert set(ids_a) == set(ids_b)
    for n in ids_a:
        np.testing.assert_array_equal(ids_a[n], ids_b[n])
    assert set(reps_a) == set(reps_b)
    for c in reps_a:
        np.testing.assert_array_equal(reps_a[c], reps_b[c])


def _ops_from_rng(rng) -> list[tuple[str, int]]:
    ops = []
    for _ in range(int(rng.integers(3, 9))):
        op = "add" if rng.random() < 0.7 else "remove"
        ops.append((op, int(rng.integers(0, 5))))
    return ops


def test_seeded_schedule_corpus():
    """Deterministic corpus: a spread of seeds, each driving a random
    fault plan over a random append/remove sequence."""
    for seed in (0, 1, 2, 7, 13, 21, 34):
        rng = np.random.default_rng(seed)
        _check_schedule(seed, _ops_from_rng(rng))


def test_schedule_reproducibility():
    """Same seed -> same fault plan -> same surviving set (the property
    that makes a chaos failure a test case, not a flake)."""
    rng = np.random.default_rng(5)
    ops = _ops_from_rng(rng)
    import tempfile
    from pathlib import Path

    def run():
        plan = faults.FaultPlan.random(5, n_faults=2, kinds=_KINDS)
        root = Path(tempfile.mkdtemp()) / "c"
        with faults.active_plan(plan):
            cs = _reopen_and_repair(root)
            for op, i in ops:
                name = f"s{i}"
                try:
                    if op == "add" and name not in cs:
                        cs.add_scenario(name, _scenario(i))
                    elif op == "remove" and name in cs:
                        cs.remove_scenario(name)
                except (faults.InjectedCrash, OSError, IngestBatchError):
                    cs = _reopen_and_repair(root)
        cs = CorpusStore(root)
        if not cs.verify().clean:
            cs.repair()
        return cs.names, [f for f in plan.fired]

    names1, fired1 = run()
    names2, fired2 = run()
    assert fired1 == fired2 or [f[:2] for f in fired1] == \
        [f[:2] for f in fired2]                    # details carry tmp paths
    assert names1 == names2


if HAVE_HYPOTHESIS:

    @given(st.integers(0, 10_000),
           st.lists(st.tuples(st.sampled_from(["add", "remove"]),
                              st.integers(0, 4)),
                    min_size=2, max_size=8))
    @settings(max_examples=15, deadline=None)
    def test_random_schedule_property(seed, ops):
        _check_schedule(seed, ops)
