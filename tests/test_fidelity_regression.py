"""Golden fidelity-regression harness for the scenario zoo.

Per-scenario δ̄ of the corpus-synthesized proxies is checked against the
checked-in baseline ``artifacts/fidelity_baseline.json`` with an explicit
one-sided tolerance: solver, clustering, or grammar changes may *improve*
fidelity freely, but a silent regression beyond ``tolerance`` fails.

Regenerate the baseline after an intentional fidelity change::

    PYTHONPATH=src python tests/test_fidelity_regression.py --update-baseline

The measurement is the reduced zoo (``n_ranks=4, steps=2``, all ranks
measured) synthesized through the batch corpus path — the same joint
clustering the production pipeline uses, so the baseline pins the whole
front half + solver + replay stack, not just the solver.
"""
import json
from pathlib import Path

import pytest

BASELINE_PATH = Path(__file__).resolve().parent.parent / "artifacts" \
    / "fidelity_baseline.json"

#: reduced-zoo measurement shape (keep in sync with the baseline file)
MEASURE_KWARGS = {"n_ranks": 4, "steps": 2}

#: one-sided regression allowance on per-scenario mean δ̄.  δ̄ is
#: deterministic per platform; the slack absorbs cross-platform libm /
#: BLAS drift, not real regressions (a solver change that costs more than
#: this much fidelity on any scenario must update the baseline on purpose).
TOLERANCE = 0.05


def measure() -> dict:
    """Per-scenario mean δ̄ + comm losslessness for the reduced zoo."""
    from repro.core.synthesize import synthesize_corpus

    corp = synthesize_corpus(**MEASURE_KWARGS)
    out = {}
    for sname, res in corp.results.items():
        fid = res.fidelity(sample_ranks=None)
        out[sname] = {"mean_delta": float(fid.mean),
                      "comm_lossless": bool(fid.comm_lossless)}
    return out


def test_fidelity_no_regression():
    assert BASELINE_PATH.exists(), (
        f"missing {BASELINE_PATH}; regenerate with "
        "PYTHONPATH=src python tests/test_fidelity_regression.py "
        "--update-baseline")
    baseline = json.loads(BASELINE_PATH.read_text())
    assert baseline["measure_kwargs"] == MEASURE_KWARGS, (
        "baseline was measured at a different zoo shape; regenerate it")
    got = measure()

    missing = set(got) - set(baseline["scenarios"])
    assert not missing, (
        f"scenarios {sorted(missing)} have no fidelity baseline; "
        "regenerate with --update-baseline")

    failures = []
    for sname, want in baseline["scenarios"].items():
        if sname not in got:
            failures.append(f"{sname}: scenario disappeared from the zoo")
            continue
        row = got[sname]
        if not row["comm_lossless"]:
            failures.append(f"{sname}: comm stream no longer lossless")
        if row["mean_delta"] > want["mean_delta"] + baseline["tolerance"]:
            failures.append(
                f"{sname}: mean δ̄ regressed {want['mean_delta']:.4f} -> "
                f"{row['mean_delta']:.4f} "
                f"(tolerance {baseline['tolerance']})")
    assert not failures, "fidelity regression:\n  " + "\n  ".join(failures)


def update_baseline() -> None:
    payload = {
        "comment": "per-scenario mean δ̄ of the reduced zoo; regenerate "
                   "with tests/test_fidelity_regression.py "
                   "--update-baseline after intentional fidelity changes",
        "measure_kwargs": MEASURE_KWARGS,
        "tolerance": TOLERANCE,
        "scenarios": measure(),
    }
    BASELINE_PATH.parent.mkdir(parents=True, exist_ok=True)
    BASELINE_PATH.write_text(json.dumps(payload, indent=1, sort_keys=True)
                             + "\n")
    print(f"wrote {BASELINE_PATH}:")
    for sname, row in sorted(payload["scenarios"].items()):
        print(f"  {sname}: mean_delta={row['mean_delta']:.4f} "
              f"comm_lossless={row['comm_lossless']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update-baseline", action="store_true",
                    help="re-measure the zoo and overwrite "
                         "artifacts/fidelity_baseline.json")
    args = ap.parse_args()
    if args.update_baseline:
        update_baseline()
    else:
        ap.error("pass --update-baseline (the check itself runs "
                 "under pytest)")
