"""Golden fidelity-regression harness for the scenario zoo — point + bands.

Two coupled tiers over one shared corpus synthesis:

* **point regression** (deterministic): per-scenario δ̄ of the
  corpus-synthesized proxies against the checked-in baseline
  ``artifacts/fidelity_baseline.json`` with an explicit one-sided
  tolerance — solver, clustering, or grammar changes may *improve*
  fidelity freely, but a silent regression beyond ``tolerance`` fails.
  Scenarios whose δ̄ sits far from the pack (flash-ring, δ̄≈2.29) carry
  an explicit ``expected_band`` entry instead of the shared tolerance,
  so the harness states the accepted range instead of hiding the outlier
  under a blanket slack.
* **statistical regression** (seeded noise): the same proxies replayed
  under the calibrated noise models (``NoiseConfig`` — fixed seed and
  replica count, so the distribution is reproducible bit-for-bit) must
  land their noisy mean δ̄ inside the per-scenario confidence band
  pinned in the baseline (``mean ± max(z·std, tolerance)``).

Regenerate the baseline after an intentional fidelity change::

    PYTHONPATH=src python tests/test_fidelity_regression.py --update-baseline

This rewrites both the point and band columns; the point column must NOT
move for noise-layer-only changes (noise replay is opt-in — the
``noise=None`` path traces byte-identical jaxprs).

The measurement is the reduced zoo (``n_ranks=4, steps=2``, all ranks
measured) synthesized through the batch corpus path — the same joint
clustering the production pipeline uses, so the baseline pins the whole
front half + solver + replay stack, not just the solver.
"""
import json
from pathlib import Path

import pytest

BASELINE_PATH = Path(__file__).resolve().parent.parent / "artifacts" \
    / "fidelity_baseline.json"

#: reduced-zoo measurement shape (keep in sync with the baseline file)
MEASURE_KWARGS = {"n_ranks": 4, "steps": 2}

#: one-sided regression allowance on per-scenario mean δ̄.  δ̄ is
#: deterministic per platform; the slack absorbs cross-platform libm /
#: BLAS drift, not real regressions (a solver change that costs more than
#: this much fidelity on any scenario must update the baseline on purpose).
TOLERANCE = 0.05

#: seeded replay distribution the statistical tier is pinned at —
#: changing either regenerates different (still deterministic) bands
NOISE_KWARGS = {"seed": 0, "n_replicas": 6}

#: normal-approximation band width in noise standard deviations
BAND_Z = 1.96

#: scenarios checked against an explicit accepted range instead of the
#: shared one-sided tolerance (outliers the harness should name, not hide)
EXPECTED_BAND = {"flash-ring": (2.0, 2.6)}

_MEASURED: dict | None = None


def measure() -> dict:
    """Per-scenario point δ̄, comm losslessness, and seeded noise bands
    for the reduced zoo (one corpus synthesis, shared across tests)."""
    global _MEASURED
    if _MEASURED is not None:
        return _MEASURED
    from repro.core.replay import NoiseConfig
    from repro.core.synthesize import synthesize_corpus

    corp = synthesize_corpus(**MEASURE_KWARGS)
    cfg = NoiseConfig(**NOISE_KWARGS)
    out = {}
    for sname, res in corp.results.items():
        fid = res.fidelity(sample_ranks=None)
        dist = res.fidelity(sample_ranks=None, noise=cfg)
        half = max(BAND_Z * dist.std, TOLERANCE)
        out[sname] = {
            "mean_delta": float(fid.mean),
            "comm_lossless": bool(fid.comm_lossless),
            "noise_mean": float(dist.mean),
            "noise_std": float(dist.std),
            "band": [float(dist.mean - half), float(dist.mean + half)],
        }
    _MEASURED = out
    return out


def _baseline() -> dict:
    assert BASELINE_PATH.exists(), (
        f"missing {BASELINE_PATH}; regenerate with "
        "PYTHONPATH=src python tests/test_fidelity_regression.py "
        "--update-baseline")
    baseline = json.loads(BASELINE_PATH.read_text())
    assert baseline["measure_kwargs"] == MEASURE_KWARGS, (
        "baseline was measured at a different zoo shape; regenerate it")
    return baseline


def test_fidelity_no_regression():
    baseline = _baseline()
    got = measure()

    missing = set(got) - set(baseline["scenarios"])
    assert not missing, (
        f"scenarios {sorted(missing)} have no fidelity baseline; "
        "regenerate with --update-baseline")

    failures = []
    for sname, want in baseline["scenarios"].items():
        if sname not in got:
            failures.append(f"{sname}: scenario disappeared from the zoo")
            continue
        row = got[sname]
        if not row["comm_lossless"]:
            failures.append(f"{sname}: comm stream no longer lossless")
        band = want.get("expected_band")
        if band is not None:
            if not band[0] <= row["mean_delta"] <= band[1]:
                failures.append(
                    f"{sname}: mean δ̄ {row['mean_delta']:.4f} left its "
                    f"expected band [{band[0]}, {band[1]}]")
        elif row["mean_delta"] > want["mean_delta"] + baseline["tolerance"]:
            failures.append(
                f"{sname}: mean δ̄ regressed {want['mean_delta']:.4f} -> "
                f"{row['mean_delta']:.4f} "
                f"(tolerance {baseline['tolerance']})")
    assert not failures, "fidelity regression:\n  " + "\n  ".join(failures)


def test_noisy_mean_within_pinned_band():
    """Statistical tier: the seeded noise replay's mean δ̄ must land inside
    every scenario's pinned confidence band — a calibration, lowering, or
    RNG-stream change that shifts the distribution fails loudly even when
    the deterministic point δ̄ is untouched."""
    baseline = _baseline()
    assert baseline.get("noise_kwargs") == NOISE_KWARGS, (
        "baseline bands were pinned at a different noise distribution; "
        "regenerate with --update-baseline")
    got = measure()

    failures = []
    for sname, want in baseline["scenarios"].items():
        if sname not in got:
            continue       # the point tier already reports disappearance
        row = got[sname]
        lo, hi = want["band"]
        if not lo <= row["noise_mean"] <= hi:
            failures.append(
                f"{sname}: noisy mean δ̄ {row['noise_mean']:.4f} outside "
                f"pinned band [{lo:.4f}, {hi:.4f}]")
        if not row["noise_std"] > 0:
            failures.append(
                f"{sname}: degenerate noise distribution (std=0) — "
                "calibration lost its variance signal")
    assert not failures, ("statistical fidelity regression:\n  "
                          + "\n  ".join(failures))


def test_noise_band_centering():
    """The freshly measured band must contain its own point δ̄ — the noise
    factors are mean-one, so the noisy mean stays near the deterministic
    value and the band (≥ TOLERANCE half-width) must cover it."""
    got = measure()
    for sname, row in got.items():
        lo, hi = row["band"]
        assert lo <= row["mean_delta"] <= hi, (sname, row)


@pytest.mark.parametrize("sname", sorted(EXPECTED_BAND))
def test_outlier_has_explicit_band(sname):
    baseline = _baseline()
    want = baseline["scenarios"].get(sname)
    assert want is not None and "expected_band" in want, (
        f"{sname} is a known δ̄ outlier; its baseline row must carry an "
        "explicit expected_band entry (regenerate with --update-baseline)")
    assert tuple(want["expected_band"]) == EXPECTED_BAND[sname]


def update_baseline() -> None:
    scenarios = measure()
    for sname, band in EXPECTED_BAND.items():
        if sname in scenarios:
            scenarios[sname]["expected_band"] = list(band)
    payload = {
        "comment": "per-scenario mean δ̄ (point + seeded noise bands) of "
                   "the reduced zoo; regenerate with "
                   "tests/test_fidelity_regression.py --update-baseline "
                   "after intentional fidelity changes",
        "measure_kwargs": MEASURE_KWARGS,
        "noise_kwargs": NOISE_KWARGS,
        "band_z": BAND_Z,
        "tolerance": TOLERANCE,
        "scenarios": scenarios,
    }
    BASELINE_PATH.parent.mkdir(parents=True, exist_ok=True)
    BASELINE_PATH.write_text(json.dumps(payload, indent=1, sort_keys=True)
                             + "\n")
    print(f"wrote {BASELINE_PATH}:")
    for sname, row in sorted(payload["scenarios"].items()):
        print(f"  {sname}: mean_delta={row['mean_delta']:.4f} "
              f"noise_mean={row['noise_mean']:.4f} "
              f"band=[{row['band'][0]:.4f}, {row['band'][1]:.4f}] "
              f"comm_lossless={row['comm_lossless']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update-baseline", action="store_true",
                    help="re-measure the zoo and overwrite "
                         "artifacts/fidelity_baseline.json "
                         "(point + band columns)")
    args = ap.parse_args()
    if args.update_baseline:
        update_baseline()
    else:
        ap.error("pass --update-baseline (the check itself runs "
                 "under pytest)")
