"""Batched multi-rank replay engine tests (paper §3.3).

Parity: ``run_all`` (group-deduplicated and group-vmapped) and the
vectorized ``fidelity`` path must agree with the per-rank baseline.
Caching: repeated calls must hit the compile/metrics caches — asserted via
the trace counters, not timing.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import CommEvent, ComputeEvent
from repro.core.replay import ProxyProgram
from repro.core.synthesize import synthesize
from repro.sharding.collectives import LocalSim


def _mk_traces(n_ranks=8):
    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    comp = ComputeEvent((2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.))
    traces = []
    for r in range(n_ranks):
        tr = [comp, comm, comp, perm] * 6
        if r == 0:
            tr = tr + [comm]        # rank-0 extra event → second signature
        traces.append(tr)
    return traces


def _synth(n_ranks=8, **kw):
    return synthesize(rank_traces=_mk_traces(n_ranks), axis_sizes={"x": n_ranks},
                      name=f"batched_{n_ranks}", **kw)


def _fresh_proxy(res):
    """Second ProxyProgram over the same module: empty caches."""
    return ProxyProgram(res.proxy.source, res.proxy.module, res.merged,
                        res.proxy.combos, res.proxy.axis_sizes)


class CountingSim(LocalSim):
    """Subclass => identity-keyed in the compile cache, so every group is
    traced afresh against this instance and ``trace_events`` is exact."""


def _assert_states_close(a: dict, b: dict, rtol=1e-5, atol=1e-6):
    assert a.keys() == b.keys()
    for r in a:
        for k in a[r]:
            x = np.asarray(a[r][k], np.float32)
            y = np.asarray(b[r][k], np.float32)
            np.testing.assert_allclose(x, y, rtol=rtol, atol=atol,
                                       err_msg=f"rank {r} leaf {k}")


def test_signature_groups_metadata():
    res = _synth()
    mod = res.proxy.module
    groups = mod.SIGNATURE_GROUPS
    seen = [r for _, ranks, _ in groups for r in ranks]
    assert sorted(seen) == list(range(8))            # exact cover
    for sig, ranks, hint in groups:
        for r in ranks:
            assert mod.program_signature(r) == sig
        # every group's program touches axis "x" (size 8) → hint 8
        assert hint == 8
    # rank 0 (extra event) is alone; everyone else shares one group
    sizes = sorted(len(rs) for _, rs, _ in groups)
    assert sizes == [1, 7]
    assert res.stats["n_signature_groups"] == 2
    assert res.proxy.group_device_hints() == {sig: 8 for sig, _, _ in groups}


def test_run_all_rejects_out_of_range_ranks():
    res = _synth()
    import pytest
    for kw in ({}, {"batched": False}):
        with pytest.raises(ValueError, match="out of range"):
            res.proxy.run_all(ranks=[99], **kw)
    with pytest.raises(ValueError, match="out of range"):
        res.proxy.time_all(ranks=[-1])


def test_run_all_matches_per_rank():
    res = _synth()
    batched = res.proxy.run_all()
    per_rank = res.proxy.run_all(batched=False)
    _assert_states_close(batched, per_rank)


def test_run_all_vmap_path_matches_per_rank():
    """Distinct per-rank states: the stacked/vmapped executable must agree
    with replaying each seeded rank individually."""
    res = _synth()
    batched = res.proxy.run_all(per_rank_seeds=True)
    per_rank = res.proxy.run_all(batched=False, per_rank_seeds=True)
    _assert_states_close(batched, per_rank, rtol=1e-4, atol=1e-5)


def test_vectorized_fidelity_matches_per_rank():
    res = _synth()
    fb = res.fidelity(sample_ranks=None)
    fp = res.proxy.fidelity(res.rank_traces, sample_ranks=None, batched=False)
    np.testing.assert_allclose(fb.delta, fp.delta, rtol=1e-6, atol=0)
    assert abs(fb.mean - fp.mean) <= 1e-6 * max(abs(fp.mean), 1e-30)
    assert fb.comm_lossless == fp.comm_lossless


def test_compile_cache_hit_on_second_call():
    res = _synth()
    proxy = _fresh_proxy(res)
    proxy.run_all()
    first = proxy.cache_stats()
    assert first["jit_traces"] > 0
    proxy.run_all()
    second = proxy.cache_stats()
    # second sweep must not re-trace anything
    assert second["jit_traces"] == first["jit_traces"]

    # vmapped group executables: explicit hit counters
    proxy.run_all(per_rank_seeds=True)
    miss = proxy.cache_stats()["batch_cache_misses"]
    proxy.run_all(per_rank_seeds=True)
    after = proxy.cache_stats()
    assert after["batch_cache_misses"] == miss
    assert after["batch_cache_hits"] > 0


def test_metrics_cache_one_trace_per_group():
    res = _synth()
    proxy = _fresh_proxy(res)
    keys = [[g.table[i].key() for i in ids]
            for g, ids in zip(res.grammars, res.rank_ids)]
    proxy.fidelity(res.rank_traces, keys, sample_ranks=None)
    stats = proxy.cache_stats()
    assert stats["metric_traces"] == stats["cached_metric_groups"] == 2
    proxy.fidelity(res.rank_traces, keys, sample_ranks=None)
    assert proxy.cache_stats()["metric_traces"] == 2   # no re-trace


def test_event_counts_per_rank_vs_batched():
    """The batched engine traces the same generated comm call sites as the
    per-rank path (trace-time event counts per signature group agree)."""
    res = _synth()
    for _, grp in res.proxy.signature_groups():
        c_single = CountingSim()
        _fresh_proxy(res).run_all(ranks=grp[:1], batched=False, comm=c_single)
        c_group = CountingSim()
        _fresh_proxy(res).run_all(ranks=grp, per_rank_seeds=True, comm=c_group)
        assert c_single.trace_events > 0
        assert c_group.trace_events == c_single.trace_events


def test_run_all_group_results_isolated_across_ranks():
    """Shared-seed groups share result *leaves* (immutable, documented) but
    never result *dicts*: rebinding one rank's buffer — the only mutation
    JAX permits — must leave its group siblings untouched."""
    res = _synth()
    out = res.proxy.run_all()
    grp = next(rs for _, rs in res.proxy.signature_groups() if len(rs) > 1)
    r0, r1 = grp[0], grp[1]
    key = sorted(out[r1])[0]
    before = np.asarray(out[r1][key], np.float32).copy()
    out[r0][key] = jnp.zeros_like(out[r0][key]) - 123.0
    np.testing.assert_array_equal(np.asarray(out[r1][key], np.float32), before)
    # leaf aliasing is safe: numpy views of jax buffers are read-only, so
    # in-place mutation cannot corrupt a sibling behind the dict's back
    view = np.asarray(out[r1][key])
    assert not view.flags.writeable


def test_localsim_accepts_batched_rank_axis():
    """LocalSim.do is vmappable over a leading rank axis (the compat layer
    supplies the optimization_barrier batching rule on old JAX)."""
    comm = LocalSim()
    st = {"buf0": jnp.full((4, 16), 0.5)}

    def one_rank(st):
        return comm.do(st, "buf0", kind="psum", axes=("x",), detail=(),
                       shape=(16,), dtype="float32")

    out = jax.jit(jax.vmap(one_rank))(st)
    assert out["buf0"].shape == (4, 16)
    np.testing.assert_allclose(np.asarray(out["buf0"]), 0.5)
