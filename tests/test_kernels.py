"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU):
shapes × dtypes × masking variants, per the assignment's kernel contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.proxy_blocks.ops import mxu_block, stream_block
from repro.kernels.proxy_blocks.ref import mxu_ref, stream_ref
from repro.kernels.ssd.ops import ssd_diag_block
from repro.kernels.ssd.ref import ssd_diag_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,g,d,win,causal", [
    (1, 256, 4, 2, 64, None, True),
    (2, 256, 2, 2, 128, 128, True),
    (1, 384, 4, 1, 64, None, True),
    (1, 512, 2, 1, 64, None, False),
])
def test_flash_kernel_sweep(b, s, h, g, d, win, causal, dtype, rng):
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, s, g, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, s, g, d)), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=win)
    r = h // g
    qq = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kk = jnp.repeat(k, r, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vv = jnp.repeat(v, r, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    ref = attention_ref(qq, kk, vv, causal=causal, window=win)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("b,c,q,g,r,p,n", [
    (1, 2, 32, 1, 4, 16, 16),
    (2, 2, 16, 2, 8, 8, 32),
    (1, 1, 64, 1, 12, 16, 16),   # r > slab width: exercises head slabbing
])
def test_ssd_kernel_sweep(b, c, q, g, r, p, n, rng):
    h = g * r
    x = jnp.asarray(rng.normal(0, 1, (b, c, q, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, c, q, h)), jnp.float32)
    adt = -jnp.asarray(rng.uniform(0.01, 0.5, (b, c, q, h)), jnp.float32)
    cum = jnp.cumsum(adt, axis=2)
    bm = jnp.asarray(rng.normal(0, 1, (b, c, q, g, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (b, c, q, g, n)), jnp.float32)
    out = ssd_diag_block(x, dt, cum, bm, cm, r)
    ref = ssd_diag_ref(x.reshape(b, c, q, g, r, p), dt.reshape(b, c, q, g, r),
                       cum.reshape(b, c, q, g, r), bm, cm)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.reshape(b, c, q, h, p)),
                               atol=2e-4)


def test_ssd_chunked_vs_sequential_recurrence(rng):
    """Chunked SSD (dual form) == literal state-space recurrence."""
    from repro.models.ssm import ssd_chunked
    b, l, h, p, n, g = 1, 64, 4, 16, 16, 1
    x = jnp.asarray(rng.normal(0, 1, (b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, l, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 1.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (b, l, g, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (b, l, g, n)), jnp.float32)
    y = np.asarray(ssd_chunked(x, dt, a, bm, cm, chunk=16))
    state = np.zeros((b, h, p, n))
    for i in range(l):
        da = np.exp(np.asarray(dt[:, i]) * np.asarray(a))
        state = state * da[..., None, None] + \
            (np.asarray(dt[:, i])[..., None] * np.asarray(x[:, i]))[..., None] \
            * np.asarray(bm[:, i])[:, :, None, :]
        np.testing.assert_allclose(
            y[:, i], np.einsum("bhpn,bhn->bhp", state,
                               np.asarray(cm[:, i])), atol=1e-3)


def test_ssd_prefill_state_matches_decode(rng):
    """Prefill's returned SSM state == state after step-by-step decode."""
    from repro.models.ssm import ssd_chunked
    b, l, h, p, n = 1, 32, 2, 8, 8
    x = jnp.asarray(rng.normal(0, 1, (b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, l, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 1.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (b, l, 1, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (b, l, 1, n)), jnp.float32)
    _, final = ssd_chunked(x, dt, a, bm, cm, chunk=8, return_final=True)
    state = np.zeros((b, h, p, n))
    for i in range(l):
        da = np.exp(np.asarray(dt[:, i]) * np.asarray(a))
        state = state * da[..., None, None] + \
            (np.asarray(dt[:, i])[..., None] * np.asarray(x[:, i]))[..., None] \
            * np.asarray(bm[:, i])[:, :, None, :]
    np.testing.assert_allclose(np.asarray(final), state, atol=1e-4)


@pytest.mark.parametrize("reps", [1, 7, 32])
def test_mxu_block_kernel(reps, rng):
    a = jnp.asarray(rng.uniform(-1, 1, (128, 128)), jnp.bfloat16)
    b = jnp.asarray(rng.uniform(-1, 1, (128, 128)) / 128, jnp.bfloat16)
    out = mxu_block(a, b, reps)
    ref = mxu_ref(a, b, reps)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


@pytest.mark.parametrize("n,reps", [(2048, 3), (4096, 17)])
def test_stream_block_kernel(n, reps, rng):
    v = jnp.asarray(rng.uniform(0, 1, (n,)), jnp.float32)
    out = stream_block(v, reps)
    ref = stream_ref(v, reps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
