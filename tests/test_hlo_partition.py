"""HLO cost parser + partitioning rule tests."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.hlo_cost import (
    analyze, parse_module, shape_bytes, shape_elems, while_trip_count,
)

_SIMPLE_HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    %cond (p: (s32[], f32[8,8])) -> pred[] {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (x: f32[8,8]) -> f32[8,8] {
      %x = f32[8,8] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,8]) tuple(%zero, %x)
      %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
      %ar = f32[8,8] all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
      ROOT %out = f32[8,8] get-tuple-element(%w), index=1
    }
""")


def test_shape_parsing():
    assert shape_bytes("f32[8,8]") == 256
    assert shape_bytes("bf16[4,4]{1,0}") == 32
    assert shape_bytes("(f32[2], s32[3])") == 20
    assert shape_elems("pred[]") == 1


def test_loop_aware_flops_and_collectives():
    cost = analyze(_SIMPLE_HLO)
    assert cost.flops == 12 * 2 * 8 ** 3          # 12 trips x one 8^3 dot
    assert cost.collective_bytes == 256           # one all-reduce operand
    assert cost.collective_by_kind["all-reduce"] == 256


def test_trip_count_detection():
    comps = parse_module(_SIMPLE_HLO)
    comps.pop("__entry__", None)
    assert while_trip_count(comps, "cond") == 12


def test_real_module_scan_vs_unrolled():
    """Parser equality on real XLA output (subprocess: needs >1 device)."""
    prog = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import make_mesh
        from repro.launch.hlo_cost import analyze
        mesh = make_mesh((2,2), ("data","model"))
        def layer(x, w): return jnp.tanh(x @ w)
        def scanned(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (layer(c, w), None), x, ws)
            return y
        def unrolled(x, ws):
            for i in range(6): x = layer(x, ws[i])
            return x
        x = jax.ShapeDtypeStruct((32, 128), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data")))
        ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32,
                                  sharding=NamedSharding(mesh, P(None, None, "model")))
        fs = analyze(jax.jit(scanned).lower(x, ws).compile().as_text()).flops
        fu = analyze(jax.jit(unrolled).lower(x, ws).compile().as_text()).flops
        assert abs(fs - fu) / fu < 1e-6, (fs, fu)
        print("OK", fs)
    """)
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_partition_rules():
    prog = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.sharding.partition import (LogicalRules, sharding_for_shape,
                                              spec_for)
        mesh = make_test_mesh(2, 4)
        rules = LogicalRules()
        # heads divide -> sharded; non-dividing dim dropped
        s = sharding_for_shape((16, 8, 64), ("batch", "heads", None), mesh)
        assert s.spec == jax.sharding.PartitionSpec("data", "model")
        s = sharding_for_shape((16, 6, 64), ("batch", "heads", None), mesh)
        assert s.spec == jax.sharding.PartitionSpec("data"), s.spec
        # override mechanism
        r2 = rules.with_overrides(embed="data")
        assert r2.mesh_axes("embed") == "data"
        print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=240)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_attn_mode_chain():
    prog = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch.mesh import make_test_mesh
        from repro.models.flash import attn_mode
        mesh = make_test_mesh(2, 4)
        assert attn_mode(mesh, 8, 4) == "heads"     # 8 % 4 == 0
        assert attn_mode(mesh, 6, 16) == "batch"    # 16 % 8 == 0
        assert attn_mode(mesh, 6, 4) == "cp"        # nothing divides
        assert attn_mode(None, 3, 1) == "heads"     # off-mesh
        print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=240)
    assert proc.returncode == 0, proc.stderr[-2000:]
