"""Unit tests for the space-optimized Sequitur (paper §2.5.2).

Hypothesis-based property tests live in test_sequitur_prop.py so this
module always runs, dependency or not."""
import numpy as np

from repro.core.sequitur import Sequitur, compress


def expand_equals(seq):
    s = compress(seq)
    assert s.expand() == list(seq)
    return s


def test_empty():
    assert compress([]).expand() == []


def test_single_run_is_o1():
    """aaaa...a must compress to a single run-length symbol (paper: O(1))."""
    s = compress([7] * 1000)
    assert s.expand() == [7] * 1000
    assert s.size() <= 2


def test_periodic_compresses():
    seq = [1, 2, 3] * 200
    s = expand_equals(seq)
    assert s.size() < 20


def test_nested_loops():
    inner = [1, 2] * 5 + [3]
    seq = (inner * 8 + [4]) * 6
    s = expand_equals(seq)
    assert s.size() < len(seq) / 5


def test_push_run_bulk():
    s = Sequitur()
    s.push(1)
    s.push_run(2, 10 ** 9)  # a billion-iteration loop in O(1)
    s.push(3)
    rules = s.grammar_rules()
    total = sum(len(b) for b in rules.values())
    assert total <= 4
    # expanded_length semantics via grammar
    from repro.core.grammar import Grammar, TerminalTable
    t = TerminalTable()
    g = Grammar(rules=rules, table=t)
    assert g.expanded_length() == 10 ** 9 + 2


def test_digram_uniqueness_invariant():
    rng = np.random.RandomState(3)
    seq = list(rng.randint(0, 5, 500))
    s = compress(seq)
    # no adjacent pair (with exponents) may occur twice across rule bodies
    # (checked on the frozen grammar, implementation-neutral)
    seen = {}
    for rid, body in s.grammar_rules().items():
        for a, b in zip(body, body[1:]):
            key = (a[:2], a[2], b[:2], b[2])
            assert key not in seen, f"duplicate digram {key}"
            seen[key] = rid


def test_rule_utility_invariant():
    rng = np.random.RandomState(4)
    seq = list(rng.randint(0, 4, 400))
    s = compress(seq)
    rules = s.grammar_rules()
    uses = {rid: 0 for rid in rules if rid != 0}
    for body in rules.values():
        for kind, ref, exp in body:
            if kind == "r":
                uses[ref] = uses.get(ref, 0) + (1 if exp == 1 else 2)
    for rid, cnt in uses.items():
        assert cnt >= 2, f"rule {rid} used once"
