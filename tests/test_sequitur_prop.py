"""Hypothesis property tests for the space-optimized Sequitur (§2.5.2).

Split from test_sequitur.py so the plain unit tests there always run;
this module (alone) skips when hypothesis is absent."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.sequitur import Sequitur, compress


def expand_equals(seq):
    s = compress(seq)
    assert s.expand() == list(seq)
    return s


@given(st.lists(st.integers(0, 3), max_size=120))
@settings(max_examples=300, deadline=None)
def test_lossless_property(seq):
    """Core invariant: grammar expansion reproduces the input exactly."""
    expand_equals(seq)


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 9)), max_size=40))
@settings(max_examples=200, deadline=None)
def test_lossless_runs_property(runs):
    """push_run with arbitrary (symbol, count) sequences stays lossless."""
    s = Sequitur()
    expect = []
    for sym, cnt in runs:
        s.push_run(sym, cnt)
        expect.extend([sym] * cnt)
    assert s.expand() == expect


@given(st.integers(1, 6), st.integers(1, 30), st.integers(0, 5))
@settings(max_examples=100, deadline=None)
def test_loop_grammar_size_constant(body_len, reps, tail):
    """A repeated loop body compresses to size independent of rep count."""
    rng = np.random.RandomState(body_len * 977 + tail)
    body = list(rng.randint(0, 50, body_len))
    seq = body * reps + list(rng.randint(0, 50, tail))
    s = expand_equals(seq)
    s_many = expand_equals(body * (reps + 64) + list(rng.randint(0, 50, tail)))
    # growing the loop count must not grow the grammar by more than O(1)
    assert s_many.size() <= s.size() + 4
