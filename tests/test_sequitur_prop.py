"""Property tests for the space-optimized Sequitur (§2.5.2).

Split from test_sequitur.py so the plain unit tests there always run.
The losslessness and O(1)-loop-growth properties also always run, over a
seeded deterministic corpus; only the hypothesis-randomized exploration
skips when hypothesis is absent (the perpetual-skip audit: the gating
condition is the optional dependency, not the JAX floor).
"""
import numpy as np
import pytest

from repro.core.sequitur import Sequitur, compress

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic corpus in this module still runs")


def expand_equals(seq):
    s = compress(seq)
    assert s.expand() == list(seq)
    return s


def _check_runs_lossless(runs):
    s = Sequitur()
    expect = []
    for sym, cnt in runs:
        s.push_run(sym, cnt)
        expect.extend([sym] * cnt)
    assert s.expand() == expect


def _check_loop_grammar_size(body_len, reps, tail):
    rng = np.random.RandomState(body_len * 977 + tail)
    body = list(rng.randint(0, 50, body_len))
    seq = body * reps + list(rng.randint(0, 50, tail))
    s = expand_equals(seq)
    s_many = expand_equals(body * (reps + 64) + list(rng.randint(0, 50, tail)))
    # growing the loop count must not grow the grammar by more than O(1)
    assert s_many.size() <= s.size() + 4


def test_lossless_examples():
    rng = np.random.RandomState(1)
    for n in (0, 1, 2, 7, 30, 120):
        for alphabet in (1, 2, 4):
            expand_equals(list(rng.randint(0, alphabet, n)))


def test_lossless_runs_examples():
    rng = np.random.RandomState(2)
    _check_runs_lossless([])
    for n in (1, 5, 40):
        _check_runs_lossless(list(zip(rng.randint(0, 3, n).tolist(),
                                      rng.randint(1, 10, n).tolist())))


def test_loop_grammar_size_examples():
    for body_len, reps, tail in ((1, 1, 0), (3, 10, 2), (6, 30, 5),
                                 (4, 17, 0), (2, 5, 3)):
        _check_loop_grammar_size(body_len, reps, tail)


if HAVE_HYPOTHESIS:

    @given(st.lists(st.integers(0, 3), max_size=120))
    @settings(max_examples=300, deadline=None)
    def test_lossless_property(seq):
        """Core invariant: grammar expansion reproduces the input exactly."""
        expand_equals(seq)

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 9)),
                    max_size=40))
    @settings(max_examples=200, deadline=None)
    def test_lossless_runs_property(runs):
        """push_run with arbitrary (symbol, count) sequences stays lossless."""
        _check_runs_lossless(runs)

    @given(st.integers(1, 6), st.integers(1, 30), st.integers(0, 5))
    @settings(max_examples=100, deadline=None)
    def test_loop_grammar_size_constant(body_len, reps, tail):
        """A repeated loop body compresses to size independent of rep count."""
        _check_loop_grammar_size(body_len, reps, tail)

else:            # keep the gating visible in the test report

    @needs_hypothesis
    def test_lossless_property():
        raise AssertionError("unreachable: skipif guards this test")

    @needs_hypothesis
    def test_lossless_runs_property():
        raise AssertionError("unreachable: skipif guards this test")

    @needs_hypothesis
    def test_loop_grammar_size_constant():
        raise AssertionError("unreachable: skipif guards this test")
