"""Streaming corpus store tests: on-disk layout, incremental cluster
index, content-addressed fit cache, and the load-bearing invariant —
incremental ``synthesize_corpus(store=...)`` is bit-identical (per-scenario
δ̄, grammars, stats) to a from-scratch run on the same scenario set."""
import json

import numpy as np
import pytest

from repro.core import proxy_search
from repro.core.corpus_store import ClusterIndex, CorpusStore, FitCache
from repro.core.events import CommEvent, ComputeEvent, cluster_corpus
from repro.core.synthesize import synthesize_corpus
from repro.core.trace_ir import TraceStore

_V1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
_V2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
_V3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)


def _store(vectors, comm_axis="x", n_ranks=4):
    comm = CommEvent("psum", (8,), "float32", (comm_axis,))
    tr = []
    for v in vectors:
        tr += [ComputeEvent(tuple(v)), comm]
    return TraceStore.from_rank_traces([list(tr) for _ in range(n_ranks)],
                                       {comm_axis: n_ranks})


def _zoo3():
    return {"a": _store([_V1, _V2]), "b": _store([_V1, _V3]),
            "c": _store([_V2, _V3])}


# ---------------------------------------------------------------------------
# store basics: layout, manifest, hashing, round trips
# ---------------------------------------------------------------------------


def test_add_iterate_reload(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "corpus")
    hashes = {n: cs.add_scenario(n, st) for n, st in stores.items()}
    # names come back in canonical manifest order (shard-major,
    # content-hash sorted) — a pure function of the scenario set, not of
    # ingestion order
    assert sorted(cs.names) == ["a", "b", "c"]
    assert len(cs) == 3 and "b" in cs and "zz" not in cs
    for n, st in cs:
        orig = stores[n]
        assert np.array_equal(st.tokens, orig.tokens)
        assert st.content_hash() == hashes[n] == cs.content_hash(n)
    # a second handle reads everything back from disk, same order
    cs2 = CorpusStore(tmp_path / "corpus")
    assert cs2.names == cs.names
    for n in cs2.names:
        assert cs2.load_scenario(n).content_hash() == hashes[n]
        assert cs2.scenario_path(n).exists()


def test_manifest_layout(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    manifest = json.loads((tmp_path / "c" / "manifest.json").read_text())
    assert manifest["version"] == 2
    assert manifest["rel_tol"] == 0.05
    assert manifest["n_shards"] == 16
    # scenario entries live in per-shard manifests keyed by content hash
    (shard_file,) = (tmp_path / "c" / "shards").glob("shard-*.json")
    shard = json.loads(shard_file.read_text())
    assert shard["version"] == 2
    (entry,) = shard["entries"]
    assert entry["name"] == "a"
    assert entry["file"] == "scenarios/a.npz"
    assert set(entry) >= {"content_hash", "n_ranks", "n_events",
                          "n_compute_events"}
    # the shard is the one the entry's content hash selects
    i = int(entry["content_hash"][:8], 16) % 16
    assert shard_file.name == f"shard-{i:02d}.json"


def test_v1_manifest_migrates_on_open(tmp_path):
    """A v1 store (flat scenario list, pre-partial-sums index) reshards
    and rebuilds its index once on open; clustering matches a fresh v2
    store over the same scenarios."""
    stores = _zoo3()
    root = tmp_path / "c"
    cs = CorpusStore(root)
    for n, st in stores.items():
        cs.add_scenario(n, st)
    ids0, reps0 = cs.cluster_assignments()

    # rewrite the store as a v1 layout: flat manifest, no shards/sidecars
    entries = [dict(e) for e in cs._iter_entries()]
    import shutil
    shutil.rmtree(root / "shards")
    (root / "cluster_index.npz").unlink()
    for n in stores:
        (root / "scenarios" / f"{n}.buckets.npz").unlink()
    (root / "manifest.json").write_text(json.dumps(
        {"version": 1, "rel_tol": 0.05, "scenarios": entries,
         "table_fingerprint": None}))

    cs2 = CorpusStore(root)
    manifest = json.loads((root / "manifest.json").read_text())
    assert manifest["version"] == 2
    assert cs2.names == cs.names
    ids1, reps1 = cs2.cluster_assignments()
    for n in cs.names:
        np.testing.assert_array_equal(ids0[n], ids1[n])
    for cid in reps0:
        np.testing.assert_array_equal(reps0[cid], reps1[cid])
    # sidecars healed
    for n in stores:
        assert (root / "scenarios" / f"{n}.buckets.npz").exists()


def test_content_hash_sensitivity():
    a, b = _store([_V1, _V2]), _store([_V1, _V2])
    assert a.content_hash() == b.content_hash()
    assert a.content_hash() != _store([_V2, _V1]).content_hash()
    assert a.content_hash() != _store([_V1, _V2], n_ranks=3).content_hash()


def test_load_columns_partial(tmp_path):
    st = _store([_V1, _V2])
    p = st.save(tmp_path / "t")
    cols = TraceStore.load_columns(p, ["metrics", "cluster_ids"])
    assert np.array_equal(cols["metrics"], st.metrics)
    assert np.array_equal(cols["cluster_ids"], st.cluster_ids)
    with pytest.raises(ValueError, match="unknown store columns"):
        TraceStore.load_columns(p, ["comm"])


def test_store_rejects_duplicates_and_bad_names(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    with pytest.raises(ValueError, match="already in corpus"):
        cs.add_scenario("a", _store([_V2]))
    with pytest.raises(ValueError, match="invalid scenario name"):
        cs.add_scenario("../evil", _store([_V2]))


def test_rel_tol_pinned_by_manifest(tmp_path):
    CorpusStore(tmp_path / "c", rel_tol=0.05)
    CorpusStore(tmp_path / "c", rel_tol=0.05)        # matching reopen OK
    with pytest.raises(ValueError, match="rel_tol"):
        CorpusStore(tmp_path / "c", rel_tol=0.1)


# ---------------------------------------------------------------------------
# incremental cluster index
# ---------------------------------------------------------------------------


def test_index_matches_oneshot_clustering(tmp_path):
    """Per-scenario assignments + reps == cluster_corpus over the
    manifest-order scenario metrics, bit for bit."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    ids, reps = cs.cluster_assignments()

    want_ids, want_reps = cluster_corpus(
        [stores[n].metrics for n in cs.names], cs.rel_tol)
    for i, n in enumerate(cs.names):
        np.testing.assert_array_equal(ids[n], want_ids[i])
    assert set(reps) == set(want_reps)
    for cid in reps:
        np.testing.assert_array_equal(reps[cid], want_reps[cid])


def test_index_novel_events_spawn_new_clusters(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1, _V2]))
    n0 = cs.index.n_clusters
    cs.add_scenario("b", _store([_V1, _V2]))     # nothing novel
    assert cs.index.n_clusters == n0
    cs.add_scenario("c", _store([_V3]))          # genuinely novel
    assert cs.index.n_clusters == n0 + 1


def test_index_persists_across_reopen(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    ids0, reps0 = cs.cluster_assignments()
    cs2 = CorpusStore(tmp_path / "c")
    ids1, reps1 = cs2.cluster_assignments()
    for n in cs.names:
        np.testing.assert_array_equal(ids0[n], ids1[n])
    for cid in reps0:
        np.testing.assert_array_equal(reps0[cid], reps1[cid])
    # and ingest continues from the persisted state
    cs2.add_scenario("d", _store([_V3, _V1]))
    assert np.array_equal(cs2.index.assignments("d"),
                          cs2.cluster_assignments()[0]["d"])


def test_index_rejects_duplicate_ingest():
    idx = ClusterIndex.empty()
    idx.ingest("a", np.asarray([_V1]))
    with pytest.raises(ValueError, match="already"):
        idx.ingest("a", np.asarray([_V1]))


def test_index_empty_scenario():
    idx = ClusterIndex.empty()
    idx.ingest("empty", np.zeros((0, 6)))
    assert idx.assignments("empty").shape == (0,)
    assert idx.n_clusters == 0


def test_match_clusters_vectorized_bit_identical_to_reference(tmp_path):
    """The sorted-view + searchsorted matcher returns exactly the
    ``(cids, matched)`` the per-row dict-lookup loop (the preserved
    parity oracle) does — on zoo metrics, perturbed/fuzzed rows, and the
    all-fallback and empty edge cases."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)

    rng = np.random.default_rng(7)
    streams = [np.concatenate([stores[n].metrics for n in cs.names]),
               rng.uniform(0.0, 1e9, size=(64, 6)),           # all fallback
               np.concatenate([stores["a"].metrics,
                               rng.uniform(0.0, 1e7, size=(32, 6))]),
               stores["b"].metrics * (1.0 + 1e-7),            # near-key
               np.zeros((0, 6))]
    for metrics in streams:
        cids_v, match_v = cs.index.match_clusters(metrics)
        cids_r, match_r = cs.index.match_clusters_reference(metrics)
        np.testing.assert_array_equal(cids_v, cids_r)
        np.testing.assert_array_equal(match_v, match_r)

    for fn in (cs.index.match_clusters, cs.index.match_clusters_reference):
        with pytest.raises(ValueError, match="expected"):
            fn(np.zeros((3, 4)))
    empty = ClusterIndex.empty()
    for fn in (empty.match_clusters, empty.match_clusters_reference):
        with pytest.raises(ValueError, match="empty cluster index"):
            fn(np.asarray([_V1]))


def test_store_mutation_notifications(tmp_path):
    """add/remove notify subscribers with the affected names after the
    mutation commits; unsubscribe stops delivery; the manifest
    fingerprint moves with every mutation and returns to the prior value
    when the same content set is restored."""
    cs = CorpusStore(tmp_path / "c")
    seen: list[tuple] = []
    cs.subscribe(lambda ev, names: seen.append((ev, names)))
    fp0 = cs.manifest_fingerprint()
    cs.add_scenario("a", _store([_V1, _V2]))
    fp1 = cs.manifest_fingerprint()
    assert seen == [("add", ("a",))] and fp1 != fp0
    cs.add_scenarios([("b", _store([_V1, _V3])),
                      ("c", _store([_V2, _V3]))])
    assert seen[-1] == ("add", ("b", "c"))
    cs.remove_scenario("b")
    assert seen[-1] == ("remove", ("b",))
    cs.remove_scenario("c")
    cs.remove_scenario("a")
    assert cs.manifest_fingerprint() == fp0     # pure function of the set
    second: list[tuple] = []
    fn = lambda ev, names: second.append((ev, names))  # noqa: E731
    cs.subscribe(fn)
    cs.unsubscribe(fn)
    cs.unsubscribe(fn)                          # double-unsubscribe is a no-op
    n = len(seen)
    cs.add_scenario("a", _store([_V1]))
    assert len(seen) == n + 1 and second == []  # first still fires, fn gone


def test_remove_scenario_o_remaining(tmp_path):
    """Removal drops the scenario's partial-sum table and refolds the
    survivors — no full rebuild (the index never re-touches metrics) and
    bit-identical to one-shot clustering over the survivors."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    cs.remove_scenario("b")
    assert set(cs.names) == {"a", "c"}
    assert not cs.scenario_path("b").exists()
    assert not cs._sidecar_path("b").exists()
    # index now equals one-shot clustering over the survivors in order
    want_ids, _ = cluster_corpus([stores[n].metrics for n in cs.names],
                                 cs.rel_tol)
    ids, _ = cs.cluster_assignments()
    for i, n in enumerate(cs.names):
        np.testing.assert_array_equal(ids[n], want_ids[i])
    with pytest.raises(KeyError):
        cs.content_hash("b")
    # O(remaining): the surviving tables are the SAME objects — removal
    # renumbered and refolded partials, it did not rebuild from metrics
    assert set(cs.index.tables) == {"a", "c"}


# ---------------------------------------------------------------------------
# the load-bearing invariant: incremental == from-scratch, bit for bit
# ---------------------------------------------------------------------------


def _assert_same_corpus(corp_inc, corp_bat, names):
    for n in names:
        ri, rb = corp_inc.results[n], corp_bat.results[n]
        assert ri.merged.rules == rb.merged.rules
        assert ri.merged.mains == rb.merged.mains
        assert [e.key() for e in ri.merged.table.events] == \
            [e.key() for e in rb.merged.table.events]
        fi = ri.fidelity(sample_ranks=None)
        fb = rb.fidelity(sample_ranks=None)
        assert fi.comm_lossless and fb.comm_lossless
        np.testing.assert_array_equal(fi.delta, fb.delta)


def test_incremental_append_bit_identical(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    cs.add_scenario("b", stores["b"])
    synthesize_corpus(store=cs)                   # warm caches over {a, b}
    cs.add_scenario("c", stores["c"])
    corp_inc = synthesize_corpus(store=cs)
    corp_bat = synthesize_corpus([(n, stores[n]) for n in cs.names])
    _assert_same_corpus(corp_inc, corp_bat, cs.names)
    assert corp_inc.stats["incremental"]
    # unchanged scenarios skip Sequitur: either via the front-half memo
    # (joint cluster ids unchanged) or, when the append's canonical
    # position relabels clusters, via the label-invariant grammar cache
    assert (corp_inc.stats["n_front_reused"]
            + corp_inc.stats["n_grammar_cache_hits"]) >= 2


def test_incremental_single_dispatch_for_misses(tmp_path, monkeypatch):
    """However many terminals are stale, at most ONE fit_batch dispatch."""
    calls = []
    orig = proxy_search.fit_batch

    def counting(targets, *a, **kw):
        calls.append(np.atleast_2d(targets).shape[0])
        return orig(targets, *a, **kw)

    monkeypatch.setattr(proxy_search, "fit_batch", counting)
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    synthesize_corpus(store=cs)
    assert len(calls) == 1
    corp = synthesize_corpus(store=cs)            # fully cached now
    assert len(calls) == 1                        # no new dispatch
    assert corp.stats["n_solver_calls"] == 0
    assert corp.stats["n_refit_terminals"] == 0
    assert corp.stats["n_result_reused"] == 3


def test_incremental_fit_cache_survives_reopen(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    synthesize_corpus(store=cs)
    assert (tmp_path / "c" / "fit_cache.npz").exists()
    cs2 = CorpusStore(tmp_path / "c")             # fresh process analog
    corp = synthesize_corpus(store=cs2)
    assert corp.stats["n_refit_terminals"] == 0
    assert corp.stats["n_solver_calls"] == 0
    corp_bat = synthesize_corpus([(n, stores[n]) for n in cs2.names])
    _assert_same_corpus(corp, corp_bat, cs2.names)


def test_incremental_after_remove_bit_identical(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    synthesize_corpus(store=cs)
    cs.remove_scenario("a")
    corp_inc = synthesize_corpus(store=cs)
    corp_bat = synthesize_corpus([(n, stores[n]) for n in cs.names])
    _assert_same_corpus(corp_inc, corp_bat, cs.names)


def test_store_kwarg_validation(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    with pytest.raises(ValueError, match="rel_tol"):
        synthesize_corpus(store=cs, rel_tol=0.2)
    with pytest.raises(ValueError, match="add_scenario"):
        synthesize_corpus(["a"], store=cs)
    with pytest.raises(ValueError, match="add_scenario"):
        synthesize_corpus(store=cs, n_ranks=4)


def test_duplicate_content_scenarios_assemble_separately(tmp_path):
    """Two scenarios with identical trace content still get their own
    named modules and out_dir entries (the result memo keys on the
    scenario name, not just content)."""
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("left", _store([_V1, _V2]))
    cs.add_scenario("right", _store([_V1, _V2]))
    out = tmp_path / "out"
    corp = synthesize_corpus(store=cs, out_dir=out)
    assert corp.results["left"].proxy.module.__name__ != \
        corp.results["right"].proxy.module.__name__
    assert (out / "left").is_dir() and (out / "right").is_dir()
    corp_bat = synthesize_corpus(
        [(n, cs.load_scenario(n)) for n in cs.names])
    _assert_same_corpus(corp, corp_bat, cs.names)


def test_index_self_heals_when_missing_or_corrupt(tmp_path):
    """The manifest is the source of truth: a deleted, corrupt, or stale
    cluster_index.npz (crash between persist writes) rebuilds from the
    scenario artifacts instead of serving inconsistent assignments."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    ids0, reps0 = cs.cluster_assignments()

    ipath = tmp_path / "c" / "cluster_index.npz"
    ipath.unlink()                                  # crash: index lost
    cs2 = CorpusStore(tmp_path / "c")
    for n in cs.names:
        np.testing.assert_array_equal(cs2.cluster_assignments()[0][n],
                                      ids0[n])
    assert ipath.exists()                           # re-persisted

    ipath.write_bytes(b"not an npz")                # crash: truncated
    cs3 = CorpusStore(tmp_path / "c")
    for cid, rep in cs3.cluster_assignments()[1].items():
        np.testing.assert_array_equal(rep, reps0[cid])

    (tmp_path / "c" / "fit_cache.npz").write_bytes(b"garbage")
    cs4 = CorpusStore(tmp_path / "c")               # corrupt fits: drop
    corp = synthesize_corpus(store=cs4)             # re-solves cleanly
    assert corp.stats["n_refit_terminals"] == corp.stats["n_compute_terminals"]


def test_zoo_ingest_one_at_a_time(tmp_path):
    """registry.ingest_scenarios streams zoo scenarios into the store and
    is an idempotent catch-up on re-run."""
    from repro.configs.registry import ingest_scenarios

    cs = CorpusStore(tmp_path / "c")
    added = ingest_scenarios(cs, ["transformer-dp", "ssm-decode"],
                             n_ranks=4, steps=2)
    assert added == ["transformer-dp", "ssm-decode"]
    assert set(cs.names) == {"transformer-dp", "ssm-decode"}
    assert ingest_scenarios(cs, ["transformer-dp", "ssm-decode"],
                            n_ranks=4, steps=2) == []
    corp = synthesize_corpus(store=cs)
    rep = corp.report(sample_ranks=None)
    assert rep["all_comm_lossless"]
    assert set(rep["scenarios"]) == {"transformer-dp", "ssm-decode"}


# ---------------------------------------------------------------------------
# fit cache unit behaviour
# ---------------------------------------------------------------------------


def test_fit_cache_roundtrip(tmp_path):
    (fr,) = proxy_search.fit_batch(np.asarray([_V1]))
    cache = FitCache()
    cache.put("k1", fr)
    assert "k1" in cache and len(cache) == 1
    p = tmp_path / "fits.npz"
    cache.save(p)
    back = FitCache.load(p)
    fr2 = back.get("k1")
    np.testing.assert_array_equal(fr2.x, fr.x)
    np.testing.assert_array_equal(fr2.predicted, fr.predicted)
    np.testing.assert_array_equal(fr2.target, fr.target)
    np.testing.assert_array_equal(fr2.per_metric_rel_err,
                                  fr.per_metric_rel_err)
    assert fr2.residual == fr.residual and fr2.unroll == fr.unroll


def test_fit_cache_empty_save_removes_file(tmp_path):
    p = tmp_path / "fits.npz"
    cache = FitCache()
    cache.put("k", proxy_search.fit_batch(np.asarray([_V2]))[0])
    cache.save(p)
    assert p.exists()
    FitCache().save(p)
    assert not p.exists()


# ---------------------------------------------------------------------------
# content-addressed grammar cache
# ---------------------------------------------------------------------------


def test_grammar_cache_persists_and_hits_on_reopen(tmp_path):
    """A fresh CorpusStore handle (in-memory memos cold) must resolve
    every previously-seen rank stream from the persisted grammar cache —
    Sequitur runs only for genuinely novel streams."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    cs.add_scenario("b", stores["b"])
    corp = synthesize_corpus(store=cs)
    assert corp.stats["n_grammar_cache_misses"] >= 1
    assert (tmp_path / "c" / "grammar_cache.json").exists()

    cs2 = CorpusStore(tmp_path / "c")           # reopen: memo gone
    assert len(cs2.grammars) == len(cs.grammars) > 0
    cs2.add_scenario("c", stores["c"])
    corp2 = synthesize_corpus(store=cs2)
    # a and b re-ran compress_store (no memo) but every one of their
    # streams hit the cache; only c's novel streams missed
    assert corp2.stats["n_front_reused"] == 0
    assert corp2.stats["n_grammar_cache_hits"] >= 2
    corp_bat = synthesize_corpus([(n, stores[n]) for n in cs2.names])
    _assert_same_corpus(corp2, corp_bat, cs2.names)


def test_grammar_cache_warm_append_all_unchanged_hit(tmp_path):
    """The acceptance shape: warm store + append records grammar-cache
    hits for all unchanged rank streams (and δ̄ parity holds)."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    cs.add_scenario("b", stores["b"])
    synthesize_corpus(store=cs)
    cs2 = CorpusStore(tmp_path / "c")
    cs2.add_scenario("c", stores["c"])
    corp = synthesize_corpus(store=cs2)
    # every distinct stream of a and b is unchanged -> cache hit; the
    # zoo3 stores are single-signature (one distinct stream each)
    assert corp.stats["n_grammar_cache_hits"] >= 2
    # second synthesis on the same handle: front memo takes over, cache
    # counters stay put
    h0 = corp.stats["n_grammar_cache_hits"]
    corp_again = synthesize_corpus(store=cs2)
    assert corp_again.stats["n_grammar_cache_hits"] == 0
    assert corp_again.stats["n_front_reused"] == 3
    assert h0 >= 2


def test_grammar_cache_corrupt_file_self_heals(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    synthesize_corpus(store=cs)
    gpath = tmp_path / "c" / "grammar_cache.json"
    assert gpath.exists()
    gpath.write_text("{not json")
    cs2 = CorpusStore(tmp_path / "c")           # corrupt cache -> empty
    assert len(cs2.grammars) == 0
    corp = synthesize_corpus(store=cs2)          # re-runs Sequitur, works
    corp_bat = synthesize_corpus([("a", stores["a"])])
    _assert_same_corpus(corp, corp_bat, ("a",))


def test_grammar_cache_empty_save_removes_file(tmp_path):
    from repro.core.corpus_store import GrammarCache
    p = tmp_path / "grammar_cache.json"
    cache = GrammarCache()
    cache.put("k", {0: [("t", 0, 1)]})
    cache.save(p)
    assert p.exists() and not cache.dirty
    GrammarCache().save(p)
    assert not p.exists()


# ---------------------------------------------------------------------------
# loud tolerance validation (never silently re-cluster under a mismatch)
# ---------------------------------------------------------------------------


def test_index_load_rejects_tolerance_mismatch(tmp_path):
    from repro.core.corpus_store import ToleranceMismatchError
    idx = ClusterIndex.empty(0.1)
    idx.ingest("a", np.asarray([_V1]))
    p = tmp_path / "idx.npz"
    idx.save(p)
    back = ClusterIndex.load(p, expected_rel_tol=0.1)   # matching OK
    assert back.rel_tol == 0.1
    with pytest.raises(ToleranceMismatchError, match="rel_tol"):
        ClusterIndex.load(p, expected_rel_tol=0.05)


def test_index_rebuild_rejects_tolerance_mismatch():
    from repro.core.corpus_store import ToleranceMismatchError
    with pytest.raises(ToleranceMismatchError, match="rel_tol"):
        ClusterIndex.rebuild(0.1, [("a", np.asarray([_V1]))],
                             expected_rel_tol=0.05)
    idx = ClusterIndex.rebuild(0.05, [("a", np.asarray([_V1]))],
                               expected_rel_tol=0.05)
    assert idx.n_clusters == 1


def test_store_open_rejects_mismatched_index_loudly(tmp_path):
    """A readable index built at a different tolerance means mixed store
    dirs, not bit rot — the store must refuse, not silently re-cluster."""
    from repro.core.corpus_store import ToleranceMismatchError
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    rogue = ClusterIndex.empty(0.1)
    rogue.ingest("a", _store([_V1]).metrics)
    rogue.save(tmp_path / "c" / "cluster_index.npz")
    with pytest.raises(ToleranceMismatchError, match="rel_tol"):
        CorpusStore(tmp_path / "c")


# ---------------------------------------------------------------------------
# concurrent appenders + crash safety
# ---------------------------------------------------------------------------


def _appender_proc(root, items):
    """Child-process appender: open the store and append (name, path)
    scenarios one at a time — racing any sibling appenders on the shard
    manifests."""
    cs = CorpusStore(root)
    for name, path in items:
        cs.add_scenario(name, TraceStore.load(path))


def _save_zoo(stores, tmp_path):
    return {n: (n, str(st.save(tmp_path / f"in_{n}.npz")))
            for n, st in stores.items()}


def test_concurrent_appenders_bit_identical(tmp_path):
    """Two processes appending disjoint scenarios to one store: the final
    state is bit-identical to serial ingestion of the union (in either
    order — store state is a pure function of the scenario set)."""
    import multiprocessing as mp

    stores = _zoo3()
    items = _save_zoo(stores, tmp_path)
    root = tmp_path / "shared"
    CorpusStore(root)                                   # create
    ctx = mp.get_context("fork")
    procs = [ctx.Process(target=_appender_proc,
                         args=(str(root), [items["a"], items["b"]])),
             ctx.Process(target=_appender_proc,
                         args=(str(root), [items["c"]]))]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0

    cs = CorpusStore(root)
    serial = CorpusStore(tmp_path / "serial")
    for n, st in stores.items():
        serial.add_scenario(n, st)
    assert cs.names == serial.names
    for n in stores:
        assert cs.content_hash(n) == serial.content_hash(n)
    ids_c, reps_c = cs.cluster_assignments()
    ids_s, reps_s = serial.cluster_assignments()
    for n in stores:
        np.testing.assert_array_equal(ids_c[n], ids_s[n])
    assert set(reps_c) == set(reps_s)
    for cid in reps_c:
        np.testing.assert_array_equal(reps_c[cid], reps_s[cid])


def _churn_proc(root, items):
    for name, path in items:
        cs = CorpusStore(root)
        cs.add_scenario(name, TraceStore.load(path))


def test_kill_mid_write_leaves_store_loadable(tmp_path):
    """SIGKILL an appender mid-append: every manifest/shard/index write
    is tmp-file + atomic rename, so a fresh handle always opens a
    consistent store (possibly missing the in-flight scenario) and its
    clustering self-heals to match the surviving manifest."""
    import multiprocessing as mp
    import time

    base = {f"s{i}": _store([_V1, _V2] if i % 2 else [_V3, _V1],
                            n_ranks=2 + i % 3)
            for i in range(12)}
    items = list(_save_zoo(base, tmp_path).values())
    root = tmp_path / "victim"
    CorpusStore(root)
    ctx = mp.get_context("fork")
    p = ctx.Process(target=_churn_proc, args=(str(root), items))
    p.start()
    time.sleep(0.4)
    p.kill()                                           # SIGKILL, mid-write
    p.join(timeout=60)

    cs = CorpusStore(root)                             # must not raise
    json.loads((root / "manifest.json").read_text())   # valid JSON
    for sp in (root / "shards").glob("shard-*.json"):
        json.loads(sp.read_text())
    # every listed scenario is fully readable and consistently clustered
    want_ids, _ = cluster_corpus(
        [cs.load_scenario(n).metrics for n in cs.names], cs.rel_tol)
    ids, _ = cs.cluster_assignments()
    for i, n in enumerate(cs.names):
        np.testing.assert_array_equal(ids[n], want_ids[i])


def test_parallel_add_scenarios_matches_serial(tmp_path):
    """add_scenarios with a worker pool lands bit-identical store state
    (names, hashes, clustering) to one-at-a-time serial ingest."""
    stores = _zoo3()
    items = list(_save_zoo(stores, tmp_path).values())

    par = CorpusStore(tmp_path / "par")
    hashes = par.add_scenarios(items, n_workers=2)
    ser = CorpusStore(tmp_path / "ser")
    for n, st in stores.items():
        ser.add_scenario(n, st)

    assert par.names == ser.names
    for n in stores:
        assert hashes[n] == ser.content_hash(n)
    ids_p, reps_p = par.cluster_assignments()
    ids_s, reps_s = ser.cluster_assignments()
    for n in stores:
        np.testing.assert_array_equal(ids_p[n], ids_s[n])
    for cid in reps_s:
        np.testing.assert_array_equal(reps_p[cid], reps_s[cid])
    # the worker pool warmed the grammar cache with the scenario-local
    # front half
    assert len(par.grammars) > 0
    # and synthesis over either store is bit-identical
    corp_p = synthesize_corpus(store=par)
    corp_s = synthesize_corpus(store=ser)
    _assert_same_corpus(corp_p, corp_s, par.names)


def test_add_scenarios_rejects_duplicates(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    with pytest.raises(ValueError, match="already in corpus"):
        cs.add_scenarios([("a", _store([_V2]))])
    with pytest.raises(ValueError, match="duplicate"):
        cs.add_scenarios([("x", _store([_V1])), ("x", _store([_V2]))])
