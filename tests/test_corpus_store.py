"""Streaming corpus store tests: on-disk layout, incremental cluster
index, content-addressed fit cache, and the load-bearing invariant —
incremental ``synthesize_corpus(store=...)`` is bit-identical (per-scenario
δ̄, grammars, stats) to a from-scratch run on the same scenario set."""
import json

import numpy as np
import pytest

from repro.core import proxy_search
from repro.core.corpus_store import ClusterIndex, CorpusStore, FitCache
from repro.core.events import CommEvent, ComputeEvent, cluster_vectors
from repro.core.synthesize import synthesize_corpus
from repro.core.trace_ir import TraceStore

_V1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
_V2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
_V3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)


def _store(vectors, comm_axis="x", n_ranks=4):
    comm = CommEvent("psum", (8,), "float32", (comm_axis,))
    tr = []
    for v in vectors:
        tr += [ComputeEvent(tuple(v)), comm]
    return TraceStore.from_rank_traces([list(tr) for _ in range(n_ranks)],
                                       {comm_axis: n_ranks})


def _zoo3():
    return {"a": _store([_V1, _V2]), "b": _store([_V1, _V3]),
            "c": _store([_V2, _V3])}


# ---------------------------------------------------------------------------
# store basics: layout, manifest, hashing, round trips
# ---------------------------------------------------------------------------


def test_add_iterate_reload(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "corpus")
    hashes = {n: cs.add_scenario(n, st) for n, st in stores.items()}
    assert cs.names == ["a", "b", "c"]
    assert len(cs) == 3 and "b" in cs and "zz" not in cs
    for n, st in cs:
        orig = stores[n]
        assert np.array_equal(st.tokens, orig.tokens)
        assert st.content_hash() == hashes[n] == cs.content_hash(n)
    # a second handle reads everything back from disk
    cs2 = CorpusStore(tmp_path / "corpus")
    assert cs2.names == ["a", "b", "c"]
    for n in cs2.names:
        assert cs2.load_scenario(n).content_hash() == hashes[n]
        assert cs2.scenario_path(n).exists()


def test_manifest_layout(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    manifest = json.loads((tmp_path / "c" / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert manifest["rel_tol"] == 0.05
    (entry,) = manifest["scenarios"]
    assert entry["name"] == "a"
    assert entry["file"] == "scenarios/a.npz"
    assert set(entry) >= {"content_hash", "n_ranks", "n_events",
                          "n_compute_events"}


def test_content_hash_sensitivity():
    a, b = _store([_V1, _V2]), _store([_V1, _V2])
    assert a.content_hash() == b.content_hash()
    assert a.content_hash() != _store([_V2, _V1]).content_hash()
    assert a.content_hash() != _store([_V1, _V2], n_ranks=3).content_hash()


def test_load_columns_partial(tmp_path):
    st = _store([_V1, _V2])
    p = st.save(tmp_path / "t")
    cols = TraceStore.load_columns(p, ["metrics", "cluster_ids"])
    assert np.array_equal(cols["metrics"], st.metrics)
    assert np.array_equal(cols["cluster_ids"], st.cluster_ids)
    with pytest.raises(ValueError, match="unknown store columns"):
        TraceStore.load_columns(p, ["comm"])


def test_store_rejects_duplicates_and_bad_names(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    with pytest.raises(ValueError, match="already in corpus"):
        cs.add_scenario("a", _store([_V2]))
    with pytest.raises(ValueError, match="invalid scenario name"):
        cs.add_scenario("../evil", _store([_V2]))


def test_rel_tol_pinned_by_manifest(tmp_path):
    CorpusStore(tmp_path / "c", rel_tol=0.05)
    CorpusStore(tmp_path / "c", rel_tol=0.05)        # matching reopen OK
    with pytest.raises(ValueError, match="rel_tol"):
        CorpusStore(tmp_path / "c", rel_tol=0.1)


# ---------------------------------------------------------------------------
# incremental cluster index
# ---------------------------------------------------------------------------


def test_index_matches_oneshot_clustering(tmp_path):
    """Per-scenario assignments + reps == cluster_vectors over the
    manifest-order concatenation, bit for bit."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    ids, reps = cs.cluster_assignments()

    all_metrics = np.concatenate([stores[n].metrics for n in cs.names])
    want_ids, want_reps = cluster_vectors(all_metrics, cs.rel_tol)
    off = 0
    for n in cs.names:
        k = stores[n].n_compute_events
        np.testing.assert_array_equal(ids[n], want_ids[off:off + k])
        off += k
    assert set(reps) == set(want_reps)
    for cid in reps:
        np.testing.assert_array_equal(reps[cid], want_reps[cid])


def test_index_novel_events_spawn_new_clusters(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1, _V2]))
    n0 = cs.index.n_clusters
    cs.add_scenario("b", _store([_V1, _V2]))     # nothing novel
    assert cs.index.n_clusters == n0
    cs.add_scenario("c", _store([_V3]))          # genuinely novel
    assert cs.index.n_clusters == n0 + 1


def test_index_persists_across_reopen(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    ids0, reps0 = cs.cluster_assignments()
    cs2 = CorpusStore(tmp_path / "c")
    ids1, reps1 = cs2.cluster_assignments()
    for n in cs.names:
        np.testing.assert_array_equal(ids0[n], ids1[n])
    for cid in reps0:
        np.testing.assert_array_equal(reps0[cid], reps1[cid])
    # and ingest continues from the persisted state
    cs2.add_scenario("d", _store([_V3, _V1]))
    assert np.array_equal(cs2.index.assignments("d"),
                          cs2.cluster_assignments()[0]["d"])


def test_index_rejects_duplicate_ingest():
    idx = ClusterIndex.empty()
    idx.ingest("a", np.asarray([_V1]))
    with pytest.raises(ValueError, match="already"):
        idx.ingest("a", np.asarray([_V1]))


def test_index_empty_scenario():
    idx = ClusterIndex.empty()
    idx.ingest("empty", np.zeros((0, 6)))
    assert idx.assignments("empty").shape == (0,)
    assert idx.n_clusters == 0


def test_remove_scenario_rebuilds(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    cs.remove_scenario("b")
    assert cs.names == ["a", "c"] and not cs.scenario_path("b").exists()
    # index now equals one-shot clustering over the survivors
    all_metrics = np.concatenate([stores[n].metrics for n in ("a", "c")])
    want_ids, _ = cluster_vectors(all_metrics, cs.rel_tol)
    ids, _ = cs.cluster_assignments()
    np.testing.assert_array_equal(
        np.concatenate([ids["a"], ids["c"]]), want_ids)
    with pytest.raises(KeyError):
        cs.content_hash("b")


# ---------------------------------------------------------------------------
# the load-bearing invariant: incremental == from-scratch, bit for bit
# ---------------------------------------------------------------------------


def _assert_same_corpus(corp_inc, corp_bat, names):
    for n in names:
        ri, rb = corp_inc.results[n], corp_bat.results[n]
        assert ri.merged.rules == rb.merged.rules
        assert ri.merged.mains == rb.merged.mains
        assert [e.key() for e in ri.merged.table.events] == \
            [e.key() for e in rb.merged.table.events]
        fi = ri.fidelity(sample_ranks=None)
        fb = rb.fidelity(sample_ranks=None)
        assert fi.comm_lossless and fb.comm_lossless
        np.testing.assert_array_equal(fi.delta, fb.delta)


def test_incremental_append_bit_identical(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    cs.add_scenario("b", stores["b"])
    synthesize_corpus(store=cs)                   # warm caches over {a, b}
    cs.add_scenario("c", stores["c"])
    corp_inc = synthesize_corpus(store=cs)
    corp_bat = synthesize_corpus([(n, stores[n]) for n in ("a", "b", "c")])
    _assert_same_corpus(corp_inc, corp_bat, ("a", "b", "c"))
    assert corp_inc.stats["incremental"]
    assert corp_inc.stats["n_front_reused"] >= 2


def test_incremental_single_dispatch_for_misses(tmp_path, monkeypatch):
    """However many terminals are stale, at most ONE fit_batch dispatch."""
    calls = []
    orig = proxy_search.fit_batch

    def counting(targets, *a, **kw):
        calls.append(np.atleast_2d(targets).shape[0])
        return orig(targets, *a, **kw)

    monkeypatch.setattr(proxy_search, "fit_batch", counting)
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    synthesize_corpus(store=cs)
    assert len(calls) == 1
    corp = synthesize_corpus(store=cs)            # fully cached now
    assert len(calls) == 1                        # no new dispatch
    assert corp.stats["n_solver_calls"] == 0
    assert corp.stats["n_refit_terminals"] == 0
    assert corp.stats["n_result_reused"] == 3


def test_incremental_fit_cache_survives_reopen(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    synthesize_corpus(store=cs)
    assert (tmp_path / "c" / "fit_cache.npz").exists()
    cs2 = CorpusStore(tmp_path / "c")             # fresh process analog
    corp = synthesize_corpus(store=cs2)
    assert corp.stats["n_refit_terminals"] == 0
    assert corp.stats["n_solver_calls"] == 0
    corp_bat = synthesize_corpus([(n, stores[n]) for n in cs2.names])
    _assert_same_corpus(corp, corp_bat, cs2.names)


def test_incremental_after_remove_bit_identical(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    synthesize_corpus(store=cs)
    cs.remove_scenario("a")
    corp_inc = synthesize_corpus(store=cs)
    corp_bat = synthesize_corpus([(n, stores[n]) for n in ("b", "c")])
    _assert_same_corpus(corp_inc, corp_bat, ("b", "c"))


def test_store_kwarg_validation(tmp_path):
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", _store([_V1]))
    with pytest.raises(ValueError, match="rel_tol"):
        synthesize_corpus(store=cs, rel_tol=0.2)
    with pytest.raises(ValueError, match="add_scenario"):
        synthesize_corpus(["a"], store=cs)
    with pytest.raises(ValueError, match="add_scenario"):
        synthesize_corpus(store=cs, n_ranks=4)


def test_duplicate_content_scenarios_assemble_separately(tmp_path):
    """Two scenarios with identical trace content still get their own
    named modules and out_dir entries (the result memo keys on the
    scenario name, not just content)."""
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("left", _store([_V1, _V2]))
    cs.add_scenario("right", _store([_V1, _V2]))
    out = tmp_path / "out"
    corp = synthesize_corpus(store=cs, out_dir=out)
    assert corp.results["left"].proxy.module.__name__ != \
        corp.results["right"].proxy.module.__name__
    assert (out / "left").is_dir() and (out / "right").is_dir()
    corp_bat = synthesize_corpus(
        [("left", cs.load_scenario("left")),
         ("right", cs.load_scenario("right"))])
    _assert_same_corpus(corp, corp_bat, ("left", "right"))


def test_index_self_heals_when_missing_or_corrupt(tmp_path):
    """The manifest is the source of truth: a deleted, corrupt, or stale
    cluster_index.npz (crash between persist writes) rebuilds from the
    scenario artifacts instead of serving inconsistent assignments."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    for n, st in stores.items():
        cs.add_scenario(n, st)
    ids0, reps0 = cs.cluster_assignments()

    ipath = tmp_path / "c" / "cluster_index.npz"
    ipath.unlink()                                  # crash: index lost
    cs2 = CorpusStore(tmp_path / "c")
    for n in cs.names:
        np.testing.assert_array_equal(cs2.cluster_assignments()[0][n],
                                      ids0[n])
    assert ipath.exists()                           # re-persisted

    ipath.write_bytes(b"not an npz")                # crash: truncated
    cs3 = CorpusStore(tmp_path / "c")
    for cid, rep in cs3.cluster_assignments()[1].items():
        np.testing.assert_array_equal(rep, reps0[cid])

    (tmp_path / "c" / "fit_cache.npz").write_bytes(b"garbage")
    cs4 = CorpusStore(tmp_path / "c")               # corrupt fits: drop
    corp = synthesize_corpus(store=cs4)             # re-solves cleanly
    assert corp.stats["n_refit_terminals"] == corp.stats["n_compute_terminals"]


def test_zoo_ingest_one_at_a_time(tmp_path):
    """registry.ingest_scenarios streams zoo scenarios into the store and
    is an idempotent catch-up on re-run."""
    from repro.configs.registry import ingest_scenarios

    cs = CorpusStore(tmp_path / "c")
    added = ingest_scenarios(cs, ["transformer-dp", "ssm-decode"],
                             n_ranks=4, steps=2)
    assert added == ["transformer-dp", "ssm-decode"]
    assert cs.names == ["transformer-dp", "ssm-decode"]
    assert ingest_scenarios(cs, ["transformer-dp", "ssm-decode"],
                            n_ranks=4, steps=2) == []
    corp = synthesize_corpus(store=cs)
    rep = corp.report(sample_ranks=None)
    assert rep["all_comm_lossless"]
    assert set(rep["scenarios"]) == {"transformer-dp", "ssm-decode"}


# ---------------------------------------------------------------------------
# fit cache unit behaviour
# ---------------------------------------------------------------------------


def test_fit_cache_roundtrip(tmp_path):
    (fr,) = proxy_search.fit_batch(np.asarray([_V1]))
    cache = FitCache()
    cache.put("k1", fr)
    assert "k1" in cache and len(cache) == 1
    p = tmp_path / "fits.npz"
    cache.save(p)
    back = FitCache.load(p)
    fr2 = back.get("k1")
    np.testing.assert_array_equal(fr2.x, fr.x)
    np.testing.assert_array_equal(fr2.predicted, fr.predicted)
    np.testing.assert_array_equal(fr2.target, fr.target)
    np.testing.assert_array_equal(fr2.per_metric_rel_err,
                                  fr.per_metric_rel_err)
    assert fr2.residual == fr.residual and fr2.unroll == fr.unroll


def test_fit_cache_empty_save_removes_file(tmp_path):
    p = tmp_path / "fits.npz"
    cache = FitCache()
    cache.put("k", proxy_search.fit_batch(np.asarray([_V2]))[0])
    cache.save(p)
    assert p.exists()
    FitCache().save(p)
    assert not p.exists()


# ---------------------------------------------------------------------------
# content-addressed grammar cache
# ---------------------------------------------------------------------------


def test_grammar_cache_persists_and_hits_on_reopen(tmp_path):
    """A fresh CorpusStore handle (in-memory memos cold) must resolve
    every previously-seen rank stream from the persisted grammar cache —
    Sequitur runs only for genuinely novel streams."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    cs.add_scenario("b", stores["b"])
    corp = synthesize_corpus(store=cs)
    assert corp.stats["n_grammar_cache_misses"] >= 1
    assert (tmp_path / "c" / "grammar_cache.json").exists()

    cs2 = CorpusStore(tmp_path / "c")           # reopen: memo gone
    assert len(cs2.grammars) == len(cs.grammars) > 0
    cs2.add_scenario("c", stores["c"])
    corp2 = synthesize_corpus(store=cs2)
    # a and b re-ran compress_store (no memo) but every one of their
    # streams hit the cache; only c's novel streams missed
    assert corp2.stats["n_front_reused"] == 0
    assert corp2.stats["n_grammar_cache_hits"] >= 2
    corp_bat = synthesize_corpus([(n, stores[n]) for n in ("a", "b", "c")])
    _assert_same_corpus(corp2, corp_bat, ("a", "b", "c"))


def test_grammar_cache_warm_append_all_unchanged_hit(tmp_path):
    """The acceptance shape: warm store + append records grammar-cache
    hits for all unchanged rank streams (and δ̄ parity holds)."""
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    cs.add_scenario("b", stores["b"])
    synthesize_corpus(store=cs)
    cs2 = CorpusStore(tmp_path / "c")
    cs2.add_scenario("c", stores["c"])
    corp = synthesize_corpus(store=cs2)
    # every distinct stream of a and b is unchanged -> cache hit; the
    # zoo3 stores are single-signature (one distinct stream each)
    assert corp.stats["n_grammar_cache_hits"] >= 2
    # second synthesis on the same handle: front memo takes over, cache
    # counters stay put
    h0 = corp.stats["n_grammar_cache_hits"]
    corp_again = synthesize_corpus(store=cs2)
    assert corp_again.stats["n_grammar_cache_hits"] == 0
    assert corp_again.stats["n_front_reused"] == 3
    assert h0 >= 2


def test_grammar_cache_corrupt_file_self_heals(tmp_path):
    stores = _zoo3()
    cs = CorpusStore(tmp_path / "c")
    cs.add_scenario("a", stores["a"])
    synthesize_corpus(store=cs)
    gpath = tmp_path / "c" / "grammar_cache.json"
    assert gpath.exists()
    gpath.write_text("{not json")
    cs2 = CorpusStore(tmp_path / "c")           # corrupt cache -> empty
    assert len(cs2.grammars) == 0
    corp = synthesize_corpus(store=cs2)          # re-runs Sequitur, works
    corp_bat = synthesize_corpus([("a", stores["a"])])
    _assert_same_corpus(corp, corp_bat, ("a",))


def test_grammar_cache_empty_save_removes_file(tmp_path):
    from repro.core.corpus_store import GrammarCache
    p = tmp_path / "grammar_cache.json"
    cache = GrammarCache()
    cache.put("k", {0: [("t", 0, 1)]})
    cache.save(p)
    assert p.exists() and not cache.dirty
    GrammarCache().save(p)
    assert not p.exists()
