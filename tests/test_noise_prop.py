"""Property tests for the calibrated noise models (variability-aware replay).

Same convention as test_interproc_prop.py: a seeded deterministic corpus
always runs; only the hypothesis-randomized exploration skips when
hypothesis is absent (the gating condition is the optional dependency,
not the JAX floor).
"""
import numpy as np
import pytest

import jax

from repro.core import noise
from repro.core.events import CommEvent, ComputeEvent, is_comm
from repro.core.synthesize import synthesize

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic corpus in this module still runs")


# ---------------------------------------------------------------------------
# factor distribution (deterministic, seeded)
# ---------------------------------------------------------------------------


def _samples(sigma, shift, n=4000, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    return np.asarray(jax.vmap(
        lambda k: noise.sample_factor(k, sigma, shift))(keys))


def _check_factor_distribution(sigma, shift):
    s = _samples(sigma, shift)
    assert np.isfinite(s).all()
    # shifted lognormal: strictly above the shift floor, hence positive
    assert (s > shift - 1e-7).all() and (s > 0).all()
    # mean-one by construction (the -sigma^2/2 drift correction)
    assert abs(float(s.mean()) - 1.0) < 5 * s.std() / np.sqrt(len(s)) + 1e-3
    want = noise.factor_variance(sigma, shift)
    got = float(s.var())
    assert got == pytest.approx(want, rel=0.25, abs=1e-6)


def test_factor_distribution_grid():
    for sigma in (0.01, 0.1, 0.5, 1.0):
        for shift in (0.0, 0.5, 0.8):
            _check_factor_distribution(sigma, shift)


def test_variance_scales_with_sigma():
    """Analytic and empirical variance both strictly increase with σ."""
    sigmas = (0.01, 0.05, 0.2, 0.8)
    for shift in (0.0, 0.8):
        analytic = [noise.factor_variance(s, shift) for s in sigmas]
        assert all(a < b for a, b in zip(analytic, analytic[1:]))
        empirical = [float(_samples(s, shift).var()) for s in sigmas]
        assert all(a < b for a, b in zip(empirical, empirical[1:]))
    # shift compresses the multiplicative part: variance shrinks with shift
    assert noise.factor_variance(0.5, 0.8) < noise.factor_variance(0.5, 0.0)


def test_zero_sigma_degenerates_to_unit():
    assert noise.factor_variance(0.0, 0.0) == 0.0
    s = _samples(0.0, 0.7, n=64)
    np.testing.assert_allclose(s, 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# calibration → emission round-trip
# ---------------------------------------------------------------------------


def _jittered_traces(n_ranks=4, reps=6, seed=7):
    """Synthetic rank traces whose compute occurrences jitter ~3% around a
    cluster center — calibration must see a nonzero log-spread."""
    rng = np.random.default_rng(seed)
    base = np.array([2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.])
    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    traces = []
    for _r in range(n_ranks):
        tr = []
        for _ in range(reps):
            f = 1.0 + 0.03 * rng.standard_normal()
            tr += [ComputeEvent(tuple(base * f)), comm,
                   ComputeEvent(tuple(base * (2 * f))), perm]
        traces.append(tr)
    return traces


def test_params_roundtrip_through_emission():
    """calibrate → synthesize → module.NOISE_MODELS is the exact
    per-terminal table the model would emit (repr floats round-trip)."""
    res = synthesize(rank_traces=_jittered_traces(), axis_sizes={"x": 4})
    model = noise.calibrate(res.store, rel_tol=0.05)
    want = model.terminal_params(res.merged.table.events)
    got = res.proxy.module.NOISE_MODELS
    assert tuple(got) == tuple(want)
    # comm terminals carry the shifted-lognormal floor params
    for (sig, shift), ev in zip(got, res.merged.table.events):
        assert sig >= noise.SIGMA_FLOOR
        if is_comm(ev):
            assert shift == noise.COMM_SHIFT
        else:
            assert shift == 0.0
    # the jitter is visible: at least one compute terminal above the floor
    assert any(sig > noise.SIGMA_FLOOR for (sig, shift), ev
               in zip(got, res.merged.table.events) if not is_comm(ev))


def test_unrolled_flavor_emits_same_table():
    res_t = synthesize(rank_traces=_jittered_traces(), axis_sizes={"x": 4},
                       codegen="table")
    res_u = synthesize(rank_traces=_jittered_traces(), axis_sizes={"x": 4},
                       codegen="unrolled")
    assert tuple(res_t.proxy.module.NOISE_MODELS) == \
        tuple(res_u.proxy.module.NOISE_MODELS)


def test_noise_model_json_roundtrip_exact():
    model = noise.NoiseModel(
        compute_sigmas={0: 0.1234567891234567, 3: noise.SIGMA_FLOOR},
        comm_params={"psum": (0.7071067811865476, 0.8)},
        sigma_floor=0.01)
    back = noise.NoiseModel.from_json(model.to_json())
    assert back == model


def test_corpus_store_manifest_roundtrip(tmp_path):
    from repro.core.corpus_store import CorpusStore
    from repro.core.trace_ir import TraceStore
    store = TraceStore.from_rank_traces(_jittered_traces(), {"x": 4})
    cs = CorpusStore(tmp_path / "c", rel_tol=0.05)
    cs.add_scenario("jitter", store)
    got = cs.noise_params("jitter")
    want = noise.calibrate(store, rel_tol=0.05)
    assert got == want


# ---------------------------------------------------------------------------
# hypothesis exploration (optional dependency)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(sigma=st.floats(1e-3, 1.5), shift=st.floats(0.0, 0.95),
           seed=st.integers(0, 2**31 - 1))
    def test_factor_samples_positive_random(sigma, shift, seed):
        s = _samples(sigma, shift, n=128, seed=seed)
        assert np.isfinite(s).all() and (s > 0).all()
        assert (s > shift - 1e-6).all()

    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(st.dictionaries(st.integers(0, 50),
                           st.floats(1e-4, 2.0), max_size=6),
           st.floats(1e-4, 1.0), st.floats(0.0, 0.99))
    def test_noise_model_json_roundtrip_random(sigmas, csig, cshift):
        model = noise.NoiseModel(compute_sigmas=sigmas,
                                 comm_params={"all_gather": (csig, cshift)},
                                 sigma_floor=0.01)
        assert noise.NoiseModel.from_json(model.to_json()) == model
