"""Model-level flash attention (custom VJP) vs the _sdpa oracle:
forward + gradients across GQA/window/cross variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _sdpa, causal_mask
from repro.models.flash import flash_attention


CASES = [
    (2, 512, 512, 8, 4, 64, None, True),
    (2, 512, 512, 8, 2, 32, 128, True),
    (1, 1500, 1500, 4, 4, 32, None, False),   # non-pow2 (whisper frames)
    (2, 256, 1601, 8, 4, 32, None, False),    # cross (vlm patches)
    (2, 1024, 1024, 6, 3, 32, 192, True),     # window + strip path
]


@pytest.mark.parametrize("b,s,t,h,g,d,win,causal", CASES)
def test_flash_forward(b, s, t, h, g, d, win, causal, rng):
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, t, g, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, t, g, d)), jnp.float32)
    mask = causal_mask(s, t, win) if causal else None
    ref = _sdpa(q, k, v, mask, None)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          q_chunk=128, kv_chunk=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("b,s,t,h,g,d,win,causal", CASES[:3])
def test_flash_backward(b, s, t, h, g, d, win, causal, rng):
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, t, g, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, t, g, d)), jnp.float32)
    mask = causal_mask(s, t, win) if causal else None

    def f_ref(q, k, v):
        return (_sdpa(q, k, v, mask, None) ** 2).sum()

    def f_fl(q, k, v):
        return (flash_attention(q, k, v, causal=causal, window=win,
                                q_chunk=128, kv_chunk=256) ** 2).sum()

    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gr, gf):
        scale = max(float(jnp.max(jnp.abs(a))), 1e-9)
        np.testing.assert_allclose(np.asarray(b_) / scale,
                                   np.asarray(a) / scale, atol=2e-4)


def test_flash_bf16():
    rng = np.random.RandomState(5)
    q = jnp.asarray(rng.normal(0, 1, (1, 512, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (1, 512, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (1, 512, 2, 64)), jnp.bfloat16)
    ref = _sdpa(q, k, v, causal_mask(512, 512), None)
    out = flash_attention(q, k, v, q_chunk=128, kv_chunk=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
