"""Inter-process compression unit tests (paper §2.6, Algorithm 1).

Hypothesis-based property tests live in test_interproc_prop.py so this
module always runs, dependency or not."""
from repro.core.events import ComputeEvent
from repro.core.grammar import TerminalTable, from_sequitur
from repro.core.interproc import (
    difference_degree, levenshtein, merge_grammars, merge_main_rules,
)
from repro.core.sequitur import Sequitur


def _grammar(ids):
    table = TerminalTable()
    s = Sequitur()
    for i in ids:
        ev = ComputeEvent((float(i + 1), 0, 0, 0, 0, 0), cluster_id=i)
        s.push(table.intern(ev))
    return from_sequitur(s, table)


def test_levenshtein():
    assert levenshtein("kitten", "sitting") == 3
    assert levenshtein([], [1, 2]) == 2
    assert difference_degree("abc", "abc") == 0.0


def test_identical_ranks_merge_to_one_cluster():
    g = [_grammar([1, 2, 3] * 10) for _ in range(16)]
    merged = merge_grammars(g)
    assert len(merged.mains) == 1
    assert merged.cluster_ranks[0] == frozenset(range(16))
    for r in range(16):
        assert merged.expand_rank(r) == g[r].expand_ids()


def test_nonterminal_dedup_across_ranks():
    g = [_grammar([1, 2, 1, 2, 3, 1, 2, 1, 2, 3] * 5) for _ in range(8)]
    merged = merge_grammars(g)
    solo = merge_grammars(g[:1])
    # 8 SPMD ranks must not grow the merged rule set vs 1 rank
    assert len(merged.rules) == len(solo.rules)


def test_two_stage_pipeline_clusters():
    """Pipeline-parallel style: two different programs → two clusters."""
    a = [_grammar([1, 2] * 20) for _ in range(4)]      # stage 0
    b = [_grammar([7, 8, 9] * 20) for _ in range(4)]   # stage 1
    merged = merge_grammars(a + b, threshold=0.3)
    assert len(merged.mains) == 2
    for r in range(8):
        expect = (a + b)[r].expand_ids()
        got = merged.expand_rank(r)
        # ids are remapped to the global table; compare via event keys
        src = (a + b)[r]
        assert [merged.table[i].key() for i in got] == \
            [src.table[i].key() for i in expect]


def test_similar_mains_lcs_merge_with_ranksets():
    """Near-identical mains (boundary ranks drop one event) LCS-merge."""
    base = [1, 2, 3, 4, 5, 6]
    interior = [_grammar(base) for _ in range(6)]
    boundary = [_grammar([1, 2, 3, 5, 6]) for _ in range(2)]  # missing '4'
    merged = merge_grammars(interior + boundary, threshold=0.5)
    assert len(merged.mains) == 1
    # losslessness per rank despite the shared main rule
    for r in range(8):
        src = (interior + boundary)[r]
        got = merged.expand_rank(r)
        assert [merged.table[i].key() for i in got] == \
            [src.table[i].key() for i in src.expand_ids()]
    # at least one symbol must carry a partial rank set (the branch)
    partial = [s for s in merged.mains[0] if len(s[3]) not in (0, 8)]
    assert partial


def test_high_difference_no_merge():
    """Paper: MG's Δ>0.95 ⇒ no merging effect — disjoint mains stay apart."""
    mains = [tuple(("t", i, 1) for i in range(10)),
             tuple(("t", i + 100, 1) for i in range(10))]
    merged, ranks = merge_main_rules(mains, threshold=0.3)
    assert len(merged) == 2
