"""Jaxpr tracer tests: event extraction, scan handling, per-rank expansion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.events import is_comm
from repro.core.tracer import (
    Trace, TraceSession, compute_cost, per_rank_traces, record_compute,
    record_event, trace_fn,
)
from repro.core.events import CommEvent, ComputeEvent


def test_compute_only():
    tr = trace_fn(lambda x: jnp.tanh(x @ x).sum(), jnp.ones((64, 64)))
    assert len(tr.comm_events()) == 0
    total = tr.total_compute()
    assert total[0] == 2 * 64 ** 3              # mxu flops
    assert total[3] == 64 * 64                  # tanh transcendentals


def test_scan_without_collectives_is_o1_events():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=50)
        return y
    tr = trace_fn(f, jnp.ones((16, 16)))
    comps = tr.compute_events()
    assert len(comps) == 1                      # one aggregated event
    v = comps[0].vector
    assert v[0] == 50 * 2 * 16 ** 3             # cost multiplied by length
    assert v[5] >= 50                           # scan steps recorded


def test_dynamic_while_counts_one_iteration():
    def f(x):
        return jax.lax.while_loop(lambda c: c[0, 0] < 100.0,
                                  lambda c: jnp.tanh(c @ c), x)
    tr = trace_fn(f, jnp.ones((8, 8)))
    v = tr.total_compute()
    assert v[0] == 2 * 8 ** 3


def test_gather_metric():
    tab = jnp.ones((1024,))
    idx = jnp.zeros((128,), jnp.int32)
    tr = trace_fn(lambda t, i: t[i].sum(), tab, idx)
    assert tr.total_compute()[4] == 128


def _shard_map_prog():
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((jax.device_count(),), ("x",))
    n = jax.device_count()
    from jax.sharding import PartitionSpec as P

    def f(u):
        left = jax.lax.ppermute(u, "x", [(i, (i + 1) % n) for i in range(n)])
        u = jnp.tanh(u + left)
        return jax.lax.psum(u.sum(), "x")

    return shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P()), n


def test_shard_map_collectives_and_axis_sizes():
    f, n = _shard_map_prog()
    tr = trace_fn(f, jnp.ones((8 * jax.device_count(),)))
    kinds = [e.kind for e in tr.comm_events()]
    assert kinds == ["ppermute", "psum"]
    assert tr.axis_sizes == {"x": n}


def test_per_rank_traces_shift_dedup():
    f, n = _shard_map_prog()
    tr = trace_fn(f, jnp.ones((8 * jax.device_count(),)))
    ranks = per_rank_traces(tr)
    assert len(ranks) == n
    keys = {tuple(e.key() for e in r) for r in ranks}
    assert len(keys) == 1                       # SPMD: identical after encoding


def test_scan_with_collectives_unrolls_events():
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((jax.device_count(),), ("x",))
    from jax.sharding import PartitionSpec as P

    def f(u):
        def body(c, _):
            return jnp.tanh(c) + jax.lax.psum(c.sum(), "x"), None
        u, _ = jax.lax.scan(body, u, None, length=7)
        return u

    g = shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    tr = trace_fn(g, jnp.ones((8 * jax.device_count(),)))
    assert len(tr.comm_events()) == 7


def test_trace_session_interposition():
    with TraceSession(n_ranks=4) as sess:
        record_event(CommEvent("psum", (4,), "float32", ("x",)))
        record_compute(lambda x: x @ x, jnp.ones((8, 8)))
        record_event(CommEvent("ppermute", (2,), "float32", ("x",),
                               ("shift", 1)), ranks=[0, 1])
    assert len(sess.rank_streams[0]) == 3
    assert len(sess.rank_streams[2]) == 2


def test_instrumented_wrappers_record():
    from repro.compat import make_mesh, shard_map
    from repro.sharding import collectives as C
    mesh = make_mesh((jax.device_count(),), ("x",))
    from jax.sharding import PartitionSpec as P

    def f(u):
        return C.psum(u.sum(), "x")

    g = shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P())
    with TraceSession(n_ranks=jax.device_count()) as sess:
        jax.jit(g)(jnp.ones((8 * jax.device_count(),)))
    assert any(is_comm(e) and e.kind == "psum" for e in sess.rank_streams[0])
