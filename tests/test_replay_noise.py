"""Variability-aware replay: seeded noise determinism + provenance CSV.

Covers the three load-bearing invariants of the noise tier:

* **oracle parity off**: ``noise=None`` replay is byte-for-byte today's
  deterministic δ̄ path — the perturb wrappers are trace-time no-ops
  unless the state carries the noise key (both codegen flavors);
* **seeded determinism on**: a fixed ``(seed, n_replicas)``
  :class:`FidelityDistribution` is reproducible bit-for-bit, identical
  between the table and unrolled flavors, and identical between LocalSim
  and a forced-8-device mesh (replica keys are placement-invariant);
* **provenance**: both fidelity CSVs carry seed/replica headers that
  round-trip through :func:`repro.core.noise.parse_fidelity_csv`.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import noise
from repro.core.events import CommEvent, ComputeEvent
from repro.core.replay import FidelityDistribution, NoiseConfig
from repro.core.synthesize import synthesize


def _run(prog: str, timeout: int = 420):
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


_TRACE_SRC = """\
def _traces(n_ranks=4, reps=6, seed=7):
    import numpy as np
    from repro.core.events import CommEvent, ComputeEvent
    rng = np.random.default_rng(seed)
    base = np.array([2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.])
    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    out = []
    for r in range(n_ranks):
        tr = []
        for _ in range(reps):
            f = 1.0 + 0.03 * rng.standard_normal()
            tr += [ComputeEvent(tuple(base * f)), comm,
                   ComputeEvent(tuple(base * (2 * f))), perm]
        if r == 0:
            tr = tr + [comm]            # second signature group
        out.append(tr)
    return out
"""
exec(_TRACE_SRC)  # defines _traces for this module AND the subprocess progs


def _synth(codegen="table", n_ranks=4):
    return synthesize(rank_traces=_traces(n_ranks), # noqa: F821
                      axis_sizes={"x": n_ranks},
                      name=f"noise_{codegen}_{n_ranks}", codegen=codegen)


CFG = NoiseConfig(seed=3, n_replicas=4)


# ---------------------------------------------------------------------------
# oracle parity when disabled
# ---------------------------------------------------------------------------


def test_noise_none_is_todays_delta_both_flavors():
    """noise=None must be the plain deterministic FidelityReport — same
    type, same δ, bit-identical across codegen flavors (the emitted
    NOISE_MODELS table is inert without opt-in)."""
    for flavor in ("table", "unrolled"):
        res = _synth(flavor)
        assert res.proxy.module.NOISE_MODELS      # table emitted...
        plain = res.fidelity(sample_ranks=None)
        off = res.fidelity(sample_ranks=None, noise=None)
        assert type(off) is type(plain)
        assert not isinstance(off, FidelityDistribution)
        np.testing.assert_array_equal(off.delta, plain.delta)
        # provenance defaults on the deterministic report
        assert (off.seed, off.n_replicas) == (0, 1)
    t = _synth("table").fidelity(sample_ranks=None)
    u = _synth("unrolled").fidelity(sample_ranks=None)
    np.testing.assert_array_equal(t.delta, u.delta)


# ---------------------------------------------------------------------------
# seeded determinism when enabled
# ---------------------------------------------------------------------------


def test_distribution_reproducible_and_seed_sensitive():
    res = _synth()
    a = res.fidelity(sample_ranks=None, noise=CFG)
    b = res.fidelity(sample_ranks=None, noise=CFG)
    assert isinstance(a, FidelityDistribution)
    assert (a.seed, a.n_replicas) == (CFG.seed, CFG.n_replicas)
    np.testing.assert_array_equal(a.replica_delta, b.replica_delta)
    np.testing.assert_array_equal(a.comm_bytes, b.comm_bytes)
    c = res.fidelity(sample_ranks=None,
                     noise=NoiseConfig(seed=CFG.seed + 1,
                                       n_replicas=CFG.n_replicas))
    assert not np.array_equal(a.replica_delta, c.replica_delta)
    # replicas genuinely differ (nonzero σ was calibrated from the jitter)
    assert np.ptp(a.replica_means) > 0


def test_noisy_flavor_parity():
    """Table and unrolled modules bind the same NOISE_MODELS to the same
    per-occurrence key stream → bit-identical distributions."""
    a = _synth("table").fidelity(sample_ranks=None, noise=CFG)
    b = _synth("unrolled").fidelity(sample_ranks=None, noise=CFG)
    np.testing.assert_array_equal(a.replica_delta, b.replica_delta)
    np.testing.assert_array_equal(a.comm_bytes, b.comm_bytes)


def test_distribution_stats_shapes():
    res = _synth()
    d = res.fidelity(sample_ranks=None, noise=CFG)
    n_rep, n_metrics, n_ranks = d.replica_delta.shape
    assert (n_rep, n_ranks) == (CFG.n_replicas, 4)
    assert d.delta_mean.shape == d.delta_std.shape == (n_metrics, n_ranks)
    assert d.replica_means.shape == (n_rep,)
    lo, hi = d.ci()
    assert lo <= d.mean <= hi
    assert d.metric_bands().shape == (n_metrics, 2)
    assert d.comm_bytes.shape == (n_rep, n_ranks)
    assert (d.comm_bytes > 0).all()
    assert d.comm_lossless


def test_run_all_noise_axis_and_guards():
    res = _synth()
    states = res.proxy.run_all(noise=CFG)
    for st in states.values():
        acc = st[noise.NOISE_COMPUTE]
        assert acc.shape[0] == CFG.n_replicas
        # replica perturbations differ along the leading axis
        assert np.ptp(np.asarray(acc).sum(axis=tuple(
            range(1, acc.ndim))), axis=0) > 0
    assert res.proxy.time_all(noise=CFG) > 0
    with pytest.raises(ValueError, match="per_rank_seeds"):
        res.proxy.run_all(noise=CFG, per_rank_seeds=True)
    with pytest.raises(ValueError, match="batched"):
        res.proxy.run_all(noise=CFG, batched=False)


def test_noise_config_validates():
    with pytest.raises(ValueError):
        NoiseConfig(n_replicas=0)


# ---------------------------------------------------------------------------
# provenance CSV round-trip
# ---------------------------------------------------------------------------


def test_fidelity_csv_provenance_roundtrip():
    res = _synth()
    rep = res.fidelity(sample_ranks=None)
    meta, delta = noise.parse_fidelity_csv(rep.to_csv())
    assert meta["seed"] == 0 and meta["n_replicas"] == 1
    assert meta["ranks"] == (0, 1, 2, 3)
    np.testing.assert_allclose(delta, rep.delta, atol=5e-5)

    dist = res.fidelity(sample_ranks=None, noise=CFG)
    meta, delta = noise.parse_fidelity_csv(dist.to_csv())
    assert meta["seed"] == CFG.seed
    assert meta["n_replicas"] == CFG.n_replicas
    assert meta["ranks"] == dist.ranks
    np.testing.assert_allclose(delta, dist.delta_mean, atol=5e-5)


# ---------------------------------------------------------------------------
# forced 8-device mesh (subprocess): LocalSim ≡ mesh bit-identity
# ---------------------------------------------------------------------------


def test_mesh_distribution_bit_identical_to_local():
    out = _run(textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax
        from repro.core.replay import NoiseConfig, submesh_axis_sizes
        from repro.core.synthesize import synthesize
        from repro.launch.mesh import make_replay_mesh
    """) + _TRACE_SRC + textwrap.dedent("""\
        res = synthesize(rank_traces=_traces(8), axis_sizes={"x": 8},
                         name="noise_mesh")
        cfg = NoiseConfig(seed=3, n_replicas=4)
        local = res.fidelity(sample_ranks=None, noise=cfg)
        mesh = make_replay_mesh(
            submesh_axis_sizes(jax.device_count(), {"x": 8}))
        on_mesh = res.fidelity(sample_ranks=None, noise=cfg, mesh=mesh)
        assert np.array_equal(local.replica_delta, on_mesh.replica_delta)
        assert np.array_equal(local.comm_bytes, on_mesh.comm_bytes)
        assert on_mesh.mesh_checked and not local.mesh_checked
        # reproducible on re-run over the mesh as well
        again = res.fidelity(sample_ranks=None, noise=cfg, mesh=mesh)
        assert np.array_equal(on_mesh.replica_delta, again.replica_delta)
        print("OK", float(local.mean))
    """))
    assert "OK" in out
