"""Serve-tier tests: nearest-scenario queries answer from the warm
cache — one synthesis at construction, then a pure-NumPy hot path (no
Sequitur, no fit dispatch, no codegen), pinned by stats counters and by
poisoning the cold-path entry points after warm-up."""
import numpy as np
import pytest

from repro.core import proxy_search, sequitur
from repro.core.corpus_store import CorpusStore
from repro.core.events import CommEvent, ComputeEvent
from repro.core.portability import CHIPS
from repro.core.replay import load_saved_module
from repro.core.trace_ir import TraceStore
from repro.serve.proxy_service import ProxyService

_V1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
_V2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
_V3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)


def _store(vectors, kind="psum", n_ranks=4):
    comm = CommEvent(kind, (8,), "float32", ("x",))
    tr = []
    for v in vectors:
        tr += [ComputeEvent(tuple(v)), comm]
    return TraceStore.from_rank_traces([list(tr) for _ in range(n_ranks)],
                                       {"x": n_ranks})


@pytest.fixture(scope="module")
def svc(tmp_path_factory):
    root = tmp_path_factory.mktemp("serve")
    cs = CorpusStore(root / "corpus")
    cs.add_scenario("heavy", _store([_V3, _V3, _V1]))
    cs.add_scenario("light", _store([_V2, _V2], kind="all_gather"))
    cs.add_scenario("mixed", _store([_V1, _V2, _V3]))
    return ProxyService(cs, out_dir=root / "modules")


def test_query_nearest_is_self(svc):
    """A corpus scenario's own trace is its own nearest neighbor, every
    row exact-key matched."""
    for name, vecs, kind in (("heavy", [_V3, _V3, _V1], "psum"),
                             ("light", [_V2, _V2], "all_gather")):
        ans = svc.query(_store(vecs, kind=kind))
        assert ans.name == name
        assert ans.distance == pytest.approx(0.0, abs=1e-12)
        assert ans.matched_frac == 1.0


def test_query_novel_trace_falls_back(svc):
    """Unseen metric rows map through the nearest-rep fallback and still
    produce a ranked answer."""
    novel = tuple(v * 1.7 + 13.0 for v in _V3)
    ans = svc.query(_store([novel, novel, _V3]))
    assert ans.name in svc.corpus.results
    assert 0.0 < ans.matched_frac < 1.0
    assert set(ans.distances) == {"heavy", "light", "mixed"}


def test_query_returns_loadable_module_and_profile(svc, tmp_path):
    ans = svc.query(_store([_V3, _V3, _V1]), chip="v5p")
    # the module is pre-assembled and on disk — reloadable elsewhere
    mod = load_saved_module(ans.module_path, name="reloaded_proxy")
    assert mod.TERMINALS == ans.module.TERMINALS
    assert ans.profile.chip == "v5p"
    assert ans.profile.step_time > 0.0
    assert np.all(ans.profile.t_total >= 0.0)


def test_hot_path_answers_from_cache(svc, monkeypatch):
    """After warm-up, queries must not re-enter synthesis: poison the
    Sequitur kernel, the fit solvers, and corpus synthesis itself — the
    hot path never touches them, and the counters agree."""
    def _boom(*a, **kw):
        raise AssertionError("cold path entered on a warm query")

    import repro.core.synthesize as synth_mod
    monkeypatch.setattr(sequitur, "compress", _boom)
    monkeypatch.setattr(sequitur.Sequitur, "push", _boom, raising=True)
    monkeypatch.setattr(proxy_search, "fit_batch", _boom)
    monkeypatch.setattr(proxy_search, "fit_combination", _boom)
    monkeypatch.setattr(synth_mod, "synthesize_corpus", _boom)

    q0 = svc.stats["n_queries"]
    for _ in range(5):
        ans = svc.query(_store([_V1, _V2, _V3]))
        assert ans.name == "mixed"
    assert svc.stats["n_warm_synthesis"] == 1          # construction only
    assert svc.stats["n_queries"] == q0 + 5
    assert svc.stats["n_module_cache_hits"] == svc.stats["n_queries"]


def test_profile_cache_memoizes_per_chip(svc):
    h0 = svc.stats["n_profile_cache_hits"]
    m0 = svc.stats["n_profile_cache_misses"]
    p1 = svc.predict_profile("heavy", "v4")            # first: miss
    p2 = svc.predict_profile("heavy", "v4")            # repeat: hit
    assert p1 is p2
    assert svc.stats["n_profile_cache_misses"] == m0 + 1
    assert svc.stats["n_profile_cache_hits"] == h0 + 1
    # chip default + all chips resolvable
    for chip in CHIPS:
        assert svc.predict_profile("light", chip).chip == chip


def test_service_rejects_empty_store_and_bad_chip(tmp_path):
    cs = CorpusStore(tmp_path / "empty")
    with pytest.raises(ValueError, match="empty corpus"):
        ProxyService(cs)
    cs.add_scenario("a", _store([_V1]))
    with pytest.raises(ValueError, match="unknown chip"):
        ProxyService(cs, chip="v999")


def test_query_empty_trace_raises(svc):
    """Regression: a zero-event trace used to embed to the all-zero
    vector and 'match' an arbitrary scenario — now it fails loudly, and
    one bad trace in a batch fails the batch before any stats move."""
    empty = TraceStore.from_rank_traces([[] for _ in range(4)], {"x": 4})
    q0 = svc.stats["n_queries"]
    with pytest.raises(ValueError, match="empty trace"):
        svc.query(empty)
    with pytest.raises(ValueError, match=r"batch index 1"):
        svc.query_batch([_store([_V1]), empty])
    assert svc.stats["n_queries"] == q0


# ---------------------------------------------------------------------------
# batched queries
# ---------------------------------------------------------------------------


def test_query_batch_matches_sequential(svc):
    """One vectorized pass answers exactly what N single queries do —
    names, distances (bitwise), per-scenario distance maps, matched
    fractions."""
    novel = tuple(v * 1.7 + 13.0 for v in _V3)
    traces = [_store([_V3, _V3, _V1]), _store([_V2, _V2], kind="all_gather"),
              _store([novel, novel, _V3]), _store([_V1, _V2, _V3])]
    singles = [svc.query(t) for t in traces]
    batched = svc.query_batch(traces)
    assert len(batched) == len(singles)
    for s, b in zip(singles, batched):
        assert b.name == s.name
        assert b.distance == s.distance            # same bits
        assert b.distances == s.distances
        assert b.matched_frac == s.matched_frac
        assert b.module is s.module
        assert b.profile is s.profile              # memoized per (name, chip)
    assert svc.stats["n_query_batches"] >= 1


def test_grammar_term_separates_schedules(tmp_path):
    """Schedule-divergent but comm/compute-identical workloads land on
    different scenarios: the interleaved and grouped streams have the
    same metric multiset and the same comm histogram, so only the
    grammar-rule-histogram term tells them apart — read from the cached
    frozen grammars, never by running Sequitur at query time."""
    inter, grouped = _store([_V1, _V2] * 6), _store([_V1] * 6 + [_V2] * 6)
    cs = CorpusStore(tmp_path / "corpus")
    cs.add_scenario("interleaved", inter)
    cs.add_scenario("grouped", grouped)
    svc = ProxyService(cs, out_dir=tmp_path / "modules")
    h0 = svc.stats["n_grammar_hist_hits"]
    a, b = svc.query_batch([_store([_V1, _V2] * 6),
                            _store([_V1] * 6 + [_V2] * 6)])
    assert a.name == "interleaved" and a.distance == pytest.approx(0.0)
    assert b.name == "grouped" and b.distance == pytest.approx(0.0)
    assert a.distances["grouped"] > 1e-3           # genuinely separated
    assert svc.stats["n_grammar_hist_hits"] > h0   # grammars came from cache
    # an uncached stream contributes a zero grammar term and a miss
    m0 = svc.stats["n_grammar_hist_misses"]
    svc.query(_store([_V2, _V1] * 3))
    assert svc.stats["n_grammar_hist_misses"] > m0


# ---------------------------------------------------------------------------
# mutation coherence: refresh, staleness, selective re-embedding
# ---------------------------------------------------------------------------


def _mutable_svc(tmp_path):
    cs = CorpusStore(tmp_path / "corpus")
    cs.add_scenario("heavy", _store([_V3, _V3, _V1]))
    cs.add_scenario("light", _store([_V2, _V2], kind="all_gather"))
    cs.add_scenario("mixed", _store([_V1, _V2, _V3]))
    return cs, ProxyService(cs, out_dir=tmp_path / "modules")


def test_refresh_matches_rebuilt_service(tmp_path):
    """Mutate the store under a subscribed service, query (which
    triggers the refresh), and pin the refreshed warm state bit-identical
    to a service constructed from scratch on the mutated store — without
    a second warm synthesis (``n_warm_synthesis`` stays 1)."""
    cs, svc = _mutable_svc(tmp_path)
    svc.query(_store([_V1, _V2, _V3]))             # warm the hot path
    cs.add_scenario("extra", _store([_V3, _V1], kind="all_to_all"))
    cs.remove_scenario("light")
    ans = svc.query(_store([_V3, _V1], kind="all_to_all"))
    assert ans.name == "extra"
    assert svc.stats["n_refresh"] == 1
    assert svc.stats["n_warm_synthesis"] == 1      # refresh is not a re-warm

    rebuilt = ProxyService(cs, out_dir=tmp_path / "modules")
    assert svc._names == rebuilt._names
    for n in rebuilt._names:
        assert np.array_equal(svc.embedding(n), rebuilt.embedding(n))
    a, b = svc.query(_store([_V1, _V2, _V3])), \
        rebuilt.query(_store([_V1, _V2, _V3]))
    assert (a.name, a.distance, a.distances) == (b.name, b.distance,
                                                 b.distances)
    # same store handle -> shared result memo -> identical module objects
    assert a.module is b.module
    svc.close(), rebuilt.close()


def test_refresh_reembeds_only_changed_scenarios(tmp_path):
    """Appending a duplicate-content scenario doubles every bucket sum
    and count exactly (IEEE: (2s)/(2c) == s/c bitwise), so cluster reps,
    coefficient rows, and the survivors' embed keys are unchanged —
    refresh re-embeds exactly the one new scenario and keeps every
    profile memo."""
    cs, svc = _mutable_svc(tmp_path)
    p_heavy = svc.predict_profile("heavy")
    p_light = svc.predict_profile("light", "v4")
    cs.add_scenario("mixed2", _store([_V1, _V2, _V3]))   # content == mixed
    svc.refresh()
    assert svc.stats["n_reembedded"] == 1
    assert svc.stats["n_profile_invalidated"] == 0
    assert svc.predict_profile("heavy") is p_heavy
    assert svc.predict_profile("light", "v4") is p_light
    # removal of an unrelated scenario: survivors again keep their state
    cs.remove_scenario("mixed2")
    svc.refresh()
    assert svc.stats["n_reembedded"] == 1          # nothing new to embed
    assert svc.predict_profile("heavy") is p_heavy
    svc.close()


def test_unsubscribed_service_fails_loudly_on_drift(tmp_path):
    """Regression (warm-cache staleness): an opted-out service must not
    answer from a cache the store has drifted away from — it detects the
    manifest-fingerprint mismatch and raises instead of serving a
    removed/stale scenario."""
    cs, _ = _mutable_svc(tmp_path)
    svc = ProxyService(cs, out_dir=tmp_path / "modules", subscribe=False)
    svc.query(_store([_V1, _V2, _V3]))             # fresh: fine
    cs.remove_scenario("mixed")
    from repro.serve.proxy_service import StaleServiceError
    with pytest.raises(StaleServiceError, match="fingerprint drifted"):
        svc.query(_store([_V1, _V2, _V3]))
    # an explicit refresh resynchronizes and service resumes
    svc.refresh()
    assert svc.query(_store([_V3, _V3, _V1])).name == "heavy"


def test_concurrent_batches_interleaved_with_mutation(tmp_path, monkeypatch):
    """query_batch from several threads, racing store append/remove of a
    duplicate-content scenario: every query answers, the stats stay
    consistent, and the cold path never runs — Sequitur and the fit
    solvers are poisoned throughout (refresh's incremental synthesis must
    resolve purely from the content-addressed caches)."""
    cs, svc = _mutable_svc(tmp_path)
    svc.query(_store([_V1, _V2, _V3]))             # warm before poisoning

    def _boom(*a, **kw):
        raise AssertionError("cold path entered during concurrent serving")

    monkeypatch.setattr(sequitur, "compress", _boom)
    monkeypatch.setattr(sequitur.Sequitur, "push", _boom, raising=True)
    monkeypatch.setattr(proxy_search, "fit_batch", _boom)
    monkeypatch.setattr(proxy_search, "fit_combination", _boom)

    import threading
    errors: list[BaseException] = []
    n_threads, n_batches = 4, 6
    traces = [_store([_V3, _V3, _V1]), _store([_V1, _V2, _V3])]

    def worker():
        try:
            for _ in range(n_batches):
                for ans in svc.query_batch(traces):
                    assert ans.name in ("heavy", "mixed", "mixed2")
        except BaseException as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    q0 = svc.stats["n_queries"]
    for t in threads:
        t.start()
    for _ in range(3):                              # racing mutations
        cs.add_scenario("mixed2", _store([_V1, _V2, _V3]))
        svc.query(_store([_V1, _V2, _V3]))
        cs.remove_scenario("mixed2")
    for t in threads:
        t.join()
    assert not errors
    expected = q0 + n_threads * n_batches * len(traces) + 3
    assert svc.stats["n_queries"] == expected
    assert svc.stats["n_module_cache_hits"] == svc.stats["n_queries"]
    assert svc.stats["n_warm_synthesis"] == 1       # never re-warmed
    assert svc.stats["n_refresh"] >= 1              # mutations were seen
    svc.close()


# ---------------------------------------------------------------------------
# nearest-neighbor structure
# ---------------------------------------------------------------------------


def test_ann_mode_matches_brute_force(tmp_path):
    """Below/above ``ann_threshold`` the service must give the same
    answer: the ball tree is exact, so names agree and distances are
    bit-equal; ANN-mode ``distances`` holds only the matched scenario."""
    cs = CorpusStore(tmp_path / "corpus")
    base = np.asarray([_V1, _V2, _V3])
    for i in range(9):
        vecs = [tuple(v) for v in base * (1.0 + 0.31 * i) + 7.0 * i]
        cs.add_scenario(f"s{i}", _store(vecs + [_V1 if i % 2 else _V2]))
    brute = ProxyService(cs, out_dir=tmp_path / "m1", ann_threshold=10 ** 6)
    ann = ProxyService(cs, out_dir=tmp_path / "m2", ann_threshold=1)
    assert brute._ann is None and ann._ann is not None
    queries = [_store([tuple(v) for v in base * (1.0 + 0.31 * i) + 7.0 * i])
               for i in range(9)] + [_store([_V1, _V1]), _store([_V3])]
    for rb, ra in zip(brute.query_batch(queries), ann.query_batch(queries)):
        assert ra.name == rb.name
        assert ra.distance == rb.distance          # same bits
        assert set(ra.distances) == {ra.name}      # ANN: matched only
        assert len(rb.distances) == 9              # brute: all scenarios
    assert ann.stats["n_ann_queries"] == len(queries)
    assert brute.stats["n_brute_queries"] == len(queries)
    brute.close(), ann.close()


def test_stage_timers_accumulate(svc):
    svc.query(_store([_V1, _V2, _V3]))
    for stage in ("match_ms", "featurize_ms", "distance_ms", "profile_ms"):
        assert svc.stats[stage] >= 0.0
    assert svc.stats["match_ms"] + svc.stats["featurize_ms"] > 0.0
