"""Serve-tier tests: nearest-scenario queries answer from the warm
cache — one synthesis at construction, then a pure-NumPy hot path (no
Sequitur, no fit dispatch, no codegen), pinned by stats counters and by
poisoning the cold-path entry points after warm-up."""
import numpy as np
import pytest

from repro.core import proxy_search, sequitur
from repro.core.corpus_store import CorpusStore
from repro.core.events import CommEvent, ComputeEvent
from repro.core.portability import CHIPS
from repro.core.replay import load_saved_module
from repro.core.trace_ir import TraceStore
from repro.serve.proxy_service import ProxyService

_V1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
_V2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
_V3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)


def _store(vectors, kind="psum", n_ranks=4):
    comm = CommEvent(kind, (8,), "float32", ("x",))
    tr = []
    for v in vectors:
        tr += [ComputeEvent(tuple(v)), comm]
    return TraceStore.from_rank_traces([list(tr) for _ in range(n_ranks)],
                                       {"x": n_ranks})


@pytest.fixture(scope="module")
def svc(tmp_path_factory):
    root = tmp_path_factory.mktemp("serve")
    cs = CorpusStore(root / "corpus")
    cs.add_scenario("heavy", _store([_V3, _V3, _V1]))
    cs.add_scenario("light", _store([_V2, _V2], kind="all_gather"))
    cs.add_scenario("mixed", _store([_V1, _V2, _V3]))
    return ProxyService(cs, out_dir=root / "modules")


def test_query_nearest_is_self(svc):
    """A corpus scenario's own trace is its own nearest neighbor, every
    row exact-key matched."""
    for name, vecs, kind in (("heavy", [_V3, _V3, _V1], "psum"),
                             ("light", [_V2, _V2], "all_gather")):
        ans = svc.query(_store(vecs, kind=kind))
        assert ans.name == name
        assert ans.distance == pytest.approx(0.0, abs=1e-12)
        assert ans.matched_frac == 1.0


def test_query_novel_trace_falls_back(svc):
    """Unseen metric rows map through the nearest-rep fallback and still
    produce a ranked answer."""
    novel = tuple(v * 1.7 + 13.0 for v in _V3)
    ans = svc.query(_store([novel, novel, _V3]))
    assert ans.name in svc.corpus.results
    assert 0.0 < ans.matched_frac < 1.0
    assert set(ans.distances) == {"heavy", "light", "mixed"}


def test_query_returns_loadable_module_and_profile(svc, tmp_path):
    ans = svc.query(_store([_V3, _V3, _V1]), chip="v5p")
    # the module is pre-assembled and on disk — reloadable elsewhere
    mod = load_saved_module(ans.module_path, name="reloaded_proxy")
    assert mod.TERMINALS == ans.module.TERMINALS
    assert ans.profile.chip == "v5p"
    assert ans.profile.step_time > 0.0
    assert np.all(ans.profile.t_total >= 0.0)


def test_hot_path_answers_from_cache(svc, monkeypatch):
    """After warm-up, queries must not re-enter synthesis: poison the
    Sequitur kernel, the fit solvers, and corpus synthesis itself — the
    hot path never touches them, and the counters agree."""
    def _boom(*a, **kw):
        raise AssertionError("cold path entered on a warm query")

    import repro.core.synthesize as synth_mod
    monkeypatch.setattr(sequitur, "compress", _boom)
    monkeypatch.setattr(sequitur.Sequitur, "push", _boom, raising=True)
    monkeypatch.setattr(proxy_search, "fit_batch", _boom)
    monkeypatch.setattr(proxy_search, "fit_combination", _boom)
    monkeypatch.setattr(synth_mod, "synthesize_corpus", _boom)

    q0 = svc.stats["n_queries"]
    for _ in range(5):
        ans = svc.query(_store([_V1, _V2, _V3]))
        assert ans.name == "mixed"
    assert svc.stats["n_warm_synthesis"] == 1          # construction only
    assert svc.stats["n_queries"] == q0 + 5
    assert svc.stats["n_module_cache_hits"] == svc.stats["n_queries"]


def test_profile_cache_memoizes_per_chip(svc):
    h0 = svc.stats["n_profile_cache_hits"]
    m0 = svc.stats["n_profile_cache_misses"]
    p1 = svc.predict_profile("heavy", "v4")            # first: miss
    p2 = svc.predict_profile("heavy", "v4")            # repeat: hit
    assert p1 is p2
    assert svc.stats["n_profile_cache_misses"] == m0 + 1
    assert svc.stats["n_profile_cache_hits"] == h0 + 1
    # chip default + all chips resolvable
    for chip in CHIPS:
        assert svc.predict_profile("light", chip).chip == chip


def test_service_rejects_empty_store_and_bad_chip(tmp_path):
    cs = CorpusStore(tmp_path / "empty")
    with pytest.raises(ValueError, match="empty corpus"):
        ProxyService(cs)
    cs.add_scenario("a", _store([_V1]))
    with pytest.raises(ValueError, match="unknown chip"):
        ProxyService(cs, chip="v999")
