"""Property tests for the event model (paper §2.2).

Split from test_events_grammar.py so the plain unit tests there always
run.  The roundtrip property itself also always runs, over a seeded
deterministic permutation corpus; only the hypothesis-randomized
exploration skips when hypothesis is absent (the perpetual-skip audit:
the gating condition is the optional dependency, not the JAX floor).
"""
import numpy as np
import pytest

from repro.core.events import decode_relative_perm, encode_relative_perm

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic corpus in this module still runs")


def _check_roundtrip(perm, size):
    enc = encode_relative_perm(perm, size)
    assert sorted(decode_relative_perm(enc, size)) == sorted(perm)


def test_relative_perm_roundtrip_examples():
    """Deterministic corpus: full shifts, partial participation, arbitrary
    permutations, and the empty permutation, across sizes 2..12."""
    rng = np.random.RandomState(0)
    for size in range(2, 13):
        _check_roundtrip([], size)
        for off in (0, 1, size - 1):
            _check_roundtrip([(s, (s + off) % size) for s in range(size)],
                             size)
        for _ in range(20):
            srcs = rng.permutation(size)[:rng.randint(0, size + 1)]
            dsts = rng.permutation(srcs)
            _check_roundtrip(list(zip(srcs.tolist(), dsts.tolist())), size)


if HAVE_HYPOTHESIS:

    @given(st.integers(2, 16), st.data())
    @settings(max_examples=200, deadline=None)
    def test_relative_perm_roundtrip_property(size, data):
        srcs = data.draw(st.lists(st.integers(0, size - 1), unique=True,
                                  min_size=0, max_size=size))
        dsts = data.draw(st.permutations(srcs))
        _check_roundtrip(list(zip(srcs, dsts)), size)

else:            # keep the gating visible in the test report

    @needs_hypothesis
    def test_relative_perm_roundtrip_property():
        raise AssertionError("unreachable: skipif guards this test")
