"""Hypothesis property tests for the event model (paper §2.2).

Split from test_events_grammar.py so the plain unit tests there always
run; this module (alone) skips when hypothesis is absent."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.events import decode_relative_perm, encode_relative_perm


@given(st.integers(2, 16), st.data())
@settings(max_examples=200, deadline=None)
def test_relative_perm_roundtrip_property(size, data):
    srcs = data.draw(st.lists(st.integers(0, size - 1), unique=True,
                              min_size=0, max_size=size))
    dsts = data.draw(st.permutations(srcs))
    perm = list(zip(srcs, dsts))
    enc = encode_relative_perm(perm, size)
    assert sorted(decode_relative_perm(enc, size)) == sorted(perm)
