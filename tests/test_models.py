"""Per-architecture smoke tests: reduced config, one loss/prefill/decode
step on CPU, asserting shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get, smoke
from repro.models.model import build_forward, init_cache, init_params


def _batch(cfg, b=2, s=16):
    out = {"tokens": jnp.ones((b, s), jnp.int32),
           "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.n_vision_tokens:
        out["vision_embeds"] = jnp.ones((b, cfg.n_vision_tokens, cfg.d_model),
                                        jnp.float32)
    if cfg.n_audio_frames:
        out["audio_frames"] = jnp.ones((b, cfg.n_audio_frames, cfg.d_model),
                                       jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = smoke(get(arch))
    params = init_params(cfg)
    batch = _batch(cfg)
    loss_fn = build_forward(cfg, "loss")
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg)))(params)
    assert np.isfinite(float(loss))
    gn = jax.tree.reduce(lambda a, g: a + float(jnp.sum(jnp.abs(g))), grads, 0.0)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy next token from prefill == decode-step replay of the prompt."""
    cfg = smoke(get(arch))
    params = init_params(cfg)
    b, s = 2, 8
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    batch = _batch(cfg, b, s)
    batch["tokens"] = toks
    batch.pop("labels")
    logits_p, _ = jax.jit(lambda p, bt: build_forward(cfg, "prefill")(
        p, bt, cfg))(params, batch)
    assert logits_p.shape == (b, cfg.padded_vocab)

    cache = init_cache(cfg, b, 32)
    dec = jax.jit(lambda p, c, bt, pos: build_forward(cfg, "decode")(
        p, c, bt, pos, cfg))
    logits_d = None
    for i in range(s):
        dbatch = dict(batch)
        dbatch["tokens"] = toks[:, i:i + 1]
        if cfg.family == "encdec":
            break  # decode needs prefilled cross-KV; covered in serve test
        dbatch.pop("vision_embeds", None)
        logits_d, cache = dec(params, cache, dbatch, jnp.int32(i))
    if logits_d is not None and not cfg.n_vision_tokens:
        np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                                   rtol=2e-2, atol=2e-2)


def test_gemma_ring_cache_decode_matches_full():
    """Sliding-window ring cache ≡ full cache + window mask."""
    import dataclasses
    cfg = smoke(get("gemma3-4b"))
    cfg = dataclasses.replace(cfg, window=8)
    params = init_params(cfg)
    b, steps = 1, 20
    rng = np.random.RandomState(1)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (b, steps)), jnp.int32)
    dec = jax.jit(lambda p, c, bt, pos: build_forward(cfg, "decode")(
        p, c, bt, pos, cfg))
    ring = init_cache(cfg, b, steps)        # local layers get window-size ring
    full_cfg = dataclasses.replace(cfg, window=steps + 1)  # window > len: full
    dec_full = jax.jit(lambda p, c, bt, pos: build_forward(full_cfg, "decode")(
        p, c, bt, pos, full_cfg))
    full = init_cache(full_cfg, b, steps)
    for i in range(steps):
        bt = {"tokens": toks[:, i:i + 1]}
        l_ring, ring = dec(params, ring, bt, jnp.int32(i))
        l_full, full = dec_full(params, full, bt, jnp.int32(i))
        if i < 8 - 1:  # inside the window both views must agree exactly
            np.testing.assert_allclose(np.asarray(l_ring), np.asarray(l_full),
                                       rtol=2e-3, atol=2e-3)


def test_unit_pattern_expansion():
    cfg = get("gemma3-4b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 34
    assert kinds[:6] == ["l", "l", "l", "l", "l", "g"]
    cfg = get("jamba-v0.1-52b")
    kinds = cfg.layer_kinds()
    assert kinds.count("g") == 4 and kinds.count("m") == 28
    assert sum(cfg.is_moe_layer(i) for i in range(32)) == 16


def test_param_counts_close_to_nameplate():
    expect = {"qwen3-32b": 32e9, "mixtral-8x22b": 140e9,
              "deepseek-moe-16b": 16e9, "jamba-v0.1-52b": 52e9,
              "mamba2-2.7b": 2.7e9}
    for arch, n in expect.items():
        got = get(arch).approx_params()
        assert 0.7 * n < got < 1.45 * n, (arch, got)
