"""DeviceComm replay backend + manual-DP compressed train step — the
mesh-executing paths (subprocess: needs forced host devices)."""
import subprocess
import sys
import textwrap


def _run(prog: str, timeout: int = 420):
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_device_comm_all_kinds_execute():
    """Every collective kind replays under shard_map on a real mesh and the
    lowered HLO contains exactly the expected collective ops."""
    out = _run(textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.sharding.collectives import DeviceComm
        from repro.launch.hlo_cost import analyze

        mesh = make_mesh((8,), ("x",))
        comm = DeviceComm({"x": 8})
        st = {"b0": jnp.full((16, 8), 0.5, jnp.float32)}

        def prog(st):
            st = comm.do(st, "b0", kind="psum", axes=("x",), detail=(),
                         shape=(16, 8), dtype="float32")
            st = comm.do(st, "b0", kind="all_gather", axes=("x",),
                         detail=(0,), shape=(16, 8), dtype="float32")
            st = comm.do(st, "b0", kind="reduce_scatter", axes=("x",),
                         detail=(0,), shape=(16, 8), dtype="float32")
            st = comm.do(st, "b0", kind="all_to_all", axes=("x",),
                         detail=(0, 1), shape=(16, 8), dtype="float32")
            st = comm.do(st, "b0", kind="ppermute", axes=("x",),
                         detail=("shift", 1), shape=(16, 8), dtype="float32")
            return st

        sm = shard_map(prog, mesh=mesh,
                       in_specs=(jax.tree.map(lambda _: P(), st),),
                       out_specs=jax.tree.map(lambda _: P(), st),
                       check_vma=False)
        compiled = jax.jit(sm).lower(st).compile()
        got = compiled({"b0": jnp.full((16, 8), 0.5, jnp.float32)})
        import numpy as np
        assert np.isfinite(np.asarray(got["b0"])).all()
        kinds = analyze(compiled.as_text()).collective_by_kind
        for want in ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute"):
            assert kinds.get(want, 0) > 0, (want, dict(kinds))
        print("OK", dict(kinds))
    """))
    assert "OK" in out


def test_manual_dp_compressed_step_wire_dtype():
    """The int8 error-feedback DP step trains (loss finite, params move)
    and its gradient all-reduce moves s32 payloads (4x fewer bf16-equiv
    bytes than f32)."""
    out = _run(textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np, re
        from repro.configs import get, smoke
        from repro.launch.mesh import make_dp_mesh
        from repro.models.model import init_params
        from repro.train.compression import init_error_state
        from repro.train.loop import make_manual_dp_train_step
        from repro.train.optimizer import adamw_init

        cfg = smoke(get("llama3.2-3b"))
        mesh = make_dp_mesh(4)
        step = make_manual_dp_train_step(cfg, mesh)
        params = init_params(cfg)
        opt = adamw_init(params)
        err = init_error_state(params)
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        lowered = jax.jit(step).lower(params, opt, err, batch)
        txt = lowered.compile().as_text()
        # int8 quantize -> int32-accumulate all-reduce on the wire
        int_ars = re.findall(r"s32\\[[0-9,]*\\][^\\n]*all-reduce", txt) or \
                  re.findall(r"all-reduce[^\\n]*s32", txt)
        assert int_ars, "no integer all-reduce found"
        p2, o2, e2, m = jax.jit(step)(params, opt, err, batch)
        assert np.isfinite(float(m["loss"]))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, p2)
        assert max(jax.tree.leaves(d)) > 0
        print("OK loss", float(m["loss"]))
    """))
    assert "OK" in out


def test_proxy_replay_on_mesh_runs():
    """A synthesized proxy executes under DeviceComm on the mesh end-to-end
    (not just lowering) and produces finite state."""
    out = _run(textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core.synthesize import synthesize
        from repro.core.replay import init_replay_state
        from repro.sharding.collectives import DeviceComm

        mesh = make_mesh((8,), ("x",))

        def f(u):
            left = jax.lax.ppermute(u, "x", [(i, (i+1) % 8) for i in range(8)])
            u = jnp.tanh((u + left) @ jnp.ones((128, 128)) * 0.01)
            return jax.lax.psum(u.sum(), "x")

        g = shard_map(f, mesh=mesh, in_specs=P(None, "x"), out_specs=P())
        res = synthesize(g, jnp.ones((64, 1024)), name="mesh_replay")
        comm = DeviceComm({"x": 8})
        mod = res.proxy.module
        st = init_replay_state(mod)
        sm = shard_map(lambda s: mod.run_rank(s, comm, 0), mesh=mesh,
                       in_specs=(jax.tree.map(lambda _: P(), st),),
                       out_specs=jax.tree.map(lambda _: P(), st),
                       check_vma=False)
        got = jax.jit(sm)(st)
        for leaf in jax.tree.leaves(got):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
        print("OK")
    """))
    assert "OK" in out
