"""Program-table lowering + compiled-vs-unrolled parity (grammar-compiled
replay tier).

The bar: grammar-compiled modules (scan/switch program tables) must be
indistinguishable from the unrolled ``codegen_reference`` oracle in every
observable — bit-identical δ̄, identical per-rank comm sequences, equivalent
executed states — while their traced executables stay O(grammar).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks
from repro.core.events import CommEvent, ComputeEvent
from repro.core.progtable import (
    ProgramTable, SWITCH_MIN_LEN, expand_symbols, jaxpr_eqn_count,
)
from repro.core.replay import (
    ProxyProgram, REP_UNROLL_THRESHOLD, load_saved_module, rep,
)
from repro.core.synthesize import synthesize
from repro.core.tracer import _contains_cond
from repro.sharding.collectives import LocalSim


def _has_prim(jaxpr, name: str) -> bool:
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            return True
        for v in eqn.params.values():
            sub = [v] if (hasattr(v, "eqns") or hasattr(v, "jaxpr")) else \
                (list(v) if isinstance(v, (tuple, list)) else [])
            for b in sub:
                if (hasattr(b, "eqns") or hasattr(b, "jaxpr")) \
                        and _has_prim(b, name):
                    return True
    return False


# ---------------------------------------------------------------------------
# lowering units
# ---------------------------------------------------------------------------


def test_expand_symbols_nested():
    rules = {0: (("t", 1, 2), ("r", 1, 1)), 1: (("t", 0, 3),)}
    assert expand_symbols((("r", 0, 2),), rules) == [1, 1, 0, 0, 0] * 2
    assert expand_symbols((), rules) == []
    assert expand_symbols((("t", 5, 4),), {}) == [5] * 4


def test_rep_unroll_threshold_crossover():
    """Exponents at the threshold unroll (no loop primitive in the jaxpr);
    one past it emits a single rolled loop body."""
    def f(st, comm):
        return {"v": st["v"] + 1.0}

    st = {"v": jnp.zeros(())}
    at = jax.make_jaxpr(lambda s: rep(f, REP_UNROLL_THRESHOLD, s, None))(st)
    above = jax.make_jaxpr(
        lambda s: rep(f, REP_UNROLL_THRESHOLD + 1, s, None))(st)
    assert not (_has_prim(at, "scan") or _has_prim(at, "while"))
    assert _has_prim(above, "scan") or _has_prim(above, "while")
    # unrolled body: one add per repeat; rolled: one body regardless of n
    assert jaxpr_eqn_count(at) == REP_UNROLL_THRESHOLD
    big = jax.make_jaxpr(lambda s: rep(f, 1000, s, None))(st)
    assert jaxpr_eqn_count(big) == jaxpr_eqn_count(above)
    # semantics unchanged across the crossover
    assert float(rep(f, REP_UNROLL_THRESHOLD + 1, st, None)["v"]) == \
        REP_UNROLL_THRESHOLD + 1


def _compute_desc(i: int):
    x = [0] * 11
    x[i] = 1
    x[10] = 1 + i   # x11 must cover the block-turn budget sum(x1..9)
    return ("compute", tuple(x), 1)


def test_switch_lowering_threshold():
    """Sequences below SWITCH_MIN_LEN (or without symbol reuse) lower
    straight-line; at/above it with reuse they dispatch via switch."""
    terms = [_compute_desc(0), _compute_desc(1)]
    short = tuple([("t", 0, 1), ("t", 1, 1)] * (SWITCH_MIN_LEN // 2 - 1))
    long = tuple([("t", 0, 1), ("t", 1, 1)] * SWITCH_MIN_LEN)
    distinct = tuple(("t", i % 2, 1 + i // 2) for i in range(SWITCH_MIN_LEN))
    pt = ProgramTable(terms, {}, [short, long, distinct])
    st = blocks.init_state(0)
    comm = LocalSim()
    j_short = jax.make_jaxpr(lambda s: pt.run(0, s, comm))(st)
    j_long = jax.make_jaxpr(lambda s: pt.run(1, s, comm))(st)
    assert not _contains_cond(j_short)
    assert _contains_cond(j_long) and _has_prim(j_long, "scan")
    # all-distinct symbols: switch saves nothing, stays straight-line
    assert not _contains_cond(jax.make_jaxpr(
        lambda s: pt.run(2, s, comm))(st))
    # switch body is sized by distinct symbols: growing the sequence 8x
    # leaves the executable the same size
    pt8 = ProgramTable(terms, {}, [long * 8])
    assert jaxpr_eqn_count(jax.make_jaxpr(
        lambda s: pt8.run(0, s, comm))(st)) == jaxpr_eqn_count(j_long)


def test_program_table_executes_like_manual_expansion():
    """Eager execution of a lowered program (switch path included) equals
    manually applying the expanded terminal sequence in order."""
    terms = [_compute_desc(0), _compute_desc(1)]
    rules = {0: (("t", 0, 2), ("t", 1, 1))}
    prog = tuple([("r", 0, 2), ("t", 1, 1)] * 3)   # len 6 -> switch path
    pt = ProgramTable(terms, rules, [prog])
    comm = LocalSim()
    got = pt.run(0, blocks.init_state(0), comm)
    want = blocks.init_state(0)
    for gid in expand_symbols(prog, rules):
        kind, x, unroll = terms[gid]
        want = blocks.run_combo(want, x, unroll=unroll)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k], np.float32),
                                   np.asarray(want[k], np.float32),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


# ---------------------------------------------------------------------------
# compiled vs unrolled parity (the codegen_reference oracle bar)
# ---------------------------------------------------------------------------


def _mk_traces(n_ranks=4, reps=24, irregular=False):
    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    comps = [ComputeEvent(tuple(
        np.array([2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.]) * 1.5 ** i))
        for i in range(5)]
    if irregular:
        # deterministic low-regularity schedule: the main rule stays long
        # and heterogeneous, exercising the switch-scan lowering
        sched = [(7 * i * i + 3 * i) % 5 for i in range(reps)]
    else:
        sched = [i % 2 for i in range(reps)]
    traces = []
    for r in range(n_ranks):
        tr = []
        for s in sched:
            tr.extend([comps[s], comm if s % 2 == 0 else perm])
        if r == 0:
            tr = tr + [comm]
        traces.append(tr)
    return traces


def _pair(name, **kw):
    res = synthesize(rank_traces=_mk_traces(**kw), axis_sizes={"x": 4},
                     name=f"{name}_t")
    ref = synthesize(rank_traces=_mk_traces(**kw), axis_sizes={"x": 4},
                     name=f"{name}_u", codegen="unrolled")
    assert res.stats["codegen"] == "table"
    assert ref.stats["codegen"] == "unrolled"
    assert res.proxy.module.CODEGEN == "table"
    assert ref.proxy.module.CODEGEN == "unrolled"
    return res, ref


def test_parity_delta_and_comm_sequences():
    res, ref = _pair("par", irregular=True, reps=40)
    # identical signature metadata by construction (shared helpers)
    assert res.proxy.module.SIGNATURE_GROUPS == ref.proxy.module.SIGNATURE_GROUPS
    # per-rank comm sequences: symbolic expansion of the emitted tables
    # reproduces the merged grammar's lossless expansion exactly
    for r in range(4):
        assert res.proxy.module.expand_rank_ids(r) == \
            res.merged.expand_rank(r)
    # δ̄ bit-identical: exact walker on scan/switch == unrolled statements
    for r in range(4):
        np.testing.assert_array_equal(res.proxy.rank_metrics(r),
                                      ref.proxy.rank_metrics(r))
    ft = res.fidelity(sample_ranks=None)
    fu = ref.fidelity(sample_ranks=None)
    np.testing.assert_array_equal(ft.delta, fu.delta)
    assert ft.comm_lossless and fu.comm_lossless


def test_parity_executed_states():
    res, ref = _pair("exec", irregular=True, reps=24)
    out_t = res.proxy.run_all(per_rank_seeds=True)
    out_u = ref.proxy.run_all(per_rank_seeds=True)
    assert sorted(out_t) == sorted(out_u)
    for r in out_t:
        for k in out_t[r]:
            np.testing.assert_allclose(
                np.asarray(out_t[r][k], np.float32),
                np.asarray(out_u[r][k], np.float32),
                rtol=1e-4, atol=1e-5, err_msg=f"rank {r} leaf {k}")


def test_compiled_executable_stays_grammar_sized():
    """10x more trace events, same compiled executable: eqn counts are a
    pure function of the grammar, while the unrolled flavor's never get
    smaller than the compiled one."""
    small, small_ref = _pair("g1", reps=24)
    big, big_ref = _pair("g2", reps=240)
    assert big.stats["n_events"] >= 10 * small.stats["n_events"] * 0.9
    e_small = max(small.proxy.group_eqn_counts().values())
    e_big = max(big.proxy.group_eqn_counts().values())
    assert e_big <= 2 * e_small, (e_small, e_big)
    for sig, n in big.proxy.group_eqn_counts().items():
        assert n <= big_ref.proxy.group_eqn_counts()[sig]


# ---------------------------------------------------------------------------
# saved-module round-trip (both flavors)
# ---------------------------------------------------------------------------


def test_load_saved_module_roundtrip_both_flavors(tmp_path):
    res = synthesize(rank_traces=_mk_traces(irregular=True, reps=40),
                     axis_sizes={"x": 4}, name="rt_t",
                     out_dir=tmp_path / "t")
    ref = synthesize(rank_traces=_mk_traces(irregular=True, reps=40),
                     axis_sizes={"x": 4}, name="rt_u",
                     out_dir=tmp_path / "u", codegen="unrolled")
    for src, flavor in ((res, "table"), (ref, "unrolled")):
        mod = load_saved_module(src.proxy.module.__proxy_path__,
                                f"rt_reload_{flavor}")
        assert mod.CODEGEN == flavor
        assert mod.SIGNATURE_GROUPS == src.proxy.module.SIGNATURE_GROUPS
        assert mod.COMM_BUFFERS == src.proxy.module.COMM_BUFFERS
        for r in range(4):
            assert mod.program_signature(r) == \
                src.proxy.module.program_signature(r)
        proxy = ProxyProgram(src.source, mod, src.merged, src.proxy.combos,
                             src.proxy.axis_sizes)
        orig = src.proxy.run_all()
        redo = proxy.run_all()
        for r in orig:
            for k in orig[r]:
                np.testing.assert_allclose(
                    np.asarray(redo[r][k], np.float32),
                    np.asarray(orig[r][k], np.float32),
                    rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(proxy.rank_metrics(0),
                                      src.proxy.rank_metrics(0))
    # compiled tables survive the round-trip symbolically too
    mod_t = load_saved_module(res.proxy.module.__proxy_path__, "rt_expand")
    for r in range(4):
        assert mod_t.expand_rank_ids(r) == res.merged.expand_rank(r)


# ---------------------------------------------------------------------------
# walker exact mode stays opt-in
# ---------------------------------------------------------------------------


def test_walker_legacy_cond_semantics_unchanged():
    """Original-program tracing (exact_cond off, the default) keeps the
    legacy max-of-branch-costs semantics for data-dependent conds — the
    fidelity baselines of traced models must not drift."""
    from jax import lax
    from repro.core.tracer import trace_fn

    def f(x):
        return lax.cond(x.sum() > 0,
                        lambda v: v * 2.0,
                        lambda v: (v @ v.T).sum() + v, x)

    x = jnp.ones((8, 8))
    legacy = trace_fn(f, x).total_compute()
    # branch index is data-dependent -> exact mode cannot resolve it either,
    # so both modes fall back to the same conservative cost
    exact = trace_fn(f, x, exact_cond=True).total_compute()
    np.testing.assert_array_equal(legacy, exact)
    assert legacy[0] > 0   # flops counted from the heavy branch
