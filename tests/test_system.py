"""End-to-end system tests: the full Siesta pipeline on real distributed
programs — trace → grammar → merge → QP → codegen → replay → fidelity.

Runs in a subprocess with 8 forced host devices so shard_map programs have a
real mesh (the main pytest process keeps the single CPU device)."""
import json
import subprocess
import sys
import textwrap

import pytest

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.synthesize import synthesize

    mesh = make_mesh((8,), ("x",))

    def stencil_step(u, w):
        def scanbody(c, _):
            u, w = c
            left = jax.lax.ppermute(u[:, :1], "x",
                                    [(i, (i + 1) % 8) for i in range(8)])
            right = jax.lax.ppermute(u[:, -1:], "x",
                                     [(i, (i - 1) % 8) for i in range(8)])
            u = u + 0.1 * (left + right - 2.0 * u)
            for _ in range(3):
                u = jnp.tanh(u @ w)
            r = jax.lax.psum(jnp.sum(u), "x")
            return (u, w), r
        (u, _), rs = jax.lax.scan(scanbody, (u, w), None, length=12)
        return u, rs

    f = shard_map(stencil_step, mesh=mesh,
                  in_specs=(P(None, "x"), P()), out_specs=(P(None, "x"), P()))
    u = jnp.ones((256, 1024))
    w = jnp.ones((128, 128)) * 0.01
    res = synthesize(f, u, w, name="systest")
    fid = res.fidelity()
    out = res.proxy.run_local(ranks=[0, 3])
    report = {
        "comm_lossless": bool(fid.comm_lossless),
        "mean_delta": float(fid.mean),
        "compression_ratio": float(res.stats["compression_ratio"]),
        "n_events": int(res.stats["n_events"]),
        "n_rules": int(res.stats["n_rules"]),
        "mean_fit": float(res.stats["mean_fit_rel_err"]),
        "replay_time": float(res.proxy.time_local(0, iters=2)),
        "source_has_shift": "('shift', 1)" in res.source,
    }
    print("REPORT:" + json.dumps(report))
""")


@pytest.fixture(scope="module")
def e2e_report():
    proc = subprocess.run([sys.executable, "-c", _PROG], capture_output=True,
                          text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("REPORT:")]
    assert line, proc.stdout
    return json.loads(line[0][len("REPORT:"):])


def test_comm_lossless(e2e_report):
    """Paper §1: communication behaviour reproduced losslessly."""
    assert e2e_report["comm_lossless"]


def test_fidelity(e2e_report):
    """Mean per-(metric, rank) relative error in the paper's Table 3 range."""
    assert e2e_report["mean_delta"] < 0.10, e2e_report


def test_compression(e2e_report):
    """Grammar ≪ trace (paper Table 3 shows 10^2-10^4x on loops)."""
    assert e2e_report["compression_ratio"] > 30, e2e_report


def test_relative_rank_encoding_in_source(e2e_report):
    assert e2e_report["source_has_shift"]


def test_replay_executes(e2e_report):
    assert e2e_report["replay_time"] > 0
