"""Event model + grammar data-structure unit tests (paper §2.2, §2.5).

Hypothesis-based property tests live in test_events_grammar_prop.py so
this module always runs, dependency or not."""
import numpy as np
import pytest

from repro.core.events import (
    CommEvent, ComputeEvent, cluster_compute_events, cluster_vectors,
    decode_relative_perm, dtype_bytes, encode_relative_perm,
)
from repro.core.grammar import compress_events, raw_trace_bytes


def test_dtype_bytes_str_inputs():
    assert dtype_bytes("float32") == 4
    assert dtype_bytes("bfloat16") == 2
    assert dtype_bytes("float8_e4m3fn") == 1
    assert dtype_bytes("int64") == 8


def test_dtype_bytes_np_dtype_inputs():
    assert dtype_bytes(np.dtype("float64")) == 8
    assert dtype_bytes(np.dtype("complex64")) == 8
    assert dtype_bytes(np.int8) == 1          # scalar type, not dtype
    assert dtype_bytes(np.dtype("bool")) == 1
    import jax.numpy as jnp
    assert dtype_bytes(jnp.bfloat16) == 2     # ml_dtypes name resolution


def test_dtype_bytes_unknown_defaults_to_4():
    assert dtype_bytes("not-a-dtype") == 4
    assert dtype_bytes(np.dtype("datetime64[ns]")) == 4


def test_relative_perm_shift_roundtrip():
    size = 12
    perm = [(i, (i + 3) % size) for i in range(size)]
    enc = encode_relative_perm(perm, size)
    assert enc == ("shift", 3)
    assert sorted(decode_relative_perm(enc, size)) == sorted(perm)


def test_relative_perm_partial():
    size = 8
    perm = [(i, i + 1) for i in range(size - 1)]  # non-periodic boundary
    enc = encode_relative_perm(perm, size)
    assert enc[0] == "shift" and enc[1] == 1 and len(enc) == 3
    assert sorted(decode_relative_perm(enc, size)) == sorted(perm)


def test_same_shift_same_key():
    """Paper Fig. 2: neighbour exchanges collapse to one terminal."""
    size = 12
    e1 = CommEvent("ppermute", (128,), "float32", ("x",),
                   encode_relative_perm([(i, (i + 1) % size) for i in range(size)], size))
    e2 = CommEvent("ppermute", (128,), "float32", ("x",),
                   encode_relative_perm([((i + 5) % size, (i + 6) % size) for i in range(size)], size))
    assert e1.key() == e2.key()


def test_cluster_compute_events():
    evs = [ComputeEvent((1e9, 1e6, 1e8, 0., 0., 0.)),
           ComputeEvent((1.02e9, 1.01e6, 1.01e8, 0., 0., 0.)),
           ComputeEvent((5e9, 5e6, 5e8, 0., 0., 0.))]
    out, reps = cluster_compute_events(evs, rel_tol=0.05)
    assert out[0].cluster_id == out[1].cluster_id != out[2].cluster_id
    assert len(reps) == 2


def test_cluster_vectors_edge_cases():
    ids, reps = cluster_vectors(np.zeros((0, 6)))
    assert len(ids) == 0 and reps == {}
    with pytest.raises(ValueError):
        cluster_vectors(np.zeros((3, 5)))
    # non-positive metrics quantize to the same sentinel bucket
    ids, reps = cluster_vectors(np.zeros((4, 6)))
    assert ids.tolist() == [0, 0, 0, 0]
    np.testing.assert_array_equal(reps[0], np.zeros(6))


def test_compress_events_lossless():
    rng = np.random.RandomState(0)
    evs = []
    for _ in range(50):
        evs.append(CommEvent("psum", (8, 8), "float32", ("x",)))
        evs.append(ComputeEvent((1e6, 1e3, 1e5, 0., 0., 0.)))
    g = compress_events(evs)
    assert [g.table[i].key() for i in g.expand_ids()] == [e.key() for e in evs]
    assert g.encoded_size_bytes() < raw_trace_bytes(evs) / 5
    assert g.expanded_length() == len(evs)
