"""BallTree exactness: the serve tier's nearest-neighbor structure is
pinned bit-identical (index and distance) to ``brute_force_nearest``,
the retained parity oracle — including ties, duplicates, degenerate
point sets, and leaf-size extremes."""
import numpy as np
import pytest

from repro.serve.ann import BallTree, brute_force_nearest


def _fuzz_cases():
    rng = np.random.default_rng(11)
    for n, d in [(1, 3), (2, 1), (7, 5), (8, 5), (9, 5), (33, 2),
                 (200, 35), (513, 8)]:
        yield rng.normal(size=(n, d)), rng.normal(size=(16, d))


def test_balltree_matches_brute_force_bitwise():
    for pts, queries in _fuzz_cases():
        tree = BallTree(pts)
        assert len(tree) == len(pts)
        for q in queries:
            bi, bd = brute_force_nearest(pts, q)
            ti, td = tree.query(q)
            assert ti == bi
            assert td == bd            # same bits, not just approx


def test_balltree_on_unit_normalized_embedding_scale():
    # serve-tier regime: L2-normalized rows, tiny pairwise gaps
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(128, 35))
    pts /= np.sqrt((pts ** 2).sum(axis=1, keepdims=True))
    tree = BallTree(pts)
    for q in pts[::7]:                 # queries that sit exactly on points
        ti, td = tree.query(q)
        bi, bd = brute_force_nearest(pts, q)
        assert (ti, td) == (bi, bd) and td == 0.0
    for q in rng.normal(size=(32, 35)):
        assert tree.query(q) == brute_force_nearest(pts, q)


def test_balltree_ties_break_to_lowest_index():
    # duplicated rows at several indices: the first occurrence must win,
    # exactly as np.argmin does for the oracle
    base = np.asarray([[0.0, 0.0], [1.0, 1.0], [1.0, 1.0], [0.0, 0.0],
                       [2.0, -1.0], [1.0, 1.0]])
    pts = np.tile(base, (4, 1))        # 24 rows, heavy duplication
    tree = BallTree(pts, leaf_size=2)
    for q in [np.asarray([1.0, 1.0]), np.asarray([0.0, 0.0]),
              np.asarray([0.5, 0.5]), np.asarray([10.0, 10.0])]:
        assert tree.query(q) == brute_force_nearest(pts, q)


def test_balltree_leaf_size_invariance():
    rng = np.random.default_rng(5)
    pts = rng.normal(size=(50, 4))
    queries = rng.normal(size=(20, 4))
    answers = [brute_force_nearest(pts, q) for q in queries]
    for leaf in (1, 2, 8, 50, 100):
        tree = BallTree(pts, leaf_size=leaf)
        assert [tree.query(q) for q in queries] == answers


def test_balltree_identical_points():
    pts = np.ones((17, 6))
    tree = BallTree(pts)
    assert tree.query(np.ones(6)) == (0, 0.0)
    assert tree.query(np.zeros(6)) == brute_force_nearest(pts, np.zeros(6))


def test_empty_inputs_raise():
    with pytest.raises(ValueError, match="non-empty"):
        BallTree(np.zeros((0, 3)))
    with pytest.raises(ValueError, match="non-empty"):
        BallTree(np.zeros(4))          # not (n, d)
    with pytest.raises(ValueError, match="empty point set"):
        brute_force_nearest(np.zeros((0, 3)), np.zeros(3))
