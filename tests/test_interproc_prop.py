"""Property tests for inter-process merging (paper §2.6).

Split from test_interproc.py so the plain unit tests there always run.
The losslessness property also always runs, over a seeded deterministic
corpus of per-rank sequences; only the hypothesis-randomized exploration
skips when hypothesis is absent (the perpetual-skip audit: the gating
condition is the optional dependency, not the JAX floor).
"""
import numpy as np
import pytest

from repro.core.events import ComputeEvent
from repro.core.grammar import TerminalTable, from_sequitur
from repro.core.interproc import merge_grammars
from repro.core.sequitur import Sequitur

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic corpus in this module still runs")


def _grammar(ids):
    table = TerminalTable()
    s = Sequitur()
    for i in ids:
        ev = ComputeEvent((float(i + 1), 0, 0, 0, 0, 0), cluster_id=i)
        s.push(table.intern(ev))
    return from_sequitur(s, table)


def _check_merge_lossless(rank_seqs):
    """Losslessness for arbitrary per-rank sequences at any threshold."""
    gs = [_grammar(seq) for seq in rank_seqs]
    for threshold in (0.0, 0.5, 1.0):
        merged = merge_grammars(gs, threshold=threshold)
        for r, g in enumerate(gs):
            got = merged.expand_rank(r)
            assert [merged.table[i].key() for i in got] == \
                [g.table[i].key() for i in g.expand_ids()]


def test_merge_lossless_examples():
    """Deterministic corpus: identical SPMD ranks, disjoint ranks, and
    seeded heterogeneous mixes (the Algorithm 1 clustering cases)."""
    _check_merge_lossless([[0, 1, 2]] * 4)
    _check_merge_lossless([[0, 0, 1], [2, 3], [4]])
    rng = np.random.RandomState(4)
    for _ in range(8):
        seqs = [rng.randint(0, 6, rng.randint(1, 30)).tolist()
                for _ in range(rng.randint(1, 8))]
        _check_merge_lossless(seqs)


if HAVE_HYPOTHESIS:

    @given(st.lists(st.lists(st.integers(0, 5), min_size=1, max_size=30),
                    min_size=1, max_size=8))
    @settings(max_examples=60, deadline=None)
    def test_merge_lossless_property(rank_seqs):
        _check_merge_lossless(rank_seqs)

else:            # keep the gating visible in the test report

    @needs_hypothesis
    def test_merge_lossless_property():
        raise AssertionError("unreachable: skipif guards this test")
