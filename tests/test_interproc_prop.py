"""Hypothesis property tests for inter-process merging (paper §2.6).

Split from test_interproc.py so the plain unit tests there always run;
this module (alone) skips when hypothesis is absent."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.events import ComputeEvent
from repro.core.grammar import TerminalTable, from_sequitur
from repro.core.interproc import merge_grammars
from repro.core.sequitur import Sequitur


def _grammar(ids):
    table = TerminalTable()
    s = Sequitur()
    for i in ids:
        ev = ComputeEvent((float(i + 1), 0, 0, 0, 0, 0), cluster_id=i)
        s.push(table.intern(ev))
    return from_sequitur(s, table)


@given(st.lists(st.lists(st.integers(0, 5), min_size=1, max_size=30),
                min_size=1, max_size=8))
@settings(max_examples=60, deadline=None)
def test_merge_lossless_property(rank_seqs):
    """Losslessness for arbitrary per-rank sequences at any threshold."""
    gs = [_grammar(seq) for seq in rank_seqs]
    for threshold in (0.0, 0.5, 1.0):
        merged = merge_grammars(gs, threshold=threshold)
        for r, g in enumerate(gs):
            got = merged.expand_rank(r)
            assert [merged.table[i].key() for i in got] == \
                [g.table[i].key() for i in g.expand_ids()]
