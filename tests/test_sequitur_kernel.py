"""Parity suite: flat-array Sequitur kernel vs the reference oracle.

The flat kernel (:mod:`repro.core.sequitur`) must emit ``to_json``-identical
grammars to the preserved object-graph implementation
(:mod:`repro.core.sequitur_reference`) on every stream — zoo scenario
streams, seeded fuzz (including the RLE-adversarial shapes where a naive
run-collapse would diverge from scalar pushes), ``push_run`` exponent
edges, and rule-utility inline chains.

Follows the ROADMAP property-test convention: the deterministic seeded
corpus always runs; only the randomized hypothesis exploration is
skipif-gated on the optional dependency.
"""
import json

import numpy as np
import pytest

from repro.core import sequitur, sequitur_reference, trace_ir
from repro.core.grammar import Grammar, TerminalTable
from repro.core.sequitur import Sequitur as Flat, rle_runs
from repro.core.sequitur_reference import Sequitur as Ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic corpus in this module still runs")


def _check_parity(seq):
    """push_many parity + push_ids (RLE batch path) parity + losslessness."""
    seq = list(seq)
    r = Ref()
    r.push_many(seq)
    f = Flat()
    f.push_many(seq)
    f2 = Flat()
    f2.push_ids(np.asarray(seq, dtype=np.int64))
    gr = r.grammar_rules()
    for g in (f.grammar_rules(), f2.grammar_rules()):
        assert g == gr
        assert list(g) == list(gr), "rule-id insertion order diverged"
    table = TerminalTable()   # shared table: to_json equality == rules parity
    assert Grammar(rules=f2.grammar_rules(), table=table).to_json() \
        == Grammar(rules=gr, table=table).to_json()
    assert f.expand() == seq
    assert f2.expand() == seq
    assert f.size() == r.size()


def _check_runs_parity(runs):
    """push_run (reference O(1) bulk semantics) parity."""
    r = Ref()
    f = Flat()
    for s, c in runs:
        r.push_run(s, c)
        f.push_run(s, c)
    assert f.grammar_rules() == r.grammar_rules()
    assert list(f.grammar_rules()) == list(r.grammar_rules())


# -- deterministic corpus (always runs) -------------------------------------


def test_fuzz_seed_parity():
    """>= 8 pinned fuzz seeds across alphabet sizes, with injected runs
    (the RLE fast path must stay bit-identical to scalar pushes)."""
    for seed in range(10):
        rng = np.random.RandomState(seed)
        for _ in range(30):
            n = rng.randint(0, 220)
            alpha = int(rng.choice([1, 2, 3, 4, 5, 10, 30]))
            s = rng.randint(0, alpha, n).tolist()
            if n and rng.rand() < 0.6:
                for _ in range(rng.randint(1, 4)):
                    pos = rng.randint(0, len(s))
                    s = s[:pos] + [s[pos]] * rng.randint(2, 12) + s[pos:]
            _check_parity(s)


def test_rle_adversarial_streams():
    """Streams where collapsing a run before pushing would skip a digram
    match that scalar pushes take (e.g. the second (x, a) digram in
    [x, a, b, x, a, a] matches before the run merge) — the batch path
    must replay the match in the same online order."""
    cases = [
        [3, 1, 2, 3, 1, 1],
        [0, 1, 0, 1, 1, 0, 1],
        [2, 2, 2, 1, 2, 2, 2, 1, 2, 2, 2],
        [0, 0, 1, 0, 0, 1, 0, 0],
        [1, 2, 1, 2, 2, 2, 1, 2, 1],
        [0] * 50 + [1] + [0] * 50 + [1] + [0] * 50,
    ]
    for s in cases:
        _check_parity(s)


def test_zoo_stream_parity():
    """Kernel parity on the reduced scenario zoo's actual interned rank
    streams (the inputs compress_store feeds the kernel in production)."""
    from benchmarks.synthesize_time import (
        _assert_stream_parity, _distinct_local_streams,
    )
    from repro.configs.registry import SCENARIO_IDS, build_scenario

    total = 0
    for name in list(SCENARIO_IDS)[:3]:
        store = build_scenario(name, n_ranks=4, steps=2)
        streams = _distinct_local_streams(store)
        assert streams
        _assert_stream_parity(streams)
        total += len(streams)
    assert total >= 3


def test_push_run_exponent_edges():
    """push_run edge cases: zero/negative counts, O(1) huge counts,
    exponent merges across run boundaries."""
    f = Flat()
    f.push_run(1, 0)       # no-op, like the reference
    f.push_run(1, -3)
    assert f.grammar_rules() == {0: []}
    f.push(1)
    f.push_run(2, 10 ** 9)          # a billion-iteration loop in O(1)
    f.push_run(2, 10 ** 9)          # merges into 2e9 without expansion
    f.push(3)
    rules = f.grammar_rules()
    assert sum(len(b) for b in rules.values()) <= 4
    assert ("t", 2, 2 * 10 ** 9) in rules[0]
    # parity on run sequences that trigger merges and matches
    rng = np.random.RandomState(3)
    for _ in range(50):
        n = rng.randint(0, 40)
        runs = list(zip(rng.randint(0, 3, n).tolist(),
                        rng.randint(1, 9, n).tolist()))
        _check_runs_parity(runs)


def test_rule_utility_inline_chains():
    """Periodic streams drive create-substitute-inline churn every period
    (rules spliced back into their parent) — the flat kernel must replay
    the whole chain identically, including rule-id accounting."""
    for period, reps in (([1, 2, 1, 3], 50), ([1, 2, 3, 4, 1, 2], 30),
                         ([0, 1, 2, 0, 1, 3], 40)):
        _check_parity(period * reps)
        _check_parity(period * reps + period[:2])
    # nested loops: inner rule must survive (exponent > 1 blocks inlining)
    inner = [1, 2] * 5 + [3]
    _check_parity((inner * 8 + [4]) * 6)


def test_negative_terminals_rejected():
    f = Flat()
    with pytest.raises(ValueError):
        f.push(-1)
    with pytest.raises(ValueError):
        f.push_runs([0, -2], [1, 1])


def test_no_silent_reference_fallback():
    """The production wiring must expose the flat kernel — a fallback to
    the reference would silently forfeit the perf tier (CI runs the same
    guard via benchmarks/synthesize_time.py --parity)."""
    assert sequitur.Sequitur.KERNEL == "flat"
    assert sequitur_reference.Sequitur.KERNEL == "reference"
    assert trace_ir.Sequitur is sequitur.Sequitur
    assert sequitur.Sequitur is not sequitur_reference.Sequitur


def test_columns_export():
    f = Flat()
    f.push_ids([0, 1, 0, 1, 0, 1])
    cols = f.columns()
    assert set(cols) == {"sym", "exp", "prev", "next"}
    n = len(cols["sym"])
    assert all(len(c) == n for c in cols.values())
    assert cols["sym"][0] == -2**31        # main guard sentinel
    # live links point inside the pool
    live = cols["next"][cols["next"] >= 0]
    assert live.max(initial=0) < n


def test_rle_runs_helper():
    ids, counts = rle_runs(np.asarray([5, 5, 5, 2, 2, 7], dtype=np.int64))
    assert ids == [5, 2, 7] and counts == [3, 2, 1]
    assert rle_runs(np.zeros(0, dtype=np.int64)) == ([], [])


def test_cached_rules_round_trip_json():
    """GrammarCache persistence must preserve rule-id order and body
    tuples exactly (to_json equality after a save/load round trip)."""
    from repro.core.corpus_store import GrammarCache

    rng = np.random.RandomState(9)
    stream = np.asarray(rng.randint(0, 4, 150), dtype=np.int64)
    f = Flat()
    f.push_ids(stream)
    rules = f.grammar_rules()
    cache = GrammarCache()
    key = cache.key(stream, 0.5)
    cache.put(key, rules)
    import tempfile
    from pathlib import Path
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "grammar_cache.json"
        cache.save(path)
        loaded = GrammarCache.load(path)
    table = TerminalTable()
    assert Grammar(rules=loaded.get(key), table=table).to_json() \
        == Grammar(rules=rules, table=table).to_json()
    assert loaded.hits == 1
    # different threshold -> different key (conservative keying)
    assert cache.key(stream, 0.5) != cache.key(stream, 0.7)


# -- randomized exploration (hypothesis-gated) -------------------------------


if HAVE_HYPOTHESIS:

    @given(st.lists(st.integers(0, 3), max_size=120))
    @settings(max_examples=300, deadline=None)
    def test_parity_property(seq):
        """Core invariant: flat kernel output == reference, any stream."""
        _check_parity(seq)

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 9)),
                    max_size=40))
    @settings(max_examples=200, deadline=None)
    def test_push_run_parity_property(runs):
        """push_run with arbitrary (symbol, count) sequences stays in
        lockstep with the reference."""
        _check_runs_parity(runs)

else:            # keep the gating visible in the test report

    @needs_hypothesis
    def test_parity_property():
        raise AssertionError("unreachable: skipif guards this test")

    @needs_hypothesis
    def test_push_run_parity_property():
        raise AssertionError("unreachable: skipif guards this test")
