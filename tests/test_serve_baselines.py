"""Serving engine + baseline synthesizer tests."""
import numpy as np
import pytest

from repro.configs import get, smoke
from repro.core import blocks as B
from repro.core.baselines import (
    minime_fit, minime_ratios, original_time, scalabench_compress,
    siesta_predicted_time,
)
from repro.core.events import CommEvent, ComputeEvent
from repro.core.proxy_search import fit_combination
from repro.models.model import init_params
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-2.7b",
                                  "whisper-large-v3", "gemma3-4b"])
def test_serve_generate(arch):
    cfg = smoke(get(arch))
    params = init_params(cfg)
    eng = ServeEngine(cfg, params, max_len=32)
    res = eng.generate(np.ones((2, 8), np.int32), 6)
    assert res.tokens.shape == (2, 6)
    assert res.tokens_per_sec > 0
    assert (res.tokens >= 0).all() and (res.tokens < cfg.padded_vocab).all()


def test_serve_prefill_decode_agree():
    """Engine greedy continuation is deterministic across calls."""
    cfg = smoke(get("llama3.2-3b"))
    params = init_params(cfg)
    eng = ServeEngine(cfg, params, max_len=64)
    prompts = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab
    a = eng.generate(prompts, 5).tokens
    b = eng.generate(prompts, 5).tokens
    np.testing.assert_array_equal(a, b)


# -- baselines -----------------------------------------------------------------


def test_minime_single_block_ok_but_worse_than_qp():
    """Paper Fig. 5/6: greedy is usable on one aggregate event but the QP
    dominates on the full 6-metric objective."""
    b = B.calibration_matrix()
    rng = np.random.RandomState(0)
    worse = 0
    for _ in range(10):
        t = b @ rng.randint(10, 300, 11).astype(float)
        g = minime_fit(t)
        q = fit_combination(t)
        g_err = float(np.mean(g.per_metric_rel_err[t > 0]))
        q_err = float(np.mean(q.per_metric_rel_err[t > 0]))
        worse += g_err >= q_err - 1e-9
    assert worse >= 8  # QP at least ties on ≥80% of targets


def test_minime_size_matched_but_ratios_drift():
    """The greedy matches total work but drifts on the ratio mix — the
    failure mode the paper's Fig. 6 highlights (and the QP avoids)."""
    b = B.calibration_matrix()
    t = b @ np.array([50, 10, 40, 5, 3, 8, 2, 1, 6, 9, 140], float)
    g = minime_fit(t)
    ops_t = t[0] + t[1]
    ops_g = g.predicted[0] + g.predicted[1]
    assert abs(ops_g - ops_t) / ops_t < 0.3          # size matched
    q = fit_combination(t)
    rt = minime_ratios(t)
    drift = lambda pred: float(np.mean(np.abs(
        np.log((minime_ratios(pred) + 1e-9) / (rt + 1e-9)))))
    assert drift(q.predicted) < drift(g.predicted)   # QP dominates


def _mk_trace():
    comp = ComputeEvent((5e9, 6e7, 1.5e9, 1e6, 2e5, 1e3))
    comm = CommEvent("psum", (1024, 1024), "float32", ("x",))
    return [comp, comm] * 20


def test_scalabench_portability_failure_vs_siesta():
    """Paper §3.5.4 / Fig. 10-11: when the platform gets 2x slower, the
    sleep-based proxy's predicted time does not move; Siesta's tracks it."""
    trace = _mk_trace()
    sb = scalabench_compress(trace)
    fits = [fit_combination(ev.vector) for ev in trace if not isinstance(ev, CommEvent)]
    combos = [(f.x, f.unroll) for f in fits]
    comm = [e for e in trace if isinstance(e, CommEvent)]

    t_orig_a = original_time(trace, flops_rate_scale=1.0)
    t_orig_b = original_time(trace, flops_rate_scale=0.5)   # platform B: 2x slower
    err = lambda pred, ref: abs(pred - ref) / ref

    sb_a = sb.predicted_time(1.0)
    sb_b = sb.predicted_time(0.5)
    si_a = siesta_predicted_time(combos, comm, 1.0)
    si_b = siesta_predicted_time(combos, comm, 0.5)

    assert err(si_a, t_orig_a) < 0.15
    assert err(si_b, t_orig_b) < 0.15          # Siesta tracks the change
    assert err(sb_b, t_orig_b) > 0.25          # ScalaBench cannot
    assert sb_a == pytest.approx(sb_b, rel=0.35)  # sleeps barely move


def test_scalabench_histogram_is_lossy():
    tr = [CommEvent("psum", (n,), "float32", ("x",)) for n in (100, 120, 260)]
    sb = scalabench_compress(tr)
    # 100 and 120 land in the same log2 bucket -> replayed as the bucket
    # mean: the per-event payload is NOT preserved (Siesta's is, exactly)
    first = sb.bucket_means[sb.op_sequence[0]]
    assert first != tr[0].payload_bytes
