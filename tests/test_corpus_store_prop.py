"""Property tests for the streaming corpus store (sibling ``_prop``
module per repo convention).

The property: **incremental ingest in any scenario order produces the
same cluster reps and δ̄ as one-shot clustering on the union in that
order** — i.e. the :class:`~repro.core.corpus_store.ClusterIndex` is an
exact streaming decomposition of ``cluster_vectors``, for every
permutation of the corpus.

The deterministic half (seeded example corpus + fixed permutations)
always runs; only the hypothesis-randomized exploration skips when
hypothesis is absent.
"""
import itertools

import numpy as np
import pytest

from repro.core.corpus_store import ClusterIndex, CorpusStore
from repro.core.events import CommEvent, ComputeEvent, cluster_vectors
from repro.core.synthesize import synthesize_corpus
from repro.core.trace_ir import TraceStore

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic example corpus in this module still runs")


def _check_order_invariance(scenario_metrics, rel_tol=0.05):
    """The property body, hypothesis-free: streaming ingest of the given
    (name, metrics) sequence equals one-shot clustering of the
    concatenation, bit for bit."""
    idx = ClusterIndex.empty(rel_tol)
    for name, metrics in scenario_metrics:
        idx.ingest(name, metrics)
    chunks = [m for _, m in scenario_metrics if len(m)]
    concat = (np.concatenate(chunks) if chunks else np.zeros((0, 6)))
    want_ids, want_reps = cluster_vectors(concat, rel_tol)
    off = 0
    for name, metrics in scenario_metrics:
        k = len(metrics)
        np.testing.assert_array_equal(idx.assignments(name),
                                      want_ids[off:off + k])
        off += k
    _, reps = idx.derive()
    assert set(reps) == set(want_reps)
    for cid in reps:
        np.testing.assert_array_equal(reps[cid], want_reps[cid])


def _seeded_metrics(seed: int, n: int) -> np.ndarray:
    """Metric rows with deliberate near-duplicates, zero columns, and
    magnitude spread — the cases that stress bucket boundaries."""
    rng = np.random.RandomState(seed)
    base = np.abs(rng.lognormal(8, 4, (max(n, 1), 6)))
    base[rng.rand(*base.shape) < 0.3] = 0.0
    dup = base[rng.randint(0, len(base), len(base) // 2 or 1)]
    out = np.concatenate([base, dup * (1 + 0.01 * rng.randn(*dup.shape))])
    return np.abs(out[:n])


# ---------------------------------------------------------------------------
# deterministic half — always runs
# ---------------------------------------------------------------------------


def test_order_invariance_examples():
    """Every permutation of a 3-scenario seeded corpus streams exactly."""
    parts = [("s0", _seeded_metrics(0, 7)), ("s1", _seeded_metrics(1, 5)),
             ("s2", _seeded_metrics(2, 9))]
    for order in itertools.permutations(parts):
        _check_order_invariance(list(order))


def test_order_invariance_with_empty_and_singleton():
    parts = [("empty", np.zeros((0, 6))), ("one", _seeded_metrics(3, 1)),
             ("many", _seeded_metrics(4, 12))]
    for order in itertools.permutations(parts):
        _check_order_invariance(list(order))


def test_delta_order_invariance_end_to_end(tmp_path):
    """δ̄ half of the property: for two different ingestion orders, the
    incremental corpus δ̄ equals the from-scratch corpus δ̄ on the union
    in that same order."""
    v1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
    v2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
    v3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)
    comm = CommEvent("psum", (8,), "float32", ("x",))

    def _store(vectors):
        tr = []
        for v in vectors:
            tr += [ComputeEvent(tuple(v)), comm]
        return TraceStore.from_rank_traces([list(tr) for _ in range(3)],
                                           {"x": 3})

    stores = {"a": _store([v1, v2]), "b": _store([v2, v3]),
              "c": _store([v3, v1])}
    for i, order in enumerate((("a", "b", "c"), ("c", "a", "b"))):
        cs = CorpusStore(tmp_path / f"corpus{i}")
        for n in order:
            cs.add_scenario(n, stores[n])
        corp_inc = synthesize_corpus(store=cs)
        corp_bat = synthesize_corpus([(n, stores[n]) for n in order])
        for n in order:
            fi = corp_inc.results[n].fidelity(sample_ranks=None)
            fb = corp_bat.results[n].fidelity(sample_ranks=None)
            assert fi.comm_lossless and fb.comm_lossless
            np.testing.assert_array_equal(fi.delta, fb.delta)


# ---------------------------------------------------------------------------
# hypothesis half — randomized exploration of the same property
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(st.lists(st.tuples(st.integers(0, 2 ** 31 - 1),
                              st.integers(0, 16)),
                    min_size=1, max_size=6),
           st.floats(0.01, 0.3))
    @settings(max_examples=60, deadline=None)
    def test_order_invariance_property(parts, rel_tol):
        scenario_metrics = [(f"s{i}", _seeded_metrics(seed, n))
                            for i, (seed, n) in enumerate(parts)]
        _check_order_invariance(scenario_metrics, rel_tol)

else:            # keep the gating visible in the test report

    @needs_hypothesis
    def test_order_invariance_property():
        raise AssertionError("unreachable: skipif guards this test")
