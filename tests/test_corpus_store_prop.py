"""Property tests for the streaming corpus store (sibling ``_prop``
module per repo convention).

The property: **incremental ingest in any scenario order produces the
same cluster reps and δ̄ as one-shot clustering of the scenarios in that
order** — i.e. the :class:`~repro.core.corpus_store.ClusterIndex` is an
exact streaming decomposition of ``cluster_corpus`` (the per-scenario
partial-sums fold), for every permutation of the corpus.  And since the
store's canonical manifest order is a pure function of the scenario set,
two stores ingested in *different* orders converge to bit-identical
state — including after removals.

The deterministic half (seeded example corpus + fixed permutations)
always runs; only the hypothesis-randomized exploration skips when
hypothesis is absent.
"""
import itertools

import numpy as np
import pytest

from repro.core.corpus_store import ClusterIndex, CorpusStore
from repro.core.events import CommEvent, ComputeEvent, cluster_corpus
from repro.core.synthesize import synthesize_corpus
from repro.core.trace_ir import TraceStore

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic example corpus in this module still runs")


def _check_order_invariance(scenario_metrics, rel_tol=0.05):
    """The property body, hypothesis-free: streaming ingest of the given
    (name, metrics) sequence equals one-shot ``cluster_corpus`` of the
    same sequence, bit for bit — then removing the first scenario equals
    one-shot clustering of the survivors (the O(remaining) removal is an
    exact refold, not an approximation)."""
    idx = ClusterIndex.empty(rel_tol)
    for name, metrics in scenario_metrics:
        idx.ingest(name, metrics)
    want_ids, want_reps = cluster_corpus(
        [m for _, m in scenario_metrics], rel_tol)
    for i, (name, _) in enumerate(scenario_metrics):
        np.testing.assert_array_equal(idx.assignments(name), want_ids[i])
    _, reps = idx.derive()
    assert set(reps) == set(want_reps)
    for cid in reps:
        np.testing.assert_array_equal(reps[cid], want_reps[cid])

    if len(scenario_metrics) > 1:
        gone, survivors = scenario_metrics[0], scenario_metrics[1:]
        idx.remove(gone[0])
        want_ids, want_reps = cluster_corpus(
            [m for _, m in survivors], rel_tol)
        for i, (name, _) in enumerate(survivors):
            np.testing.assert_array_equal(idx.assignments(name),
                                          want_ids[i])
        _, reps = idx.derive()
        assert set(reps) == set(want_reps)
        for cid in reps:
            np.testing.assert_array_equal(reps[cid], want_reps[cid])


def _seeded_metrics(seed: int, n: int) -> np.ndarray:
    """Metric rows with deliberate near-duplicates, zero columns, and
    magnitude spread — the cases that stress bucket boundaries."""
    rng = np.random.RandomState(seed)
    base = np.abs(rng.lognormal(8, 4, (max(n, 1), 6)))
    base[rng.rand(*base.shape) < 0.3] = 0.0
    dup = base[rng.randint(0, len(base), len(base) // 2 or 1)]
    out = np.concatenate([base, dup * (1 + 0.01 * rng.randn(*dup.shape))])
    return np.abs(out[:n])


# ---------------------------------------------------------------------------
# deterministic half — always runs
# ---------------------------------------------------------------------------


def test_order_invariance_examples():
    """Every permutation of a 3-scenario seeded corpus streams exactly."""
    parts = [("s0", _seeded_metrics(0, 7)), ("s1", _seeded_metrics(1, 5)),
             ("s2", _seeded_metrics(2, 9))]
    for order in itertools.permutations(parts):
        _check_order_invariance(list(order))


def test_order_invariance_with_empty_and_singleton():
    parts = [("empty", np.zeros((0, 6))), ("one", _seeded_metrics(3, 1)),
             ("many", _seeded_metrics(4, 12))]
    for order in itertools.permutations(parts):
        _check_order_invariance(list(order))


def test_delta_order_invariance_end_to_end(tmp_path):
    """δ̄ half of the property: stores ingested in two different orders
    converge to the same canonical state — each bit-identical to the
    from-scratch corpus on its manifest-order scenario list, and to each
    other."""
    v1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
    v2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
    v3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)
    comm = CommEvent("psum", (8,), "float32", ("x",))

    def _store(vectors):
        tr = []
        for v in vectors:
            tr += [ComputeEvent(tuple(v)), comm]
        return TraceStore.from_rank_traces([list(tr) for _ in range(3)],
                                           {"x": 3})

    stores = {"a": _store([v1, v2]), "b": _store([v2, v3]),
              "c": _store([v3, v1])}
    deltas_per_order = []
    for i, order in enumerate((("a", "b", "c"), ("c", "a", "b"))):
        cs = CorpusStore(tmp_path / f"corpus{i}")
        for n in order:
            cs.add_scenario(n, stores[n])
        corp_inc = synthesize_corpus(store=cs)
        corp_bat = synthesize_corpus([(n, stores[n]) for n in cs.names])
        row = {}
        for n in cs.names:
            fi = corp_inc.results[n].fidelity(sample_ranks=None)
            fb = corp_bat.results[n].fidelity(sample_ranks=None)
            assert fi.comm_lossless and fb.comm_lossless
            np.testing.assert_array_equal(fi.delta, fb.delta)
            row[n] = fi.delta
        deltas_per_order.append(row)
    # ingestion order washes out entirely
    first, second = deltas_per_order
    assert set(first) == set(second)
    for n in first:
        np.testing.assert_array_equal(first[n], second[n])


def test_removal_order_invariance_end_to_end(tmp_path):
    """Append {a,b,c} then remove b: store state (assignments + reps) is
    bit-identical to a store that only ever saw {a,c}."""
    parts = {"a": _seeded_metrics(10, 8), "b": _seeded_metrics(11, 6),
             "c": _seeded_metrics(12, 9)}
    comm = CommEvent("psum", (4,), "float32", ("x",))

    def _store(metrics):
        tr = []
        for v in metrics:
            tr += [ComputeEvent(tuple(v)), comm]
        return TraceStore.from_rank_traces([list(tr), list(tr)], {"x": 2})

    churn = CorpusStore(tmp_path / "churn")
    for n in ("a", "b", "c"):
        churn.add_scenario(n, _store(parts[n]))
    churn.remove_scenario("b")
    clean = CorpusStore(tmp_path / "clean")
    for n in ("a", "c"):
        clean.add_scenario(n, _store(parts[n]))

    assert churn.names == clean.names
    ids_x, reps_x = churn.cluster_assignments()
    ids_y, reps_y = clean.cluster_assignments()
    for n in churn.names:
        np.testing.assert_array_equal(ids_x[n], ids_y[n])
    assert set(reps_x) == set(reps_y)
    for cid in reps_x:
        np.testing.assert_array_equal(reps_x[cid], reps_y[cid])


# ---------------------------------------------------------------------------
# hypothesis half — randomized exploration of the same property
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(st.lists(st.tuples(st.integers(0, 2 ** 31 - 1),
                              st.integers(0, 16)),
                    min_size=1, max_size=6),
           st.floats(0.01, 0.3))
    @settings(max_examples=60, deadline=None)
    def test_order_invariance_property(parts, rel_tol):
        scenario_metrics = [(f"s{i}", _seeded_metrics(seed, n))
                            for i, (seed, n) in enumerate(parts)]
        _check_order_invariance(scenario_metrics, rel_tol)

else:            # keep the gating visible in the test report

    @needs_hypothesis
    def test_order_invariance_property():
        raise AssertionError("unreachable: skipif guards this test")
