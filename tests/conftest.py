"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only tests that need a small mesh spawn with
the forced host device count via the ``mesh_env`` marker / subprocess."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
