"""Codegen + replay tests (paper §2.7, Algorithm 2)."""
import numpy as np

from repro.core.codegen import _fmt_rankset, generate_source
from repro.core.events import CommEvent, ComputeEvent
from repro.core.replay import ProxyProgram, init_replay_state, load_module
from repro.core.synthesize import compress_rank_traces, synthesize
from repro.core.proxy_search import fit_combination


def test_fmt_rankset():
    assert _fmt_rankset(frozenset(range(8)), 8) == "ALL"
    assert _fmt_rankset(frozenset({3}), 8) == "frozenset((3,))"
    assert _fmt_rankset(frozenset({0, 2, 4}), 8) == "frozenset(range(0, 5, 2))"
    assert _fmt_rankset(frozenset({1, 2, 3}), 8) == "frozenset(range(1, 4))"
    assert "frozenset((0, 3, 7,))" == _fmt_rankset(frozenset({0, 3, 7}), 8)
    # regression: a 2-element set is always a literal, never a range —
    # {0, 5} used to render as frozenset(range(0, 6, 5))
    assert _fmt_rankset(frozenset({0, 5}), 8) == "frozenset((0, 5,))"
    assert _fmt_rankset(frozenset({2, 3}), 8) == "frozenset((2, 3,))"


def _mk_traces(n_ranks=4):
    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    comp = ComputeEvent((2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.))
    traces = []
    for r in range(n_ranks):
        tr = [comp, comm, comp, perm] * 6
        if r == 0:
            tr = tr + [comm]  # rank-0 extra event → rank-set branch
        traces.append(tr)
    return traces


def test_generated_source_roundtrip():
    res = synthesize(rank_traces=_mk_traces(), axis_sizes={"x": 4},
                     name="cg_test")
    src = res.source
    assert "def run_rank" in src and "COMM_BUFFERS" in src
    assert "kind='psum'" in src and "('shift', 1)" in src
    mod = res.proxy.module
    # per-rank signature dedupe: rank 0 differs, ranks 1-3 identical
    sigs = {mod.program_signature(r) for r in range(4)}
    assert len(sigs) == 2
    # lossless expansion against original id streams
    fid = res.fidelity()
    assert fid.comm_lossless
    assert fid.mean < 0.02, fid.delta


def test_replay_executes_all_ranks():
    res = synthesize(rank_traces=_mk_traces(), axis_sizes={"x": 4})
    out = res.proxy.run_local()
    assert np.isfinite(np.float32(out["s"]))


def test_rank_metrics_match_combo_prediction():
    """Walker metrics of generated code == sum of fitted combo costs
    (+ comm sequence-point epsilon)."""
    res = synthesize(rank_traces=_mk_traces(), axis_sizes={"x": 4})
    from repro.core import blocks as B
    want = np.zeros(6)
    for (x, u) in res.proxy.combos.values():
        want += 12 * B.combo_cost(x, u)  # each compute terminal runs 12x
    got = res.proxy.rank_metrics(1)
    # comm sequence points add a few vpu/byte ops; tolerance covers them
    assert np.all(np.abs(got - want) / np.maximum(want, 1.0) < 0.05)


def test_solver_auto_crossover_in_synthesize():
    """solver="auto" (the default) resolves by distinct-compute-terminal
    count: exact NNLS below the threshold, batched PGD above it."""
    from repro.core.proxy_search import PGD_TERMINAL_THRESHOLD

    small = synthesize(rank_traces=_mk_traces(), axis_sizes={"x": 4})
    assert small.stats["solver"] == "nnls"

    # one rank, > threshold mutually-distinct compute events (1.5x apart
    # beats the 5% clustering tolerance) → every event is its own terminal
    base = np.array([2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.])
    big_trace = [ComputeEvent(tuple(base * 1.5 ** i))
                 for i in range(PGD_TERMINAL_THRESHOLD + 1)]
    big = synthesize(rank_traces=[big_trace], axis_sizes={})
    assert big.stats["n_unique_terminals"] > PGD_TERMINAL_THRESHOLD
    assert big.stats["solver"] == "pgd"
    # explicit choice still wins
    forced = synthesize(rank_traces=[big_trace[:3]], axis_sizes={},
                        solver="pgd")
    assert forced.stats["solver"] == "pgd"


def test_count_scale():
    res = synthesize(rank_traces=_mk_traces(), axis_sizes={"x": 4},
                     count_scale=0.25)
    full = synthesize(rank_traces=_mk_traces(), axis_sizes={"x": 4})
    m_scaled = res.proxy.rank_metrics(1)
    m_full = full.proxy.rank_metrics(1)
    ratio = m_scaled[0] / max(m_full[0], 1)
    assert 0.15 < ratio < 0.35
