"""Columnar trace IR tests: lossless round trips, .npz artifacts, and
bit-exactness of the columnar front half vs the per-event reference
(paper front half: trace → cluster → grammars → merge)."""
import numpy as np
import pytest

from repro.core import frontend_reference as ref
from repro.core.events import (
    CommEvent, ComputeEvent, cluster_compute_events, cluster_vectors,
)
from repro.core.synthesize import synthesize
from repro.core.trace_ir import TraceStore, compress_store


def _mixed_traces(n_ranks=4):
    """Heterogeneous traces exercising every detail-tuple shape: shift /
    partial shift / explicit perm / canonicalized axis_index_groups,
    plus a pre-clustered compute event."""
    comm = CommEvent("psum", (16,), "float32", ("x",), ("groups", 0))
    shift = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    part = CommEvent("ppermute", (8,), "float32", ("x",),
                     ("shift", 1, (0, 1, 2)))
    perm = CommEvent("ppermute", (2,), "float32", ("x",),
                     ("perm", ((0, 1), (1, 0))))
    comp = ComputeEvent((2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.))
    comp2 = ComputeEvent((4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0))
    pre = ComputeEvent((1e6, 0., 0., 0., 0., 0.), cluster_id=3)
    traces = []
    for r in range(n_ranks):
        tr = [comp, comm, comp2, shift, part] * 4
        if r == 0:
            tr = tr + [perm, pre]
        traces.append(tr)
    return traces


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------


def test_event_list_roundtrip_lossless():
    traces = _mixed_traces()
    st = TraceStore.from_rank_traces(traces, {"x": 4})
    back = st.to_rank_traces()
    assert len(back) == len(traces)
    for a, b in zip(traces, back):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert x == y            # dataclass equality: full field match
            assert x.key() == y.key()


def test_store_shape_accessors():
    st = TraceStore.from_rank_traces(_mixed_traces(), {"x": 4})
    assert st.n_ranks == 4
    assert st.n_events == 4 * 20 + 2
    assert st.n_compute_events + st.n_comm_events == st.n_events
    assert len(st.rank_tokens(0)) == 22
    # comm pool is deduplicated by canonical key
    assert len(st.comm_pool) == 4


def test_raw_trace_bytes_matches_per_event_sum():
    traces = _mixed_traces()
    st = TraceStore.from_rank_traces(traces, {"x": 4})
    want = sum(len(ev.key()) + 1 for tr in traces for ev in tr)
    assert st.raw_trace_bytes() == want


def test_compute_totals_vectorized():
    traces = _mixed_traces()
    st = TraceStore.from_rank_traces(traces, {"x": 4})
    totals = st.compute_totals()
    for r, tr in enumerate(traces):
        want = np.zeros(6)
        for ev in tr:
            if isinstance(ev, ComputeEvent):
                want += ev.vector
        np.testing.assert_array_equal(totals[r], want)


def test_npz_roundtrip_preserves_everything(tmp_path):
    st = TraceStore.from_rank_traces(_mixed_traces(), {"x": 4})
    p = st.save(tmp_path / "trace")
    assert p.suffix == ".npz"
    st2 = TraceStore.load(p)
    assert np.array_equal(st.tokens, st2.tokens)
    assert np.array_equal(st.extents, st2.extents)
    assert np.array_equal(st.metrics, st2.metrics)
    assert np.array_equal(st.cluster_ids, st2.cluster_ids)
    assert st2.axis_sizes == {"x": 4}
    assert [e for e in st.comm_pool] == [e for e in st2.comm_pool]
    # events (incl. detail tuples and pre-assigned cluster ids) survive
    for a, b in zip(st.to_rank_traces(), st2.to_rank_traces()):
        assert a == b


def test_npz_roundtrip_preserves_grammars_and_fidelity(tmp_path):
    st = TraceStore.from_rank_traces(_mixed_traces(), {"x": 4})
    res = synthesize(store=st, name="orig")
    st2 = TraceStore.load(st.save(tmp_path / "trace"))
    res2 = synthesize(store=st2, name="reloaded")
    assert res.merged.rules == res2.merged.rules
    assert res.merged.mains == res2.merged.mains
    assert [e.key() for e in res.merged.table.events] == \
        [e.key() for e in res2.merged.table.events]
    assert res.stats["compression_ratio"] == res2.stats["compression_ratio"]
    f1, f2 = res.fidelity(), res2.fidelity()
    assert f1.comm_lossless and f2.comm_lossless
    np.testing.assert_array_equal(f1.delta, f2.delta)


def test_npz_version_mismatch_rejected(tmp_path):
    import json

    st = TraceStore.from_rank_traces(_mixed_traces(), {"x": 4})
    p = st.save(tmp_path / "trace")
    z = dict(np.load(p))
    z["meta"] = np.asarray(json.dumps({"version": 999, "axis_sizes": {}}))
    with open(p, "wb") as f:
        np.savez(f, **z)
    with pytest.raises(ValueError, match="version"):
        TraceStore.load(p)


def test_fidelity_store_backed_matches_event_lists():
    """SynthesisResult.fidelity reads the columnar store; the numbers are
    bit-identical to feeding materialized event lists."""
    res = synthesize(rank_traces=_mixed_traces(), axis_sizes={"x": 4},
                     name="fidsrc")
    keys = [[g.table[i].key() for i in ids]
            for g, ids in zip(res.grammars, res.rank_ids)]
    f_store = res.fidelity(sample_ranks=None)
    f_lists = res.proxy.fidelity(res.store.to_rank_traces(), keys,
                                 sample_ranks=None)
    np.testing.assert_array_equal(f_store.delta, f_lists.delta)
    assert f_store.comm_lossless == f_lists.comm_lossless


def test_saved_proxy_module_reloads(tmp_path):
    from repro.core.replay import load_saved_module

    res = synthesize(rank_traces=_mixed_traces(), axis_sizes={"x": 4},
                     name="persist", out_dir=tmp_path)
    mod = load_saved_module(res.proxy.module.__proxy_path__, "persist_again")
    assert mod.SIGNATURE_GROUPS == res.proxy.module.SIGNATURE_GROUPS
    assert mod.N_RANKS == 4
    st = mod.run_rank.__globals__  # sanity: executable module namespace
    assert "run_rank" in st


# ---------------------------------------------------------------------------
# bit-exactness vs the per-event reference
# ---------------------------------------------------------------------------


def test_cluster_vectors_matches_reference():
    rng = np.random.RandomState(0)
    evs = [ComputeEvent(tuple(v)) for v in
           np.abs(rng.lognormal(10, 3, (300, 6)))]
    # salt in near-duplicates and zero metrics
    evs += [ComputeEvent((1e9, 1e6, 1e8, 0., 0., 0.)),
            ComputeEvent((1.02e9, 1.01e6, 1.01e8, 0., 0., 0.))] * 5
    out_ref, reps_ref = ref.cluster_compute_events_reference(evs)
    out_new, reps_new = cluster_compute_events(evs)
    assert [e.cluster_id for e in out_new] == [e.cluster_id for e in out_ref]
    assert set(reps_new) == set(reps_ref)
    for k in reps_new:
        np.testing.assert_array_equal(reps_new[k], reps_ref[k])
    # array front-end agrees with the event front-end
    ids, reps2 = cluster_vectors(np.stack([e.vector for e in evs]))
    assert ids.tolist() == [e.cluster_id for e in out_new]


def test_compress_store_bit_identical_to_reference():
    traces = _mixed_traces()
    g2, m2, ids2, reps2 = ref.compress_rank_traces_reference(traces)
    st = TraceStore.from_rank_traces(traces, {"x": 4})
    g1, m1, ids1, reps1 = compress_store(st)
    assert ids1 == ids2
    assert [g.rules for g in g1] == [g.rules for g in g2]
    assert [[e.key() for e in g.table.events] for g in g1] == \
        [[e.key() for e in g.table.events] for g in g2]
    assert m1.rules == m2.rules
    assert m1.mains == m2.mains
    assert m1.cluster_ranks == m2.cluster_ranks
    assert [e.key() for e in m1.table.events] == \
        [e.key() for e in m2.table.events]
    for k in reps1:
        np.testing.assert_array_equal(reps1[k], reps2[k])


def test_synthesize_bit_identical_to_reference_pipeline():
    """Acceptance pin: grammar rules, terminal keys, compression ratio and
    δ̄ through the columnar path equal the pre-refactor per-event pipeline."""
    traces = _mixed_traces()
    res = synthesize(rank_traces=traces, axis_sizes={"x": 4}, name="parity")
    g2, m2, ids2, _ = ref.compress_rank_traces_reference(traces)
    assert res.rank_ids == ids2
    assert res.merged.rules == m2.rules and res.merged.mains == m2.mains
    assert [e.key() for e in res.merged.table.events] == \
        [e.key() for e in m2.table.events]
    want_bytes = sum(len(ev.key()) + 1 for tr in traces for ev in tr)
    assert res.stats["trace_bytes"] == want_bytes
    assert res.stats["compression_ratio"] == \
        want_bytes / m2.encoded_size_bytes()
    fid = res.fidelity()
    assert fid.comm_lossless


def test_signature_dedup_shares_grammar_objects():
    """SPMD ranks with byte-identical streams share one Sequitur run."""
    traces = _mixed_traces(n_ranks=8)
    st = TraceStore.from_rank_traces(traces, {"x": 8})
    g, m, ids, _ = compress_store(st)
    assert g[1] is g[2] and g[2] is g[7]      # identical ranks share
    assert g[0] is not g[1]                   # heterogeneous rank 0 does not
    # sharing is invisible in the output: the merged program still expands
    # to each rank's exact event-id sequence (losslessness invariant)
    for r in range(8):
        got = [m.table[i].key() for i in m.expand_rank(r)]
        want = [g[r].table[i].key() for i in ids[r]]
        assert got == want
        assert len(got) == len(traces[r])


def _random_traces(seed: int, n_ranks: int = 5):
    """Seeded fuzz traces: lognormal metric spreads with zero columns and
    near-duplicates, every comm kind/detail shape, per-rank stream
    heterogeneity — the drift surface the fixed fixtures don't cover."""
    rng = np.random.RandomState(seed)
    kinds = [("psum", ()), ("all_gather", (0,)), ("reduce_scatter", (0,)),
             ("all_to_all", (0, 0)), ("pmax", ()), ("pmin", ()),
             ("broadcast", (0,)),
             ("ppermute", ("shift", 1)),
             ("ppermute", ("shift", 2, (0, 1, 2))),
             ("ppermute", ("perm", ((0, 1), (1, 0))))]
    comms = []
    for _ in range(rng.randint(2, 7)):
        kind, detail = kinds[rng.randint(len(kinds))]
        shape = tuple(int(s) for s in rng.randint(1, 9,
                                                  rng.randint(1, 4)))
        dtype = ["float32", "bfloat16", "int32"][rng.randint(3)]
        comms.append(CommEvent(kind, shape, dtype, ("x",), detail))

    def compute():
        v = np.abs(rng.lognormal(8, 4, 6))
        v[rng.rand(6) < 0.35] = 0.0
        if rng.rand() < 0.3:              # near-duplicate pressure
            v = v * (1 + 0.01 * rng.randn(6))
        return ComputeEvent(tuple(np.abs(v)))

    traces = []
    for r in range(n_ranks):
        tr = []
        for _ in range(rng.randint(3, 40)):
            if rng.rand() < 0.45:
                tr.append(comms[rng.randint(len(comms))])
            else:
                tr.append(compute())
        if rng.rand() < 0.5:              # byte-identical SPMD siblings
            traces.append(list(tr))
        traces.append(tr)
    return traces[:n_ranks]


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 7, 11, 23, 42])
def test_compress_store_matches_reference_randomized(seed):
    """Drift oracle under seeded fuzz (not just the fixed fixtures): the
    columnar front half must stay byte-identical to the preserved
    per-event reference on arbitrary metric/comm streams."""
    traces = _random_traces(seed)
    g2, m2, ids2, reps2 = ref.compress_rank_traces_reference(traces)
    st = TraceStore.from_rank_traces(traces, {"x": len(traces)})
    g1, m1, ids1, reps1 = compress_store(st)
    assert ids1 == ids2
    assert [g.rules for g in g1] == [g.rules for g in g2]
    assert [[e.key() for e in g.table.events] for g in g1] == \
        [[e.key() for e in g.table.events] for g in g2]
    assert m1.rules == m2.rules
    assert m1.mains == m2.mains
    assert m1.cluster_ranks == m2.cluster_ranks
    assert [e.key() for e in m1.table.events] == \
        [e.key() for e in m2.table.events]
    assert set(reps1) == set(reps2)
    for k in reps1:
        np.testing.assert_array_equal(reps1[k], reps2[k])
    # size accounting rides the same streams: keep it drift-pinned too
    want_bytes = sum(len(ev.key()) + 1 for tr in traces for ev in tr)
    assert st.raw_trace_bytes() == want_bytes


def test_from_template_equals_per_rank_ingestion():
    """Template specialization (rawperm participation classes) produces the
    identical store as materializing per-rank traces first."""
    from repro.core.tracer import Trace, per_rank_traces

    comp = ComputeEvent((1e6, 2e3, 5e5, 0., 0., 0.))
    full = CommEvent("ppermute", (4,), "float32", ("x",),
                     ("rawperm", tuple((i, (i + 1) % 4) for i in range(4))))
    partial = CommEvent("ppermute", (4,), "float32", ("x",),
                        ("rawperm", ((0, 1), (1, 2), (2, 3))))
    red = CommEvent("psum", (8,), "float32", ("x",))
    template = Trace([comp, full, comp, partial, red], {"x": 4})

    st_t = TraceStore.from_template(template)
    st_r = TraceStore.from_rank_traces(per_rank_traces(template), {"x": 4})
    assert np.array_equal(st_t.tokens, st_r.tokens)
    assert np.array_equal(st_t.extents, st_r.extents)
    assert np.array_equal(st_t.metrics, st_r.metrics)
    assert np.array_equal(st_t.cluster_ids, st_r.cluster_ids)
    assert [e.key() for e in st_t.comm_pool] == \
        [e.key() for e in st_r.comm_pool]
    # rank 3 is not a source in the partial halo but is a destination;
    # rank 0 sends only — both participate; the store keeps that exact
    assert st_t.rank_events(0) == per_rank_traces(template)[0]


def test_compress_store_rejects_ids_without_reps():
    st = TraceStore.from_rank_traces(_mixed_traces(), {"x": 4})
    with pytest.raises(ValueError):
        compress_store(st, cluster_ids=np.zeros(st.n_compute_events,
                                                dtype=np.int64))


def test_rank_events_gather_matches_per_token_decode():
    """The interned-key gather in rank_events must reproduce the naive
    per-token decode exactly (value-equal ComputeEvents may alias one
    instance — events are frozen)."""
    st = TraceStore.from_rank_traces(_mixed_traces(), {"x": 4})
    for r in range(st.n_ranks):
        got = st.rank_events(r)
        want = []
        for t in st.rank_tokens(r).tolist():
            if t < 0:
                want.append(st.comm_pool[-t - 1])
            else:
                want.append(ComputeEvent(tuple(st.metrics[t].tolist()),
                                         cluster_id=int(st.cluster_ids[t])))
        assert got == want
    # SPMD-tiled rows intern by value: identical template events share
    # one instance across ranks
    e0 = st.rank_events(1)[0]
    e1 = st.rank_events(2)[0]
    assert e0 is e1


def test_compress_store_profile_counters():
    st = TraceStore.from_rank_traces(_mixed_traces(), {"x": 4})
    profile = {}
    compress_store(st, profile=profile)
    assert profile["n_distinct_streams"] == 2      # rank 0 vs ranks 1-3
    assert profile["n_sequitur_runs"] == 2
    assert profile["grammar_cache_hits"] == 0      # no cache passed
    for k in ("cluster_ms", "intern_ms", "grammar_ms", "merge_ms"):
        assert profile[k] >= 0.0
    # profile accumulates across calls (one dict for a whole corpus)
    compress_store(st, profile=profile)
    assert profile["n_sequitur_runs"] == 4
