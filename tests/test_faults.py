"""Chaos tests: deterministic fault injection over every store
touchpoint, fsck/quarantine semantics, per-item ingest isolation, lock
timeout diagnostics, and degraded-mode serving.

The write-site sweep here is the kill-mid-write test generalized over
**every** atomic-write site via the registered fault points
(:data:`repro.core.faults.FAULT_POINTS`); ``benchmarks/chaos.py`` runs
the same enumeration with the full δ̄-parity oracle."""
import json
import multiprocessing as mp

import numpy as np
import pytest

from repro.core import faults
from repro.core.corpus_store import (
    CorpusStore, IngestBatchError, LockTimeoutError, ScenarioCorruptError,
    ShardCorruptError, _file_lock,
)
from repro.core.events import CommEvent, ComputeEvent
from repro.core.trace_ir import TraceStore

_V1 = (2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.)
_V2 = (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0)
_V3 = (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)


def _store(vectors, comm_axis="x", n_ranks=4):
    comm = CommEvent("psum", (8,), "float32", (comm_axis,))
    tr = []
    for v in vectors:
        tr += [ComputeEvent(tuple(v)), comm]
    return TraceStore.from_rank_traces([list(tr) for _ in range(n_ranks)],
                                       {comm_axis: n_ranks})


def _zoo3():
    return {"a": _store([_V1, _V2]), "b": _store([_V1, _V3]),
            "c": _store([_V2, _V3])}


def _seeded(tmp_path, names=("a", "b")):
    cs = CorpusStore(tmp_path / "corpus")
    zoo = _zoo3()
    for n in names:
        cs.add_scenario(n, zoo[n])
    return cs


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.clear_plan()


# ---------------------------------------------------------------------------
# the fault layer itself
# ---------------------------------------------------------------------------


def test_registry_covers_all_kinds():
    pts = faults.registered_points()
    assert len(pts) == len(set(pts)) >= 13
    for p in pts:
        for k in faults.FAULT_POINTS[p]:
            assert k in faults.FAULT_KINDS


def test_spec_rejects_unregistered():
    with pytest.raises(ValueError, match="unregistered"):
        faults.FaultSpec("write.nonsense", "crash_before")
    with pytest.raises(ValueError, match="not supported"):
        faults.FaultSpec("read.shard", "torn_write")


def test_inert_without_plan():
    assert faults.current_plan() is None
    assert faults.arm("write.shard", "anything") is None
    faults.crash_point("read.shard", "anything")     # no-op


def test_plan_random_is_seed_deterministic():
    a = faults.FaultPlan.random(seed=7, n_faults=5)
    b = faults.FaultPlan.random(seed=7, n_faults=5)
    assert ([(s.point, s.kind, s.skip) for s in a.specs]
            == [(s.point, s.kind, s.skip) for s in b.specs])
    c = faults.FaultPlan.random(seed=8, n_faults=5)
    assert ([(s.point, s.kind) for s in a.specs]
            != [(s.point, s.kind) for s in c.specs]) or a.seed != c.seed


def test_match_skip_and_count_semantics():
    plan = faults.FaultPlan([faults.FaultSpec(
        "write.shard", "crash_before", match="shard-03", skip=1, count=1)])
    with faults.active_plan(plan):
        assert faults.arm("write.shard", "shard-01.json") is None  # no match
        assert faults.arm("write.shard", "shard-03.json") is None  # skipped
        with pytest.raises(faults.InjectedCrash):
            faults.arm("write.shard", "shard-03.json")             # fires
        assert faults.arm("write.shard", "shard-03.json") is None  # burnt out
    assert plan.fired == [("write.shard", "crash_before", "shard-03.json")]


def test_injected_crash_not_swallowed_by_except_exception():
    with pytest.raises(faults.InjectedCrash):
        try:
            raise faults.InjectedCrash("write.shard")
        except Exception:                            # the self-heal pattern
            pytest.fail("InjectedCrash must not be catchable as Exception")


# ---------------------------------------------------------------------------
# kill-mid-write, parameterized over EVERY atomic-write site
# ---------------------------------------------------------------------------

#: how to drive one write at each site on a 2-scenario store
def _trigger(cs, site):
    if site in ("write.scenario_npz", "write.sidecar", "write.shard",
                "write.index", "write.manifest"):
        cs.add_scenario("c", _zoo3()["c"])
        if site == "write.manifest":
            cs.save_fits(table_fingerprint="chaos")  # manifest rewrite
    elif site == "write.fit_cache":
        from types import SimpleNamespace
        cs.fits.put("k", SimpleNamespace(
            x=np.arange(11), predicted=np.zeros(6), target=np.zeros(6),
            residual=0.0, per_metric_rel_err=np.zeros(6), unroll=1))
        cs.save_fits()
    elif site == "write.grammar_cache":
        cs.grammars.put("k", {0: [("t", 1, 2)]})
        cs.save_grammars()
    else:                                            # pragma: no cover
        raise AssertionError(site)


_WRITE_SITES = [p for p in faults.registered_points()
                if p.startswith("write.")]


@pytest.mark.parametrize("kind", ["crash_before", "crash_after",
                                  "torn_write"])
@pytest.mark.parametrize("site", _WRITE_SITES)
def test_kill_mid_write_every_site(tmp_path, site, kind):
    cs = _seeded(tmp_path)
    baseline = cs.names
    plan = faults.FaultPlan.crash_at(site, kind)
    with faults.active_plan(plan):
        with pytest.raises(faults.InjectedCrash):
            _trigger(cs, site)
    assert plan.fired, f"fault at {site} never fired"

    # reopen from disk as a crashed process's successor would
    cs2 = CorpusStore(tmp_path / "corpus")
    rep = cs2.verify()
    if not rep.clean:
        cs2.repair()
        assert cs2.verify().clean, cs2.verify().summary()
    # survivors are a subset of {baseline + c}; every survivor loads
    assert set(baseline) <= set(cs2.names) | set(rep.fatal_names)
    for n in cs2.names:
        st = cs2.load_scenario(n)
        assert st.content_hash() == cs2.content_hash(n)
    # index coherent with the manifest view
    assert cs2.index.order == cs2.names


@pytest.mark.parametrize("site", _WRITE_SITES)
def test_eio_mid_write_surfaces_and_store_survives(tmp_path, site):
    cs = _seeded(tmp_path)
    with faults.active_plan(faults.FaultPlan.crash_at(site, "io_error")):
        with pytest.raises(OSError):
            _trigger(cs, site)
    cs2 = CorpusStore(tmp_path / "corpus")
    rep = cs2.verify()
    if not rep.clean:
        cs2.repair()
        assert cs2.verify().clean
    assert set(cs2.names) >= {"a", "b"} - set(rep.fatal_names)


# ---------------------------------------------------------------------------
# typed corruption errors (satellite: truncated npz regression)
# ---------------------------------------------------------------------------


def test_truncated_scenario_npz_is_typed(tmp_path):
    cs = _seeded(tmp_path)
    p = cs.scenario_path("a")
    p.write_bytes(p.read_bytes()[:48])
    cs._stores.clear()                       # force the disk read
    with pytest.raises(ScenarioCorruptError) as ei:
        cs.load_scenario("a")
    assert ei.value.name == "a"
    assert str(p) == ei.value.path
    assert isinstance(ei.value.cause, Exception)
    assert "repair" in str(ei.value)


def test_truncated_npz_poisons_synthesis_with_typed_error(tmp_path):
    from repro.core.synthesize import synthesize_corpus
    cs = _seeded(tmp_path)
    p = cs.scenario_path("b")
    p.write_bytes(p.read_bytes()[:48])
    cs._stores.clear()
    cs.memo.clear()
    with pytest.raises(ScenarioCorruptError):
        synthesize_corpus(store=cs)


def test_read_eio_becomes_scenario_corrupt(tmp_path):
    cs = _seeded(tmp_path)
    cs._stores.clear()
    plan = faults.FaultPlan([faults.FaultSpec("read.scenario_npz",
                                              "io_error")])
    with faults.active_plan(plan):
        with pytest.raises(ScenarioCorruptError):
            cs._metrics_of("a")


def test_torn_shard_recorded_not_raised_at_open(tmp_path):
    cs = _seeded(tmp_path)
    shard = next(s for s in (tmp_path / "corpus" / "shards").iterdir()
                 if len(json.loads(s.read_text())["entries"]))
    shard.write_bytes(shard.read_bytes()[:20])
    cs2 = CorpusStore(tmp_path / "corpus")       # opens, does not raise
    assert cs2.shard_errors
    err = next(iter(cs2.shard_errors.values()))
    assert isinstance(err, ShardCorruptError)
    from repro.core.synthesize import synthesize_corpus
    with pytest.raises(ShardCorruptError):       # but synthesis refuses
        synthesize_corpus(store=cs2)
    cs2.repair()
    assert not cs2.shard_errors
    assert set(cs2.names) == {"a", "b"}
    assert cs2.verify().clean


def test_torn_manifest_header_recovers_from_meta_twin(tmp_path):
    cs = _seeded(tmp_path)
    n_shards = cs.n_shards
    (tmp_path / "corpus" / "manifest.json").write_bytes(b'{"version": 2,')
    cs2 = CorpusStore(tmp_path / "corpus")
    assert cs2.n_shards == n_shards
    assert set(cs2.names) == {"a", "b"}
    assert cs2.verify().clean


# ---------------------------------------------------------------------------
# verify / repair / quarantine
# ---------------------------------------------------------------------------


def test_verify_clean_on_healthy_store(tmp_path):
    cs = _seeded(tmp_path, names=("a", "b", "c"))
    rep = cs.verify()
    assert rep.clean and rep.n_scenarios == 3
    assert "clean" in rep.summary()


def test_verify_finds_hash_mismatch(tmp_path):
    cs = _seeded(tmp_path)
    other = _zoo3()["c"]
    other.save(cs.scenario_path("a"))            # wrong content, loads fine
    cs._stores.clear()
    rep = cs.verify()
    assert [i.kind for i in rep.fatal] == ["hash_mismatch"]
    assert rep.fatal_names == ["a"]


def test_verify_shallow_skips_payloads(tmp_path):
    cs = _seeded(tmp_path)
    p = cs.scenario_path("a")
    p.write_bytes(p.read_bytes()[:48])
    rep = cs.verify(deep=False)
    assert rep.clean                              # existence checks only
    assert not cs.verify(deep=True).clean


def test_repair_quarantines_and_restores_parity(tmp_path):
    from repro.core.synthesize import synthesize_corpus
    cs = _seeded(tmp_path, names=("a", "b", "c"))
    p = cs.scenario_path("b")
    p.write_bytes(p.read_bytes()[:48])
    cs._sidecar_path("b").unlink()               # double fault
    cs._stores.clear()
    cs.memo.clear()

    rr = cs.repair()
    assert rr.quarantined == ["b"]
    assert cs.verify().clean
    assert set(cs.names) == {"a", "c"}
    q = cs.quarantine_dir()
    assert (q / "b.npz").exists()
    record = json.loads((q / "b.json").read_text())
    assert record["name"] == "b"

    # the oracle: post-repair δ̄ bit-identical to from-scratch synthesis
    # of the survivors
    corp = synthesize_corpus(store=cs)
    fresh = CorpusStore(tmp_path / "fresh")
    zoo = _zoo3()
    for n in cs.names:
        fresh.add_scenario(n, zoo[n])
    corp2 = synthesize_corpus(store=fresh)
    for n in cs.names:
        ri, rb = corp.results[n], corp2.results[n]
        assert ri.merged.rules == rb.merged.rules
        fi = ri.fidelity(sample_ranks=None)
        fb = rb.fidelity(sample_ranks=None)
        np.testing.assert_array_equal(fi.delta, fb.delta)


def test_repair_heals_corrupt_sidecar_without_quarantine(tmp_path):
    cs = _seeded(tmp_path)
    sp = cs._sidecar_path("a")
    sp.write_bytes(b"garbage")
    (tmp_path / "corpus" / "cluster_index.npz").unlink()
    cs2 = CorpusStore(tmp_path / "corpus")       # heals from metrics
    assert set(cs2.names) == {"a", "b"}
    assert not cs2.damaged
    assert cs2.verify().clean


def test_repair_heals_corrupt_caches(tmp_path):
    cs = _seeded(tmp_path)
    (tmp_path / "corpus" / "grammar_cache.json").write_text("{nope")
    from types import SimpleNamespace
    cs.fits.put("k", SimpleNamespace(
        x=np.arange(11), predicted=np.zeros(6), target=np.zeros(6),
        residual=0.0, per_metric_rel_err=np.zeros(6), unroll=1))
    cs.save_fits()
    fpath = tmp_path / "corpus" / "fit_cache.npz"
    fpath.write_bytes(fpath.read_bytes()[:30])
    rep = cs.verify()
    assert {i.kind for i in rep.issues} == {"cache_corrupt"}
    cs.repair()
    assert cs.verify().clean
    assert len(cs.fits) == 0


# ---------------------------------------------------------------------------
# lock retry / timeout
# ---------------------------------------------------------------------------


def test_slow_lock_retries_through_contention(tmp_path):
    plan = faults.FaultPlan([faults.FaultSpec("lock.acquire", "slow_lock",
                                              count=3)])
    with faults.active_plan(plan):
        with _file_lock(tmp_path / "x.lock", timeout=5.0):
            pass
    assert len(plan.fired) == 3                  # contended thrice, then won


def test_lock_timeout_diagnostic(tmp_path):
    plan = faults.FaultPlan([faults.FaultSpec("lock.acquire", "slow_lock",
                                              count=10_000)])
    with faults.active_plan(plan):
        with pytest.raises(LockTimeoutError) as ei:
            with _file_lock(tmp_path / "x.lock", timeout=0.05):
                pass
    assert ei.value.attempts > 1
    assert "x.lock" in str(ei.value)


# ---------------------------------------------------------------------------
# per-item ingest isolation (satellite: BrokenProcessPool fallback)
# ---------------------------------------------------------------------------


def _fork_available():
    return "fork" in mp.get_all_start_methods()


@pytest.mark.skipif(not _fork_available(), reason="needs fork start method")
def test_worker_death_falls_back_to_serial(tmp_path):
    cs = CorpusStore(tmp_path / "corpus")
    zoo = _zoo3()
    # the poisoned item OOM-kills its fork worker (os._exit) -> a real
    # BrokenProcessPool; the parent's serial retry must land all items
    plan = faults.FaultPlan([faults.FaultSpec(
        "worker.ingest", "worker_death", match="b")])
    with faults.active_plan(plan):
        hashes = cs.add_scenarios(sorted(zoo.items()), n_workers=2)
    assert set(hashes) == {"a", "b", "c"}
    assert cs.stats["n_pool_breaks"] >= 1
    assert cs.stats["n_serial_retries"] >= 1
    assert set(cs.names) == {"a", "b", "c"}
    assert cs.verify().clean


def test_one_bad_item_costs_only_itself(tmp_path):
    cs = CorpusStore(tmp_path / "corpus")
    zoo = _zoo3()
    bad = tmp_path / "nope.npz"
    bad.write_bytes(b"not an npz")
    items = [("a", zoo["a"]), ("bad", str(bad)), ("c", zoo["c"])]
    with pytest.raises(IngestBatchError) as ei:
        cs.add_scenarios(items)
    err = ei.value
    assert set(err.hashes) == {"a", "c"}         # survivors committed
    assert [e.name for e in err.errors] == ["bad"]
    assert err.errors[0].retried
    assert set(cs.names) == {"a", "c"}
    assert cs.stats["n_ingest_errors"] == 1
    assert cs.verify().clean


@pytest.mark.skipif(not _fork_available(), reason="needs fork start method")
def test_pool_bad_item_isolated_and_retried(tmp_path):
    cs = CorpusStore(tmp_path / "corpus")
    zoo = _zoo3()
    bad = tmp_path / "nope.npz"
    bad.write_bytes(b"not an npz")
    items = [("a", zoo["a"]), ("bad", str(bad)), ("c", zoo["c"])]
    with pytest.raises(IngestBatchError) as ei:
        cs.add_scenarios(items, n_workers=2)
    assert set(ei.value.hashes) == {"a", "c"}
    assert set(cs.names) == {"a", "c"}
    assert cs.verify().clean


# ---------------------------------------------------------------------------
# inertness + coverage: every registered point is actually threaded
# ---------------------------------------------------------------------------


def test_store_lifecycle_hits_every_fault_point(tmp_path):
    """An empty plan records every consultation; a registered point the
    store never consults is dead registry weight (and a hole in the
    chaos sweep's coverage)."""
    from types import SimpleNamespace
    plan = faults.FaultPlan([])
    with faults.active_plan(plan):
        cs = CorpusStore(tmp_path / "corpus")
        zoo = _zoo3()
        cs.add_scenarios(sorted(zoo.items())[:2], n_workers=2
                         if _fork_available() else 0)
        cs.add_scenario("c", zoo["c"])
        cs.save_fits(table_fingerprint="cov")
        cs.fits.put("k", SimpleNamespace(
            x=np.arange(11), predicted=np.zeros(6), target=np.zeros(6),
            residual=0.0, per_metric_rel_err=np.zeros(6), unroll=1))
        cs.save_fits()
        cs.grammars.put("k", {0: [("t", 1, 2)]})
        cs.save_grammars()
        # reads: shard + index at reopen; sidecar + scenario via eviction
        (tmp_path / "corpus" / "cluster_index.npz").unlink()
        cs2 = CorpusStore(tmp_path / "corpus")
        cs2._stores.clear()
        cs2.load_scenario("a")
        (tmp_path / "corpus" / "cluster_index.npz").unlink()
        CorpusStore(tmp_path / "corpus")          # sidecar-driven rebuild
        ip = tmp_path / "corpus" / "cluster_index.npz"
        from repro.core.corpus_store import ClusterIndex
        ClusterIndex.load(ip, expected_rel_tol=cs.rel_tol)
    hit = {p for p, _ in plan.hits}
    missing = set(faults.registered_points()) - hit
    assert not missing, f"points never consulted: {sorted(missing)}"
    assert not plan.fired                         # empty plan fires nothing


# ---------------------------------------------------------------------------
# degraded-mode serving
# ---------------------------------------------------------------------------


def _svc(tmp_path):
    from repro.serve.proxy_service import ProxyService
    cs = _seeded(tmp_path, names=("a", "b", "c"))
    return cs, ProxyService(cs, out_dir=tmp_path / "modules")


def test_degraded_serving_keeps_answering_and_recovers(tmp_path):
    """The acceptance loop: induce a refresh failure (corrupt scenario
    behind a legitimate mutation), keep answering from the last-good
    snapshot with ``degraded=True`` and the culprit excluded from
    matching, then repair the store and pin the recovered state
    bit-identical to a rebuilt service."""
    from repro.serve.proxy_service import ProxyService
    cs, svc = _svc(tmp_path)
    assert svc.health()["status"] == "ok"

    p = cs.scenario_path("a")
    p.write_bytes(p.read_bytes()[:48])
    cs._stores.clear()                    # force refresh to read the disk
    cs.memo.clear()
    cs.remove_scenario("b")               # legit mutation -> stale bit

    ans = svc.query(_store([_V2, _V3]))   # refresh fails; last-good serves
    assert ans.name == "c"
    assert svc.stats["degraded"] is True
    assert svc.stats["n_degraded_refreshes"] == 1
    h = svc.health()
    assert h["status"] == "degraded"
    assert "ScenarioCorruptError" in h["cause"]
    assert h["excluded_scenarios"] == 1

    # the damaged scenario is excluded from matching: its own trace must
    # answer with some healthy scenario, never "a"
    assert svc.query(_store([_V1, _V2])).name != "a"
    # no retry storm: the store hasn't changed, so further queries do
    # not re-attempt the refresh
    assert svc.stats["n_degraded_refreshes"] == 1

    rr = cs.repair()                      # quarantine -> notify -> retry
    assert rr.quarantined == ["a"]
    ans2 = svc.query(_store([_V2, _V3]))
    assert ans2.name == "c"
    assert svc.stats["degraded"] is False
    assert svc.health()["status"] == "ok"
    assert svc.stats["n_warm_synthesis"] == 1      # never re-warmed

    rebuilt = ProxyService(cs, out_dir=tmp_path / "modules")
    assert svc._names == rebuilt._names
    for n in rebuilt._names:
        assert np.array_equal(svc.embedding(n), rebuilt.embedding(n))
    q1, q2 = svc.query(_store([_V2, _V3])), rebuilt.query(_store([_V2, _V3]))
    assert (q1.name, q1.distance, q1.distances) == (q2.name, q2.distance,
                                                    q2.distances)
    svc.close(), rebuilt.close()


def test_degraded_on_generic_synthesis_failure(tmp_path, monkeypatch):
    """Degraded mode is not specific to corruption: any refresh
    exception keeps the last-good snapshot serving (with nothing
    excluded when no scenario is implicated)."""
    cs, svc = _svc(tmp_path)

    def _boom(*a, **k):
        raise RuntimeError("induced synthesis failure")

    import repro.core.synthesize as synth_mod
    monkeypatch.setattr(synth_mod, "synthesize_corpus", _boom)
    cs.remove_scenario("b")
    ans = svc.query(_store([_V2, _V3]))
    assert ans.name == "c"
    assert svc.stats["degraded"] is True
    assert svc.health()["excluded_scenarios"] == 0
    assert "RuntimeError" in svc.health()["cause"]

    monkeypatch.undo()                    # "transient" failure clears
    cs.add_scenario("d", _store([_V1, _V3], comm_axis="y"))
    svc.query(_store([_V2, _V3]))
    assert svc.stats["degraded"] is False
    assert svc.health()["status"] == "ok"
    svc.close()
