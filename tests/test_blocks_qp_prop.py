"""Hypothesis property tests for the QP block-combination search.

Split from test_blocks_qp.py so the plain unit tests there always run;
this module (alone) skips when hypothesis is absent."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import blocks as B
from repro.core.proxy_search import fit_combination, rel_error


@given(st.lists(st.integers(0, 1000), min_size=9, max_size=9),
       st.integers(0, 500), st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_fit_property_block_mixes(body, x10, slack):
    x = np.array(body + [x10, sum(body) + slack], dtype=float)
    b = B.calibration_matrix()
    t = b @ x
    if not np.any(t > 0):
        return
    fit = fit_combination(t)
    err = rel_error(t, fit.predicted)
    assert np.all(err[t > 0] < 0.05)
