"""Property tests for the QP block-combination search.

Split from test_blocks_qp.py so the plain unit tests there always run.
The fit-accuracy property also always runs, over a seeded deterministic
corpus of block mixes; only the hypothesis-randomized exploration skips
when hypothesis is absent (the perpetual-skip audit: the gating condition
is the optional dependency, not the JAX floor).
"""
import numpy as np
import pytest

from repro.core import blocks as B
from repro.core.proxy_search import fit_combination, rel_error

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="randomized exploration needs hypothesis (requirements-dev.txt);"
           " the deterministic corpus in this module still runs")


def _check_fit(body, x10, slack):
    x = np.array(list(body) + [x10, sum(body) + slack], dtype=float)
    b = B.calibration_matrix()
    t = b @ x
    if not np.any(t > 0):
        return
    fit = fit_combination(t)
    err = rel_error(t, fit.predicted)
    assert np.all(err[t > 0] < 0.05)


def test_fit_examples_block_mixes():
    """Deterministic corpus: pure single blocks, dense mixes, and seeded
    random mixes — every target made from real block combinations must
    fit to < 5% on its present metrics."""
    for j in range(9):
        body = [0] * 9
        body[j] = 37
        _check_fit(body, 0, 0)
    _check_fit([11, 0, 7, 0, 3, 0, 0, 19, 2], 5, 1)
    rng = np.random.RandomState(3)
    for _ in range(6):
        _check_fit(rng.randint(0, 1000, 9).tolist(),
                   int(rng.randint(0, 500)), int(rng.randint(0, 500)))


if HAVE_HYPOTHESIS:

    @given(st.lists(st.integers(0, 1000), min_size=9, max_size=9),
           st.integers(0, 500), st.integers(0, 500))
    @settings(max_examples=30, deadline=None)
    def test_fit_property_block_mixes(body, x10, slack):
        _check_fit(body, x10, slack)

else:            # keep the gating visible in the test report

    @needs_hypothesis
    def test_fit_property_block_mixes():
        raise AssertionError("unreachable: skipif guards this test")
