"""Proxy-block calibration + QP search unit tests (paper §2.4).

Hypothesis-based property tests live in test_blocks_qp_prop.py so this
module always runs, dependency or not."""
import jax
import numpy as np
import pytest

from repro.core import blocks as B
from repro.core.proxy_search import (
    PGD_TERMINAL_THRESHOLD, choose_solver, fit_batch_pgd, fit_combination,
    rel_error, substituted_matrix,
)
from repro.core.tracer import compute_cost


def test_calibration_matrix_shape_and_signatures():
    b = B.calibration_matrix()
    assert b.shape == (6, 11)
    names = B.BLOCK_NAMES
    mxu = b[0]
    assert mxu[names.index("mxu_vmem")] > 0 and mxu[names.index("mxu_small")] > 0
    assert np.all(mxu[2:] == 0)                      # only mxu blocks hit MXU
    assert b[3][names.index("trans_chain")] > 0      # transcendentals
    assert np.count_nonzero(b[3]) == 1
    assert b[4][names.index("gather_rand")] > 0      # gather
    assert np.count_nonzero(b[4]) == 1
    assert b[5][names.index("scan_seq")] > 0         # scan steps
    assert b[5][names.index("empty_loop")] == 1
    assert b[5][names.index("loop_turn")] == 1


def test_combo_cost_equals_walker_exactly():
    """THE consistency theorem: combo_cost == jaxpr-walker cost of
    run_combo, bit-exact, for any (x, unroll)."""
    st_ = jax.eval_shape(B.init_state)
    for x in ([1, 0, 2, 0, 1, 0, 0, 1, 0, 3, 9],
              [5, 4, 3, 2, 1, 1, 2, 3, 4, 0, 25],
              [0, 0, 0, 0, 0, 0, 0, 0, 0, 7, 0]):
        for u in (1, 8):
            traced = compute_cost(lambda s: B.run_combo(s, x, u), st_)
            pred = B.combo_cost(x, u)
            np.testing.assert_allclose(traced, pred, rtol=0, atol=0)


def test_run_combo_rejects_bad_coupling():
    st_ = B.init_state()
    with pytest.raises(ValueError):
        B.run_combo(st_, [5, 0, 0, 0, 0, 0, 0, 0, 0, 0, 2])


def test_run_combo_executes():
    st_ = B.init_state()
    out = B.run_combo(st_, [2, 1, 3, 1, 1, 1, 1, 1, 1, 4, 15])
    assert np.isfinite(np.asarray(out["a"], np.float32)).all()
    assert np.isfinite(float(out["s"]))


def test_fit_recovers_exact_combination():
    """A target that IS a block mix must be recovered near-exactly."""
    b = B.calibration_matrix()
    x_true = np.array([40, 12, 25, 8, 5, 9, 3, 2, 7, 11, 130])
    t = b @ x_true
    fit = fit_combination(t)
    err = rel_error(t, fit.predicted)
    assert np.all(err[t > 0] < 0.08), (fit.x, err)


def test_fit_respects_constraints():
    rng = np.random.RandomState(0)
    b = B.calibration_matrix()
    for _ in range(20):
        t = b @ rng.randint(0, 200, 11).astype(float)
        fit = fit_combination(t)
        assert np.all(fit.x >= 0)
        assert fit.x[10] >= np.sum(fit.x[:9])          # paper's x11 coupling


def test_fit_large_targets():
    """Model-layer-scale targets (walker-realistic ratios): error < 1%."""
    t = np.array([3.2e12, 4.1e10, 8.0e11, 2.5e8, 1.1e8, 4.0e5])
    fit = fit_combination(t)
    assert np.all(fit.per_metric_rel_err[t > 0] < 0.01), fit.summary()
    assert fit.unroll > 1  # millions of applications, thousands of turns


def test_fit_pure_movement_segment():
    """Data-movement-only segments (bytes, no ALU) are representable."""
    t = np.array([0, 0, 2e9, 0, 0, 0])
    fit = fit_combination(t)
    assert fit.per_metric_rel_err[2] < 0.02, fit.summary()


def test_substitution_matrix_semantics():
    b = B.calibration_matrix()
    bs = substituted_matrix(b)
    np.testing.assert_allclose(bs[:, :9], b[:, :9] + b[:, 10:11])
    np.testing.assert_allclose(bs[:, 9], b[:, 9])


def test_pgd_matches_nnls():
    rng = np.random.RandomState(1)
    b = B.calibration_matrix()
    targets = np.stack([b @ rng.randint(1, 500, 11).astype(float)
                        for _ in range(8)])
    xs = fit_batch_pgd(targets, iters=600)
    for t, x in zip(targets, xs):
        pred = b @ x
        err = rel_error(t, pred)
        assert np.all(err[t > 0] < 0.25), (x, err)


def test_solver_auto_crossover():
    """Pin the pgd-by-default crossover: nnls at or below the terminal-count
    threshold, pgd strictly above, explicit choices untouched."""
    assert choose_solver(PGD_TERMINAL_THRESHOLD) == "nnls"
    assert choose_solver(PGD_TERMINAL_THRESHOLD + 1) == "pgd"
    assert choose_solver(0) == "nnls"
    assert choose_solver(10_000, solver="nnls") == "nnls"
    assert choose_solver(1, solver="pgd") == "pgd"
