"""Mesh-sharded signature-group replay (the device-parallel sweep tier).

Three layers of coverage:

* pure planner tests (no devices touched): sub-mesh geometry, proportional
  device partitioning, hint capping, round-robin overflow;
* single-device in-process tests: the mesh path runs on whatever mesh the
  host has, and the placement-keyed compile cache hits on repeat sweeps;
* subprocess tests on a forced 8-device CPU host platform (the repo idiom
  for mesh execution, see test_device_comm.py): every ``DeviceComm``
  collective kind — including the non-divisible ``reduce_scatter`` /
  ``all_to_all`` fallbacks and all ``_detail_to_perm`` decode paths — with
  the rank axis ``vmap``-folded through the real collectives, asserting
  pool-buffer shape/dtype stability and batched-vs-sequential equality,
  plus the end-to-end 16-rank sweep: one ``shard_map`` dispatch per
  signature group, disjoint placements, placement-keyed caching, and δ̄
  bit-identical to the sequential mesh path.
"""
import subprocess
import sys
import textwrap

import numpy as np

from repro import compat
from repro.core.replay import plan_mesh_sweep, submesh_axis_sizes


def _run(prog: str, timeout: int = 420):
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


# ---------------------------------------------------------------------------
# planner (pure)
# ---------------------------------------------------------------------------


def test_collective_batching_audit_clean():
    """Every collective the replay can emit must be vmap-batchable — the
    soundness condition of folding the rank axis through DeviceComm."""
    assert compat.collective_batching_audit() == []


def test_submesh_axis_sizes():
    assert submesh_axis_sizes(8, {"x": 16}) == {"x": 8}
    assert submesh_axis_sizes(8, {"data": 4, "model": 4}) == \
        {"data": 4, "model": 2}
    assert submesh_axis_sizes(6, {"x": 4}) == {"x": 2}
    assert submesh_axis_sizes(5, {"x": 16}) == {"x": 1}   # coprime → unit
    assert submesh_axis_sizes(3, {}) == {"x": 1}          # comm-free proxy
    assert submesh_axis_sizes(1, {"x": 16}) == {"x": 1}


def test_plan_proportional_disjoint():
    groups = [(("a",), [0]), (("b",), list(range(1, 16)))]
    plan = plan_mesh_sweep(groups, {("a",): 16, ("b",): 16}, {"x": 16}, 8)
    assert [p.n_devices for p in plan] == [4, 4]
    assert plan[0].device_ids == (0, 1, 2, 3)
    assert plan[1].device_ids == (4, 5, 6, 7)
    assert dict(plan[0].axis_sizes) == {"x": 4}
    assert plan[0].ranks == (0,) and plan[1].ranks == tuple(range(1, 16))
    # placements are hashable cache-key components
    assert isinstance(hash(plan[0]), int) and plan[0].key() != plan[1].key()


def test_plan_caps_at_hint_and_realizable():
    """A comm-free group never gets more than 1 device, and the big group's
    share shrinks to a realizable sub-mesh size (7 → 4 on a 16-wide axis)
    instead of collapsing to a unit mesh."""
    groups = [(("free",), [0]), (("big",), list(range(1, 16)))]
    plan = plan_mesh_sweep(groups, {("free",): 1, ("big",): 16}, {"x": 16}, 8)
    assert plan[0].n_devices == 1
    assert dict(plan[0].axis_sizes) == {"x": 1}
    assert plan[1].n_devices == 4
    assert dict(plan[1].axis_sizes) == {"x": 4}
    assert set(plan[0].device_ids).isdisjoint(plan[1].device_ids)


def test_plan_never_oversubscribes():
    """One dominant hint + many unit groups: bumping every group to >= 1
    device must not push device ids past the mesh (regression: hints
    [100,1,1,1,1,1,1] on 8 devices used to plan ids 8 and 9)."""
    groups = [((i,), [i]) for i in range(7)]
    hints = {(0,): 100, **{(i,): 1 for i in range(1, 7)}}
    plan = plan_mesh_sweep(groups, hints, {"x": 100}, 8)
    ids = [i for p in plan for i in p.device_ids]
    assert max(ids) < 8
    assert len(ids) == len(set(ids))     # still disjoint
    assert all(p.n_devices >= 1 for p in plan)


def test_plan_wraps_when_groups_exceed_devices():
    groups = [((i,), [i]) for i in range(5)]
    plan = plan_mesh_sweep(groups, {}, {"x": 4}, 2)
    assert [p.device_ids for p in plan] == [(0,), (1,), (0,), (1,), (0,)]
    assert all(dict(p.axis_sizes) == {"x": 1} for p in plan)


def test_plan_empty_groups():
    assert plan_mesh_sweep([], {}, {"x": 4}, 8) == []


def test_count_scale_scaled_hints_and_unit_group_sharing():
    """ROADMAP item: ``count_scale`` scales the generated device hints, and
    the planner packs the resulting unit-hint groups onto one shared device
    instead of idling devices sized for the full traced span."""
    from repro.core.events import CommEvent, ComputeEvent
    from repro.core.synthesize import synthesize

    comp = ComputeEvent((2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.))
    comp2 = ComputeEvent((7.7e5, 1.1e4, 3.3e5, 0., 0., 1.0))
    big = CommEvent("psum", (16,), "float32", ("x", "y"))
    small = CommEvent("psum", (4,), "float32", ("y",))
    traces = [[comp, big] * 6 for _ in range(14)]
    traces.append([comp2, small] * 6)                 # own main cluster
    traces.append([comp2, small] * 6 + [small])       # … with a branch
    axis = {"x": 8, "y": 2}

    full = synthesize(rank_traces=traces, axis_sizes=axis, name="cs_full")
    scaled = synthesize(rank_traces=traces, axis_sizes=axis,
                        count_scale=0.5, name="cs_half")
    assert sorted(g[2] for g in full.proxy.module.SIGNATURE_GROUPS) == \
        [2, 2, 16]
    assert sorted(g[2] for g in scaled.proxy.module.SIGNATURE_GROUPS) == \
        [1, 1, 8]

    # scaled hints + sharing: the two unit groups land on ONE shared device
    groups = scaled.proxy.signature_groups()
    plan = plan_mesh_sweep(groups, scaled.proxy.group_device_hints(), axis,
                           8, share_unit_groups=True)
    units = [p for p in plan if len(p.ranks) == 1]
    bigp = next(p for p in plan if len(p.ranks) > 1)
    assert len(units) == 2
    assert units[0].device_ids == units[1].device_ids
    assert set(units[0].device_ids).isdisjoint(bigp.device_ids)
    assert bigp.n_devices == 4         # realizable share of the freed mesh

    # unscaled hints (no unit groups): placements stay disjoint
    plan2 = plan_mesh_sweep(full.proxy.signature_groups(),
                            full.proxy.group_device_hints(), axis, 8,
                            share_unit_groups=True)
    ids = [i for p in plan2 for i in p.device_ids]
    assert len(ids) == len(set(ids))

    # no scarcity (total demand fits the mesh): unit groups keep their own
    # devices and run in parallel — packing only kicks in when demand
    # exceeds supply
    plan3 = plan_mesh_sweep(
        [(("a",), [0]), (("b",), [1]), (("c",), [2])],
        {("a",): 4, ("b",): 1, ("c",): 1}, {"x": 8}, 8,
        share_unit_groups=True)
    ids3 = [i for p in plan3 for i in p.device_ids]
    assert len(ids3) == len(set(ids3))


# ---------------------------------------------------------------------------
# mesh execution on whatever the host has (single device in tier-1)
# ---------------------------------------------------------------------------


def _synth(n_ranks=8):
    from repro.core.events import CommEvent, ComputeEvent
    from repro.core.synthesize import synthesize

    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    comp = ComputeEvent((2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.))
    traces = []
    for r in range(n_ranks):
        tr = [comp, comm, comp, perm] * 6
        if r == 0:
            tr = tr + [comm]        # rank-0 extra event → second signature
        traces.append(tr)
    return synthesize(rank_traces=traces, axis_sizes={"x": n_ranks},
                      name=f"mesh_{n_ranks}")


def test_mesh_run_all_and_placement_cache():
    """The mesh sweep runs on the host's own device set (a unit mesh on the
    tier-1 single-CPU run) and repeat sweeps hit the placement-keyed
    compile cache instead of re-tracing."""
    import jax
    from repro.launch.mesh import make_replay_mesh

    res = _synth()
    mesh = make_replay_mesh(
        submesh_axis_sizes(jax.device_count(), {"x": 8}))
    plan = res.proxy.mesh_sweep_plan(mesh)
    assert len(plan) == 2

    out = res.proxy.run_all(mesh=mesh, per_rank_seeds=True)
    assert sorted(out) == list(range(8))
    stats = res.proxy.cache_stats()
    assert stats["jit_traces"] == len(plan)   # one dispatchable per group
    for st in out.values():
        for leaf in jax.tree_util.tree_leaves(st):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()

    res.proxy.run_all(mesh=mesh, per_rank_seeds=True)
    stats2 = res.proxy.cache_stats()
    assert stats2["jit_traces"] == stats["jit_traces"]      # no re-trace
    assert stats2["batch_cache_hits"] > stats["batch_cache_hits"]
    assert stats2["batch_cache_misses"] == stats["batch_cache_misses"]


def test_mesh_fidelity_matches_local():
    """δ̄ is placement-invariant: the mesh-mode report carries bit-identical
    deltas and records the on-mesh execution check."""
    import jax
    from repro.launch.mesh import make_replay_mesh

    res = _synth()
    mesh = make_replay_mesh(
        submesh_axis_sizes(jax.device_count(), {"x": 8}))
    fid_local = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                   batched=False)
    fid_mesh = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                  mesh=mesh)
    np.testing.assert_array_equal(fid_mesh.delta, fid_local.delta)
    assert fid_mesh.mesh_checked
    assert not fid_local.mesh_checked


# ---------------------------------------------------------------------------
# forced 8-device mesh (subprocess)
# ---------------------------------------------------------------------------


def test_device_comm_batched_rank_axis_all_kinds():
    """Every DeviceComm collective kind — fallback branches and all three
    _detail_to_perm decode paths included — replays a vmapped rank axis
    inside one shard_map dispatch, with pool-buffer shape/dtype stability
    and bit-equality against the sequential (per-rank dispatch) path."""
    out = _run(textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.sharding.collectives import DeviceComm

        mesh = make_mesh((8,), ("x",))
        comm = DeviceComm({"x": 8})
        N = 4
        cases = [
            ("psum", (), (16, 8), "float32"),
            ("pmax", (), (16, 8), "float32"),
            ("pmin", (), (16, 8), "float32"),
            ("all_gather", (0,), (16, 8), "float32"),
            ("reduce_scatter", (0,), (16, 8), "float32"),   # divisible
            ("reduce_scatter", (0,), (15, 8), "float32"),   # fallback
            ("all_to_all", (0, 1), (16, 8), "float32"),     # divisible
            ("all_to_all", (0, 1), (15, 8), "float32"),     # fallback
            ("ppermute", ("shift", 1), (16, 8), "float32"),  # decode: shift
            ("ppermute", ("empty",), (16, 8), "float32"),    # decode: empty
            ("ppermute", ("rawperm", tuple((i, (i + 3) % 8)
                                           for i in range(8))),
             (16, 8), "float32"),                            # decode: rawperm
            ("ppermute", (), (16, 8), "float32"),            # decode: default
            ("broadcast", (), (16, 8), "float32"),
            ("psum", (), (4, 4), "bfloat16"),   # wire dtype != buffer dtype
        ]
        rng = np.random.RandomState(0)
        for kind, detail, shape, dtype in cases:
            buf = jnp.asarray(rng.rand(N, *shape), jnp.bfloat16
                              if dtype == "bfloat16" else jnp.float32)
            def one(s, kind=kind, detail=detail, shape=shape, dtype=dtype):
                return comm.do(s, "b0", kind=kind, axes=("x",), detail=detail,
                               shape=shape, dtype=dtype)
            seq_fn = jax.jit(shard_map(one, mesh=mesh, in_specs=({"b0": P()},),
                                       out_specs={"b0": P()}, check_vma=False))
            bat_fn = jax.jit(shard_map(lambda st: jax.vmap(one)(st), mesh=mesh,
                                       in_specs=({"b0": P()},),
                                       out_specs={"b0": P()}, check_vma=False))
            bat = bat_fn({"b0": buf})["b0"]
            # pool-buffer stability: shape and dtype survive the fold-back
            assert bat.shape == buf.shape, (kind, detail, bat.shape)
            assert bat.dtype == buf.dtype, (kind, detail, bat.dtype)
            bnp = np.asarray(bat, np.float32)
            assert np.isfinite(bnp).all(), (kind, detail)
            for i in range(N):
                s = np.asarray(seq_fn({"b0": buf[i]})["b0"], np.float32)
                assert (bnp[i] == s).all(), (kind, detail, i)
        print("OK", len(cases), "cases")
    """))
    assert "OK" in out


def test_mesh_sharded_sweep_end_to_end():
    """16 per-rank-seeded ranks on a forced 8-device mesh: one shard_map
    dispatch per signature group, disjoint device subsets, states equal to
    the sequential mesh baseline, δ̄ bit-identical, and the compile cache
    keyed by placement (same mesh hits; a different placement re-traces)."""
    out = _run(textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core.events import CommEvent, ComputeEvent
        from repro.core.replay import submesh_axis_sizes
        from repro.core.synthesize import synthesize
        from repro.launch.mesh import make_replay_mesh

        N = 16
        comm = CommEvent("psum", (16,), "float32", ("x",))
        perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
        comp = ComputeEvent((2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.))
        traces = []
        for r in range(N):
            tr = [comp, comm, comp, perm] * 6
            if r == 0:
                tr = tr + [comm]
            traces.append(tr)
        res = synthesize(rank_traces=traces, axis_sizes={"x": N},
                         name="mesh_e2e")
        groups = res.proxy.module.SIGNATURE_GROUPS
        assert all(len(g) == 3 and g[2] == N for g in groups), groups

        mesh = make_replay_mesh(submesh_axis_sizes(8, {"x": N}))
        plan = res.proxy.mesh_sweep_plan(mesh)
        assert len(plan) == 2
        ids = [set(p.device_ids) for p in plan]
        assert ids[0].isdisjoint(ids[1])
        assert (ids[0] | ids[1]) <= set(range(8))

        # batched: exactly one compiled dispatch per signature group
        out_b = res.proxy.run_all(mesh=mesh, per_rank_seeds=True)
        stats = res.proxy.cache_stats()
        assert stats["jit_traces"] == len(plan), stats
        out_s = res.proxy.run_all(mesh=mesh, per_rank_seeds=True,
                                  batched=False)
        assert sorted(out_b) == sorted(out_s) == list(range(N))
        for r in out_b:
            for k in out_b[r]:
                a = np.asarray(out_b[r][k], np.float32)
                b = np.asarray(out_s[r][k], np.float32)
                assert out_b[r][k].dtype == out_s[r][k].dtype, (r, k)
                np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                           err_msg=f"rank {r} leaf {k}")

        # placement-keyed cache: same mesh -> hits, no new traces
        before = res.proxy.cache_stats()
        res.proxy.run_all(mesh=mesh, per_rank_seeds=True)
        after = res.proxy.cache_stats()
        assert after["jit_traces"] == before["jit_traces"]
        assert after["batch_cache_misses"] == before["batch_cache_misses"]
        assert after["batch_cache_hits"] > before["batch_cache_hits"]

        # a different placement (4-device mesh) compiles afresh
        mesh4 = make_replay_mesh(submesh_axis_sizes(4, {"x": N}),
                                 devices=jax.devices()[:4])
        res.proxy.run_all(mesh=mesh4, per_rank_seeds=True)
        moved = res.proxy.cache_stats()
        assert moved["batch_cache_misses"] > after["batch_cache_misses"]

        # fidelity: δ̄ bit-identical to the sequential mesh path
        fid_seq = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                     batched=False)
        fid_mesh = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                      mesh=mesh)
        assert np.array_equal(fid_mesh.delta, fid_seq.delta)
        assert fid_mesh.mesh_checked
        print("OK")
    """))
    assert "OK" in out


def test_mesh_compiled_vs_unrolled_parity_and_reload():
    """Grammar-compiled modules on a forced 8-device mesh: states match the
    unrolled codegen_reference oracle, δ̄ is bit-identical, and a compiled
    module reloaded via load_saved_module replays on the mesh with the same
    states and metadata (SIGNATURE_GROUPS round-trip)."""
    out = _run(textwrap.dedent("""\
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        from pathlib import Path
        from repro.core.events import CommEvent, ComputeEvent
        from repro.core.replay import (ProxyProgram, load_saved_module,
                                       submesh_axis_sizes)
        from repro.core.synthesize import synthesize
        from repro.launch.mesh import make_replay_mesh

        N = 8
        comm = CommEvent("psum", (16,), "float32", ("x",))
        perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
        comps = [ComputeEvent(tuple(
            np.array([2.1e6, 3.3e4, 1.1e6, 8.2e2, 0., 0.]) * 1.5 ** i))
            for i in range(5)]
        sched = [(7 * i * i + 3 * i) % 5 for i in range(24)]
        def traces():
            out = []
            for r in range(N):
                tr = []
                for s in sched:
                    tr.extend([comps[s], comm if s % 2 == 0 else perm])
                if r == 0:
                    tr = tr + [comm]
                out.append(tr)
            return out

        tmp = Path(tempfile.mkdtemp())
        res = synthesize(rank_traces=traces(), axis_sizes={"x": N},
                         name="mesh_tbl", out_dir=tmp / "t")
        ref = synthesize(rank_traces=traces(), axis_sizes={"x": N},
                         name="mesh_unr", codegen="unrolled")
        assert res.proxy.module.CODEGEN == "table"
        assert ref.proxy.module.CODEGEN == "unrolled"
        assert res.proxy.module.SIGNATURE_GROUPS == \\
            ref.proxy.module.SIGNATURE_GROUPS

        mesh = make_replay_mesh(submesh_axis_sizes(8, {"x": N}))
        out_t = res.proxy.run_all(mesh=mesh, per_rank_seeds=True)
        out_u = ref.proxy.run_all(mesh=mesh, per_rank_seeds=True)
        assert sorted(out_t) == sorted(out_u) == list(range(N))
        for r in out_t:
            for k in out_t[r]:
                np.testing.assert_allclose(
                    np.asarray(out_t[r][k], np.float32),
                    np.asarray(out_u[r][k], np.float32),
                    rtol=1e-4, atol=1e-5, err_msg=f"rank {r} leaf {k}")

        fid_t = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                   mesh=mesh)
        fid_u = ref.proxy.fidelity(ref.rank_traces, sample_ranks=None,
                                   mesh=mesh)
        assert np.array_equal(fid_t.delta, fid_u.delta)
        assert fid_t.mesh_checked and fid_u.mesh_checked

        # reload the saved compiled module and replay it on the mesh
        mod = load_saved_module(res.proxy.module.__proxy_path__, "mesh_rt")
        assert mod.CODEGEN == "table"
        assert mod.SIGNATURE_GROUPS == res.proxy.module.SIGNATURE_GROUPS
        redo = ProxyProgram(res.source, mod, res.merged, res.proxy.combos,
                            res.proxy.axis_sizes)
        out_r = redo.run_all(mesh=mesh, per_rank_seeds=True)
        for r in out_t:
            for k in out_t[r]:
                np.testing.assert_allclose(
                    np.asarray(out_r[r][k], np.float32),
                    np.asarray(out_t[r][k], np.float32),
                    rtol=1e-5, atol=1e-6, err_msg=f"rank {r} leaf {k}")
        print("OK")
    """))
    assert "OK" in out
