"""Training substrate: optimizer, checkpoint (async/atomic/elastic),
fault-tolerant trainer, gradient compression, data determinism."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, smoke
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (
    dequantize_tree, init_error_state, quantize_tree,
)
from repro.train.data import Prefetcher, TokenDataset
from repro.train.loop import Trainer, _InjectedFailure
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def test_adamw_reduces_loss_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, params, opt, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip_metric():
    cfg = AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(g, params, opt, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_data_determinism():
    ds = TokenDataset(1000, 16, 4, seed=7)
    a = ds.batch_at(42)
    b = ds.batch_at(42)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(ds.batch_at(43)["tokens"], a["tokens"])


def test_prefetcher_order():
    ds = TokenDataset(100, 8, 2)
    pf = Prefetcher(ds, start_step=5)
    try:
        for want in (5, 6, 7):
            step, batch = next(pf)
            assert step == want
            np.testing.assert_array_equal(batch["tokens"],
                                          ds.batch_at(want)["tokens"])
    finally:
        pf.close()


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = {"a": jnp.arange(6.0).reshape(2, 3),
             "nest": {"b": jnp.ones((4,), jnp.int32)}}
    mgr.save(3, state, {"step": 3})
    step, got, extra = mgr.restore(state)
    assert step == 3 and extra["step"] == 3
    np.testing.assert_array_equal(got["a"], state["a"])
    np.testing.assert_array_equal(got["nest"]["b"], state["nest"]["b"])


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"a": jnp.zeros((8,))}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, {"a": state["a"] + s})
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    _, got, _ = mgr.restore(state, step=4)
    np.testing.assert_array_equal(got["a"], state["a"] + 4)


def test_trainer_crash_resume_bitwise(tmp_path):
    """Failure injection + restore reproduces the uninterrupted run exactly
    (deterministic data + checkpointed state)."""
    cfg = smoke(get("llama3.2-3b"))
    t1 = Trainer(cfg, None, global_batch=4, seq_len=16,
                 ckpt_dir=tmp_path / "a")
    log1 = t1.run(6, ckpt_every=2)

    t2 = Trainer(cfg, None, global_batch=4, seq_len=16,
                 ckpt_dir=tmp_path / "b")
    crashed = []

    def inject(step):
        if step == 4 and not crashed:
            crashed.append(1)
            raise _InjectedFailure("simulated node loss")

    log2 = t2.run(6, ckpt_every=2, failure_injector=inject)
    l1 = {m["step"]: m["loss"] for m in log1}
    l2 = {m["step"]: m["loss"] for m in log2}
    for s in range(6):
        assert l1[s] == pytest.approx(l2[s], abs=0), s
    # params bitwise identical
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_microbatching_equivalence(tmp_path):
    from repro.train.loop import TrainOptions
    cfg = smoke(get("llama3.2-3b"))
    t1 = Trainer(cfg, None, global_batch=4, seq_len=16,
                 ckpt_dir=tmp_path / "mb1")
    t2 = Trainer(cfg, None, global_batch=4, seq_len=16,
                 ckpt_dir=tmp_path / "mb2",
                 options=TrainOptions(num_microbatches=2))
    l1 = t1.run(3)
    l2 = t2.run(3)
    for a, b in zip(l1, l2):
        assert a["loss"] == pytest.approx(b["loss"], rel=2e-2)


def test_int8_error_feedback_unbiased():
    """Quantization error is carried, so the *sum* over steps converges to
    the true gradient sum (error feedback property)."""
    rng = np.random.RandomState(0)
    g_true = {"w": jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)}
    err = init_error_state(g_true)
    acc = np.zeros((256,))
    steps = 50
    for _ in range(steps):
        q, scales, err = quantize_tree(g_true, err)
        deq = dequantize_tree(q, scales)
        acc += np.asarray(deq["w"])
    np.testing.assert_allclose(acc / steps, np.asarray(g_true["w"]),
                               atol=2e-3)


def test_elastic_reshard_noop_mesh(tmp_path):
    """reshard() round-trips state through a checkpoint (mesh=None→None)."""
    cfg = smoke(get("llama3.2-3b"))
    tr = Trainer(cfg, None, global_batch=4, seq_len=16,
                 ckpt_dir=tmp_path / "el")
    tr.run(2, ckpt_every=1)
    before = jax.tree.leaves(tr.params)[0]
    tr.reshard(None)
    after = jax.tree.leaves(tr.params)[0]
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    tr.run(1)
