"""Serve a small model with batched requests, then synthesize a proxy-app
for the *prefill step* — showing the Siesta pipeline applied to an
inference workload (the technique consumes any step function).

    PYTHONPATH=src python examples/serve_and_proxy.py
"""
import dataclasses

import numpy as np

from repro.configs import get
from repro.core.synthesize import synthesize
from repro.models.model import build_forward, init_params
from repro.serve.engine import ServeEngine


def small_llama():
    cfg = get("llama3.2-3b")
    return dataclasses.replace(
        cfg, name="llama-60m", n_layers=6, d_model=384, n_heads=6,
        n_kv_heads=2, head_dim=64, d_ff=1024, vocab=16000,
        dtype="float32", remat=False, loss_chunk=0)


def main():
    cfg = small_llama()
    params = init_params(cfg)
    eng = ServeEngine(cfg, params, max_len=160)

    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (8, 32)).astype(np.int32)
    res = eng.generate(prompts, n_new=64)
    print(f"batched serve: {res.tokens.shape[0]} requests x "
          f"{res.tokens.shape[1]} new tokens")
    print(f"  prefill: {res.prefill_sec*1e3:.1f} ms, "
          f"decode: {res.decode_sec*1e3:.1f} ms, "
          f"{res.tokens_per_sec:.0f} tok/s")

    # Siesta on the serving path: trace + synthesize the prefill step
    import jax.numpy as jnp
    prefill = build_forward(cfg, "prefill")
    batch = {"tokens": jnp.asarray(prompts)}
    result = synthesize(lambda p, b: prefill(p, b, cfg), params, batch,
                        axis_sizes={}, name="prefill_proxy")
    print("\nprefill proxy:")
    print("  events:", result.stats["n_events"],
          "| compression:", round(result.stats["compression_ratio"], 1), "x",
          "| fit err:", round(result.stats["mean_fit_rel_err"], 4))
    fid = result.fidelity()
    print("  fidelity mean delta:", round(fid.mean, 4))


if __name__ == "__main__":
    main()
