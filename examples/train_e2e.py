"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps with the full production stack — prefetching data
pipeline, AdamW, async checkpointing, crash-resume — then synthesize a
proxy-app from the training step itself.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get
from repro.train.loop import Trainer
from repro.train.optimizer import AdamWConfig


def small_qwen(full: bool):
    """--full: the ~100M-param qwen3-family member (the deliverable config;
    a few hundred steps need a real accelerator).  Default: a ~20M member
    sized for this CPU container's wall-clock."""
    cfg = get("qwen3-8b")
    if full:
        return dataclasses.replace(
            cfg, name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
            dtype="float32", remat=False, loss_chunk=0)
    return dataclasses.replace(
        cfg, name="qwen3-20m", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=1024, vocab=8000,
        dtype="float32", remat=False, loss_chunk=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="train the ~100M config (needs an accelerator)")
    args = ap.parse_args()

    cfg = small_qwen(args.full)
    print(f"model: {cfg.name}, params ~{cfg.approx_params()/1e6:.0f}M")
    tr = Trainer(cfg, None, global_batch=args.batch, seq_len=args.seq,
                 ckpt_dir="artifacts/train_e2e_ckpt",
                 opt_cfg=AdamWConfig(lr=3e-4, warmup_steps=20,
                                     total_steps=args.steps))
    log = tr.run(args.steps, ckpt_every=50)
    losses = [m["loss"] for m in log]
    t_step = float(np.median([m["sec"] for m in log[5:]]))
    print(f"step time (median): {t_step*1e3:.1f} ms")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(first 10 avg {np.mean(losses[:10]):.3f}, "
          f"last 10 avg {np.mean(losses[-10:]):.3f})")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not drop"
    print("checkpoints:", tr.ckpt.all_steps())


if __name__ == "__main__":
    main()
