"""Quickstart: synthesize a proxy-app for a distributed JAX program.

    PYTHONPATH=src python examples/quickstart.py

Traces a halo-exchange stencil (the paper's Fig. 2 pattern) running under
shard_map on 8 devices, compresses the trace to a context-free grammar,
fits TPU basic-block combinations to every compute segment, emits an
executable proxy module, and verifies fidelity + losslessness.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat import make_mesh, shard_map  # noqa: E402
from repro.core.synthesize import synthesize  # noqa: E402

N = 8
mesh = make_mesh((N,), ("x",))


def stencil_step(u, w):
    """12 iterations of: halo exchange -> compute -> global residual."""
    def body(carry, _):
        u, w = carry
        left = jax.lax.ppermute(u[:, :1], "x",
                                [(i, (i + 1) % N) for i in range(N)])
        right = jax.lax.ppermute(u[:, -1:], "x",
                                 [(i, (i - 1) % N) for i in range(N)])
        u = u + 0.1 * (left + right - 2.0 * u)
        for _ in range(3):
            u = jnp.tanh(u @ w)
        residual = jax.lax.psum(jnp.sum(u), "x")
        return (u, w), residual

    (u, _), rs = jax.lax.scan(body, (u, w), None, length=12)
    return u, rs


def main():
    f = shard_map(stencil_step, mesh=mesh,
                  in_specs=(P(None, "x"), P()),
                  out_specs=(P(None, "x"), P()))
    u = jnp.ones((256, 128 * N))
    w = jnp.ones((128, 128)) * 0.01

    result = synthesize(f, u, w, name="stencil_proxy",
                        out_dir="artifacts/proxies")
    print("=== synthesis stats ===")
    for k, v in result.stats.items():
        print(f"  {k}: {v}")

    fid = result.fidelity()
    print("\n=== fidelity (paper Table 3 columns) ===")
    print("  comm lossless:", fid.comm_lossless)
    print(f"  mean relative error: {fid.mean:.4f}")
    print(fid.heatmap_csv())

    print("\n=== replaying all ranks (batched by signature group) ===")
    states = result.proxy.run_all()
    n_groups = len(result.proxy.signature_groups())
    print(f"  {len(states)} ranks replayed in {n_groups} signature group(s)")
    t_batched = result.proxy.time_all(iters=3)
    t_per_rank = result.proxy.time_all(iters=3, batched=False)
    print(f"  full sweep: batched {t_batched*1e3:.2f} ms"
          f" vs per-rank {t_per_rank*1e3:.2f} ms"
          f" ({t_per_rank / max(t_batched, 1e-12):.1f}x)")
    print(f"  single-rank replay: {result.proxy.time_local(0, iters=3)*1e3:.2f} ms")
    print(f"\ngenerated proxy source: {result.proxy.module.__proxy_path__}")


if __name__ == "__main__":
    main()
