"""Heterogeneous-rank example: a pipeline-parallel schedule traced through
the host-level TraceSession (the PMPI-interposition analog), exercising
Algorithm 1's main-rule clustering — different pipeline stages produce
different main rules, merged with rank-set branches.

    PYTHONPATH=src python examples/pipeline_proxy.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp   # noqa: E402

from repro.core.events import CommEvent, ComputeEvent  # noqa: E402
from repro.core.synthesize import synthesize           # noqa: E402
from repro.core.tracer import TraceSession, compute_cost  # noqa: E402

STAGES = 8
MICROBATCHES = 16


def main():
    fwd = compute_cost(lambda a, b: jnp.tanh(a @ b),
                       jnp.ones((64, 512)), jnp.ones((512, 512)))
    with TraceSession(n_ranks=STAGES) as sess:
        for _ in range(MICROBATCHES):
            for r in range(STAGES):
                sess.emit([r], ComputeEvent(tuple(fwd)))
                if r < STAGES - 1:
                    sess.emit([r, r + 1],
                              CommEvent("ppermute", (64, 512), "float32",
                                        ("stage",), ("shift", 1)))
        for r in range(STAGES):
            sess.emit([r], CommEvent("psum", (512, 512), "float32", ("stage",)))

    res = synthesize(rank_traces=sess.rank_streams,
                     axis_sizes={"stage": STAGES}, name="pp_proxy")
    print("clusters:", len(res.merged.mains),
          "| cluster ranks:", [sorted(r) for r in res.merged.cluster_ranks])
    fid = res.fidelity()
    print("lossless:", fid.comm_lossless, "| mean delta:", round(fid.mean, 4))
    print("\n--- generated main rules (rank-set branches) ---")
    in_main = False
    for line in res.source.splitlines():
        if line.startswith("def main"):
            in_main = True
        if in_main:
            print(line)
        if in_main and line.strip() == "return st":
            in_main = False


if __name__ == "__main__":
    main()
