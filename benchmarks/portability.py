"""Paper Figs. 9-11 analog: robustness to platform / implementation change.

The paper ports proxies between clusters A/B/C and MPI implementations; our
analog has two halves:

* **Platform scaling** (`platform_rows`): scale the platform's compute rate
  (A → B: 2x slower chip) and compare predicted times — Siesta's block
  mixes re-execute and track the change, the ScalaBench-style sleep proxy
  cannot.
* **Cross-chip prediction** (`cross_chip_rows`): feed synthesized zoo
  proxies to :func:`repro.core.portability.predict_profile` and tabulate
  the predicted roofline step-time bound (with NOISE_MODELS error bars)
  on chips the scenarios were never traced on, cross-checked against the
  walker-measured metric totals on the reference chip.

``--smoke`` is the CI gate (one reduced scenario, hard asserts); the full
run snapshots ``artifacts/BENCH_7.json`` via ``benchmarks.run`` or direct
invocation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PROGRAMS, ensure_devices

#: reduced-zoo shape for the cross-chip rows (matches the fidelity tier)
CROSS_CHIP_KWARGS = {"n_ranks": 4, "steps": 2}


def platform_rows() -> list[dict]:
    from repro.core.baselines import (
        original_time, scalabench_compress, siesta_predicted_time,
    )
    from repro.core.events import is_comm
    from repro.core.proxy_search import fit_combination
    from repro.core.tracer import per_rank_traces, trace_fn
    rows = []
    for name, builder in PROGRAMS.items():
        fn, args, axes = builder(8)
        tr = trace_fn(fn, *args, axis_sizes=axes)
        trace = per_rank_traces(tr)[0]
        comm = [e for e in trace if is_comm(e)]
        fits = [fit_combination(e.vector) for e in trace if not is_comm(e)]
        combos = [(f.x, f.unroll) for f in fits]
        sb = scalabench_compress(trace)
        for scale, plat in ((1.0, "A"), (0.5, "B_2x_slower"),
                            (2.0, "C_2x_faster")):
            t_ref = original_time(trace, scale)
            t_si = siesta_predicted_time(combos, comm, scale)
            t_sb = sb.predicted_time(scale)
            rows.append({
                # program key is unique per (target, platform) so the
                # write_artifacts merge keeps the full trajectory
                "program": f"{name}@{plat}", "platform": plat,
                "orig_s": round(t_ref, 6),
                "siesta_err": round(abs(t_si - t_ref) / t_ref, 4),
                "scalabench_err": round(abs(t_sb - t_ref) / t_ref, 4),
            })
    return rows


def _walker_err(proxy, pred) -> float:
    """Max relative gap between the prediction's reference-chip compute /
    memory terms and the same terms rebuilt from the walker-measured
    metric totals — an independent consistency bar (the walker traces the
    executable; the predictor only reads the terminal table)."""
    from repro.core.portability import CHIPS, REFERENCE_CHIP
    from repro.launch.hlo_cost import HloCost
    chip = CHIPS[REFERENCE_CHIP]
    errs = [0.0]
    # every rank appears in exactly one signature group, so the predictor's
    # sorted rank order is simply 0..N_RANKS-1
    for i, r in enumerate(range(proxy.module.N_RANKS)):
        hc = HloCost.from_metric_vector(proxy.rank_metrics(r))
        for want, got in ((hc.flops / chip.peak_flops, pred.t_compute[i]),
                          (hc.bytes / chip.hbm_bw, pred.t_memory[i])):
            if want > 0:
                errs.append(abs(got - want) / want)
    return float(max(errs))


def cross_chip_rows(scenarios=None, **kwargs) -> list[dict]:
    """Predicted profiles for the (reduced) zoo on every known chip."""
    ensure_devices()
    from repro.core.portability import REFERENCE_CHIP, predict_all
    from repro.core.synthesize import synthesize_corpus
    kwargs = {**CROSS_CHIP_KWARGS, **kwargs}
    corp = synthesize_corpus(scenarios, **kwargs)
    rows = []
    for sname, res in corp.results.items():
        preds = predict_all(res.proxy.module)
        werr = _walker_err(res.proxy, preds[REFERENCE_CHIP])
        for cname, pred in preds.items():
            row = {"program": f"{sname}@{cname}", **pred.as_dict()}
            if cname == REFERENCE_CHIP:
                row["walker_err"] = round(werr, 6)
            rows.append(row)
    return rows


def run() -> list[dict]:
    return platform_rows() + cross_chip_rows()


def smoke() -> None:
    """CI gate: one reduced scenario, every chip, hard asserts."""
    rows = cross_chip_rows(["transformer-dp"])
    by_chip = {r["chip"]: r for r in rows}
    ref = by_chip["v5e"]
    # predictor ≡ walker on the reference chip (both read the same fitted
    # costs; the walker via the traced executable, the predictor via the
    # terminal table)
    assert ref["walker_err"] < 1e-6, ref
    assert ref["speedup_vs_ref"] == 1.0, ref
    # the noise band must contain the point prediction
    for r in rows:
        assert r["band_lo_s"] <= r["step_time_s"] <= r["band_hi_s"], r
        assert r["band_hi_s"] > r["band_lo_s"], (
            "degenerate noise band — NOISE_MODELS calibration missing?", r)
    # a strictly faster chip must predict a strictly faster step
    assert by_chip["v5p"]["step_time_s"] < ref["step_time_s"], by_chip
    assert by_chip["v5p"]["speedup_vs_ref"] > 1.0, by_chip
    for r in rows:
        print(", ".join(f"{k}={v}" for k, v in r.items()))
    print("portability smoke OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: one reduced scenario, hard asserts")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        from benchmarks.synthesize_time import write_artifacts

        rows = run()
        for r in rows:
            print(", ".join(f"{k}={v}" for k, v in r.items()))
        write_artifacts(rows, snapshot="BENCH_7.json", suite="portability")
