"""Paper Figs. 9-11 analog: robustness to platform / implementation change.

The paper ports proxies between clusters A/B/C and MPI implementations; our
analog scales the platform's compute rate (A → B: 2x slower chip) and
compares predicted times: Siesta's block mixes re-execute and track the
change, the ScalaBench-style sleep proxy cannot.  Comm-implementation
robustness is represented by swapping the collective cost model (ring vs
direct), which only the lossless comm skeleton responds to correctly.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PROGRAMS


def run() -> list[dict]:
    from repro.core.baselines import (
        original_time, scalabench_compress, siesta_predicted_time,
    )
    from repro.core.events import is_comm
    from repro.core.proxy_search import fit_combination
    from repro.core.tracer import per_rank_traces, trace_fn
    rows = []
    for name, builder in PROGRAMS.items():
        fn, args, axes = builder(8)
        tr = trace_fn(fn, *args, axis_sizes=axes)
        trace = per_rank_traces(tr)[0]
        comm = [e for e in trace if is_comm(e)]
        fits = [fit_combination(e.vector) for e in trace if not is_comm(e)]
        combos = [(f.x, f.unroll) for f in fits]
        sb = scalabench_compress(trace)
        for scale, plat in ((1.0, "A"), (0.5, "B_2x_slower"),
                            (2.0, "C_2x_faster")):
            t_ref = original_time(trace, scale)
            t_si = siesta_predicted_time(combos, comm, scale)
            t_sb = sb.predicted_time(scale)
            rows.append({
                "program": name, "platform": plat,
                "orig_s": round(t_ref, 6),
                "siesta_err": round(abs(t_si - t_ref) / t_ref, 4),
                "scalabench_err": round(abs(t_sb - t_ref) / t_ref, 4),
            })
    return rows
