"""Chaos sweep: deterministic fault injection at every registered store
touchpoint, with recovery + survivor-set parity hard-asserted per case.

Rows (→ ``artifacts/BENCH_10.json``):

1. **chaos_coverage** — a full store lifecycle (open, ingest, cache
   saves, scenario/sidecar/shard/index reads) under an *empty* fault
   plan must consult every point in
   :data:`repro.core.faults.FAULT_POINTS`.  A registered point the
   lifecycle never reaches is a hole in the sweep; a store touchpoint
   that forgot to register never shows up here and fails the paired
   test tier instead (``tests/test_faults.py``).

2. **chaos_<point>** (one row per registered point) — for each damage
   kind the point supports (``crash_before`` / ``crash_after`` /
   ``torn_write`` / ``io_error``): seed a store, install
   ``FaultPlan.crash_at(point, kind)``, drive the lifecycle until the
   fault fires (hard-asserted — a case that never fires is a coverage
   bug), then do what a restarted appender does: reopen from disk,
   ``verify()``, ``repair()`` if dirty, and assert the repaired store is
   *clean* and **bit-identical to a from-scratch store over the
   survivors** — names, content hashes, cluster assignments, and (full
   runs) the synthesized δ̄ per scenario.

3. **chaos_slow_lock** — contended lock acquisition (``slow_lock``
   budget of 3) must retry through with bounded backoff and commit;
   an unbounded hold must surface the
   :class:`~repro.core.corpus_store.LockTimeoutError` diagnostic.

4. **chaos_worker_death** — an OOM-killed pool worker
   (``worker_death`` on one item) breaks the pool; the per-item serial
   fallback must still commit every scenario, bit-identical to a
   serial-only ingest, with the break counted in ``store.stats``.

``--smoke`` sweeps every point with its most damaging supported kind
(``torn_write`` where available, else ``crash_before``), cluster-level
parity only — the CI ``incremental-corpus`` job's chaos leg.  Full runs
sweep every (point, kind) pair with δ̄ parity and append rows to
``artifacts/benchmarks.json`` via the shared ``write_artifacts``.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.synthesize_time import write_artifacts

_V = [(2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.),
      (4.4e6, 1.2e4, 2.2e6, 0., 7.0, 1.0),
      (9.9e8, 5.5e5, 3.3e7, 1.1e3, 0., 2.0)]

#: the kinds that damage store state (vs delay it); ``slow_lock`` and
#: ``worker_death`` get dedicated rows because their contract is
#: "survive without repair", not "repair to parity"
_DAMAGE_KINDS = ("crash_before", "crash_after", "torn_write", "io_error")


def _zoo() -> dict:
    from repro.core.events import CommEvent, ComputeEvent
    from repro.core.trace_ir import TraceStore

    def mk(vs):
        comm = CommEvent("psum", (8,), "float32", ("x",))
        tr = []
        for v in vs:
            tr += [ComputeEvent(tuple(v)), comm]
        return TraceStore.from_rank_traces([list(tr) for _ in range(4)],
                                           {"x": 4})

    return {"a": mk([_V[0], _V[1]]), "b": mk([_V[0], _V[2]]),
            "c": mk([_V[1], _V[2]])}


def _fake_fit():
    from types import SimpleNamespace
    return SimpleNamespace(x=np.arange(11), predicted=np.zeros(6),
                           target=np.zeros(6), residual=0.0,
                           per_metric_rel_err=np.zeros(6), unroll=1)


def _seed(root: Path, zoo: dict):
    """A healthy two-scenario store, committed before any plan installs."""
    from repro.core.corpus_store import CorpusStore

    cs = CorpusStore(root)
    cs.add_scenario("a", zoo["a"])
    cs.add_scenario("b", zoo["b"])
    return cs


def _lifecycle(root: Path, zoo: dict) -> None:
    """One pass over every registered fault point: open (shard + index
    reads), ingest (lock, worker front half, scenario/sidecar/shard/index
    writes), cache saves (fit/grammar/manifest writes), an evicted
    scenario reload, and an index rebuild from sidecars."""
    from repro.core.corpus_store import CorpusStore

    cs = CorpusStore(root)                         # read.shard, read.index
    cs.add_scenario("c", zoo["c"])                 # lock + worker + writes
    cs.save_fits(table_fingerprint="chaos")        # write.manifest
    cs.fits.put("k", _fake_fit())
    cs.save_fits()                                 # write.fit_cache
    cs.grammars.put("k", {0: [("t", 1, 2)]})
    cs.save_grammars()                             # write.grammar_cache
    cs._stores.clear()
    cs.load_scenario("a")                          # read.scenario_npz
    (root / "cluster_index.npz").unlink(missing_ok=True)
    CorpusStore(root)                              # read.sidecar rebuild


def _recover(root: Path):
    """The restarted appender's protocol: reopen from disk, fsck, repair
    if dirty, and hard-assert the result is clean."""
    from repro.core.corpus_store import CorpusStore

    cs = CorpusStore(root)
    repaired = not cs.verify().clean
    if repaired:
        cs.repair()
    rep = cs.verify()
    assert rep.clean, rep.summary()
    return cs, repaired


def _assert_survivor_parity(cs, zoo: dict, fresh_root: Path,
                            deep: bool) -> int:
    """The repaired store must equal a from-scratch store over the same
    surviving set — names, hashes, cluster derivation, and (deep) the
    synthesized δ̄ bit for bit."""
    from repro.core.corpus_store import CorpusStore

    fresh = CorpusStore(fresh_root)
    for n in cs.names:
        fresh.add_scenario(n, zoo[n])
    assert fresh.names == cs.names, (fresh.names, cs.names)
    for n in cs.names:
        assert fresh.content_hash(n) == cs.content_hash(n), n
    ids_a, reps_a = cs.cluster_assignments()
    ids_b, reps_b = fresh.cluster_assignments()
    for n in cs.names:
        np.testing.assert_array_equal(ids_a[n], ids_b[n])
    assert set(reps_a) == set(reps_b)
    for cid in reps_a:
        np.testing.assert_array_equal(reps_a[cid], reps_b[cid])
    if deep and cs.names:
        from repro.core.synthesize import synthesize_corpus
        ci = synthesize_corpus(store=cs)
        cf = synthesize_corpus(store=fresh)
        for n in cs.names:
            fi = ci.results[n].fidelity(sample_ranks=None)
            ff = cf.results[n].fidelity(sample_ranks=None)
            np.testing.assert_array_equal(fi.delta, ff.delta)
    return len(cs.names)


def _one_case(point: str, kind: str, deep: bool) -> dict:
    """Seed → inject one fault → crash → recover → parity."""
    from repro.core import faults
    from repro.core.corpus_store import (IngestBatchError,
                                         ScenarioCorruptError)

    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        root = td / "corpus"
        zoo = _zoo()
        _seed(root, zoo)

        plan = faults.FaultPlan.crash_at(point, kind)
        crashed = False
        with faults.active_plan(plan):
            try:
                _lifecycle(root, zoo)
            except (faults.InjectedCrash, OSError, IngestBatchError,
                    ScenarioCorruptError):
                # ScenarioCorruptError is the typed wrapper an injected
                # read EIO surfaces as — still a crash outcome here
                crashed = True
        assert plan.fired, f"fault {kind} at {point} never fired"

        t0 = time.perf_counter()
        cs, repaired = _recover(root)
        t_recover = time.perf_counter() - t0
        n_survivors = _assert_survivor_parity(cs, zoo, td / "fresh", deep)
        return {"kind": kind, "crashed": crashed, "repaired": repaired,
                "n_survivors": n_survivors,
                "recover_ms": round(t_recover * 1e3, 2)}


def _point_row(point: str, kinds, deep: bool) -> dict:
    cases = [_one_case(point, k, deep) for k in kinds]
    return {
        "program": f"chaos_{point}",
        "kinds": [c["kind"] for c in cases],
        "n_cases": len(cases),
        "n_fired": len(cases),              # hard-asserted per case
        "n_repaired": sum(c["repaired"] for c in cases),
        "min_survivors": min(c["n_survivors"] for c in cases),
        "recover_ms_max": max(c["recover_ms"] for c in cases),
        "delta_parity": "deep" if deep else "cluster",
        "survivor_parity": True,            # hard-asserted per case
    }


def _coverage_row() -> dict:
    """Every registered point must be consulted by the lifecycle — an
    empty plan records hits without firing anything."""
    from repro.core import faults

    with tempfile.TemporaryDirectory() as td:
        root = Path(td) / "corpus"
        zoo = _zoo()
        _seed(root, zoo)
        plan = faults.FaultPlan([])
        with faults.active_plan(plan):
            _lifecycle(root, zoo)
        hit = {p for p, _ in plan.hits}
        missing = set(faults.registered_points()) - hit
        assert not missing, f"points never consulted: {sorted(missing)}"
        assert not plan.fired
        return {"program": "chaos_coverage",
                "n_points": len(faults.registered_points()),
                "n_consulted": len(hit & set(faults.registered_points())),
                "all_points_consulted": True}


def _slow_lock_row() -> dict:
    from repro.core import faults
    from repro.core.corpus_store import (CorpusStore, LockTimeoutError,
                                         _file_lock)

    with tempfile.TemporaryDirectory() as td:
        root = Path(td) / "corpus"
        zoo = _zoo()
        # bounded contention: three failed attempts, then the lock wins
        # and the ingest commits
        plan = faults.FaultPlan([faults.FaultSpec("lock.acquire",
                                                  "slow_lock", count=3)])
        t0 = time.perf_counter()
        with faults.active_plan(plan):
            cs = CorpusStore(root)
            cs.add_scenario("a", zoo["a"])
        t_through = time.perf_counter() - t0
        assert cs.names == ["a"]
        n_retries = len(plan.fired)

        # unbounded hold: the timeout diagnostic, not a hang
        plan = faults.FaultPlan([faults.FaultSpec("lock.acquire",
                                                  "slow_lock",
                                                  count=10_000)])
        diagnosed = False
        with faults.active_plan(plan):
            try:
                with _file_lock(Path(td) / "x.lock", timeout=0.05):
                    pass
            except LockTimeoutError as e:
                diagnosed = e.attempts > 1
        assert diagnosed
        return {"program": "chaos_slow_lock",
                "n_contended_attempts": n_retries,
                "retried_through_ms": round(t_through * 1e3, 2),
                "committed_under_contention": True,
                "timeout_diagnostic": True}


def _fork_available() -> bool:
    import multiprocessing as mp
    return "fork" in mp.get_all_start_methods()


def _worker_death_row() -> dict:
    from repro.core import faults
    from repro.core.corpus_store import CorpusStore

    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        zoo = _zoo()
        items = sorted(zoo.items())
        n_workers = 2 if _fork_available() else 0
        plan = faults.FaultPlan([faults.FaultSpec("worker.ingest",
                                                  "worker_death",
                                                  match="b")])
        t0 = time.perf_counter()
        with faults.active_plan(plan):
            cs = CorpusStore(td / "corpus")
            cs.add_scenarios(items, n_workers=n_workers)
        t_ingest = time.perf_counter() - t0

        ser = CorpusStore(td / "serial")
        ser.add_scenarios(items, n_workers=0)
        assert cs.names == ser.names
        for n in cs.names:
            assert cs.content_hash(n) == ser.content_hash(n), n
        if n_workers:
            assert cs.stats["n_pool_breaks"] >= 1, cs.stats
        return {"program": "chaos_worker_death",
                "n_workers": n_workers,
                "n_pool_breaks": cs.stats["n_pool_breaks"],
                "n_serial_retries": cs.stats["n_serial_retries"],
                "ingest_ms": round(t_ingest * 1e3, 2),
                "all_items_committed": True,
                "bit_identical_to_serial": True}


def _smoke_kind(point: str) -> str:
    """The most damaging kind each point supports: a torn on-disk write
    where possible, else a pre-op crash."""
    from repro.core import faults
    return ("torn_write" if "torn_write" in faults.FAULT_POINTS[point]
            else "crash_before")


def run() -> list[dict]:
    from repro.core import faults

    rows = [_coverage_row()]
    for point in faults.registered_points():
        kinds = [k for k in faults.FAULT_POINTS[point]
                 if k in _DAMAGE_KINDS]
        rows.append(_point_row(point, kinds, deep=True))
    rows += [_slow_lock_row(), _worker_death_row()]
    return rows


def smoke() -> None:
    """CI chaos smoke: every registered point, one most-damaging fault
    each, recovery + cluster-level survivor parity hard-asserted."""
    from repro.core import faults

    cov = _coverage_row()
    print(", ".join(f"{k}={v}" for k, v in cov.items()))

    for point in faults.registered_points():
        row = _point_row(point, [_smoke_kind(point)], deep=False)
        print(", ".join(f"{k}={v}" for k, v in row.items()))
        assert row["survivor_parity"], row

    lock = _slow_lock_row()
    print(", ".join(f"{k}={v}" for k, v in lock.items()))
    worker = _worker_death_row()
    print(", ".join(f"{k}={v}" for k, v in worker.items()))
    print("chaos smoke OK")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="every point, one most-damaging fault each, "
                         "cluster-level parity hard asserts (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        rows = run()
        for r in rows:
            print(", ".join(f"{k}={v}" for k, v in r.items()))
        write_artifacts(rows, snapshot="BENCH_10.json", suite="chaos")
