"""Shared benchmark harness: the traced target programs (the paper's BT/CG/
MG/... analogs are our framework's own distributed step functions), run in a
subprocess with a forced 8-device host platform."""
from __future__ import annotations

import os

_N_DEV = 8


def ensure_devices():
    os.environ.setdefault("XLA_FLAGS",
                          f"--xla_force_host_platform_device_count={_N_DEV}")


def stencil_program(n: int = 8, length: int = 12):
    """2D-stencil analog (paper Fig. 2 / NPB MG-flavored): halo ppermutes +
    compute + global psum inside a scan."""
    ensure_devices()
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map

    mesh = make_mesh((n,), ("x",))

    def step(u, w):
        def body(c, _):
            u, w = c
            left = jax.lax.ppermute(u[:, :1], "x",
                                    [(i, (i + 1) % n) for i in range(n)])
            right = jax.lax.ppermute(u[:, -1:], "x",
                                     [(i, (i - 1) % n) for i in range(n)])
            u = u + 0.1 * (left + right - 2.0 * u)
            for _ in range(3):
                u = jnp.tanh(u @ w)
            r = jax.lax.psum(jnp.sum(u), "x")
            return (u, w), r
        (u, _), rs = jax.lax.scan(body, (u, w), None, length=length)
        return u, rs

    f = shard_map(step, mesh=mesh, in_specs=(P(None, "x"), P()),
                  out_specs=(P(None, "x"), P()))
    args = (jnp.ones((256, 128 * n)), jnp.ones((128, 128)) * 0.01)
    return f, args, {"x": n}


def allreduce_train_program(n: int = 8, layers: int = 6):
    """Data-parallel training analog (NPB CG-flavored): per-layer compute +
    gradient psum, explicit shard_map DP."""
    ensure_devices()
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map

    mesh = make_mesh((n,), ("x",))

    def step(x, ws):
        def body(c, w):
            h = jnp.tanh(c @ w)
            g = jax.lax.psum(h.sum(axis=0), "x")     # grad all-reduce analog
            return h + 1e-6 * g[None, :], None
        out, _ = jax.lax.scan(body, x, ws)
        return jax.lax.psum(out.sum(), "x")

    f = shard_map(step, mesh=mesh, in_specs=(P("x"), P()),
                  out_specs=P())
    args = (jnp.ones((16 * n, 512)), jnp.ones((layers, 512, 512)) * 0.01)
    return f, args, {"x": n}


def pipeline_traces(n_ranks: int = 8, microbatches: int = 12):
    """Pipeline-parallel schedule (heterogeneous per-rank mains — the case
    that exercises Algorithm 1's clustering).  Host-level TraceSession."""
    ensure_devices()
    import jax.numpy as jnp
    from repro.core.events import CommEvent, ComputeEvent
    from repro.core.tracer import TraceSession, compute_cost

    fwd = compute_cost(lambda a, b: jnp.tanh(a @ b),
                       jnp.ones((64, 256)), jnp.ones((256, 256)))
    with TraceSession(n_ranks=n_ranks) as sess:
        for mb in range(microbatches):
            for r in range(n_ranks):
                sess.emit([r], ComputeEvent(tuple(fwd)))
                if r < n_ranks - 1:   # send activation to next stage
                    sess.emit([r, r + 1],
                              CommEvent("ppermute", (64, 256), "float32",
                                        ("stage",), ("shift", 1)))
        for r in range(n_ranks):
            sess.emit([r], CommEvent("psum", (256, 256), "float32",
                                     ("stage",)))
    return sess.rank_streams


PROGRAMS = {
    "stencil2d": stencil_program,
    "dp_train": allreduce_train_program,
}


def exec_size_cols(proxy) -> dict:
    """Executable-size columns shared by the benchmark tables: the largest
    signature group's traced jaxpr equation count (O(grammar) for compiled
    modules, O(trace) for the unrolled reference) plus the wall-clock cost
    of tracing+compiling that group's dispatchable from cold."""
    import time

    import jax

    from repro.core.replay import init_replay_state
    from repro.sharding.collectives import LocalSim

    counts = proxy.group_eqn_counts()
    sig = max(counts, key=counts.get)
    rank = next(grp[0] for s, grp in proxy.signature_groups() if s == sig)
    comm = LocalSim()
    fn = jax.jit(lambda s: proxy.module.run_rank(s, comm, rank))
    st = init_replay_state(proxy.module)
    t0 = time.perf_counter()
    jax.block_until_ready(fn(st))
    compile_ms = (time.perf_counter() - t0) * 1e3
    return {"jaxpr_eqns": max(counts.values()),
            "compile_ms": round(compile_ms, 1)}
