"""CI guard: grammar-compiled replay parity + executable-size bound.

Two hard gates for the compiled (program-table) codegen flavor, run as part
of the corpus-smoke CI job (``python -m benchmarks.codegen_parity --smoke``):

1. **Oracle parity** — for every scenario in the zoo plus the 64-rank
   synthetic trace, the compiled module and the unrolled
   ``codegen_reference`` module must produce **bit-identical δ̄** (every
   rank, every metric) and **identical per-rank comm sequences** (the
   symbolic expansion of the emitted program tables must equal the merged
   grammar's lossless expansion).  Any drift is a synthesis bug, never a
   tolerance question.

2. **Executable-size guard** — the compiled executable is sized by the
   *grammar*, not the *trace*: growing the synthetic trace's repeated
   structure ≥10× must leave the compiled jaxpr equation count the same
   order (sublinear in events; here: bounded by 2× — in practice flat),
   while the unrolled flavor's never beats the compiled one.

The full run (``--full``) additionally snapshots the rows to
``artifacts/BENCH_6.json`` via :func:`benchmarks.synthesize_time.write_artifacts`.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

_ZOO = ("transformer-dp", "ssm-decode", "moe-ep")


def _pair(name: str, **synth_kw):
    """Synthesize the same input twice — compiled table and unrolled
    reference — returning both results."""
    from repro.core.synthesize import synthesize

    res = synthesize(name=f"{name}_tbl", **synth_kw)
    ref = synthesize(name=f"{name}_unr", codegen="unrolled", **synth_kw)
    assert res.proxy.module.CODEGEN == "table", name
    assert ref.proxy.module.CODEGEN == "unrolled", name
    return res, ref


def _assert_parity(name: str, res, ref, mesh=None) -> dict:
    """δ̄ bit-identity + comm-sequence equality, compiled vs unrolled."""
    from benchmarks.common import exec_size_cols

    n_ranks = res.merged.n_ranks
    assert res.proxy.module.SIGNATURE_GROUPS == \
        ref.proxy.module.SIGNATURE_GROUPS, name
    for r in range(n_ranks):
        assert res.proxy.module.expand_rank_ids(r) == \
            res.merged.expand_rank(r), (name, r, "comm/terminal sequence")
        assert np.array_equal(res.proxy.rank_metrics(r),
                              ref.proxy.rank_metrics(r)), (name, r, "δ̄")
    fid_t = res.fidelity(sample_ranks=None)
    fid_u = ref.fidelity(sample_ranks=None)
    assert np.array_equal(fid_t.delta, fid_u.delta), name
    assert fid_t.comm_lossless and fid_u.comm_lossless, name
    if mesh is not None:
        fm_t = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                  mesh=mesh)
        fm_u = ref.proxy.fidelity(ref.rank_traces, sample_ranks=None,
                                  mesh=mesh)
        assert np.array_equal(fm_t.delta, fm_u.delta), name
        assert fm_t.mesh_checked and fm_u.mesh_checked, name
    tab, unr = exec_size_cols(res.proxy), exec_size_cols(ref.proxy)
    assert tab["jaxpr_eqns"] <= unr["jaxpr_eqns"], (name, tab, unr)
    return {
        "program": f"codegen_parity_{name}",
        "ranks": n_ranks,
        "events": res.stats["n_events"],
        "delta_bit_identical": True,
        "comm_sequences_identical": True,
        "mesh_checked": mesh is not None,
        "table_jaxpr_eqns": tab["jaxpr_eqns"],
        "unrolled_jaxpr_eqns": unr["jaxpr_eqns"],
        "table_compile_ms": tab["compile_ms"],
        "unrolled_compile_ms": unr["compile_ms"],
    }


def zoo_rows(scenarios=_ZOO, n_ranks: int = 8, steps: int = 2,
             mesh_parity: bool = True) -> list[dict]:
    """Oracle parity across the scenario zoo, LocalSim and mesh replay."""
    import jax

    from repro.configs.registry import build_scenario
    from repro.core.replay import submesh_axis_sizes
    from repro.launch.mesh import make_replay_mesh

    rows = []
    for scen in scenarios:
        store = build_scenario(scen, n_ranks=n_ranks, steps=steps)
        res, ref = _pair(scen.replace("-", "_"), store=store)
        mesh = None
        if mesh_parity:
            mesh = make_replay_mesh(submesh_axis_sizes(
                jax.device_count(), dict(res.proxy.axis_sizes)))
        rows.append(_assert_parity(scen, res, ref, mesh=mesh))
    return rows


def size_guard_rows(n_ranks: int = 64, reps: int = 20,
                    scale: int = 10) -> list[dict]:
    """Compiled jaxpr size must be O(grammar): a trace with ``scale``× more
    repeated structure compiles to a same-order executable."""
    from benchmarks.synthesize_time import _synthetic_traces
    from repro.core.synthesize import synthesize

    rows, eqns = [], {}
    for mult in (1, scale):
        traces = _synthetic_traces(n_ranks, reps=reps * mult)
        res, ref = _pair(f"size_{mult}x", rank_traces=traces,
                         axis_sizes={"x": n_ranks})
        row = _assert_parity(f"size_{mult}x_{n_ranks}ranks", res, ref,
                             mesh=None)
        eqns[mult] = row["table_jaxpr_eqns"]
        rows.append(row)
    growth = eqns[scale] / max(eqns[1], 1)
    # sublinear-in-events bound: a scale-x event count must not scale the
    # compiled executable; 2x slack covers grammar-shape jitter at the
    # boundary, in practice the count is flat
    assert growth <= 2.0, (
        f"compiled executable grew {growth:.1f}x under a {scale}x trace — "
        f"O(grammar) sizing regressed: {eqns}")
    rows[-1].update({"event_scale": scale,
                     "eqn_growth": round(growth, 2),
                     "sublinear": True})
    return rows


def run() -> list[dict]:
    return zoo_rows() + size_guard_rows()


def smoke() -> None:
    """CI gate: small zoo + size guard, hard asserts, bounded runtime."""
    rows = zoo_rows(scenarios=_ZOO[:2], n_ranks=4, steps=2)
    rows += size_guard_rows(n_ranks=16, reps=12)
    for r in rows:
        print(", ".join(f"{k}={v}" for k, v in r.items()))
    print("codegen parity OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: 2 zoo scenarios + 16-rank size guard")
    ap.add_argument("--full", action="store_true",
                    help="full zoo + 64-rank size guard; snapshots "
                         "artifacts/BENCH_6.json")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        from benchmarks.synthesize_time import write_artifacts

        rows = run()
        for r in rows:
            print(", ".join(f"{k}={v}" for k, v in r.items()))
        write_artifacts(rows, snapshot="BENCH_6.json",
                        suite="codegen_parity")
