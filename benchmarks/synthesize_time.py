"""Synthesis front-end wall-clock: per-event baseline vs columnar trace IR.

Two tiers:

1. **frontend_64ranks** — a 64-rank synthetic trace (~51k events, 8
   near-identical compute variants, per-rank heterogeneity every 16th
   rank) compressed by the per-event reference
   (:mod:`repro.core.frontend_reference`) and by the columnar path
   (:class:`repro.core.trace_ir.TraceStore` + ``compress_store``).  The
   outputs are asserted bit-identical; ``frontend_speedup`` is the
   acceptance number (target ≥ 5× including event-list ingestion;
   ``compress_speedup`` excludes ingestion — the real pipeline traces
   straight into the store and never pays it).

2. **corpus_zoo** — ``synthesize_corpus`` over three model-zoo scenarios
   vs the per-scenario ``synthesize`` loop (same pgd solver): corpus makes
   **one** batched-PGD dispatch against one per scenario, shares one
   terminal table, and per-scenario δ̄ must be unchanged
   (``max_delta_diff`` = 0.0).

``python -m benchmarks.synthesize_time --smoke`` runs a reduced corpus
(2 scenarios, 4 ranks) with hard asserts — the CI corpus smoke job.
"""
from __future__ import annotations

import time

import numpy as np

_CORPUS_SCENARIOS = ("transformer-dp", "ssm-decode", "moe-ep")


def _synthetic_traces(n_ranks: int = 64, reps: int = 200):
    from repro.core.events import CommEvent, ComputeEvent

    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    base = np.array([2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.])
    comps = [ComputeEvent(tuple(base * (1 + 0.004 * i))) for i in range(8)]
    traces = []
    for r in range(n_ranks):
        tr = []
        for i in range(reps):
            tr += [comps[i % 8], comm, comps[(i + 3) % 8], perm]
        if r % 16 == 0:
            tr = tr + [comm]
        traces.append(tr)
    return traces


def _frontend_row(n_ranks: int = 64) -> dict:
    from repro.core import frontend_reference as ref
    from repro.core.trace_ir import TraceStore, compress_store

    traces = _synthetic_traces(n_ranks)
    n_events = sum(len(t) for t in traces)

    t0 = time.perf_counter()
    g2, m2, ids2, _ = ref.compress_rank_traces_reference(traces)
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    store = TraceStore.from_rank_traces(traces, {"x": n_ranks})
    t_ingest = time.perf_counter() - t0
    t0 = time.perf_counter()
    g1, m1, ids1, _ = compress_store(store)
    t_col = time.perf_counter() - t0

    assert ids1 == ids2, "columnar rank ids diverge from reference"
    assert m1.rules == m2.rules and m1.mains == m2.mains
    assert [e.key() for e in m1.table.events] == \
        [e.key() for e in m2.table.events]
    return {
        "program": f"frontend_{n_ranks}ranks",
        "n_events": n_events,
        "reference_ms": round(t_ref * 1e3, 1),
        "columnar_ms": round(t_col * 1e3, 1),
        "ingest_ms": round(t_ingest * 1e3, 1),
        "frontend_speedup": round(t_ref / (t_col + t_ingest), 2),
        "compress_speedup": round(t_ref / t_col, 2),
        "bit_identical": True,
    }


def _corpus_rows(scenarios=_CORPUS_SCENARIOS, n_ranks=None, steps=None,
                 ) -> list[dict]:
    from repro.configs.registry import build_scenario
    from repro.core.synthesize import synthesize, synthesize_corpus

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    stores = {n: build_scenario(n, **kw) for n in scenarios}

    t0 = time.perf_counter()
    corp = synthesize_corpus([(n, st) for n, st in stores.items()])
    t_corpus = time.perf_counter() - t0

    t0 = time.perf_counter()
    loop = {n: synthesize(store=st, name=n.replace("-", "_"), solver="pgd")
            for n, st in stores.items()}
    t_loop = time.perf_counter() - t0

    delta_diffs = []
    for n in scenarios:
        f_loop = loop[n].fidelity(sample_ranks=None)
        f_corp = corp.results[n].fidelity(sample_ranks=None)
        assert f_loop.comm_lossless and f_corp.comm_lossless, n
        delta_diffs.append(abs(f_loop.mean - f_corp.mean))
    # per-scenario fidelity must be unchanged by corpus-level synthesis —
    # hard assert in the full run too, not just --smoke
    assert float(np.max(delta_diffs)) == 0.0, delta_diffs
    assert corp.stats["n_solver_calls"] == 1
    rep = corp.report(sample_ranks=None)
    return [{
        "program": f"corpus_zoo_{len(scenarios)}scenarios",
        "corpus_ms": round(t_corpus * 1e3, 1),
        "loop_ms": round(t_loop * 1e3, 1),
        "corpus_speedup": round(t_loop / max(t_corpus, 1e-12), 2),
        "solver_dispatches_corpus": corp.stats["n_solver_calls"],
        "solver_dispatches_loop": len(scenarios),
        "n_corpus_terminals": corp.stats["n_corpus_terminals"],
        "n_shared_terminals": corp.stats["n_shared_terminals"],
        "corpus_compression_ratio":
            round(corp.stats["corpus_compression_ratio"], 2),
        "mean_delta": round(rep["mean_delta"], 4),
        "max_delta_diff_vs_loop": float(np.max(delta_diffs)),
        "all_comm_lossless": rep["all_comm_lossless"],
    }]


def run() -> list[dict]:
    return [_frontend_row()] + _corpus_rows()


def smoke() -> None:
    """CI corpus smoke: 2 small scenarios, hard asserts."""
    rows = _corpus_rows(("transformer-dp", "ssm-decode"), n_ranks=4, steps=2)
    row = rows[0]
    print(", ".join(f"{k}={v}" for k, v in row.items()))
    assert row["solver_dispatches_corpus"] == 1, row
    assert row["max_delta_diff_vs_loop"] == 0.0, row
    assert row["all_comm_lossless"], row
    front = _frontend_row(n_ranks=16)
    print(", ".join(f"{k}={v}" for k, v in front.items()))
    assert front["bit_identical"]
    print("corpus smoke OK")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced corpus path with hard asserts (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for r in run():
            print(", ".join(f"{k}={v}" for k, v in r.items()))
