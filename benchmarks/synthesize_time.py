"""Synthesis front-end wall-clock: per-event baseline vs columnar trace IR.

Tiers:

1. **frontend_64ranks** — a 64-rank synthetic trace (~51k events, 8
   near-identical compute variants, per-rank heterogeneity every 16th
   rank) compressed by the per-event reference
   (:mod:`repro.core.frontend_reference`) and by the columnar path
   (:class:`repro.core.trace_ir.TraceStore` + ``compress_store``).  The
   outputs are asserted bit-identical; ``frontend_speedup`` is the
   acceptance number (target ≥ 5× including event-list ingestion;
   ``compress_speedup`` excludes ingestion — the real pipeline traces
   straight into the store and never pays it).

2. **grammar_profile_64ranks** (``--profile``) — the per-stage breakdown
   of the columnar front half (cluster / intern / grammar / merge) on the
   same trace, plus grammar-inference wall-clock three ways:

   * ``grammar_reference_ms`` — the per-event reference's grammar stage
     (one scalar intern+push loop per rank, reference Sequitur), the
     old-world cost;
   * ``grammar_ms`` — the columnar grammar stage (distinct-stream dedup +
     RLE pre-pass + flat kernel); ``grammar_speedup`` is their ratio —
     the acceptance number (target ≥ 5×);
   * ``kernel_reference_ms`` / ``kernel_ms`` — reference vs flat kernel
     on the *same deduped streams* (isolates the kernel itself from the
     dedup win); parity of the emitted rules is hard-asserted.

3. **corpus_zoo** — ``synthesize_corpus`` over three model-zoo scenarios
   vs the per-scenario ``synthesize`` loop (same pgd solver): corpus makes
   **one** batched-PGD dispatch against one per scenario, shares one
   terminal table, and per-scenario δ̄ must be unchanged
   (``max_delta_diff`` = 0.0).

4. **incremental_ingest** — a :class:`repro.core.corpus_store.CorpusStore`
   pre-loaded with N scenarios; the row times *appending scenario N+1 and
   re-synthesizing incrementally* against a from-scratch
   ``synthesize_corpus`` over all N+1, and hard-asserts per-scenario δ̄
   bit-identical between the two (the streaming-corpus invariant).

5. **grammar_cache_warm** (``--profile``) — a CorpusStore is populated and
   synthesized, then *re-opened fresh* (in-memory memos cold, on-disk
   grammar cache warm) and appended to: every unchanged rank stream must
   resolve from the persisted grammar cache, driving the warm append's
   grammar-inference cost to near zero.

6. **remove_scenario** — ``remove_scenario`` on a warm store (the
   partial-sums refold) against the pre-partial-sums baseline (full
   ``ClusterIndex.rebuild`` from survivor metrics), with post-removal
   assignments hard-asserted bit-identical — the O(remaining events)
   removal claim, measured.

``python -m benchmarks.synthesize_time --smoke`` runs a reduced corpus
(2 scenarios, 4 ranks) with hard asserts — the CI corpus smoke job.
``--incremental`` ingests the reduced full zoo one scenario at a time
into a tmp CorpusStore, re-synthesizing after each append, and asserts
the final δ̄ set bit-identical to the batch path — the CI
incremental-corpus job.  ``--parity`` checks flat-kernel vs reference
grammar equality on the reduced zoo's rank streams plus fuzz seeds, and
guards against a silent fallback to the reference kernel — the CI
grammar-parity step.  ``--profile`` runs tiers 2 and 5 and snapshots the
rows to ``artifacts/BENCH_5.json``.

Run as ``__main__`` (or via ``benchmarks.run``), rows are also appended
to ``artifacts/benchmarks.json`` so successive PRs accumulate a
machine-readable perf trajectory.
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

import numpy as np

_CORPUS_SCENARIOS = ("transformer-dp", "ssm-decode", "moe-ep")


def _synthetic_traces(n_ranks: int = 64, reps: int = 200):
    from repro.core.events import CommEvent, ComputeEvent

    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    base = np.array([2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.])
    comps = [ComputeEvent(tuple(base * (1 + 0.004 * i))) for i in range(8)]
    traces = []
    for r in range(n_ranks):
        tr = []
        for i in range(reps):
            tr += [comps[i % 8], comm, comps[(i + 3) % 8], perm]
        if r % 16 == 0:
            tr = tr + [comm]
        traces.append(tr)
    return traces


def _frontend_row(n_ranks: int = 64) -> dict:
    from repro.core import frontend_reference as ref
    from repro.core.trace_ir import TraceStore, compress_store

    traces = _synthetic_traces(n_ranks)
    n_events = sum(len(t) for t in traces)

    t0 = time.perf_counter()
    g2, m2, ids2, _ = ref.compress_rank_traces_reference(traces)
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    store = TraceStore.from_rank_traces(traces, {"x": n_ranks})
    t_ingest = time.perf_counter() - t0
    t0 = time.perf_counter()
    g1, m1, ids1, _ = compress_store(store)
    t_col = time.perf_counter() - t0

    assert ids1 == ids2, "columnar rank ids diverge from reference"
    assert m1.rules == m2.rules and m1.mains == m2.mains
    assert [e.key() for e in m1.table.events] == \
        [e.key() for e in m2.table.events]
    return {
        "program": f"frontend_{n_ranks}ranks",
        "n_events": n_events,
        "reference_ms": round(t_ref * 1e3, 1),
        "columnar_ms": round(t_col * 1e3, 1),
        "ingest_ms": round(t_ingest * 1e3, 1),
        "frontend_speedup": round(t_ref / (t_col + t_ingest), 2),
        "compress_speedup": round(t_ref / t_col, 2),
        "bit_identical": True,
    }


# ---------------------------------------------------------------------------
# grammar-inference profile (tier 2) + kernel parity helpers
# ---------------------------------------------------------------------------


def _distinct_local_streams(store, rel_tol: float = 0.05,
                            cluster_ids=None) -> list[np.ndarray]:
    """The distinct per-rank local-id streams ``compress_store`` feeds the
    grammar kernel (dedup by byte-identical symbol stream, first-appearance
    factorization)."""
    from repro.core.events import cluster_vectors
    from repro.core.trace_ir import (
        _first_appearance_factorize, rank_symbol_streams,
    )

    if cluster_ids is None:
        cluster_ids, _ = cluster_vectors(store.metrics, rel_tol)
    sym_all = rank_symbol_streams(store, cluster_ids)
    out, seen = [], set()
    for r in range(store.n_ranks):
        sym = sym_all[store.extents[r]:store.extents[r + 1]]
        key = sym.tobytes()
        if key not in seen:
            seen.add(key)
            out.append(_first_appearance_factorize(sym)[0])
    return out


def _assert_stream_parity(streams) -> None:
    """Hard parity: flat kernel vs reference on each local-id stream,
    plus the no-silent-fallback guard."""
    from repro.core import sequitur, sequitur_reference, trace_ir
    from repro.core.grammar import Grammar, TerminalTable
    from repro.core.sequitur import rle_runs

    assert sequitur.Sequitur.KERNEL == "flat", \
        "repro.core.sequitur no longer exposes the flat kernel"
    assert trace_ir.Sequitur is sequitur.Sequitur, \
        "compress_store silently fell back off the flat kernel"
    assert sequitur_reference.Sequitur.KERNEL == "reference"
    for lids in streams:
        r = sequitur_reference.Sequitur()
        r.push_ids(lids)
        f = sequitur.Sequitur()
        f.push_runs(*rle_runs(lids))
        table = TerminalTable()     # same table: to_json equality == rules
        assert Grammar(rules=f.grammar_rules(), table=table).to_json() == \
            Grammar(rules=r.grammar_rules(), table=table).to_json(), \
            "flat kernel diverges from sequitur_reference"


def _profile_row(n_ranks: int = 64) -> dict:
    from repro.core import frontend_reference as ref
    from repro.core.events import is_comm
    from repro.core.grammar import TerminalTable
    from repro.core.sequitur import Sequitur as Flat, rle_runs
    from repro.core.sequitur_reference import Sequitur as Ref
    from repro.core.trace_ir import TraceStore, compress_store

    traces = _synthetic_traces(n_ranks)
    store = TraceStore.from_rank_traces(traces, {"x": n_ranks})

    profile: dict = {}
    compress_store(store, profile=profile)

    # old-world grammar inference: the reference front end's grammar stage
    # (one scalar intern+push loop per rank, reference Sequitur), isolated
    # from its clustering stage
    flat_events, index = [], []
    for tr in traces:
        idx = []
        for ev in tr:
            if not is_comm(ev):
                idx.append(len(flat_events))
                flat_events.append(ev)
            else:
                idx.append(-1)
        index.append(idx)
    clustered, _ = ref.cluster_compute_events_reference(flat_events)
    t0 = time.perf_counter()
    for tr, idx in zip(traces, index):
        table = TerminalTable()
        seq = Ref()
        for ev, fi in zip(tr, idx):
            seq.push(table.intern(clustered[fi] if fi >= 0 else ev))
    t_ref_grammar = time.perf_counter() - t0

    # kernel-only comparison on the same deduped streams
    streams = _distinct_local_streams(store)
    _assert_stream_parity(streams)
    rles = [rle_runs(lids) for lids in streams]
    t0 = time.perf_counter()
    for lids in streams:
        r = Ref()
        r.push_ids(lids)
    t_kernel_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    for ids, counts in rles:
        f = Flat()
        f.push_runs(ids, counts)
    t_kernel = time.perf_counter() - t0

    front_ms = (profile["cluster_ms"] + profile["intern_ms"]
                + profile["grammar_ms"] + profile["merge_ms"])
    return {
        "program": f"grammar_profile_{n_ranks}ranks",
        "n_events": store.n_events,
        "n_distinct_streams": profile["n_distinct_streams"],
        "cluster_ms": round(profile["cluster_ms"], 1),
        "intern_ms": round(profile["intern_ms"], 1),
        "grammar_ms": round(profile["grammar_ms"], 1),
        "merge_ms": round(profile["merge_ms"], 1),
        "grammar_share_pct": round(100 * profile["grammar_ms"]
                                   / max(front_ms, 1e-9), 1),
        "grammar_reference_ms": round(t_ref_grammar * 1e3, 1),
        "grammar_speedup": round(t_ref_grammar * 1e3
                                 / max(profile["grammar_ms"], 1e-9), 1),
        "kernel_reference_ms": round(t_kernel_ref * 1e3, 2),
        "kernel_ms": round(t_kernel * 1e3, 2),
        "kernel_speedup": round(t_kernel_ref / max(t_kernel, 1e-12), 2),
        "kernel": "flat",
        "parity": True,
    }


def _grammar_cache_row(scenarios=_CORPUS_SCENARIOS + ("flash-ring",),
                       n_ranks=None, steps=None) -> dict:
    """Warm-store append: populate + synthesize a CorpusStore, re-open it
    fresh (memos cold, grammar cache warm from disk), append one scenario
    and re-synthesize — every unchanged rank stream must hit the persisted
    grammar cache."""
    from repro.configs.registry import build_scenario
    from repro.core.corpus_store import CorpusStore
    from repro.core.synthesize import synthesize_corpus

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    stores = {n: build_scenario(n, **kw) for n in scenarios}
    base, extra = scenarios[:-1], scenarios[-1]

    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n in base:
            cs.add_scenario(n, stores[n])
        corp_cold = synthesize_corpus(store=cs)
        cold_ms = corp_cold.stats["grammar_ms"]

        # fresh open: in-memory front-half memo is gone, the grammar cache
        # comes back from grammar_cache.json
        cs2 = CorpusStore(td)
        assert len(cs2.grammars) > 0, "grammar cache did not persist"
        cs2.add_scenario(extra, stores[extra])
        t0 = time.perf_counter()
        corp_warm = synthesize_corpus(store=cs2)
        t_warm = time.perf_counter() - t0

        hits = corp_warm.stats["n_grammar_cache_hits"]
        misses = corp_warm.stats["n_grammar_cache_misses"]
        # every unchanged (base-scenario) stream must come from the cache:
        # misses can only be the appended scenario's novel streams
        base_streams = sum(
            len(_distinct_local_streams(
                stores[n], cs2.rel_tol,
                cluster_ids=cs2.index.assignments(n))) for n in base)
        assert hits >= base_streams, (hits, base_streams)
        return {
            "program": f"grammar_cache_warm_{len(scenarios)}scenarios",
            "added_scenario": extra,
            "warm_synthesis_ms": round(t_warm * 1e3, 1),
            "grammar_ms_cold": round(cold_ms, 2),
            "grammar_ms_warm": round(corp_warm.stats["grammar_ms"], 2),
            "grammar_cache_hits": hits,
            "grammar_cache_misses": misses,
            "unchanged_streams": base_streams,
            "all_unchanged_streams_hit": True,
        }


# ---------------------------------------------------------------------------
# corpus tiers (3, 4)
# ---------------------------------------------------------------------------


def _corpus_rows(scenarios=_CORPUS_SCENARIOS, n_ranks=None, steps=None,
                 ) -> list[dict]:
    from repro.configs.registry import build_scenario
    from repro.core.synthesize import synthesize, synthesize_corpus

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    stores = {n: build_scenario(n, **kw) for n in scenarios}

    t0 = time.perf_counter()
    corp = synthesize_corpus([(n, st) for n, st in stores.items()])
    t_corpus = time.perf_counter() - t0

    t0 = time.perf_counter()
    loop = {n: synthesize(store=st, name=n.replace("-", "_"), solver="pgd")
            for n, st in stores.items()}
    t_loop = time.perf_counter() - t0

    delta_diffs = []
    for n in scenarios:
        f_loop = loop[n].fidelity(sample_ranks=None)
        f_corp = corp.results[n].fidelity(sample_ranks=None)
        assert f_loop.comm_lossless and f_corp.comm_lossless, n
        delta_diffs.append(abs(f_loop.mean - f_corp.mean))
    # per-scenario fidelity must be unchanged by corpus-level synthesis —
    # hard assert in the full run too, not just --smoke
    assert float(np.max(delta_diffs)) == 0.0, delta_diffs
    assert corp.stats["n_solver_calls"] == 1
    rep = corp.report(sample_ranks=None)
    return [{
        "program": f"corpus_zoo_{len(scenarios)}scenarios",
        "corpus_ms": round(t_corpus * 1e3, 1),
        "loop_ms": round(t_loop * 1e3, 1),
        "corpus_speedup": round(t_loop / max(t_corpus, 1e-12), 2),
        "solver_dispatches_corpus": corp.stats["n_solver_calls"],
        "solver_dispatches_loop": len(scenarios),
        "n_corpus_terminals": corp.stats["n_corpus_terminals"],
        "n_shared_terminals": corp.stats["n_shared_terminals"],
        "corpus_compression_ratio":
            round(corp.stats["corpus_compression_ratio"], 2),
        "mean_delta": round(rep["mean_delta"], 4),
        "max_delta_diff_vs_loop": float(np.max(delta_diffs)),
        "all_comm_lossless": rep["all_comm_lossless"],
    }]


def _incremental_rows(scenarios=_CORPUS_SCENARIOS + ("flash-ring",),
                      n_ranks=None, steps=None) -> list[dict]:
    """Time appending scenario N+1 to a warm CorpusStore (incremental
    synthesis) vs a from-scratch corpus synthesis over all N+1."""
    from repro.configs.registry import build_scenario
    from repro.core.corpus_store import CorpusStore
    from repro.core.synthesize import synthesize_corpus

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    stores = {n: build_scenario(n, **kw) for n in scenarios}
    base, extra = scenarios[:-1], scenarios[-1]

    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n in base:
            cs.add_scenario(n, stores[n])
        synthesize_corpus(store=cs)          # warm front/fit caches over N

        t0 = time.perf_counter()
        cs.add_scenario(extra, stores[extra])
        corp_inc = synthesize_corpus(store=cs)
        t_incr = time.perf_counter() - t0

        t0 = time.perf_counter()
        corp_full = synthesize_corpus([(n, stores[n]) for n in scenarios])
        t_full = time.perf_counter() - t0

        # the streaming-corpus invariant: appending must not change what a
        # from-scratch synthesis would have produced — hard assert always
        diffs = []
        for n in scenarios:
            f_inc = corp_inc.results[n].fidelity(sample_ranks=None)
            f_full = corp_full.results[n].fidelity(sample_ranks=None)
            assert f_inc.comm_lossless and f_full.comm_lossless, n
            np.testing.assert_array_equal(f_inc.delta, f_full.delta)
            diffs.append(abs(f_inc.mean - f_full.mean))
        assert float(np.max(diffs)) == 0.0, diffs

        return [{
            "program": f"incremental_ingest_{len(scenarios)}scenarios",
            "added_scenario": extra,
            "incremental_ms": round(t_incr * 1e3, 1),
            "full_resynthesis_ms": round(t_full * 1e3, 1),
            "incremental_speedup": round(t_full / max(t_incr, 1e-12), 2),
            "n_refit_terminals": corp_inc.stats["n_refit_terminals"],
            "n_cached_fits": corp_inc.stats["n_cached_fits"],
            "n_front_reused": corp_inc.stats["n_front_reused"],
            "n_result_reused": corp_inc.stats["n_result_reused"],
            "n_grammar_cache_hits": corp_inc.stats["n_grammar_cache_hits"],
            "solver_dispatches_incremental": corp_inc.stats["n_solver_calls"],
            "max_delta_diff_vs_full": float(np.max(diffs)),
        }]


def _removal_row(scenarios=_CORPUS_SCENARIOS + ("flash-ring",),
                 n_ranks=None, steps=None) -> dict:
    """Time ``remove_scenario`` on a warm store: the partial-sums refold
    (drop the scenario's bucket table, renumber + refold survivors —
    O(distinct buckets)) against the pre-partial-sums baseline (full
    ``ClusterIndex.rebuild`` from survivor metrics — O(remaining
    events)), with the durable end-to-end operation (refold + atomic
    shard/index rewrite + fsync) reported separately — so the
    O(remaining) claim is measured, not asserted, and constant file I/O
    doesn't masquerade as algorithmic cost.  Post-removal assignments
    are hard-asserted bit-identical to the from-scratch rebuild."""
    from repro.configs.registry import build_scenario
    from repro.core.corpus_store import ClusterIndex, CorpusStore

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    stores = {n: build_scenario(n, **kw) for n in scenarios}

    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n in scenarios:
            cs.add_scenario(n, stores[n])
        cs.cluster_assignments()                  # warm derive
        victim = cs.names[0]
        survivors = [n for n in cs.names if n != victim]

        # "before": what v1 remove_scenario did — re-cluster every
        # surviving event from metrics
        t0 = time.perf_counter()
        idx_rebuilt = ClusterIndex.rebuild(
            cs.rel_tol, [(n, stores[n].metrics) for n in survivors],
            expected_rel_tol=cs.rel_tol)
        idx_rebuilt.derive()
        t_rebuild = time.perf_counter() - t0

        # "after", in-memory: the partial-sums refold over the
        # survivors' pre-reduced bucket tables
        t0 = time.perf_counter()
        idx_fold = ClusterIndex(
            rel_tol=cs.rel_tol,
            tables={n: cs.index.tables[n] for n in survivors},
            order=list(survivors))
        idx_fold.derive()
        t_refold = time.perf_counter() - t0

        # the durable operation (refold + shard/index persistence)
        t0 = time.perf_counter()
        cs.remove_scenario(victim)
        cs.cluster_assignments()
        t_remove = time.perf_counter() - t0

        for n in survivors:
            np.testing.assert_array_equal(cs.index.assignments(n),
                                          idx_rebuilt.assignments(n))
            np.testing.assert_array_equal(cs.index.assignments(n),
                                          idx_fold.assignments(n))
        n_events = sum(stores[n].n_compute_events for n in survivors)
        return {
            "program": f"remove_scenario_{len(scenarios)}scenarios",
            "removed_scenario": victim,
            "n_surviving_events": n_events,
            "n_surviving_buckets": idx_fold.n_buckets,
            "refold_ms": round(t_refold * 1e3, 3),
            "full_rebuild_ms": round(t_rebuild * 1e3, 3),
            "remove_scenario_ms": round(t_remove * 1e3, 3),
            "removal_speedup": round(t_rebuild / max(t_refold, 1e-12), 2),
            "bit_identical_to_rebuild": True,
        }


# ---------------------------------------------------------------------------
# artifact trajectory
# ---------------------------------------------------------------------------


def write_artifacts(rows: list[dict], snapshot: str | None = "BENCH_5.json",
                    out_dir="artifacts",
                    suite: str = "synthesize_time") -> None:
    """Merge the rows (keyed by ``program``) into the ``suite`` entry of
    ``<out_dir>/benchmarks.json`` and refresh the pinned snapshot, so
    future PRs have a machine-readable perf baseline to regress against.
    Merging means a partial run (``--profile``) updates its own rows
    without clobbering the rest of the suite's trajectory."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    bpath = out / "benchmarks.json"
    existing = json.loads(bpath.read_text()) if bpath.exists() else {}
    merged = {r.get("program", f"row{i}"): r
              for i, r in enumerate(existing.get(suite, []))}
    for i, r in enumerate(rows):
        merged[r.get("program", f"new{i}")] = r
    rows_out = list(merged.values())
    existing[suite] = rows_out
    bpath.write_text(json.dumps(existing, indent=1))
    if snapshot:
        (out / snapshot).write_text(json.dumps(
            {"suite": suite, "rows": rows_out}, indent=1))
    print(f"wrote {bpath}" + (f" and {out / snapshot}" if snapshot else ""))


def run() -> list[dict]:
    return ([_frontend_row(), _profile_row()] + _corpus_rows()
            + _incremental_rows() + [_grammar_cache_row(), _removal_row()])


def smoke() -> None:
    """CI corpus smoke: 2 small scenarios, hard asserts."""
    rows = _corpus_rows(("transformer-dp", "ssm-decode"), n_ranks=4, steps=2)
    row = rows[0]
    print(", ".join(f"{k}={v}" for k, v in row.items()))
    assert row["solver_dispatches_corpus"] == 1, row
    assert row["max_delta_diff_vs_loop"] == 0.0, row
    assert row["all_comm_lossless"], row
    front = _frontend_row(n_ranks=16)
    print(", ".join(f"{k}={v}" for k, v in front.items()))
    assert front["bit_identical"]
    print("corpus smoke OK")


def parity() -> None:
    """CI grammar-parity step: flat kernel vs sequitur_reference on the
    reduced zoo's rank streams + seeded fuzz, and the silent-fallback
    guard (tier-1's tests/test_sequitur_kernel.py covers the same ground
    in depth; this step keeps the corpus-smoke job self-contained)."""
    from repro.configs.registry import SCENARIO_IDS, build_scenario

    n_streams = 0
    for name in SCENARIO_IDS:
        store = build_scenario(name, n_ranks=4, steps=2)
        streams = _distinct_local_streams(store)
        _assert_stream_parity(streams)
        n_streams += len(streams)
    rng = np.random.RandomState(5)
    fuzz = []
    for _ in range(8):
        seq = rng.randint(0, rng.choice([2, 3, 5]),
                          rng.randint(20, 200)).astype(np.int64)
        fuzz.append(seq)
    _assert_stream_parity(fuzz)
    print(f"grammar parity OK ({n_streams} zoo streams + {len(fuzz)} fuzz "
          f"seeds, kernel=flat)")


def incremental_smoke() -> None:
    """CI incremental-corpus smoke: ingest the (reduced) full zoo one
    scenario at a time into a tmp CorpusStore, re-synthesize after every
    append, and assert the final per-scenario δ̄ bit-identical to the
    batch corpus path over the same stores."""
    from repro.configs.registry import SCENARIO_IDS, build_scenario
    from repro.core.corpus_store import CorpusStore
    from repro.core.synthesize import synthesize_corpus

    names = list(SCENARIO_IDS)
    stores = {n: build_scenario(n, n_ranks=4, steps=2) for n in names}
    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n in names:
            cs.add_scenario(n, stores[n])
            corp = synthesize_corpus(store=cs)     # after every append
            print(f"ingested {n}: refit={corp.stats['n_refit_terminals']} "
                  f"cached={corp.stats['n_cached_fits']} "
                  f"front_reused={corp.stats['n_front_reused']} "
                  f"grammar_hits={corp.stats['n_grammar_cache_hits']}")
        batch = synthesize_corpus([(n, stores[n]) for n in names])
        for n in names:
            f_inc = corp.results[n].fidelity(sample_ranks=None)
            f_bat = batch.results[n].fidelity(sample_ranks=None)
            assert f_inc.comm_lossless and f_bat.comm_lossless, n
            np.testing.assert_array_equal(f_inc.delta, f_bat.delta)
        row = _incremental_rows(("transformer-dp", "ssm-decode", "moe-ep"),
                                n_ranks=4, steps=2)[0]
        print(", ".join(f"{k}={v}" for k, v in row.items()))
        assert row["max_delta_diff_vs_full"] == 0.0, row
        cache_row = _grammar_cache_row(
            ("transformer-dp", "ssm-decode", "moe-ep"), n_ranks=4, steps=2)
        print(", ".join(f"{k}={v}" for k, v in cache_row.items()))
        assert cache_row["all_unchanged_streams_hit"], cache_row
    print("incremental corpus smoke OK")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced corpus path with hard asserts (CI)")
    ap.add_argument("--incremental", action="store_true",
                    help="one-scenario-at-a-time CorpusStore ingest vs "
                         "batch corpus, hard asserts (CI)")
    ap.add_argument("--parity", action="store_true",
                    help="flat-kernel vs reference grammar parity on the "
                         "reduced zoo + fallback guard (CI)")
    ap.add_argument("--profile", action="store_true",
                    help="per-stage front-end breakdown + warm grammar "
                         "cache rows; snapshots artifacts/BENCH_5.json")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    elif args.incremental:
        incremental_smoke()
    elif args.parity:
        parity()
    elif args.profile:
        rows = [_profile_row(), _grammar_cache_row()]
        for r in rows:
            print(", ".join(f"{k}={v}" for k, v in r.items()))
        write_artifacts(rows)
    else:
        rows = run()
        for r in rows:
            print(", ".join(f"{k}={v}" for k, v in r.items()))
        write_artifacts(rows)
