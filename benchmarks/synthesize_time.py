"""Synthesis front-end wall-clock: per-event baseline vs columnar trace IR.

Two tiers:

1. **frontend_64ranks** — a 64-rank synthetic trace (~51k events, 8
   near-identical compute variants, per-rank heterogeneity every 16th
   rank) compressed by the per-event reference
   (:mod:`repro.core.frontend_reference`) and by the columnar path
   (:class:`repro.core.trace_ir.TraceStore` + ``compress_store``).  The
   outputs are asserted bit-identical; ``frontend_speedup`` is the
   acceptance number (target ≥ 5× including event-list ingestion;
   ``compress_speedup`` excludes ingestion — the real pipeline traces
   straight into the store and never pays it).

2. **corpus_zoo** — ``synthesize_corpus`` over three model-zoo scenarios
   vs the per-scenario ``synthesize`` loop (same pgd solver): corpus makes
   **one** batched-PGD dispatch against one per scenario, shares one
   terminal table, and per-scenario δ̄ must be unchanged
   (``max_delta_diff`` = 0.0).

3. **incremental_ingest** — a :class:`repro.core.corpus_store.CorpusStore`
   pre-loaded with N scenarios; the row times *appending scenario N+1 and
   re-synthesizing incrementally* against a from-scratch
   ``synthesize_corpus`` over all N+1, and hard-asserts per-scenario δ̄
   bit-identical between the two (the streaming-corpus invariant).

``python -m benchmarks.synthesize_time --smoke`` runs a reduced corpus
(2 scenarios, 4 ranks) with hard asserts — the CI corpus smoke job.
``--incremental`` ingests the reduced full zoo one scenario at a time
into a tmp CorpusStore, re-synthesizing after each append, and asserts
the final δ̄ set bit-identical to the batch path — the CI
incremental-corpus job.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

_CORPUS_SCENARIOS = ("transformer-dp", "ssm-decode", "moe-ep")


def _synthetic_traces(n_ranks: int = 64, reps: int = 200):
    from repro.core.events import CommEvent, ComputeEvent

    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    base = np.array([2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.])
    comps = [ComputeEvent(tuple(base * (1 + 0.004 * i))) for i in range(8)]
    traces = []
    for r in range(n_ranks):
        tr = []
        for i in range(reps):
            tr += [comps[i % 8], comm, comps[(i + 3) % 8], perm]
        if r % 16 == 0:
            tr = tr + [comm]
        traces.append(tr)
    return traces


def _frontend_row(n_ranks: int = 64) -> dict:
    from repro.core import frontend_reference as ref
    from repro.core.trace_ir import TraceStore, compress_store

    traces = _synthetic_traces(n_ranks)
    n_events = sum(len(t) for t in traces)

    t0 = time.perf_counter()
    g2, m2, ids2, _ = ref.compress_rank_traces_reference(traces)
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    store = TraceStore.from_rank_traces(traces, {"x": n_ranks})
    t_ingest = time.perf_counter() - t0
    t0 = time.perf_counter()
    g1, m1, ids1, _ = compress_store(store)
    t_col = time.perf_counter() - t0

    assert ids1 == ids2, "columnar rank ids diverge from reference"
    assert m1.rules == m2.rules and m1.mains == m2.mains
    assert [e.key() for e in m1.table.events] == \
        [e.key() for e in m2.table.events]
    return {
        "program": f"frontend_{n_ranks}ranks",
        "n_events": n_events,
        "reference_ms": round(t_ref * 1e3, 1),
        "columnar_ms": round(t_col * 1e3, 1),
        "ingest_ms": round(t_ingest * 1e3, 1),
        "frontend_speedup": round(t_ref / (t_col + t_ingest), 2),
        "compress_speedup": round(t_ref / t_col, 2),
        "bit_identical": True,
    }


def _corpus_rows(scenarios=_CORPUS_SCENARIOS, n_ranks=None, steps=None,
                 ) -> list[dict]:
    from repro.configs.registry import build_scenario
    from repro.core.synthesize import synthesize, synthesize_corpus

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    stores = {n: build_scenario(n, **kw) for n in scenarios}

    t0 = time.perf_counter()
    corp = synthesize_corpus([(n, st) for n, st in stores.items()])
    t_corpus = time.perf_counter() - t0

    t0 = time.perf_counter()
    loop = {n: synthesize(store=st, name=n.replace("-", "_"), solver="pgd")
            for n, st in stores.items()}
    t_loop = time.perf_counter() - t0

    delta_diffs = []
    for n in scenarios:
        f_loop = loop[n].fidelity(sample_ranks=None)
        f_corp = corp.results[n].fidelity(sample_ranks=None)
        assert f_loop.comm_lossless and f_corp.comm_lossless, n
        delta_diffs.append(abs(f_loop.mean - f_corp.mean))
    # per-scenario fidelity must be unchanged by corpus-level synthesis —
    # hard assert in the full run too, not just --smoke
    assert float(np.max(delta_diffs)) == 0.0, delta_diffs
    assert corp.stats["n_solver_calls"] == 1
    rep = corp.report(sample_ranks=None)
    return [{
        "program": f"corpus_zoo_{len(scenarios)}scenarios",
        "corpus_ms": round(t_corpus * 1e3, 1),
        "loop_ms": round(t_loop * 1e3, 1),
        "corpus_speedup": round(t_loop / max(t_corpus, 1e-12), 2),
        "solver_dispatches_corpus": corp.stats["n_solver_calls"],
        "solver_dispatches_loop": len(scenarios),
        "n_corpus_terminals": corp.stats["n_corpus_terminals"],
        "n_shared_terminals": corp.stats["n_shared_terminals"],
        "corpus_compression_ratio":
            round(corp.stats["corpus_compression_ratio"], 2),
        "mean_delta": round(rep["mean_delta"], 4),
        "max_delta_diff_vs_loop": float(np.max(delta_diffs)),
        "all_comm_lossless": rep["all_comm_lossless"],
    }]


def _incremental_rows(scenarios=_CORPUS_SCENARIOS + ("flash-ring",),
                      n_ranks=None, steps=None) -> list[dict]:
    """Time appending scenario N+1 to a warm CorpusStore (incremental
    synthesis) vs a from-scratch corpus synthesis over all N+1."""
    from repro.configs.registry import build_scenario
    from repro.core.corpus_store import CorpusStore
    from repro.core.synthesize import synthesize_corpus

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    stores = {n: build_scenario(n, **kw) for n in scenarios}
    base, extra = scenarios[:-1], scenarios[-1]

    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n in base:
            cs.add_scenario(n, stores[n])
        synthesize_corpus(store=cs)          # warm front/fit caches over N

        t0 = time.perf_counter()
        cs.add_scenario(extra, stores[extra])
        corp_inc = synthesize_corpus(store=cs)
        t_incr = time.perf_counter() - t0

        t0 = time.perf_counter()
        corp_full = synthesize_corpus([(n, stores[n]) for n in scenarios])
        t_full = time.perf_counter() - t0

        # the streaming-corpus invariant: appending must not change what a
        # from-scratch synthesis would have produced — hard assert always
        diffs = []
        for n in scenarios:
            f_inc = corp_inc.results[n].fidelity(sample_ranks=None)
            f_full = corp_full.results[n].fidelity(sample_ranks=None)
            assert f_inc.comm_lossless and f_full.comm_lossless, n
            np.testing.assert_array_equal(f_inc.delta, f_full.delta)
            diffs.append(abs(f_inc.mean - f_full.mean))
        assert float(np.max(diffs)) == 0.0, diffs

        return [{
            "program": f"incremental_ingest_{len(scenarios)}scenarios",
            "added_scenario": extra,
            "incremental_ms": round(t_incr * 1e3, 1),
            "full_resynthesis_ms": round(t_full * 1e3, 1),
            "incremental_speedup": round(t_full / max(t_incr, 1e-12), 2),
            "n_refit_terminals": corp_inc.stats["n_refit_terminals"],
            "n_cached_fits": corp_inc.stats["n_cached_fits"],
            "n_front_reused": corp_inc.stats["n_front_reused"],
            "n_result_reused": corp_inc.stats["n_result_reused"],
            "solver_dispatches_incremental": corp_inc.stats["n_solver_calls"],
            "max_delta_diff_vs_full": float(np.max(diffs)),
        }]


def run() -> list[dict]:
    return [_frontend_row()] + _corpus_rows() + _incremental_rows()


def smoke() -> None:
    """CI corpus smoke: 2 small scenarios, hard asserts."""
    rows = _corpus_rows(("transformer-dp", "ssm-decode"), n_ranks=4, steps=2)
    row = rows[0]
    print(", ".join(f"{k}={v}" for k, v in row.items()))
    assert row["solver_dispatches_corpus"] == 1, row
    assert row["max_delta_diff_vs_loop"] == 0.0, row
    assert row["all_comm_lossless"], row
    front = _frontend_row(n_ranks=16)
    print(", ".join(f"{k}={v}" for k, v in front.items()))
    assert front["bit_identical"]
    print("corpus smoke OK")


def incremental_smoke() -> None:
    """CI incremental-corpus smoke: ingest the (reduced) full zoo one
    scenario at a time into a tmp CorpusStore, re-synthesize after every
    append, and assert the final per-scenario δ̄ bit-identical to the
    batch corpus path over the same stores."""
    from repro.configs.registry import SCENARIO_IDS, build_scenario
    from repro.core.corpus_store import CorpusStore
    from repro.core.synthesize import synthesize_corpus

    names = list(SCENARIO_IDS)
    stores = {n: build_scenario(n, n_ranks=4, steps=2) for n in names}
    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n in names:
            cs.add_scenario(n, stores[n])
            corp = synthesize_corpus(store=cs)     # after every append
            print(f"ingested {n}: refit={corp.stats['n_refit_terminals']} "
                  f"cached={corp.stats['n_cached_fits']} "
                  f"front_reused={corp.stats['n_front_reused']}")
        batch = synthesize_corpus([(n, stores[n]) for n in names])
        for n in names:
            f_inc = corp.results[n].fidelity(sample_ranks=None)
            f_bat = batch.results[n].fidelity(sample_ranks=None)
            assert f_inc.comm_lossless and f_bat.comm_lossless, n
            np.testing.assert_array_equal(f_inc.delta, f_bat.delta)
        row = _incremental_rows(("transformer-dp", "ssm-decode", "moe-ep"),
                                n_ranks=4, steps=2)[0]
        print(", ".join(f"{k}={v}" for k, v in row.items()))
        assert row["max_delta_diff_vs_full"] == 0.0, row
    print("incremental corpus smoke OK")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced corpus path with hard asserts (CI)")
    ap.add_argument("--incremental", action="store_true",
                    help="one-scenario-at-a-time CorpusStore ingest vs "
                         "batch corpus, hard asserts (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    elif args.incremental:
        incremental_smoke()
    else:
        for r in run():
            print(", ".join(f"{k}={v}" for k, v in r.items()))
