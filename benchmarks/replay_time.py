"""Paper Fig. 7-8 analog: replay execution time + cumulative-progress curve.

On this CPU host the original program and the proxy both execute for real;
we compare wall times and the time-vs-events-executed staircase (sequence
similarity, Fig. 8).

Also benchmarks the batched multi-rank replay engine (§3.3): a 16-rank
synthetic trace replayed per-rank (the old baseline: one jitted dispatch
per rank) vs batched by control-flow signature group (one compiled
executable per group).  Reported as ``replay_speedup`` — the acceptance
target is ≥ 3×."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import PROGRAMS

_BATCH_RANKS = 16


def _batched_replay_rows() -> list[dict]:
    from repro.core.events import CommEvent, ComputeEvent
    from repro.core.synthesize import synthesize

    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    comp = ComputeEvent((2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.))
    traces = []
    for r in range(_BATCH_RANKS):
        tr = [comp, comm, comp, perm] * 6
        if r == 0:
            tr = tr + [comm]     # heterogeneous rank → second signature group
        traces.append(tr)
    res = synthesize(rank_traces=traces, axis_sizes={"x": _BATCH_RANKS},
                     name="rt_batched")

    t_per_rank = res.proxy.time_all(iters=3, batched=False)
    t_batched = res.proxy.time_all(iters=3, batched=True)
    # distinct per-rank states: vmapped group sweep vs its own baseline
    t_vmapped = res.proxy.time_all(iters=3, batched=True, per_rank_seeds=True)
    t_seeded = res.proxy.time_all(iters=3, batched=False, per_rank_seeds=True)
    fid = res.fidelity(sample_ranks=None)
    fid_per_rank = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                      batched=False)
    return [{
        "program": f"batched_replay_{_BATCH_RANKS}ranks",
        "n_signature_groups": res.stats["n_signature_groups"],
        "per_rank_sweep_ms": round(t_per_rank * 1e3, 3),
        "batched_sweep_ms": round(t_batched * 1e3, 3),
        "vmapped_sweep_ms": round(t_vmapped * 1e3, 3),
        "per_rank_seeded_sweep_ms": round(t_seeded * 1e3, 3),
        "replay_speedup": round(t_per_rank / max(t_batched, 1e-12), 2),
        "vmapped_speedup": round(t_seeded / max(t_vmapped, 1e-12), 2),
        "ranks_per_sec_batched": round(_BATCH_RANKS / max(t_batched, 1e-12), 1),
        "fidelity_delta_vs_per_rank": float(
            np.max(np.abs(fid.delta - fid_per_rank.delta))),
    }]


def run() -> list[dict]:
    import jax
    from repro.core.synthesize import synthesize
    rows = _batched_replay_rows()
    for name, builder in PROGRAMS.items():
        fn, args, axes = builder(8)
        jfn = jax.jit(fn)
        jax.block_until_ready(jfn(*args))     # compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(jfn(*args))
        t_orig = (time.perf_counter() - t0) / 3

        res = synthesize(fn, *args, axis_sizes=axes, name=f"rt_{name}")
        t_proxy = res.proxy.time_local(0, iters=3)
        rows.append({
            "program": name,
            "orig_ms": round(t_orig * 1e3, 3),
            "proxy_ms": round(t_proxy * 1e3, 3),
            "time_err": round(abs(t_proxy - t_orig) / t_orig, 3),
        })

        # Fig. 8: cumulative roofline-seconds vs event index (shape match)
        from repro.core.metrics import roofline_seconds, comm_seconds
        from repro.core.events import is_comm
        from repro.core import blocks as B

        def curve(events, combos=None):
            out, t = [], 0.0
            ci = 0
            for e in events:
                if is_comm(e):
                    t += comm_seconds(e.payload_bytes, 8)
                else:
                    t += roofline_seconds(e.vector)
                out.append(t)
            return np.asarray(out)

        orig_curve = curve(res.rank_traces[0])
        proxy_events = [res.merged.table[i]
                        for i in res.merged.expand_rank(0)]
        proxy_curve = []
        t = 0.0
        for e in proxy_events:
            if is_comm(e):
                t += comm_seconds(e.payload_bytes, 8)
            else:
                x, u = res.proxy.combos[
                    res.merged.table.by_key[e.key()]]
                t += roofline_seconds(B.combo_cost(x, u))
            proxy_curve.append(t)
        proxy_curve = np.asarray(proxy_curve)
        m = min(len(orig_curve), len(proxy_curve))
        corr = float(np.corrcoef(orig_curve[:m], proxy_curve[:m])[0, 1])
        end_err = float(abs(proxy_curve[-1] - orig_curve[-1])
                        / orig_curve[-1])
        rows.append({
            "program": name + "_curve",
            "staircase_corr": round(corr, 5),
            "endpoint_err": round(end_err, 4),
        })
    return rows
