"""Paper Fig. 7-8 analog: replay execution time + cumulative-progress curve.

On this CPU host the original program and the proxy both execute for real;
we compare wall times and the time-vs-events-executed staircase (sequence
similarity, Fig. 8)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import PROGRAMS


def run() -> list[dict]:
    import jax
    from repro.core.synthesize import synthesize
    rows = []
    for name, builder in PROGRAMS.items():
        fn, args, axes = builder(8)
        jfn = jax.jit(fn)
        jax.block_until_ready(jfn(*args))     # compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(jfn(*args))
        t_orig = (time.perf_counter() - t0) / 3

        res = synthesize(fn, *args, axis_sizes=axes, name=f"rt_{name}")
        t_proxy = res.proxy.time_local(0, iters=3)
        rows.append({
            "program": name,
            "orig_ms": round(t_orig * 1e3, 3),
            "proxy_ms": round(t_proxy * 1e3, 3),
            "time_err": round(abs(t_proxy - t_orig) / t_orig, 3),
        })

        # Fig. 8: cumulative roofline-seconds vs event index (shape match)
        from repro.core.metrics import roofline_seconds, comm_seconds
        from repro.core.events import is_comm
        from repro.core import blocks as B

        def curve(events, combos=None):
            out, t = [], 0.0
            ci = 0
            for e in events:
                if is_comm(e):
                    t += comm_seconds(e.payload_bytes, 8)
                else:
                    t += roofline_seconds(e.vector)
                out.append(t)
            return np.asarray(out)

        orig_curve = curve(res.rank_traces[0])
        proxy_events = [res.merged.table[i]
                        for i in res.merged.expand_rank(0)]
        proxy_curve = []
        t = 0.0
        for e in proxy_events:
            if is_comm(e):
                t += comm_seconds(e.payload_bytes, 8)
            else:
                x, u = res.proxy.combos[
                    res.merged.table.by_key[e.key()]]
                t += roofline_seconds(B.combo_cost(x, u))
            proxy_curve.append(t)
        proxy_curve = np.asarray(proxy_curve)
        m = min(len(orig_curve), len(proxy_curve))
        corr = float(np.corrcoef(orig_curve[:m], proxy_curve[:m])[0, 1])
        end_err = float(abs(proxy_curve[-1] - orig_curve[-1])
                        / orig_curve[-1])
        rows.append({
            "program": name + "_curve",
            "staircase_corr": round(corr, 5),
            "endpoint_err": round(end_err, 4),
        })
    return rows
