"""Paper Fig. 7-8 analog: replay execution time + cumulative-progress curve.

On this CPU host the original program and the proxy both execute for real;
we compare wall times and the time-vs-events-executed staircase (sequence
similarity, Fig. 8).

Also benchmarks the multi-rank replay engine (§3.3) across its three tiers
on a 16-rank synthetic trace:

1. **per-rank** (``batched=False``): one jitted dispatch per rank — the
   original baseline.  Use it only as a parity/measurement reference.
2. **batched-local** (``run_all``/``time_all`` default): one compiled
   executable per control-flow signature group, the rank axis ``vmap``-ed
   through ``LocalSim`` sequence points.  The right tier when only the
   compute stream matters (single host, no real network): ~7× sweep
   throughput here.
3. **mesh-sharded** (``mesh=``): signature groups placed on disjoint device
   subsets, each group replaying its *real* collectives via ``DeviceComm``
   in a single ``shard_map`` dispatch (rank axis folded through the
   collectives), groups dispatched asynchronously.  The right tier when
   comm fidelity at the target's concurrency matters — it is the path
   whose lowered HLO reproduces the traced collective schedule.

Run under ``benchmarks.run`` (which forces an 8-device CPU host platform),
the mesh sweep replays all 16 per-rank-seeded ranks in one dispatch per
signature group.  ``mesh_state_delta_vs_seq`` is the max |final-state
difference| between that batched sweep and the sequential mesh path (one
dispatch per rank, same placement) — executed on the mesh, and must be
exactly 0.0 (bit-identical).  ``fid_delta_vs_local`` confirms δ̄ is
placement-invariant (walker metrics never depend on the replay backend).
Local-tier acceptance target stays ≥ 3× (``replay_speedup``)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import PROGRAMS

_BATCH_RANKS = 16


def _batched_replay_rows() -> list[dict]:
    import jax
    from repro.core.events import CommEvent, ComputeEvent
    from repro.core.replay import submesh_axis_sizes
    from repro.core.synthesize import synthesize
    from repro.launch.mesh import make_replay_mesh

    comm = CommEvent("psum", (16,), "float32", ("x",))
    perm = CommEvent("ppermute", (4, 4), "bfloat16", ("x",), ("shift", 1))
    comp = ComputeEvent((2.1e7, 3.3e5, 1.1e7, 8.2e3, 0., 0.))
    traces = []
    for r in range(_BATCH_RANKS):
        tr = [comp, comm, comp, perm] * 6
        if r == 0:
            tr = tr + [comm]     # heterogeneous rank → second signature group
        traces.append(tr)
    res = synthesize(rank_traces=traces, axis_sizes={"x": _BATCH_RANKS},
                     name="rt_batched")

    t_per_rank = res.proxy.time_all(iters=3, batched=False)
    t_batched = res.proxy.time_all(iters=3, batched=True)
    # distinct per-rank states: vmapped group sweep vs its own baseline
    t_vmapped = res.proxy.time_all(iters=3, batched=True, per_rank_seeds=True)
    t_seeded = res.proxy.time_all(iters=3, batched=False, per_rank_seeds=True)
    fid = res.fidelity(sample_ranks=None)
    fid_per_rank = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                      batched=False)
    rows = [{
        "program": f"batched_replay_{_BATCH_RANKS}ranks",
        "n_signature_groups": res.stats["n_signature_groups"],
        "per_rank_sweep_ms": round(t_per_rank * 1e3, 3),
        "batched_sweep_ms": round(t_batched * 1e3, 3),
        "vmapped_sweep_ms": round(t_vmapped * 1e3, 3),
        "per_rank_seeded_sweep_ms": round(t_seeded * 1e3, 3),
        "replay_speedup": round(t_per_rank / max(t_batched, 1e-12), 2),
        "vmapped_speedup": round(t_seeded / max(t_vmapped, 1e-12), 2),
        "ranks_per_sec_batched": round(_BATCH_RANKS / max(t_batched, 1e-12), 1),
        "fidelity_delta_vs_per_rank": float(
            np.max(np.abs(fid.delta - fid_per_rank.delta))),
    }]

    # tier 3: mesh-sharded sweep — real collectives, one shard_map dispatch
    # per signature group, groups on disjoint device subsets
    n_dev = jax.device_count()
    mesh = make_replay_mesh(submesh_axis_sizes(n_dev, {"x": _BATCH_RANKS}))
    plan = res.proxy.mesh_sweep_plan(mesh)
    t_mesh_seq = res.proxy.time_all(iters=3, mesh=mesh, batched=False,
                                    per_rank_seeds=True)
    t_mesh = res.proxy.time_all(iters=3, mesh=mesh, per_rank_seeds=True)
    # executed-on-mesh bit-identity: batched group dispatch vs the
    # sequential per-rank dispatches on the same placement
    out_b = res.proxy.run_all(mesh=mesh, per_rank_seeds=True)
    out_s = res.proxy.run_all(mesh=mesh, per_rank_seeds=True, batched=False)
    state_delta = max(
        float(np.max(np.abs(np.asarray(out_b[r][k], np.float32)
                            - np.asarray(out_s[r][k], np.float32))))
        for r in out_b for k in out_b[r])
    fid_mesh = res.proxy.fidelity(res.rank_traces, sample_ranks=None,
                                  mesh=mesh)
    rows.append({
        "program": f"mesh_sharded_replay_{_BATCH_RANKS}ranks",
        "mesh_devices": n_dev,
        "mesh_groups": len(plan),
        "mesh_dispatches_per_sweep": len(plan),   # one shard_map per group
        "mesh_seq_sweep_ms": round(t_mesh_seq * 1e3, 3),
        "mesh_sweep_ms": round(t_mesh * 1e3, 3),
        "mesh_speedup": round(t_mesh_seq / max(t_mesh, 1e-12), 2),
        "mesh_state_delta_vs_seq": state_delta,
        "fid_delta_vs_local": float(
            np.max(np.abs(fid_mesh.delta - fid_per_rank.delta))),
        "mesh_checked": fid_mesh.mesh_checked,
    })
    rows.append(_codegen_row(res))
    return rows


def _codegen_row(res) -> dict:
    """Grammar-compiled vs unrolled-reference executables on the same
    grammar: per-signature-group traced eqn counts and cold compile cost.
    δ̄ bit-identity between the flavors is asserted here too — the
    benchmark must never report timings for diverging programs."""
    from benchmarks.common import exec_size_cols
    from repro.core.codegen_reference import generate_source as emit_unrolled
    from repro.core.replay import ProxyProgram, load_module

    src_u = emit_unrolled(res.merged, res.proxy.combos, name="rt_unrolled",
                          axis_sizes=res.proxy.axis_sizes)
    mod_u = load_module(src_u, "rt_unrolled")
    ref = ProxyProgram(src_u, mod_u, res.merged, res.proxy.combos,
                       res.proxy.axis_sizes)
    for r in (0, 1):
        assert np.array_equal(res.proxy.rank_metrics(r),
                              ref.rank_metrics(r)), f"δ̄ diverged, rank {r}"
    tab = exec_size_cols(res.proxy)
    unr = exec_size_cols(ref)
    return {
        "program": f"codegen_table_vs_unrolled_{_BATCH_RANKS}ranks",
        "table_jaxpr_eqns": tab["jaxpr_eqns"],
        "unrolled_jaxpr_eqns": unr["jaxpr_eqns"],
        "eqn_ratio": round(unr["jaxpr_eqns"] / max(tab["jaxpr_eqns"], 1), 2),
        "table_compile_ms": tab["compile_ms"],
        "unrolled_compile_ms": unr["compile_ms"],
        "group_eqns_table": {str(k): v
                             for k, v in res.proxy.group_eqn_counts().items()},
        "group_eqns_unrolled": {str(k): v
                                for k, v in ref.group_eqn_counts().items()},
    }


def run() -> list[dict]:
    import jax
    from repro.core.synthesize import synthesize
    rows = _batched_replay_rows()
    for name, builder in PROGRAMS.items():
        fn, args, axes = builder(8)
        jfn = jax.jit(fn)
        jax.block_until_ready(jfn(*args))     # compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(jfn(*args))
        t_orig = (time.perf_counter() - t0) / 3

        res = synthesize(fn, *args, axis_sizes=axes, name=f"rt_{name}")
        t_proxy = res.proxy.time_local(0, iters=3)
        rows.append({
            "program": name,
            "orig_ms": round(t_orig * 1e3, 3),
            "proxy_ms": round(t_proxy * 1e3, 3),
            "time_err": round(abs(t_proxy - t_orig) / t_orig, 3),
        })

        # Fig. 8: cumulative roofline-seconds vs event index (shape match)
        from repro.core.metrics import roofline_seconds, comm_seconds
        from repro.core.events import is_comm
        from repro.core import blocks as B

        def curve(events, combos=None):
            out, t = [], 0.0
            ci = 0
            for e in events:
                if is_comm(e):
                    t += comm_seconds(e.payload_bytes, 8)
                else:
                    t += roofline_seconds(e.vector)
                out.append(t)
            return np.asarray(out)

        orig_curve = curve(res.rank_traces[0])
        proxy_events = [res.merged.table[i]
                        for i in res.merged.expand_rank(0)]
        proxy_curve = []
        t = 0.0
        for e in proxy_events:
            if is_comm(e):
                t += comm_seconds(e.payload_bytes, 8)
            else:
                x, u = res.proxy.combos[
                    res.merged.table.by_key[e.key()]]
                t += roofline_seconds(B.combo_cost(x, u))
            proxy_curve.append(t)
        proxy_curve = np.asarray(proxy_curve)
        m = min(len(orig_curve), len(proxy_curve))
        corr = float(np.corrcoef(orig_curve[:m], proxy_curve[:m])[0, 1])
        end_err = float(abs(proxy_curve[-1] - orig_curve[-1])
                        / orig_curve[-1])
        rows.append({
            "program": name + "_curve",
            "staircase_corr": round(corr, 5),
            "endpoint_err": round(end_err, 4),
        })
    return rows
