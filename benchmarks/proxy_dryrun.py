"""Losslessness under compilation: lower the generated proxy under
shard_map on a mesh and compare its collective schedule (op kinds + wire
bytes from the loop-aware HLO analysis) with the traced original's.

This is the strongest portability claim the CPU container can check: the
proxy's *compiled* communication equals the original's, byte for byte."""
from __future__ import annotations


def run() -> list[dict]:
    from benchmarks.common import PROGRAMS
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.synthesize import synthesize
    from repro.core.replay import init_replay_state
    from repro.launch.hlo_cost import analyze
    from repro.sharding.collectives import DeviceComm

    rows = []
    for name, builder in PROGRAMS.items():
        fn, args, axes = builder(8)
        res = synthesize(fn, *args, axis_sizes=axes, name=f"pd_{name}")
        n = list(axes.values())[0]
        axis = list(axes.keys())[0]
        mesh = make_mesh((n,), (axis,))
        comm = DeviceComm(axes)
        mod = res.proxy.module
        st = init_replay_state(mod)

        def proxy_rank(st):
            return mod.run_rank(st, comm, 0)

        sm = shard_map(proxy_rank, mesh=mesh,
                       in_specs=(jax.tree.map(lambda _: P(), st),),
                       out_specs=jax.tree.map(lambda _: P(), st),
                       check_vma=False)
        proxy_hlo = jax.jit(sm).lower(st).compile().as_text()
        orig_hlo = jax.jit(fn).lower(*args).compile().as_text()
        pc = analyze(proxy_hlo)
        oc = analyze(orig_hlo)
        rows.append({
            "program": name,
            "orig_coll_bytes": int(oc.collective_bytes),
            "proxy_coll_bytes": int(pc.collective_bytes),
            "orig_kinds": {k: int(v) for k, v in oc.collective_by_kind.items()},
            "proxy_kinds": {k: int(v) for k, v in pc.collective_by_kind.items()},
            "bytes_err": round(abs(pc.collective_bytes - oc.collective_bytes)
                               / max(oc.collective_bytes, 1), 4),
        })
    return rows
