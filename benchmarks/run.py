"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Each module's ``run()`` returns a list of dict rows; everything is printed
as CSV-ish lines and dumped to artifacts/benchmarks.json.  Runs in THIS
process — benchmarks.common sets the 8-device host platform before jax
initializes, so invoke as a fresh process.
"""
from benchmarks import common  # noqa: F401  (sets XLA_FLAGS first)
common.ensure_devices()

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
from pathlib import Path  # noqa: E402

SUITES = ("compression_table", "minime_compare", "replay_time",
          "synthesize_time", "codegen_parity", "portability", "proxy_dryrun",
          "corpus_scale", "chaos")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="artifacts/benchmarks.json")
    args = ap.parse_args()

    results = {}
    for suite in SUITES:
        if args.only and suite != args.only:
            continue
        mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
        t0 = time.perf_counter()
        rows = mod.run()
        dt = time.perf_counter() - t0
        results[suite] = rows
        print(f"\n== {suite} ({dt:.1f}s) ==")
        for row in rows:
            print(", ".join(f"{k}={v}" for k, v in row.items()))
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    existing = {}
    if out.exists():
        existing = json.loads(out.read_text())
    existing.update(results)
    out.write_text(json.dumps(existing, indent=1))
    print(f"\nwrote {out}")
    if "synthesize_time" in results:
        # one snapshot writer: merge + BENCH_5.json pinning live in the
        # suite module so both entry points emit identical artifacts
        from benchmarks.synthesize_time import write_artifacts
        write_artifacts(results["synthesize_time"], out_dir=out.parent)
    if "portability" in results:
        from benchmarks.synthesize_time import write_artifacts
        write_artifacts(results["portability"], snapshot="BENCH_7.json",
                        suite="portability", out_dir=out.parent)
    if "corpus_scale" in results:
        from benchmarks.synthesize_time import write_artifacts
        write_artifacts(results["corpus_scale"], snapshot="BENCH_9.json",
                        suite="corpus_scale", out_dir=out.parent)
    if "chaos" in results:
        from benchmarks.synthesize_time import write_artifacts
        write_artifacts(results["chaos"], snapshot="BENCH_10.json",
                        suite="chaos", out_dir=out.parent)


if __name__ == "__main__":
    main()
