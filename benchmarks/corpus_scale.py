"""Fleet-scale corpus benchmarks: parallel ingest, O(remaining) removal,
and the serve tier (query latency, batched throughput, refresh cost).

Rows (→ ``artifacts/BENCH_9.json``):

1. **parallel_ingest** — the five-scenario zoo appended to a fresh
   :class:`~repro.core.corpus_store.CorpusStore` serially vs via
   ``add_scenarios(n_workers=4)`` (per-scenario front half — npz write,
   hashing, bucket table, noise calibration, grammar warm-up — fanned
   across a process pool).  Final store state is hard-asserted
   bit-identical (names, content hashes, cluster assignments, reps);
   ``n_cpus`` is recorded because the measured speedup is bounded by the
   host's core count — the ≥3× target needs ≥4 usable cores.

2. **removal** — the partial-sums refold (drop the victim's bucket
   table, refold the survivors' — O(distinct buckets)) vs the
   pre-partial-sums baseline (re-quantize + re-bucketize every surviving
   event from metrics — O(remaining events)), with the durable
   end-to-end ``remove_scenario`` (refold + atomic shard/index rewrite +
   fsync) reported separately so constant file I/O doesn't masquerade as
   algorithmic cost.  Plus the end-to-end parity check: post-removal
   incremental synthesis δ̄ bit-identical to a from-scratch synthesis of
   the survivors.

3. **query_latency** — :class:`~repro.serve.proxy_service.ProxyService`
   over the ingested corpus: one warm synthesis at construction, then
   repeated nearest-scenario queries (index match + embedding distance +
   cached module/profile) timed per query, with the per-stage
   ``match/featurize/distance/profile`` latency split from the service's
   :class:`~repro.serve.engine.StageTimers`.  Counters hard-assert the
   hot path never re-enters synthesis.

4. **batched_query_throughput** — N single :meth:`ProxyService.query`
   calls vs one :meth:`ProxyService.query_batch` over the same traces
   (one vectorized cluster match + one distance computation instead of N
   of each).  Answers are hard-asserted identical (names, bit-equal
   distances); target ≥3× throughput.

5. **refresh_vs_rewarm** — mutate a warm store (append + remove), then
   catch the service up via :meth:`ProxyService.refresh` (selective
   re-embedding, ``n_warm_synthesis`` stays 1) vs the pre-subscription
   baseline: throw the service away and rebuild store handle + service
   from disk.  Refreshed answers are hard-asserted equal to the rebuilt
   service's.

``--smoke`` runs the reduced zoo (4 ranks, 2 steps) with the same hard
asserts and no timing thresholds — parallel-ingest parity, removal
parity, query round-trip, batched-vs-sequential parity, and
refresh-vs-rebuilt parity — the CI ``incremental-corpus`` job's
fleet-scale leg.  Full runs also append rows to
``artifacts/benchmarks.json`` via the shared ``write_artifacts``.
"""
from __future__ import annotations

import os
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.synthesize_time import write_artifacts

_ZOO = ("transformer-dp", "flash-ring", "ssm-decode", "moe-ep",
        "encdec-pipeline")


def _n_cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:      # pragma: no cover - non-Linux
        return os.cpu_count() or 1


def _build_zoo(scenarios, n_ranks=None, steps=None) -> dict:
    from repro.configs.registry import build_scenario

    kw = {}
    if n_ranks:
        kw["n_ranks"] = n_ranks
    if steps:
        kw["steps"] = steps
    return {n: build_scenario(n, **kw) for n in scenarios}


def _save_items(stores, td: Path) -> list[tuple[str, str]]:
    """(name, path) pairs — the fleet-scale ingest form: workers load
    their own inputs, nothing large crosses the pipe."""
    return [(n, str(st.save(td / f"in_{n}.npz")))
            for n, st in stores.items()]


def _assert_stores_identical(a, b) -> None:
    assert a.names == b.names, (a.names, b.names)
    for n in a.names:
        assert a.content_hash(n) == b.content_hash(n), n
    ids_a, reps_a = a.cluster_assignments()
    ids_b, reps_b = b.cluster_assignments()
    for n in a.names:
        np.testing.assert_array_equal(ids_a[n], ids_b[n])
    assert set(reps_a) == set(reps_b)
    for cid in reps_a:
        np.testing.assert_array_equal(reps_a[cid], reps_b[cid])


def _ingest_row(scenarios=_ZOO, n_workers: int = 4,
                n_ranks=None, steps=None) -> dict:
    from repro.core.corpus_store import CorpusStore

    stores = _build_zoo(scenarios, n_ranks, steps)
    n_events = sum(st.n_events for st in stores.values())
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        items = _save_items(stores, td)

        t0 = time.perf_counter()
        ser = CorpusStore(td / "serial")
        ser.add_scenarios(items, n_workers=0)
        t_serial = time.perf_counter() - t0

        t0 = time.perf_counter()
        par = CorpusStore(td / "parallel")
        par.add_scenarios(items, n_workers=n_workers)
        t_parallel = time.perf_counter() - t0

        _assert_stores_identical(ser, par)
        return {
            "program": f"parallel_ingest_{len(scenarios)}scenarios",
            "n_events": n_events,
            "n_workers": n_workers,
            "n_cpus": _n_cpus(),
            "serial_ms": round(t_serial * 1e3, 1),
            "parallel_ms": round(t_parallel * 1e3, 1),
            "ingest_speedup": round(t_serial / max(t_parallel, 1e-12), 2),
            "speedup_target": 3.0,        # needs >= 4 usable cores
            "serial_events_per_sec": round(n_events / max(t_serial, 1e-12)),
            "parallel_events_per_sec":
                round(n_events / max(t_parallel, 1e-12)),
            "bit_identical_to_serial": True,
        }


def _removal_row(scenarios=_ZOO, n_ranks=None, steps=None) -> dict:
    """Removal timing (partial-sums refold vs full rebuild, in-memory
    apples-to-apples; durable ``remove_scenario`` reported separately) +
    the end-to-end parity leg: post-removal incremental δ̄ ==
    from-scratch synthesis of the survivors, bit for bit."""
    from repro.core.corpus_store import ClusterIndex, CorpusStore
    from repro.core.synthesize import synthesize_corpus

    stores = _build_zoo(scenarios, n_ranks, steps)
    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n, st in stores.items():
            cs.add_scenario(n, st)
        synthesize_corpus(store=cs)               # warm store
        victim = cs.names[0]
        survivors = [n for n in cs.names if n != victim]

        # pre-partial-sums baseline: re-quantize + re-bucketize every
        # surviving event from raw metrics — O(remaining events)
        t0 = time.perf_counter()
        idx_rebuilt = ClusterIndex.rebuild(
            cs.rel_tol, [(n, stores[n].metrics) for n in survivors],
            expected_rel_tol=cs.rel_tol)
        idx_rebuilt.derive()
        t_rebuild = time.perf_counter() - t0

        # the partial-sums refold: drop the victim's table, refold the
        # survivors' pre-reduced bucket tables — O(distinct buckets)
        t0 = time.perf_counter()
        idx_fold = ClusterIndex(
            rel_tol=cs.rel_tol,
            tables={n: cs.index.tables[n] for n in survivors},
            order=list(survivors))
        idx_fold.derive()
        t_refold = time.perf_counter() - t0
        n_buckets = idx_fold.n_buckets

        # durable end-to-end: refold + atomic shard/index rewrite + fsync
        t0 = time.perf_counter()
        cs.remove_scenario(victim)
        cs.cluster_assignments()
        t_remove = time.perf_counter() - t0

        for n in survivors:
            np.testing.assert_array_equal(cs.index.assignments(n),
                                          idx_rebuilt.assignments(n))
            np.testing.assert_array_equal(cs.index.assignments(n),
                                          idx_fold.assignments(n))

        corp_inc = synthesize_corpus(store=cs)
        corp_scr = synthesize_corpus([(n, stores[n]) for n in cs.names])
        for n in cs.names:
            f_inc = corp_inc.results[n].fidelity(sample_ranks=None)
            f_scr = corp_scr.results[n].fidelity(sample_ranks=None)
            assert f_inc.comm_lossless and f_scr.comm_lossless, n
            np.testing.assert_array_equal(f_inc.delta, f_scr.delta)

        return {
            "program": f"removal_{len(scenarios)}scenarios",
            "removed_scenario": victim,
            "n_surviving_events":
                sum(stores[n].n_compute_events for n in survivors),
            "n_surviving_buckets": n_buckets,
            "refold_ms": round(t_refold * 1e3, 3),
            "full_rebuild_ms": round(t_rebuild * 1e3, 3),
            "remove_scenario_ms": round(t_remove * 1e3, 3),
            "removal_speedup": round(t_rebuild / max(t_refold, 1e-12), 2),
            "post_removal_delta_bit_identical": True,
        }


def _query_row(scenarios=_ZOO, n_queries: int = 20,
               n_ranks=None, steps=None) -> dict:
    from repro.core.corpus_store import CorpusStore
    from repro.serve.proxy_service import ProxyService

    stores = _build_zoo(scenarios, n_ranks, steps)
    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n, st in stores.items():
            cs.add_scenario(n, st)

        t0 = time.perf_counter()
        svc = ProxyService(cs)
        t_warm = time.perf_counter() - t0

        names = list(stores)
        lat = []
        self_hits = 0
        for i in range(n_queries):
            qname = names[i % len(names)]
            t0 = time.perf_counter()
            ans = svc.query(stores[qname], chip="v5p")
            lat.append(time.perf_counter() - t0)
            self_hits += int(ans.name == qname)
            assert ans.profile.step_time > 0.0
            assert ans.module_path            # pre-assembled, on disk
        lat_ms = np.asarray(lat) * 1e3

        assert svc.stats["n_warm_synthesis"] == 1
        assert svc.stats["n_queries"] == n_queries
        assert svc.stats["n_module_cache_hits"] == n_queries
        # one profile computation per (scenario, chip); the rest memoized
        assert svc.stats["n_profile_cache_misses"] <= len(names)
        return {
            "program": f"query_latency_{len(scenarios)}scenarios",
            "n_queries": n_queries,
            "warm_synthesis_ms": round(t_warm * 1e3, 1),
            "query_mean_ms": round(float(lat_ms.mean()), 3),
            "query_p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "query_max_ms": round(float(lat_ms.max()), 3),
            "self_match_rate": round(self_hits / n_queries, 3),
            "n_warm_synthesis": svc.stats["n_warm_synthesis"],
            "n_profile_cache_misses": svc.stats["n_profile_cache_misses"],
            # per-stage latency split (StageTimers accumulators)
            **{k: svc.stats[k] for k in ("match_ms", "featurize_ms",
                                         "distance_ms", "profile_ms")},
            "answers_from_cache": True,
        }


def _batched_query_row(scenarios=_ZOO, n_queries: int = 60,
                       n_ranks=None, steps=None) -> dict:
    """N single ``query()`` calls vs one ``query_batch`` over the same
    probes: the batch pays one vectorized cluster match, one shared
    featurization memo (look-alike probes featurize once), and one
    distance computation.  Answers hard-asserted identical — names and
    bit-equal distances."""
    from repro.core.corpus_store import CorpusStore
    from repro.serve.proxy_service import ProxyService

    stores = _build_zoo(scenarios, n_ranks, steps)
    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n, st in stores.items():
            cs.add_scenario(n, st)
        svc = ProxyService(cs)
        names = list(stores)
        probes = [stores[names[i % len(names)]] for i in range(n_queries)]
        svc.query_batch(probes)               # warm both code paths

        t0 = time.perf_counter()
        seq = [svc.query(p) for p in probes]
        t_seq = time.perf_counter() - t0

        t0 = time.perf_counter()
        bat = svc.query_batch(probes)
        t_bat = time.perf_counter() - t0

        for s, b in zip(seq, bat):
            assert b.name == s.name, (b.name, s.name)
            assert b.distance == s.distance   # same bits, not just approx
        assert svc.stats["n_warm_synthesis"] == 1
        return {
            "program": f"batched_query_{len(scenarios)}scenarios",
            "n_queries": n_queries,
            "sequential_ms": round(t_seq * 1e3, 1),
            "batched_ms": round(t_bat * 1e3, 1),
            "batched_speedup": round(t_seq / max(t_bat, 1e-12), 2),
            "speedup_target": 3.0,
            "sequential_queries_per_sec": round(n_queries / max(t_seq, 1e-12)),
            "batched_queries_per_sec": round(n_queries / max(t_bat, 1e-12)),
            "answers_identical_to_sequential": True,
        }


def _refresh_row(scenarios=_ZOO, n_ranks=None, steps=None) -> dict:
    """Corpus mutation (append a replayed scenario + remove a victim)
    under a warm service: the subscribed :meth:`ProxyService.refresh`
    (incremental synthesis + selective re-embedding, ``n_warm_synthesis``
    stays 1) vs the pre-subscription baseline — throw the service away
    and rebuild a store handle + service from disk.  Refreshed answers
    hard-asserted equal to the rebuilt service's."""
    from repro.core.corpus_store import CorpusStore
    from repro.serve.proxy_service import ProxyService

    stores = _build_zoo(scenarios, n_ranks, steps)
    with tempfile.TemporaryDirectory() as td:
        cs = CorpusStore(td)
        for n, st in stores.items():
            cs.add_scenario(n, st)
        svc = ProxyService(cs)
        names = list(stores)
        svc.query(stores[names[0]])           # warm the hot path
        victim = names[-1]
        cs.add_scenario(f"{names[0]}-replay", stores[names[0]])
        cs.remove_scenario(victim)

        t0 = time.perf_counter()
        svc.refresh()
        t_refresh = time.perf_counter() - t0

        t0 = time.perf_counter()
        rebuilt = ProxyService(CorpusStore(td))     # fresh handle, from disk
        t_rewarm = time.perf_counter() - t0

        assert svc._names == rebuilt._names
        for n in rebuilt._names:
            np.testing.assert_array_equal(svc.embedding(n),
                                          rebuilt.embedding(n))
        survivors = [n for n in names if n != victim]
        for n in survivors:
            a, b = svc.query(stores[n]), rebuilt.query(stores[n])
            assert (a.name, a.distance) == (b.name, b.distance), n
        assert svc.stats["n_warm_synthesis"] == 1   # refresh != re-warm
        return {
            "program": f"refresh_{len(scenarios)}scenarios",
            "mutation": f"+{names[0]}-replay -{victim}",
            "refresh_ms": round(t_refresh * 1e3, 1),
            "rewarm_ms": round(t_rewarm * 1e3, 1),
            "refresh_speedup": round(t_rewarm / max(t_refresh, 1e-12), 2),
            "n_reembedded": svc.stats["n_reembedded"],
            "n_profile_invalidated": svc.stats["n_profile_invalidated"],
            "n_warm_synthesis": svc.stats["n_warm_synthesis"],
            "answers_identical_to_rebuilt": True,
        }


def run() -> list[dict]:
    # removal runs with stretched traces (steps=48) so the O(remaining
    # events) rebuild term dominates its constant factors and the
    # contrast with the O(distinct buckets) refold is measurable
    return [_ingest_row(), _removal_row(steps=48), _query_row(),
            _batched_query_row(), _refresh_row()]


def smoke() -> None:
    """CI fleet-scale smoke: reduced zoo, hard asserts, no timing
    thresholds (parity is the contract; throughput needs real cores)."""
    ingest = _ingest_row(n_ranks=4, steps=2)
    print(", ".join(f"{k}={v}" for k, v in ingest.items()))
    assert ingest["bit_identical_to_serial"], ingest

    removal = _removal_row(n_ranks=4, steps=2)
    print(", ".join(f"{k}={v}" for k, v in removal.items()))
    assert removal["post_removal_delta_bit_identical"], removal

    query = _query_row(n_queries=5, n_ranks=4, steps=2)
    print(", ".join(f"{k}={v}" for k, v in query.items()))
    assert query["answers_from_cache"], query
    assert query["self_match_rate"] == 1.0, query

    batched = _batched_query_row(n_queries=8, n_ranks=4, steps=2)
    print(", ".join(f"{k}={v}" for k, v in batched.items()))
    assert batched["answers_identical_to_sequential"], batched

    refresh = _refresh_row(n_ranks=4, steps=2)
    print(", ".join(f"{k}={v}" for k, v in refresh.items()))
    assert refresh["answers_identical_to_rebuilt"], refresh
    assert refresh["n_warm_synthesis"] == 1, refresh
    print("corpus scale smoke OK")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced zoo, parity + query round-trip hard "
                         "asserts, no timing thresholds (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        rows = run()
        for r in rows:
            print(", ".join(f"{k}={v}" for k, v in r.items()))
        write_artifacts(rows, snapshot="BENCH_9.json", suite="corpus_scale")
