"""Paper Table 3 analog: per program × rank count — #events, trace size,
compressed grammar size, synthesis overhead, relative error.

``jaxpr_eqns``/``compile_ms`` report the grammar-compiled executable's
traced size and cold compile cost for the largest signature group — the
O(grammar)-vs-O(trace) axis the replay tier pins (see
benchmarks/codegen_parity.py for the hard guard)."""
from __future__ import annotations

import time

from benchmarks.common import PROGRAMS, exec_size_cols, pipeline_traces


def run() -> list[dict]:
    from repro.core.synthesize import synthesize
    rows = []
    for name, builder in PROGRAMS.items():
        for n in (4, 8):
            fn, args, axes = builder(n)
            t0 = time.perf_counter()
            res = synthesize(fn, *args, axis_sizes=axes,
                             name=f"{name}_{n}")
            dt = time.perf_counter() - t0
            fid = res.fidelity()
            rows.append({
                "program": name, "ranks": n,
                "events": res.stats["n_events"],
                "trace_bytes": res.stats["trace_bytes"],
                "grammar_bytes": res.stats["grammar_bytes"],
                "ratio": round(res.stats["compression_ratio"], 1),
                "synth_sec": round(dt, 2),
                "rel_err": round(fid.mean, 4),
                "lossless_comm": fid.comm_lossless,
                **exec_size_cols(res.proxy),
            })
    # pipeline (host-level traces, heterogeneous ranks)
    for n in (4, 8):
        traces = pipeline_traces(n)
        t0 = time.perf_counter()
        res = synthesize(rank_traces=traces, axis_sizes={"stage": n},
                         name=f"pipeline_{n}")
        dt = time.perf_counter() - t0
        fid = res.fidelity()
        rows.append({
            "program": "pipeline", "ranks": n,
            "events": res.stats["n_events"],
            "trace_bytes": res.stats["trace_bytes"],
            "grammar_bytes": res.stats["grammar_bytes"],
            "ratio": round(res.stats["compression_ratio"], 1),
            "synth_sec": round(dt, 2),
            "rel_err": round(fid.mean, 4),
            "lossless_comm": fid.comm_lossless,
            **exec_size_cols(res.proxy),
        })
    return rows
