"""Paper Figs. 5-6 analog: Siesta QP vs MINIME greedy.

Fig. 5: one aggregate computation event per program (sum of all compute).
Fig. 6: every inter-collective segment fitted separately, then summed —
the regime where greedy drift compounds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PROGRAMS


def _collect(fn, args, axes):
    from repro.core.tracer import trace_fn
    tr = trace_fn(fn, *args, axis_sizes=axes)
    return [e.vector for e in tr.compute_events()]


def run() -> list[dict]:
    from repro.core.baselines import minime_fit
    from repro.core.proxy_search import fit_combination, rel_error
    rows = []
    for name, builder in PROGRAMS.items():
        fn, args, axes = builder(8)
        vecs = _collect(fn, args, axes)

        # Fig. 5: single aggregate event
        agg = np.sum(vecs, axis=0)
        q = fit_combination(agg)
        g = minime_fit(agg)
        rows.append({
            "program": name, "mode": "single_block",
            "siesta_err": round(float(np.mean(
                q.per_metric_rel_err[agg > 0])), 4),
            "minime_err": round(float(np.mean(
                g.per_metric_rel_err[agg > 0])), 4),
        })

        # Fig. 6: per-event fits, total proxy vs total target
        tq = np.zeros(6)
        tg = np.zeros(6)
        for v in vecs:
            tq += fit_combination(v).predicted
            tg += minime_fit(v).predicted
        rows.append({
            "program": name, "mode": "per_event_sum",
            "siesta_err": round(float(np.mean(
                rel_error(agg, tq)[agg > 0])), 4),
            "minime_err": round(float(np.mean(
                rel_error(agg, tg)[agg > 0])), 4),
        })
    return rows
