"""Training step builder + fault-tolerant trainer.

``make_train_step`` builds the jitted step for any ArchConfig on any mesh:
microbatched gradient accumulation (lax.scan), AdamW, donated buffers.
``make_manual_dp_train_step`` is the explicit shard_map DP variant whose
gradient all-reduce goes through int8 error-feedback compression
(4× collective-byte reduction, visible in the lowered HLO).

:class:`Trainer` provides the 1000-node operational envelope on one host:
checkpoint/restart (async saves, atomic commits), deterministic data resume,
failure injection + automatic restore, and elastic re-shard onto a new mesh.
Straggler mitigation for bulk-synchronous SPMD lives in (a) the data
prefetcher (host jitter never stalls the step) and (b) checkpoint cadence
(bounded recompute after eviction); both are exercised in tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.configs.registry import rules_for
from repro.models.model import build_forward, init_params, logical_axes_tree
from repro.sharding.partition import sharding_for_shape
from repro.train import checkpoint as ckpt_lib
from repro.train.compression import compressed_psum, init_error_state
from repro.train.data import Prefetcher, TokenDataset
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    num_microbatches: int = 1
    donate: bool = True
    grad_compression: str = "none"     # none | int8 (manual-DP step only)


def _microbatched_grads(loss_fn, params, batch, n_mb: int):
    if n_mb <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def reshape(x):
        b = x.shape[0]
        return x.reshape((n_mb, b // n_mb) + x.shape[1:])

    mbatch = jax.tree.map(reshape, batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(acc, mb):
        loss_acc, g_acc = acc
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), g_acc, g)
        return (loss_acc + loss, g_acc), None

    (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), mbatch)
    scale = 1.0 / n_mb
    return loss * scale, jax.tree.map(lambda g: g * scale, grads)


def make_train_step(cfg: ArchConfig, mesh=None, opt_cfg: AdamWConfig | None = None,
                    options: TrainOptions | None = None) -> Callable:
    """jit(train_step)(params, opt_state, batch) → (params, opt_state, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig()
    options = options or TrainOptions()
    loss_fn_raw = build_forward(cfg, "loss")

    def loss_fn(p, b):
        return loss_fn_raw(p, b, cfg, mesh)

    def step(params, opt_state, batch):
        loss, grads = _microbatched_grads(loss_fn, params, batch,
                                          options.num_microbatches)
        params, opt_state, metrics = adamw_update(grads, params, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    donate = (0, 1) if options.donate else ()
    return jax.jit(step, donate_argnums=donate)


def make_manual_dp_train_step(cfg: ArchConfig, mesh,
                              opt_cfg: AdamWConfig | None = None,
                              data_axis: str = "data") -> Callable:
    """Explicit-DP step: per-device grads → int8 error-feedback psum.

    Params replicated over ``data_axis``; batch sharded on it.  State gains
    an ``err`` tree (the feedback accumulator).  The gradient all-reduce
    moves int8 (int32-accumulated) payloads — 4× fewer wire bytes than f32.
    """
    from jax.sharding import PartitionSpec as P
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn_raw = build_forward(cfg, "loss")

    def local_step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(
            lambda p, b: loss_fn_raw(p, b, cfg, None))(params, batch)
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err)
        red, new_e = [], []
        for g, e in zip(flat_g, flat_e):
            r, ne = compressed_psum(g, data_axis, e)
            red.append(r)
            new_e.append(ne)
        grads = jax.tree.unflatten(treedef, red)
        err = jax.tree.unflatten(treedef, new_e)
        loss = jax.lax.pmean(loss, data_axis)
        params, opt_state, metrics = adamw_update(grads, params, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, err, metrics

    pspec = P()

    def batch_spec(x):
        return P(data_axis)

    step = shard_map(
        local_step, mesh=mesh,
        in_specs=(pspec, pspec, pspec, P(data_axis)),
        out_specs=(pspec, pspec, pspec, pspec),
        check_vma=False)
    return jax.jit(step)


# ---------------------------------------------------------------------------
# fault-tolerant trainer
# ---------------------------------------------------------------------------


class Trainer:
    """Single-controller trainer with the production operational envelope."""

    def __init__(self, cfg: ArchConfig, mesh=None, *, global_batch: int = 8,
                 seq_len: int = 32, ckpt_dir: str = "/tmp/repro_ckpt",
                 opt_cfg: AdamWConfig | None = None,
                 options: TrainOptions | None = None, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.options = options or TrainOptions()
        self.rules = rules_for(cfg)
        self.dataset = TokenDataset(cfg.vocab, seq_len, global_batch, seed)
        self.ckpt = ckpt_lib.CheckpointManager(ckpt_dir)
        self.step_fn = make_train_step(cfg, mesh, self.opt_cfg, self.options)
        self._init_state(seed)
        self.step = 0
        self.metrics_log: list[dict] = []

    def _init_state(self, seed: int):
        params = init_params(self.cfg, seed)
        if self.mesh is not None:
            axes = logical_axes_tree(self.cfg)
            params = jax.tree.map(
                lambda a, ax: jax.device_put(
                    a, sharding_for_shape(a.shape, ax, self.mesh, self.rules)),
                params, axes,
                is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, tuple))
        self.params = params
        self.opt_state = adamw_init(params)

    def _place_batch(self, batch: dict) -> dict:
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            axes = ("batch",) + (None,) * (v.ndim - 1)
            out[k] = jax.device_put(
                v, sharding_for_shape(v.shape, axes, self.mesh, self.rules))
        return out

    # -- checkpoint/restart ---------------------------------------------------

    def save(self, async_: bool = True):
        state = {"params": self.params, "opt": self.opt_state}
        extra = {"step": self.step}
        if async_:
            self.ckpt.save_async(self.step, state, extra)
        else:
            self.ckpt.save(self.step, state, extra)

    def restore(self, step: int | None = None) -> bool:
        if self.ckpt.latest_step() is None:
            return False
        template = {"params": self.params, "opt": self.opt_state}
        got_step, state, extra = self.ckpt.restore(template, step)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = extra.get("step", got_step)
        return True

    def reshard(self, new_mesh):
        """Elastic re-scale: persist, rebuild on the new mesh, restore."""
        self.ckpt.wait()
        self.save(async_=False)
        self.mesh = new_mesh
        self.step_fn = make_train_step(self.cfg, new_mesh, self.opt_cfg,
                                       self.options)
        self._init_state(seed=0)
        self.restore()
        if new_mesh is not None:
            axes = logical_axes_tree(self.cfg)
            self.params = jax.tree.map(
                lambda a, ax: jax.device_put(
                    a, sharding_for_shape(a.shape, ax, new_mesh, self.rules)),
                self.params, axes,
                is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, tuple))

    # -- run loop ---------------------------------------------------------------

    def run(self, n_steps: int, ckpt_every: int = 0,
            failure_injector: Callable[[int], None] | None = None,
            max_restarts: int = 3) -> list[dict]:
        restarts = 0
        target = self.step + n_steps
        extras = self.dataset.extras(self.cfg)
        while self.step < target:
            pf = Prefetcher(self.dataset, start_step=self.step, extras=extras)
            try:
                while self.step < target:
                    got_step, batch = next(pf)
                    assert got_step == self.step, (got_step, self.step)
                    if failure_injector is not None:
                        failure_injector(self.step)
                    t0 = time.perf_counter()
                    batch = self._place_batch(batch)
                    self.params, self.opt_state, metrics = self.step_fn(
                        self.params, self.opt_state, batch)
                    loss = float(metrics["loss"])
                    self.metrics_log.append({
                        "step": self.step, "loss": loss,
                        "sec": time.perf_counter() - t0,
                    })
                    self.step += 1
                    if ckpt_every and self.step % ckpt_every == 0:
                        self.save(async_=True)
            except _InjectedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise
                self.ckpt.wait()
                self._init_state(seed=0)       # fresh process semantics
                if not self.restore():
                    self.step = 0
            finally:
                pf.close()
        self.ckpt.wait()
        return self.metrics_log


class _InjectedFailure(RuntimeError):
    """Raised by tests' failure injectors to simulate a node loss."""
