"""int8 error-feedback gradient compression (distributed-optimization trick).

The DP gradient all-reduce moves ``4·|params|`` bytes per step in f32.
Quantizing to int8 with per-leaf scales cuts collective bytes 4× while the
error-feedback accumulator keeps the *expected* update unbiased over steps
(1-bit/low-bit SGD literature; here int8 keeps the QP between fidelity and
bandwidth firmly on the bandwidth side of the roofline's collective term).

Two integration points:

* :func:`quantize_tree` / :func:`dequantize_tree` + per-step error state —
  used inside the auto-sharded train step (the all-reduce XLA inserts for
  the data axis then moves int8, observable in the dry-run HLO);
* :func:`compressed_psum` — explicit shard_map form for manual-collective
  training loops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _leaf_quant(g, err):
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def quantize_tree(grads, err_state):
    """→ (int8 tree, scale tree, new error state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = _leaf_quant(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, errs))


def dequantize_tree(qtree, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qtree, scales)


def compressed_psum(x, axis: str, err):
    """shard_map building block: int8 quantize → int32-accumulate psum →
    dequantize, with error feedback.  Returns (mean-reduced x, new_err)."""
    g32 = x.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    # scales differ per device: psum the int payload and the scale-weighted
    # contribution cannot be separated exactly; use per-device scale and sum
    # of dequantized values expressed as int32 payload * broadcast scale.
    summed = lax.psum(q.astype(jnp.int32), axis)          # int32 on the wire
    n = lax.psum(jnp.ones((), jnp.float32), axis)
    out = summed.astype(jnp.float32) * scale / n
    return out.astype(x.dtype), new_err
