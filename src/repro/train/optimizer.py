"""AdamW from scratch (no optax): init/update over arbitrary param pytrees.

Optimizer moments inherit the parameter shardings (the ZeRO-style variant
additionally shards them over the data axis via the "fsdp" logical rule —
see :func:`abstract_opt_state`).  All moment math runs in f32 regardless of
param dtype; the update is fused into one tree_map per moment for minimal
HBM traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def _is_sds(x):
    return isinstance(x, jax.ShapeDtypeStruct)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(param_specs, mesh=None, rules=None) -> dict:
    """ShapeDtypeStruct opt state mirroring (sharded like) the params."""
    def like(p):
        sh = getattr(p, "sharding", None)
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=sh)
    return {
        "mu": jax.tree.map(like, param_specs, is_leaf=_is_sds),
        "nu": jax.tree.map(like, param_specs, is_leaf=_is_sds),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lr_schedule(step, cfg: AdamWConfig):
    """Linear warmup → cosine decay."""
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0.0)))


def adamw_update(grads, params, opt_state: dict, cfg: AdamWConfig
                 ) -> tuple[Any, dict, dict]:
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, p, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        step_v = mhat / (jnp.sqrt(nhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            pf = pf * (1.0 - lr * cfg.weight_decay)
        pf = pf - lr * step_v
        return pf.astype(p.dtype), mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, p, m, n) for g, p, m, n in
           zip(flat_g, flat_p, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr, "step": step}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
