"""Distributed checkpointing: per-leaf shard files + manifest, async save,
atomic commit, and **elastic restore** (resume onto a different mesh shape).

Layout of one checkpoint::

    <dir>/step_000120.tmp/            # written first
        manifest.json                 # step, leaf paths, shapes, dtypes, data state
        <leaf-key>.npy                # one file per pytree leaf
    <dir>/step_000120/                # atomic rename on completion

On a multi-controller deployment each host writes only its addressable
shards and the manifest records the global shape + index map; this
single-process implementation writes full leaves but keeps the same
manifest contract, so ``restore(..., mesh=other_mesh, shardings=...)``
re-places every leaf under the *new* mesh — the elastic-scaling path
(tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.compat import tree_flatten_with_path


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: threading.Thread | None = None
        self._async_err: list[BaseException] = []

    # -- save -------------------------------------------------------------------

    def save(self, step: int, state: dict, extra: dict | None = None) -> Path:
        """Blocking save.  ``state`` is any pytree of arrays."""
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        return self._write(step, host_state, extra or {})

    def save_async(self, step: int, state: dict, extra: dict | None = None):
        """Non-blocking save: device→host copy happens now (so training can
        mutate buffers), file IO happens on a worker thread."""
        self.wait()
        host_state = jax.tree.map(np.asarray, jax.device_get(state))

        def work():
            try:
                self._write(step, host_state, extra or {})
            except BaseException as e:  # surfaced by wait()
                self._async_err.append(e)

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err:
            raise self._async_err.pop()

    def _write(self, step: int, host_state, extra: dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _flatten_with_paths(host_state)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in leaves:
            fname = key.replace("/", "__").replace("[", "_").replace("]", "_")
            np.save(tmp / f"{fname}.npy", arr)
            manifest["leaves"][key] = {
                "file": f"{fname}.npy",
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self):
        ckpts = sorted(self.all_steps())
        for step in ckpts[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{step:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple[int, Any, dict]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of Shardings for the *current* mesh —
        pass a different mesh's shardings to reshard elastically.
        Returns (step, state, extra).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        keyed = dict(_flatten_with_paths(template))
        arrays = {}
        for key, meta in manifest["leaves"].items():
            if key not in keyed:
                continue
            arr = np.load(d / meta["file"])
            arrays[key] = arr
        flat, treedef = tree_flatten_with_path(template)
        out_leaves = []
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(flat))
        for (path, leaf), shard in zip(flat, shard_flat):
            key = "/".join(_path_str(p) for p in path)
            arr = arrays.get(key)
            if arr is None:
                raise KeyError(f"checkpoint {d} missing leaf {key}")
            dtype = getattr(leaf, "dtype", arr.dtype)
            v = jax.device_put(arr.astype(dtype), shard) if shard is not None \
                else jax.device_put(np.asarray(arr, dtype=dtype))
            out_leaves.append(v)
        state = jax.tree.unflatten(treedef, out_leaves)
        return step, state, manifest.get("extra", {})
