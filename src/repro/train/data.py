"""Deterministic synthetic LM data pipeline with host prefetch.

Real deployments swap :class:`TokenDataset` for a storage-backed reader; the
contract the trainer relies on is (a) deterministic batches given (seed,
step) — so checkpoint-restart resumes on the exact same stream — and (b) a
background prefetch thread so a slow host never stalls the device step
(the practical straggler-mitigation lever for bulk-synchronous SPMD).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class TokenDataset:
    """Zipf-distributed token stream; batch i is a pure function of (seed, i)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.zipf_a = zipf_a

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2 ** 31)
        z = rng.zipf(self.zipf_a, size=(self.global_batch, self.seq_len + 1))
        toks = (z - 1) % self.vocab
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def extras(self, cfg) -> dict[str, np.ndarray]:
        """Stub modality embeddings (VLM patches / audio frames)."""
        out = {}
        rng = np.random.RandomState(self.seed)
        if getattr(cfg, "n_vision_tokens", 0):
            out["vision_embeds"] = rng.normal(
                0, 1, (self.global_batch, cfg.n_vision_tokens, cfg.d_model)
            ).astype(np.float32)
        if getattr(cfg, "n_audio_frames", 0):
            out["audio_frames"] = rng.normal(
                0, 1, (self.global_batch, cfg.n_audio_frames, cfg.d_model)
            ).astype(np.float32)
        return out


class Prefetcher:
    """Background thread materializing batches ``depth`` steps ahead."""

    def __init__(self, ds: TokenDataset, start_step: int = 0, depth: int = 2,
                 extras: dict | None = None):
        self.ds = ds
        self.extras = extras or {}
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.ds.batch_at(step)
            batch.update(self.extras)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
