from repro.sharding.partition import (  # noqa: F401
    LogicalRules, make_named_sharding, spec_for,
)
