"""Collective wrappers + replay comm backends (DESIGN.md §2).

Two roles:

1. **Instrumented wrappers** (`psum`, `all_gather`, ...): thin wrappers over
   ``jax.lax`` collectives that additionally record a :class:`CommEvent` into
   the active :class:`~repro.core.tracer.TraceSession` — the literal PMPI
   interposition analog for host-level drivers (pipeline schedules, serving
   engines).  Inside ``jit`` they are recorded once at trace time, which is
   exactly the event the compiled program will execute.

2. **Replay comm backends** for generated proxy-apps:
   * :class:`LocalSim` — executes a cheap local op honoring the payload
     shape; used for single-host replay where only the compute stream is
     measured (comm fidelity is validated via the lowered HLO instead).
   * :class:`DeviceComm` — executes the *real* collective over mesh axes
     (must run inside ``shard_map``); payload shape, dtype, axes and permute
     offsets reproduce the traced event exactly, so the proxy's compiled
     collective schedule matches the original's (losslessness, paper §1).

Every backend folds the collective result back into the fixed-shape pool
buffer (mean/slice), so proxy state is a stable pytree through ``fori_loop``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat  # noqa: F401  (optimization_barrier vmap rule on old JAX)
from repro.core.events import CommEvent, decode_relative_perm
from repro.core import tracer as _tracer


# ---------------------------------------------------------------------------
# instrumented wrappers (use these in framework code instead of raw lax.*)
# ---------------------------------------------------------------------------


def _record(kind: str, x, axes, detail: tuple = ()):
    s = _tracer.active_session()
    if s is not None:
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        shape = tuple(getattr(x, "shape", ()))
        dtype = str(getattr(x, "dtype", "float32"))
        s.emit(None, CommEvent(kind=kind, shape=shape, dtype=dtype,
                               axes=tuple(str(a) for a in axes_t),
                               detail=detail))


def psum(x, axes):
    _record("psum", x, axes)
    return lax.psum(x, axes)


def pmax(x, axes):
    _record("pmax", x, axes)
    return lax.pmax(x, axes)


def all_gather(x, axis, *, gather_dim: int = 0, tiled: bool = False):
    _record("all_gather", x, axis, (gather_dim,))
    return lax.all_gather(x, axis, axis=gather_dim, tiled=tiled)


def psum_scatter(x, axis, *, scatter_dim: int = 0, tiled: bool = True):
    _record("reduce_scatter", x, axis, (scatter_dim,))
    return lax.psum_scatter(x, axis, scatter_dimension=scatter_dim, tiled=tiled)


def all_to_all(x, axis, split_axis: int, concat_axis: int, *, tiled: bool = True):
    _record("all_to_all", x, axis, (split_axis, concat_axis))
    return lax.all_to_all(x, axis, split_axis, concat_axis, tiled=tiled)


def ppermute(x, axis, perm: Sequence[tuple[int, int]]):
    _record("ppermute", x, axis, ("rawperm", tuple(tuple(p) for p in perm)))
    return lax.ppermute(x, axis, perm=perm)


# ---------------------------------------------------------------------------
# replay comm backends
# ---------------------------------------------------------------------------


class LocalSim:
    """Single-host replay: a shape-honoring local op per collective.

    The op creates a true data dependency on the pool buffer (a sequence
    point, like an MPI call is), with negligible compute — the paper replays
    communication on the real network; on this CPU container the network
    fidelity is asserted on the lowered HLO of the DeviceComm path instead.

    Batched rank axis: the sequence point is shape-agnostic, so the same
    backend serves the per-rank path and the ``vmap``-ed signature-group
    path of :meth:`repro.core.replay.ProxyProgram.run_all`, where every
    pool buffer carries a leading rank dimension (the required vmap rule
    for ``optimization_barrier`` is registered by :mod:`repro.compat`).

    ``trace_events`` counts ``do`` calls *at trace time* (one per comm call
    site per program trace — loop bodies count once, like the grammar's
    run-length symbols).  Caveat: plain ``LocalSim`` instances are
    interchangeable to the replay compile cache (keyed by class, so fresh
    instances reuse warm executables and trigger **no** new traces); to
    count exactly, pass an identity-keyed *subclass* instance to a fresh
    ``ProxyProgram`` — see ``CountingSim`` in tests/test_replay_batched.py,
    where equal per-signature counts between the batched and per-rank paths
    serve as a cheap losslessness probe.
    """

    def __init__(self):
        self.trace_events = 0

    def do(self, st: dict, buf: str, *, kind: str, axes, detail, shape, dtype):
        self.trace_events += 1
        st = dict(st)
        # a pure sequence point: orders the replay like the MPI call does,
        # contributes zero compute metrics (it is not the comm being modeled)
        st[buf] = jax.lax.optimization_barrier(st[buf])
        return st


class DeviceComm:
    """Mesh replay inside shard_map: executes the recorded collective exactly.

    ``axis_sizes`` must match the mesh the proxy runs under.  The payload
    tensor fed to the collective has exactly the traced shape/dtype; the
    result is folded back (mean over gathered dim / broadcast) so the pool
    buffer shape is stable — shape *and* dtype of ``st[buf]`` are invariant
    through ``do`` for every collective kind, which is what keeps the proxy
    state a fixed pytree under ``fori_loop`` and ``vmap`` alike.

    Batched rank axis: ``do`` is ``vmap``-compatible over a leading rank
    dimension, mirroring :class:`LocalSim`.  Inside ``shard_map``, the mesh
    replay engine stacks a whole signature group's states and ``vmap``-s
    ``run_rank`` over them; JAX's collective batching rules fold the rank
    axis through the *real* collectives (one batched all-reduce instead of
    n sequential ones), so an entire group replays in a single dispatch.
    Every branch below — including the non-divisible ``reduce_scatter`` /
    ``all_to_all`` fallbacks and all :func:`_detail_to_perm` decode paths —
    is audited for this (see :func:`repro.compat.collective_batching_audit`
    and tests/test_replay_mesh.py: batched-vs-sequential replay is
    bit-identical for every kind).
    """

    def __init__(self, axis_sizes: dict[str, int]):
        self.axis_sizes = dict(axis_sizes)

    def do(self, st: dict, buf: str, *, kind: str, axes, detail, shape, dtype):
        st = dict(st)
        x = st[buf].astype(dtype).reshape(shape)
        ax = axes if len(axes) > 1 else axes[0]
        if kind in ("psum", "pmax", "pmin"):
            op = {"psum": lax.psum, "pmax": lax.pmax, "pmin": lax.pmin}[kind]
            y = op(x, ax)
            if kind == "psum":
                n = 1
                for a in axes:
                    n *= self.axis_sizes[a]
                y = y / max(n, 1)
        elif kind == "all_gather":
            dim = int(detail[0]) if detail else 0
            g = lax.all_gather(x, ax, axis=0)
            y = jnp.mean(g.astype(jnp.float32), axis=0).astype(dtype)
            del dim
        elif kind == "reduce_scatter":
            dim = int(detail[0]) if detail else 0
            size = self.axis_sizes[axes[0]]
            if shape[dim] % size == 0:
                y = lax.psum_scatter(x, ax, scatter_dimension=dim, tiled=True)
                reps = [1] * y.ndim
                reps[dim] = size
                y = jnp.tile(y, reps) / size
            else:
                y = lax.psum(x, ax) / size
        elif kind == "all_to_all":
            split, concat = (int(detail[0]), int(detail[1])) if len(detail) >= 2 else (0, 0)
            size = self.axis_sizes[axes[0]]
            if x.shape[split] % size == 0:
                y = lax.all_to_all(x, ax, split, concat, tiled=True)
                y = _reshape_back(y, shape)
            else:
                y = lax.ppermute(x, ax, [(i, (i + 1) % size) for i in range(size)])
        elif kind == "ppermute":
            size = self.axis_sizes[axes[0]]
            perm = _detail_to_perm(detail, size)
            y = lax.ppermute(x, ax, perm)
        elif kind == "broadcast":
            y = lax.all_gather(x, ax, axis=0)[0]
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        st[buf] = y.reshape(st[buf].shape).astype(st[buf].dtype)
        return st


def _reshape_back(y, shape):
    n = 1
    for s in shape:
        n *= s
    return y.reshape(shape) if y.size == n else y


def _detail_to_perm(detail: tuple, size: int) -> list[tuple[int, int]]:
    if detail and detail[0] in ("shift", "perm", "empty"):
        return decode_relative_perm(detail, size)
    if detail and detail[0] == "rawperm":
        return [tuple(p) for p in detail[1]]
    return [(i, (i + 1) % size) for i in range(size)]
