"""Logical-axis partitioning rules (MaxText-style) → NamedSharding.

Model code annotates every parameter/activation with *logical* axis names
("embed", "heads", "ffn", "vocab", "batch", "seq", ...).  A
:class:`LogicalRules` table maps logical names to mesh axes; changing the
parallelism layout (the main lever in §Perf hillclimbing) means swapping the
rules, not touching model code.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: default rules for the production meshes:
#:   params:  TP over "model" (heads / ffn / vocab), replicated over data/pod
#:   activations: batch over ("pod","data"), model-parallel dims over "model"
DEFAULT_RULES: tuple[tuple[str, object], ...] = (
    ("batch",        ("pod", "data")),
    ("microbatch",   None),
    ("seq",          None),
    ("kv_seq",       "model"),      # decode: KV cache seq-sharded (flash-decode)
    ("embed",        None),
    ("heads",        "model"),
    ("kv_heads",     "model"),
    ("heads_flat",   "model"),      # flattened h·hd projection columns
    ("kv_flat",      "model"),
    ("qkv",          None),
    ("head_dim",     None),
    ("ffn",          "model"),
    ("vocab",        "model"),
    ("experts",      "model"),      # MoE: experts grouped over model axis
    ("expert_ffn",   None),
    ("layers",       None),
    ("ssm_state",    None),
    ("ssm_heads",    "model"),
    ("conv_dim",     "model"),
    ("frames",       None),
    ("patches",      None),
    ("fsdp",         "data"),       # optional ZeRO-style param shard axis
    ("attn_seq",     "model"),      # context-parallel fallback when heads
                                    # don't divide the model axis
    ("batch_attn",   ("pod", "data", "model")),  # fully-local attention:
                                    # batch sharded over the whole mesh
)


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    rules: tuple[tuple[str, object], ...] = DEFAULT_RULES

    def mesh_axes(self, logical: str):
        for name, axes in self.rules:
            if name == logical:
                return axes
        return None

    def spec(self, logical_axes: Sequence[str | None], mesh: Mesh) -> P:
        """PartitionSpec for a tensor annotated with logical axis names.

        Mesh axes absent from ``mesh`` are dropped (so the same rules work on
        single-pod and multi-pod meshes); a mesh axis may be used at most once.
        """
        used: set[str] = set()
        parts = []
        for ax in logical_axes:
            entry = self.mesh_axes(ax) if ax else None
            if entry is None:
                parts.append(None)
                continue
            cand = (entry,) if isinstance(entry, str) else tuple(entry)
            picked = tuple(a for a in cand if a in mesh.axis_names and a not in used)
            used.update(picked)
            if not picked:
                parts.append(None)
            elif len(picked) == 1:
                parts.append(picked[0])
            else:
                parts.append(picked)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def with_overrides(self, **over) -> "LogicalRules":
        new = []
        seen = set(over)
        for name, axes in self.rules:
            new.append((name, over[name]) if name in over else (name, axes))
        for name in over:
            if name not in {n for n, _ in self.rules}:
                new.append((name, over[name]))
        del seen
        return LogicalRules(tuple(new))


def spec_for(logical_axes: Sequence[str | None], mesh: Mesh,
             rules: LogicalRules | None = None) -> P:
    return (rules or LogicalRules()).spec(logical_axes, mesh)


def make_named_sharding(logical_axes: Sequence[str | None], mesh: Mesh,
                        rules: LogicalRules | None = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical_axes, mesh, rules))


def tree_shardings(tree_logical, mesh: Mesh, rules: LogicalRules | None = None):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda la: make_named_sharding(la, mesh, rules), tree_logical,
        is_leaf=lambda x: isinstance(x, (tuple, list))
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def _filter_divisible(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (e.g. 4 KV
    heads cannot shard 16-way; GSPMD would reject the constraint)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            parts.append(entry)
            continue
        cand = (entry,) if isinstance(entry, str) else tuple(entry)
        total = 1
        kept = []
        for a in cand:
            if shape[i] % (total * sizes[a]) == 0:
                kept.append(a)
                total *= sizes[a]
        parts.append(None if not kept else
                     (kept[0] if len(kept) == 1 else tuple(kept)))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constraint(x, logical_axes: Sequence[str | None], mesh: Mesh | None = None,
               rules: LogicalRules | None = None):
    """jax.lax.with_sharding_constraint with logical axes (no-op off-mesh).

    Mesh axes that do not evenly divide a dim are dropped per-dim, so the
    same model code works at full scale and in reduced smoke configs.
    """
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = spec_for(logical_axes, mesh, rules)
    spec = _filter_divisible(spec, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for_shape(shape: tuple[int, ...],
                       logical_axes: Sequence[str | None], mesh: Mesh,
                       rules: LogicalRules | None = None) -> NamedSharding:
    """NamedSharding with per-dim divisibility filtering (for in_shardings)."""
    spec = spec_for(logical_axes, mesh, rules)
    return NamedSharding(mesh, _filter_divisible(spec, tuple(shape), mesh))


def _current_mesh() -> Mesh | None:
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
