"""Batched serving engine: prefill → KV caches → greedy decode loop.

Iteration-level batching "lite": a fixed pool of batch slots decodes in
lockstep; finished sequences are masked (kept numerically live so the
compiled step shape never changes) and harvested at the end.  On a mesh the
caches follow the "kv_seq → model" sharding rule, which is what lets a 32k
context × 128-slot pool fit per chip.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import build_forward, init_cache


class StageTimers:
    """Per-stage wall-clock accumulators for serving observability —
    shared by both serving tiers (:class:`ServeEngine` prefill/decode,
    :class:`~repro.serve.proxy_service.ProxyService`
    match/featurize/distance/profile).  ``time(stage)`` is a context
    manager; :meth:`snapshot_ms` renders ``{stage}_ms`` keys for a stats
    dict or a benchmark row."""

    def __init__(self, *stages: str):
        self._acc = {s: 0.0 for s in stages}

    @contextlib.contextmanager
    def time(self, stage: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._acc[stage] += time.perf_counter() - t0

    def snapshot_ms(self) -> dict[str, float]:
        return {f"{s}_ms": round(v * 1e3, 3) for s, v in self._acc.items()}


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray          # (b, n_new)
    prefill_sec: float
    decode_sec: float
    tokens_per_sec: float


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, mesh=None, *,
                 max_len: int = 128, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self.eos_id = eos_id
        self.timers = StageTimers("prefill", "decode")
        self._prefill = jax.jit(
            lambda p, b: build_forward(cfg, "prefill")(p, b, cfg, mesh))
        self._decode = jax.jit(
            lambda p, c, b, pos: build_forward(cfg, "decode")(p, c, b, pos,
                                                              cfg, mesh))

    def _extras(self, batch_size: int) -> dict:
        out = {}
        if self.cfg.n_vision_tokens:
            out["vision_embeds"] = jnp.zeros(
                (batch_size, self.cfg.n_vision_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.n_audio_frames:
            out["audio_frames"] = jnp.zeros(
                (batch_size, self.cfg.n_audio_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return out

    def generate(self, prompts: np.ndarray, n_new: int) -> GenResult:
        """prompts: (b, prompt_len) int32 (already padded to a bucket)."""
        b, plen = prompts.shape
        assert plen + n_new <= self.max_len, "exceeds engine max_len"
        batch = {"tokens": jnp.asarray(prompts, jnp.int32), **self._extras(b)}

        t0 = time.perf_counter()
        logits, pre_cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        # re-home the prefill cache into full-length decode buffers
        full = init_cache(self.cfg, b, self.max_len,
                          self.cfg.n_audio_frames or 0)
        cache = jax.tree.map(self._embed_cache, full, pre_cache)

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        done = np.zeros((b,), bool)
        for i in range(n_new - 1):
            dbatch = {"tokens": tok[:, None]}
            logits, cache = self._decode(self.params, cache, dbatch,
                                         jnp.int32(plen + i))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            t_np = np.asarray(tok)
            if self.eos_id >= 0:
                done |= t_np == self.eos_id
                t_np = np.where(done, self.eos_id, t_np)
            out.append(t_np)
            if done.all():
                break
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        self.timers._acc["prefill"] += t1 - t0
        self.timers._acc["decode"] += t2 - t1
        gen = np.stack(out, axis=1)
        n_tok = gen.size
        return GenResult(tokens=gen, prefill_sec=t1 - t0, decode_sec=t2 - t1,
                         tokens_per_sec=n_tok / max(t2 - t1, 1e-9))

    @staticmethod
    def _embed_cache(full_leaf, pre_leaf):
        """Place a prefill cache leaf into the front of the full-length buffer
        (matching trailing dims; seq axis is wherever shapes differ)."""
        if full_leaf.shape == pre_leaf.shape:
            return pre_leaf.astype(full_leaf.dtype)
        # find the (single) mismatching axis = the cache sequence axis
        axis = next(i for i, (a, b) in enumerate(zip(full_leaf.shape,
                                                     pre_leaf.shape)) if a != b)
        idx = (0,) * full_leaf.ndim
        return jax.lax.dynamic_update_slice(
            full_leaf, pre_leaf.astype(full_leaf.dtype), idx)
