"""Exact nearest-neighbor search over scenario embeddings (serve tier).

Above a corpus-size threshold the :class:`~repro.serve.proxy_service.
ProxyService` distance stage stops materializing the full query ×
scenario distance matrix and queries a :class:`BallTree` instead.  The
tree is *exact*, not approximate: leaf distances use the same
``sqrt(((pts - q) ** 2).sum(axis=1))`` reduction as the brute-force
path, pruning keeps a slack margin wider than the float error of the
bound, and ties break to the lowest scenario index — so the answer is
pinned equal (index and distance bits) to :func:`brute_force_nearest`,
which stays as the parity oracle per the repo's oracle discipline
(``sequitur_reference``, ``frontend_reference``, ...).

Embeddings here are short unit-normalized vectors (a few dozen dims) and
corpora are 10²–10⁴ scenarios, squarely ball-tree territory; no external
ANN dependency, pure NumPy.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_LEAF_SIZE = 8
#: pruning slack — absolute, orders of magnitude above the ~1e-16 float
#: error of the triangle-inequality bound on unit-scale embeddings, so a
#: subtree holding the true nearest (or an equal-distance lower-index
#: tie) is never pruned by rounding
_SLACK = 1e-9


def brute_force_nearest(points: np.ndarray, q: np.ndarray,
                        ) -> tuple[int, float]:
    """``(index, distance)`` of the nearest row of ``points`` to ``q`` —
    first index wins ties.  The parity oracle :class:`BallTree` is pinned
    against."""
    points = np.asarray(points, dtype=np.float64)
    if not len(points):
        raise ValueError("cannot search an empty point set")
    d = np.sqrt(((points - np.asarray(q, dtype=np.float64)) ** 2).sum(axis=1))
    i = int(np.argmin(d))
    return i, float(d[i])


@dataclasses.dataclass
class _Node:
    center: np.ndarray
    radius: float
    idx: np.ndarray | None          # leaf: row indices into the point set
    left: "_Node | None"
    right: "_Node | None"


class BallTree:
    """Exact ball tree over a fixed point set (max-spread median splits,
    stable order), queried one vector at a time for the single nearest
    row."""

    def __init__(self, points: np.ndarray, leaf_size: int = _LEAF_SIZE):
        self._pts = np.ascontiguousarray(points, dtype=np.float64)
        if self._pts.ndim != 2 or not len(self._pts):
            raise ValueError("BallTree needs a non-empty (n, d) point set")
        self._root = self._build(np.arange(len(self._pts), dtype=np.int64),
                                 max(int(leaf_size), 1))

    def __len__(self) -> int:
        return len(self._pts)

    def _build(self, idx: np.ndarray, leaf_size: int) -> _Node:
        pts = self._pts[idx]
        center = pts.mean(axis=0)
        radius = float(np.sqrt(((pts - center) ** 2).sum(axis=1)).max())
        if len(idx) <= leaf_size:
            return _Node(center, radius, idx, None, None)
        spread = pts.max(axis=0) - pts.min(axis=0)
        order = np.argsort(pts[:, int(np.argmax(spread))], kind="stable")
        mid = len(idx) // 2
        return _Node(center, radius, None,
                     self._build(idx[order[:mid]], leaf_size),
                     self._build(idx[order[mid:]], leaf_size))

    def query(self, q: np.ndarray) -> tuple[int, float]:
        """``(index, distance)`` of the exact nearest point — same answer
        (bits included) as :func:`brute_force_nearest`, lowest index on
        ties."""
        q = np.asarray(q, dtype=np.float64)
        best = [np.inf, -1]           # [distance, index]
        self._visit(self._root, q, best)
        return int(best[1]), float(best[0])

    def _visit(self, node: _Node, q: np.ndarray, best: list) -> None:
        bound = float(np.sqrt(((q - node.center) ** 2).sum())) - node.radius
        if bound - _SLACK > best[0]:
            return
        if node.idx is not None:
            d = np.sqrt(((self._pts[node.idx] - q) ** 2).sum(axis=1))
            dmin = d.min()
            cand = int(node.idx[d == dmin].min())
            if dmin < best[0] or (dmin == best[0] and cand < best[1]):
                best[0], best[1] = float(dmin), cand
            return
        # nearer child first: tightens ``best`` before the far subtree
        dl = ((q - node.left.center) ** 2).sum()
        dr = ((q - node.right.center) ** 2).sum()
        first, second = ((node.left, node.right) if dl <= dr
                         else (node.right, node.left))
        self._visit(first, q, best)
        self._visit(second, q, best)
