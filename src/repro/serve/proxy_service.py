"""Proxy-serving query tier: "give me a proxy shaped like X" without
re-synthesis.

The fleet-scale payoff of the corpus store (ROADMAP "Fleet-scale
corpus"): profiling feeds a trace in, placement/procurement asks which
known workload it resembles and what it would cost on each chip — the
automated profiling → prediction loop of Synapse (PAPERS.md).  The
serving discipline mirrors :class:`repro.serve.engine.ServeEngine`: pay
the compile/synthesis cost once up front, then answer every request from
warm state at fixed cost.

:class:`ProxyService` wraps a :class:`~repro.core.corpus_store.
CorpusStore`.  Construction runs **one** incremental corpus synthesis
(on a warm store: fully cache-resolved) and precomputes a feature
embedding per scenario.  A query then:

1. maps the query trace's metric rows onto the corpus clusters with the
   index's exact-key/nearest-rep matcher (pure NumPy, no re-clustering);
2. featurizes the trace over the corpus terminal-table **fit
   coefficients** (per-cluster block-combination loop counts, summed
   over the trace's rows) plus its **comm-kind histogram** (payload ×
   occurrence mass per collective kind);
3. returns the nearest scenario's *cached pre-assembled proxy module*
   and a memoized cross-chip :func:`~repro.core.portability.
   predict_profile` estimate.

No Sequitur, no fit dispatch, no codegen on the hot path — the
``stats`` counters pin this (``n_warm_synthesis`` stays 1 however many
queries run), and tests assert it by poisoning the cold-path entry
points after warm-up.

Featurizing over fit coefficients rather than raw metrics deliberately
measures distance in *proxy space*: two traces that synthesize to the
same block combinations are the same workload to the serving tier, even
if their raw metric magnitudes differ.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.events import COMM_KINDS
from repro.core.interproc import compute_gid_index
from repro.core.portability import (
    CHIPS, REFERENCE_CHIP, ProfilePrediction, predict_profile,
)
from repro.core.trace_ir import TraceStore

_KIND_INDEX = {k: i for i, k in enumerate(COMM_KINDS)}
_N_COEF = 11                       # block-combination loop counts (x_1..x_11)


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Answer to one nearest-scenario query."""

    name: str                      # nearest corpus scenario
    distance: float                # embedding distance to it
    distances: dict[str, float]    # all scenarios, for inspection
    module: object                 # its cached pre-assembled proxy module
    profile: ProfilePrediction     # cross-chip roofline estimate
    matched_frac: float            # fraction of rows exact-key matched

    @property
    def module_path(self) -> str:
        """The generated proxy source on disk — reloadable anywhere via
        :func:`repro.core.replay.load_saved_module`."""
        return self.module.__proxy_path__


def _unit_log(v: np.ndarray) -> np.ndarray:
    """log1p then L2-normalize: comparable across trace lengths and
    robust to the metric magnitude spread."""
    v = np.log1p(np.maximum(np.asarray(v, dtype=np.float64), 0.0))
    n = float(np.linalg.norm(v))
    return v / n if n > 0 else v


class ProxyService:
    """Warm-cache nearest-scenario serving over a corpus store.

    ::

        svc = ProxyService(cstore)                 # one warm synthesis
        ans = svc.query(trace_store, chip="v5p")   # hot path: pure NumPy
        ans.module.__proxy_path__                  # pre-assembled proxy
        ans.profile.step_time                      # cross-chip estimate

    ``chip`` is the default target for profile predictions; per-query
    ``chip=`` overrides.  ``count_scale``/``threshold``/``out_dir``
    forward to the warm :func:`~repro.core.synthesize.synthesize_corpus`
    call (``out_dir`` makes the cached modules land somewhere durable).
    """

    def __init__(self, cstore, *, chip: str = REFERENCE_CHIP,
                 threshold: float = 0.5, count_scale: float = 1.0,
                 out_dir=None):
        if not cstore.names:
            raise ValueError("cannot serve an empty corpus")
        if chip not in CHIPS:
            raise ValueError(f"unknown chip {chip!r} (have {sorted(CHIPS)})")
        from repro.core.synthesize import synthesize_corpus   # lazy: jax
        self._cstore = cstore
        self.chip = chip
        self.stats = {
            "n_warm_synthesis": 0,
            "n_queries": 0,
            "n_module_cache_hits": 0,
            "n_profile_cache_hits": 0,
            "n_profile_cache_misses": 0,
            "n_matched_rows": 0,
            "n_fallback_rows": 0,
        }
        # the single cold-path synthesis (on a warm store this resolves
        # from the persisted grammar/fit caches and the result memo)
        self.corpus = synthesize_corpus(store=cstore, threshold=threshold,
                                        count_scale=count_scale,
                                        out_dir=out_dir)
        self.stats["n_warm_synthesis"] += 1

        # cluster id -> fit-coefficient row, via the corpus terminal table
        gid_of = compute_gid_index(self.corpus.table)
        n_cids = (max(gid_of) + 1) if gid_of else 0
        self._coef = np.zeros((n_cids, _N_COEF))
        for cid, gid in gid_of.items():
            fr = self.corpus.fits.get(gid)
            if fr is not None:
                self._coef[cid] = np.asarray(fr.x, dtype=np.float64)

        ids_by_name, _ = cstore.cluster_assignments()
        self._embeddings = {
            name: self._featurize(cstore.load_scenario(name),
                                  ids_by_name[name])
            for name in cstore.names
        }
        self._profiles: dict[tuple[str, str], ProfilePrediction] = {}

    # -- featurization (pure NumPy) --------------------------------------------

    def _featurize(self, store: TraceStore, cids: np.ndarray) -> np.ndarray:
        """Embed one trace: summed fit-coefficient mass over its compute
        rows ⊕ comm-kind payload·occurrence histogram, each log-scaled
        and unit-normalized."""
        comp = np.zeros(_N_COEF)
        if len(cids) and len(self._coef):
            valid = cids[(cids >= 0) & (cids < len(self._coef))]
            comp = self._coef[valid].sum(axis=0)
        comm = np.zeros(len(COMM_KINDS))
        occ = store.comm_occurrence_counts()
        for c, ev in enumerate(store.comm_pool):
            comm[_KIND_INDEX[ev.kind]] += float(occ[c]) * ev.payload_bytes
        return np.concatenate([_unit_log(comp), _unit_log(comm)])

    def embedding(self, name: str) -> np.ndarray:
        """The precomputed embedding of a corpus scenario."""
        return self._embeddings[name]

    # -- the hot path ----------------------------------------------------------

    def query(self, store: TraceStore, chip: str | None = None,
              ) -> QueryResult:
        """Nearest corpus scenario for a query trace — index matching +
        embedding distance + cached module/profile lookup; no synthesis
        stage runs."""
        self.stats["n_queries"] += 1
        cids, matched = self._cstore.index.match_clusters(store.metrics)
        self.stats["n_matched_rows"] += int(matched.sum())
        self.stats["n_fallback_rows"] += int((~matched).sum())
        q = self._featurize(store, cids)
        distances = {n: float(np.linalg.norm(q - e))
                     for n, e in self._embeddings.items()}
        name = min(distances, key=distances.get)
        module = self.corpus.results[name].proxy.module   # pre-assembled
        self.stats["n_module_cache_hits"] += 1
        profile = self.predict_profile(name, chip)
        return QueryResult(
            name=name, distance=distances[name], distances=distances,
            module=module, profile=profile,
            matched_frac=(float(matched.mean()) if len(matched) else 1.0))

    def predict_profile(self, name: str, chip: str | None = None,
                        ) -> ProfilePrediction:
        """Memoized cross-chip roofline estimate for a corpus scenario's
        proxy module (the prediction is a pure function of the cached
        module, so one computation per (scenario, chip) serves every
        query)."""
        chip = chip or self.chip
        key = (name, chip)
        hit = self._profiles.get(key)
        if hit is None:
            self.stats["n_profile_cache_misses"] += 1
            hit = predict_profile(self.corpus.results[name].proxy.module,
                                  chip)
            self._profiles[key] = hit
        else:
            self.stats["n_profile_cache_hits"] += 1
        return hit
