"""Proxy-serving query tier: "give me a proxy shaped like X" without
re-synthesis.

The fleet-scale payoff of the corpus store (ROADMAP "Fleet-scale
corpus"): profiling feeds a trace in, placement/procurement asks which
known workload it resembles and what it would cost on each chip — the
automated profiling → prediction loop of Synapse (PAPERS.md).  The
serving discipline mirrors :class:`repro.serve.engine.ServeEngine`: pay
the compile/synthesis cost once up front, then answer every request from
warm state at fixed cost — batched, observable, and coherent under
corpus mutation:

* **Batched queries.**  :meth:`ProxyService.query_batch` featurizes many
  traces against one vectorized cluster match over their concatenated
  metric rows (:class:`~repro.core.corpus_store.ClusterMatcher`) and
  answers them with a single (n_queries × n_scenarios) distance
  computation; :meth:`ProxyService.query` is the batch of one, so the
  two paths cannot drift.

* **Mutation-coherent warm cache.**  The service subscribes to
  :meth:`CorpusStore.subscribe` notifications; ``add``/``remove`` flips
  a stale bit and the next query triggers :meth:`ProxyService.refresh`
  — one incremental ``synthesize_corpus`` (memo/cache-resolved, *not* a
  re-warm: ``n_warm_synthesis`` stays 1) that re-embeds **only** the
  scenarios whose label-invariant embed key changed and invalidates
  only the ``(name, chip)`` profile memos whose module changed.
  Refreshed state is pinned bit-identical to a freshly constructed
  service on the mutated store.  An *unsubscribed* service detects
  manifest-fingerprint drift and raises :class:`StaleServiceError`
  instead of serving removed scenarios.

* **Nearest-neighbor structure.**  At or above ``ann_threshold``
  scenarios the distance stage queries an exact
  :class:`~repro.serve.ann.BallTree` instead of materializing the full
  distance matrix; the brute-force path stays as the parity oracle
  (same nearest scenario, bit-equal distance).  In ANN mode
  ``QueryResult.distances`` holds only the matched scenario.

* **Sequence-aware embedding.**  Embeddings concatenate three
  unit-log-normalized terms: summed fit-coefficient mass over matched
  clusters, the comm-kind payload·occurrence histogram, and a
  grammar-rule histogram (depth-binned transitive rule-instantiation
  counts, :func:`repro.core.grammar.rule_histogram`) read from the
  store's cached frozen grammars — schedule-divergent but comm-identical
  workloads separate, with **no Sequitur on any path** (an uncached
  query stream just contributes a zero term and bumps
  ``n_grammar_hist_misses``).

* **Observability.**  ``stats`` carries per-stage latency accumulators
  (``match_ms``/``featurize_ms``/``distance_ms``/``profile_ms``, via the
  shared :class:`repro.serve.engine.StageTimers`) and hit-rate counters;
  ``benchmarks/corpus_scale.py`` snapshots them per row.

No Sequitur, no fit dispatch, no codegen on the hot path — the ``stats``
counters pin this (``n_warm_synthesis`` stays 1 however many queries and
refreshes run), and tests assert it by poisoning the cold-path entry
points after warm-up.

Featurizing over fit coefficients rather than raw metrics deliberately
measures distance in *proxy space*: two traces that synthesize to the
same block combinations are the same workload to the serving tier, even
if their raw metric magnitudes differ.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading

import numpy as np

from repro.core.corpus_store import GrammarCache, ScenarioCorruptError
from repro.core.events import COMM_KINDS, N_METRICS
from repro.core.grammar import GRAMMAR_HIST_BINS, rule_histogram
from repro.core.interproc import compute_gid_index
from repro.core.portability import (
    CHIPS, REFERENCE_CHIP, ProfilePrediction, predict_profile,
)
from repro.core.trace_ir import (
    TraceStore, _first_appearance_factorize, rank_symbol_streams,
)
from repro.serve.ann import BallTree
from repro.serve.engine import StageTimers

_KIND_INDEX = {k: i for i, k in enumerate(COMM_KINDS)}
_N_COEF = 11                       # block-combination loop counts (x_1..x_11)

#: corpus size at which the distance stage switches from the brute-force
#: matrix to the exact ball tree (overridable per service)
ANN_THRESHOLD = 64


class StaleServiceError(RuntimeError):
    """The corpus store mutated under a service that is not subscribed to
    its mutation notifications — the warm cache can no longer be trusted,
    so the service fails loudly instead of answering from stale state."""


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Answer to one nearest-scenario query."""

    name: str                      # nearest corpus scenario
    distance: float                # embedding distance to it
    #: per-scenario distances for inspection — every scenario in
    #: brute-force mode; only the matched one once the ANN index is
    #: active (the tree never materializes the rest)
    distances: dict[str, float]
    module: object                 # its cached pre-assembled proxy module
    profile: ProfilePrediction     # cross-chip roofline estimate
    matched_frac: float            # fraction of rows exact-key matched

    @property
    def module_path(self) -> str:
        """The generated proxy source on disk — reloadable anywhere via
        :func:`repro.core.replay.load_saved_module`."""
        return self.module.__proxy_path__


def _unit_log_rows(m: np.ndarray) -> np.ndarray:
    """Row-wise log1p then L2-normalize: comparable across trace lengths
    and robust to the metric magnitude spread.  One vectorized pass over
    a whole batch; the reduction (elementwise square, per-row sum, sqrt)
    is row-local, so a row's bits do not depend on batch size — the
    batch of one and the batch of N embed identically."""
    m = np.log1p(np.maximum(np.asarray(m, dtype=np.float64), 0.0))
    n = np.sqrt((m ** 2).sum(axis=1, keepdims=True))
    return np.divide(m, np.where(n > 0, n, 1.0))


def _unit_log(v: np.ndarray) -> np.ndarray:
    """:func:`_unit_log_rows` of a single vector."""
    return _unit_log_rows(np.asarray(v, dtype=np.float64)[None])[0]


class ProxyService:
    """Warm-cache nearest-scenario serving over a corpus store.

    ::

        svc = ProxyService(cstore)                 # one warm synthesis
        ans = svc.query(trace_store, chip="v5p")   # hot path: pure NumPy
        outs = svc.query_batch(traces)             # one vectorized pass
        ans.module.__proxy_path__                  # pre-assembled proxy
        ans.profile.step_time                      # cross-chip estimate

    ``chip`` is the default target for profile predictions; per-query
    ``chip=`` overrides.  ``count_scale``/``threshold``/``out_dir``
    forward to the warm :func:`~repro.core.synthesize.synthesize_corpus`
    call (``out_dir`` makes the cached modules land somewhere durable).
    ``subscribe=False`` opts out of the store's mutation notifications —
    such a service raises :class:`StaleServiceError` if the store drifts
    under it.  ``ann_threshold`` sets the corpus size at which nearest-
    scenario lookup switches to the exact ball tree.
    """

    def __init__(self, cstore, *, chip: str = REFERENCE_CHIP,
                 threshold: float = 0.5, count_scale: float = 1.0,
                 out_dir=None, subscribe: bool = True,
                 ann_threshold: int = ANN_THRESHOLD):
        if not cstore.names:
            raise ValueError("cannot serve an empty corpus")
        if chip not in CHIPS:
            raise ValueError(f"unknown chip {chip!r} (have {sorted(CHIPS)})")
        from repro.core.synthesize import synthesize_corpus   # lazy: jax
        self._cstore = cstore
        self.chip = chip
        self._threshold = threshold
        self._count_scale = count_scale
        self._out_dir = out_dir
        self._ann_threshold = int(ann_threshold)
        self._lock = threading.RLock()
        self._stale = False
        self._timers = StageTimers("match", "featurize", "distance",
                                   "profile")
        self.stats = {
            "n_warm_synthesis": 0,
            "n_refresh": 0,
            "n_queries": 0,
            "n_query_batches": 0,
            "n_module_cache_hits": 0,
            "n_profile_cache_hits": 0,
            "n_profile_cache_misses": 0,
            "n_profile_invalidated": 0,
            "n_matched_rows": 0,
            "n_fallback_rows": 0,
            "n_reembedded": 0,
            "n_grammar_hist_hits": 0,
            "n_grammar_hist_misses": 0,
            "n_ann_queries": 0,
            "n_brute_queries": 0,
            # degraded-mode serving (see refresh()): a failed refresh
            # keeps answering from the last-good snapshot
            "degraded": False,
            "n_degraded_refreshes": 0,
            "n_excluded_scenarios": 0,
        }
        self._degraded_cause: BaseException | None = None
        self._failed_fingerprint: str | None = None
        self.stats.update(self._timers.snapshot_ms())
        # the single cold-path synthesis (on a warm store this resolves
        # from the persisted grammar/fit caches and the result memo)
        self.corpus = synthesize_corpus(store=cstore, threshold=threshold,
                                        count_scale=count_scale,
                                        out_dir=out_dir)
        self.stats["n_warm_synthesis"] += 1
        self._embeddings: dict[str, np.ndarray] = {}
        self._embed_keys: dict[str, str] = {}
        self._profiles: dict[tuple[str, str], ProfilePrediction] = {}
        self._sync(count_reembeds=False)
        self._subscribed = False
        if subscribe:
            cstore.subscribe(self._on_store_mutation)
            self._subscribed = True

    # -- warm-state derivation / refresh ---------------------------------------

    def _sync(self, count_reembeds: bool) -> None:
        """(Re)derive every piece of warm serving state from the current
        ``self.corpus`` + store view, reusing embeddings whose
        label-invariant embed key is unchanged."""
        cstore = self._cstore
        # cluster id -> fit-coefficient row, via the corpus terminal table
        gid_of = compute_gid_index(self.corpus.table)
        n_cids = (max(gid_of) + 1) if gid_of else 0
        self._coef = np.zeros((n_cids, _N_COEF))
        for cid, gid in gid_of.items():
            fr = self.corpus.fits.get(gid)
            if fr is not None:
                self._coef[cid] = np.asarray(fr.x, dtype=np.float64)
        # frozen matcher snapshot: in-flight queries stay immune to index
        # mutations until the next sync
        self._matcher = cstore.index.matcher()
        ids_by_name, _ = cstore.cluster_assignments()
        old_keys, old_emb = self._embed_keys, self._embeddings
        embeddings: dict[str, np.ndarray] = {}
        keys: dict[str, str] = {}
        memo: dict = {}
        n_re = 0
        for name in cstore.names:
            k = self._embed_key(name, ids_by_name[name])
            keys[name] = k
            if old_keys.get(name) == k:
                embeddings[name] = old_emb[name]
            else:
                embeddings[name] = self._featurize(
                    cstore.load_scenario(name), ids_by_name[name], memo)
                n_re += 1
        if count_reembeds:
            self.stats["n_reembedded"] += n_re
        self._embeddings, self._embed_keys = embeddings, keys
        self._names = list(embeddings)
        self._emb_mat = np.stack([embeddings[n] for n in self._names])
        self._ann = (BallTree(self._emb_mat)
                     if len(self._names) >= self._ann_threshold else None)
        self._fingerprint = cstore.manifest_fingerprint()

    def _embed_key(self, name: str, cids: np.ndarray) -> str:
        """Content key of one scenario's embedding: trace content hash ⊕
        first-appearance cluster pattern ⊕ the coefficient rows of the
        clusters it touches.  Deliberately invariant under pure cluster
        relabeling (the common effect of unrelated appends/removals), so
        refresh re-embeds only scenarios whose embedding inputs actually
        changed."""
        local, uniq, _ = _first_appearance_factorize(
            np.asarray(cids, dtype=np.int64))
        h = hashlib.sha256(
            f"embed|1|{self._threshold!r}|"
            f"{self._cstore.content_hash(name)}|".encode())
        h.update(np.ascontiguousarray(local, dtype=np.int64).tobytes())
        for u in uniq.tolist():
            if 0 <= u < len(self._coef):
                h.update(self._coef[int(u)].tobytes())
            else:
                h.update(b"\xff")
        return h.hexdigest()

    def _on_store_mutation(self, event: str, names) -> None:
        # runs inside the mutator (under the store lock): only flip the
        # stale bit — taking the service lock here would invert the
        # service-then-store lock order refresh uses
        self._stale = True

    def _ensure_fresh(self) -> None:
        if self._degraded_cause is not None:
            # degraded: retry the refresh only once the store actually
            # changed (a repair/mutation moves the fingerprint) — never a
            # retry storm against the same broken state
            if (self._stale or self._cstore.manifest_fingerprint()
                    != self._failed_fingerprint):
                self.refresh()
            return
        if self._stale:
            self.refresh()
            return
        if self._cstore.manifest_fingerprint() != self._fingerprint:
            if self._subscribed:
                self.refresh()        # notification raced us: catch up
            else:
                raise StaleServiceError(
                    "corpus store mutated under an unsubscribed "
                    "ProxyService (manifest fingerprint drifted); construct "
                    "a fresh service or subscribe to mutation notifications")

    def refresh(self) -> "ProxyService":
        """Catch the warm cache up with the mutated store: one
        incremental ``synthesize_corpus`` (memo/cache-resolved — not a
        re-warm), selective re-embedding, precise profile-memo
        invalidation.  Resulting state is bit-identical to a freshly
        constructed service on the mutated store.

        A refresh that *fails* (corrupt scenario artifact, damaged
        store, synthesis error) does not take the service down: the
        last-good snapshot keeps serving, ``stats["degraded"]`` /
        :meth:`health` surface the cause, scenarios implicated in the
        failure are excluded from matching, and the next store change
        (e.g. :meth:`~repro.core.corpus_store.CorpusStore.repair`
        quarantining the culprit) triggers a retry that restores normal
        service — with state bit-identical to a rebuilt one."""
        from repro.core.synthesize import synthesize_corpus   # lazy: jax
        with self._lock:
            cstore = self._cstore
            with cstore.lock:
                # clear the stale bit *before* re-deriving: a mutation
                # landing after we release the store lock re-arms it, so
                # no update is ever lost
                self._stale = False
                if not cstore.names:
                    raise ValueError("cannot serve an empty corpus")
                old_corpus = self.corpus
                old_modules = {n: r.proxy.module
                               for n, r in self.corpus.results.items()}
                try:
                    self.corpus = synthesize_corpus(
                        store=cstore, threshold=self._threshold,
                        count_scale=self._count_scale,
                        out_dir=self._out_dir)
                    self.stats["n_refresh"] += 1
                    dropped = 0
                    for key in list(self._profiles):
                        res = self.corpus.results.get(key[0])
                        if (res is None or res.proxy.module
                                is not old_modules.get(key[0])):
                            del self._profiles[key]
                            dropped += 1
                    self.stats["n_profile_invalidated"] += dropped
                    self._sync(count_reembeds=True)
                except Exception as e:
                    # keep serving the last-good snapshot (InjectedCrash
                    # is a BaseException: a simulated process death is
                    # not degradable and propagates)
                    self.corpus = old_corpus
                    self._enter_degraded(e)
                    return self
                self._exit_degraded()
        return self

    # -- degraded-mode serving -------------------------------------------------

    def _enter_degraded(self, cause: BaseException) -> None:
        """A refresh failed: record the cause + the fingerprint it failed
        against (the retry gate), and drop scenarios implicated in the
        failure from the match set so a damaged scenario is never
        *answered* from the stale snapshot."""
        self._degraded_cause = cause
        self._failed_fingerprint = self._cstore.manifest_fingerprint()
        self.stats["degraded"] = True
        self.stats["n_degraded_refreshes"] += 1
        bad: set[str] = set(getattr(self._cstore, "damaged", {}) or {})
        c: BaseException | None = cause
        while c is not None:
            if isinstance(c, ScenarioCorruptError):
                bad.add(c.name)
            c = c.__cause__
        keep = [n for n in self._names if n not in bad]
        if keep and len(keep) < len(self._names):
            self._names = keep
            self._emb_mat = np.stack([self._embeddings[n] for n in keep])
            self._ann = (BallTree(self._emb_mat)
                         if len(keep) >= self._ann_threshold else None)
        self.stats["n_excluded_scenarios"] = (
            len(self._embeddings) - len(self._names))

    def _exit_degraded(self) -> None:
        self._degraded_cause = None
        self._failed_fingerprint = None
        self.stats["degraded"] = False
        self.stats["n_excluded_scenarios"] = 0

    def health(self) -> dict:
        """Liveness/consistency snapshot for operators: ``status`` is
        ``"ok"`` or ``"degraded"``; degraded responses carry the refresh
        failure's cause and how much of the corpus is still served."""
        with self._lock:
            degraded = self._degraded_cause is not None
            return {
                "status": "degraded" if degraded else "ok",
                "degraded": degraded,
                "cause": (f"{type(self._degraded_cause).__name__}: "
                          f"{self._degraded_cause}" if degraded else None),
                "serving_scenarios": len(self._names),
                "excluded_scenarios": int(
                    self.stats["n_excluded_scenarios"]),
                "n_refresh": int(self.stats["n_refresh"]),
                "n_degraded_refreshes": int(
                    self.stats["n_degraded_refreshes"]),
            }

    def close(self) -> None:
        """Detach from the store's mutation notifications (idempotent)."""
        if self._subscribed:
            self._cstore.unsubscribe(self._on_store_mutation)
            self._subscribed = False

    # -- featurization (pure NumPy + cached frozen grammars) -------------------

    def _grammar_hist(self, store: TraceStore, cids: np.ndarray,
                      memo: dict | None = None) -> np.ndarray:
        """Summed depth-binned rule histogram over the trace's per-rank
        streams, read from the store's cached frozen grammars (the same
        content-addressed keys joint synthesis populates) — no Sequitur;
        an uncached stream contributes zeros and counts a miss.  ``memo``
        dedupes work on repeated streams, keyed first by raw stream bytes
        (skipping factorize + hashing entirely) and then by grammar key;
        :meth:`query_batch` shares one memo across the whole batch, so
        look-alike probes pay for featurization once."""
        memo = {} if memo is None else memo
        hist = np.zeros(2 * GRAMMAR_HIST_BINS, dtype=np.int64)
        syms = rank_symbol_streams(store, np.asarray(cids, dtype=np.int64))
        ext = store.extents
        for r in range(store.n_ranks):
            s = syms[int(ext[r]):int(ext[r + 1])]
            if not len(s):
                continue
            sb = s.tobytes()
            h = memo.get(sb)
            if h is None:
                local_ids, _, _ = _first_appearance_factorize(s)
                key = GrammarCache.key(local_ids, self._threshold)
                h = memo.get(key)
                if h is None:
                    rules = self._cstore.grammars.get(key)
                    if rules is None:
                        self.stats["n_grammar_hist_misses"] += 1
                        h = np.zeros(2 * GRAMMAR_HIST_BINS, dtype=np.int64)
                    else:
                        self.stats["n_grammar_hist_hits"] += 1
                        h = rule_histogram(rules)
                    memo[key] = h
                memo[sb] = h
            hist += h
        return hist

    def _featurize_parts(self, store: TraceStore, cids: np.ndarray,
                         memo: dict | None = None,
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The three raw (un-normalized) embedding terms of one trace:
        summed fit-coefficient mass over its compute rows, comm-kind
        payload·occurrence histogram, grammar-rule histogram."""
        comp = np.zeros(_N_COEF)
        if len(cids) and len(self._coef):
            valid = cids[(cids >= 0) & (cids < len(self._coef))]
            comp = self._coef[valid].sum(axis=0)
        comm = np.zeros(len(COMM_KINDS))
        occ = store.comm_occurrence_counts()
        for c, ev in enumerate(store.comm_pool):
            comm[_KIND_INDEX[ev.kind]] += float(occ[c]) * ev.payload_bytes
        return comp, comm, self._grammar_hist(store, cids, memo)

    @staticmethod
    def _embed_rows(parts: list) -> np.ndarray:
        """Normalize a batch of :meth:`_featurize_parts` outputs in three
        vectorized passes (one per term) — row bits are batch-size
        independent, so this is the single embedding definition for
        corpus scenarios, single queries, and batches alike."""
        return np.concatenate(
            [_unit_log_rows(np.stack([p[i] for p in parts]))
             for i in range(3)], axis=1)

    def _featurize(self, store: TraceStore, cids: np.ndarray,
                   memo: dict | None = None) -> np.ndarray:
        """Embed one trace: the three terms of :meth:`_featurize_parts`,
        each log-scaled and unit-normalized."""
        return self._embed_rows([self._featurize_parts(store, cids, memo)])[0]

    def embedding(self, name: str) -> np.ndarray:
        """The precomputed embedding of a corpus scenario."""
        return self._embeddings[name]

    # -- the hot path ----------------------------------------------------------

    def query(self, store: TraceStore, chip: str | None = None,
              ) -> QueryResult:
        """Nearest corpus scenario for a query trace — index matching +
        embedding distance + cached module/profile lookup; no synthesis
        stage runs.  The batch of one: bit-identical to
        :meth:`query_batch` by construction."""
        return self.query_batch([store], chip=chip)[0]

    def query_batch(self, stores, chip: str | None = None,
                    ) -> list[QueryResult]:
        """Answer many queries in one vectorized pass: a single cluster
        match over the concatenated metric rows, per-segment
        featurization, and one (n_queries × n_scenarios) distance
        computation (or one ball-tree walk per query in ANN mode)."""
        stores = list(stores)
        for i, st in enumerate(stores):
            if st.n_events == 0:
                raise ValueError(
                    f"cannot query an empty trace (batch index {i}): the "
                    "all-zero embedding would match an arbitrary scenario")
        if not stores:
            return []
        with self._lock:
            return self._query_batch_locked(stores, chip)

    def _query_batch_locked(self, stores: list, chip: str | None,
                            ) -> list[QueryResult]:
        self._ensure_fresh()
        self.stats["n_query_batches"] += 1
        self.stats["n_queries"] += len(stores)

        ext = np.cumsum([0] + [st.metrics.shape[0] for st in stores])
        with self._timers.time("match"):
            allm = (np.concatenate([st.metrics for st in stores])
                    if ext[-1] else np.zeros((0, N_METRICS)))
            cids_all, matched_all = self._matcher.match(allm)
        self.stats["n_matched_rows"] += int(matched_all.sum())
        self.stats["n_fallback_rows"] += int((~matched_all).sum())

        with self._timers.time("featurize"):
            memo: dict = {}       # shared: look-alike probes featurize once
            Q = self._embed_rows(
                [self._featurize_parts(st, cids_all[ext[i]:ext[i + 1]], memo)
                 for i, st in enumerate(stores)])

        with self._timers.time("distance"):
            if self._ann is not None:
                self.stats["n_ann_queries"] += len(stores)
                picks = [self._ann.query(q) for q in Q]
                idxs = [i for i, _ in picks]
                dists = [{self._names[i]: float(d)} for i, d in picks]
            else:
                self.stats["n_brute_queries"] += len(stores)
                D = np.sqrt(((Q[:, None, :] - self._emb_mat[None]) ** 2)
                            .sum(axis=-1))
                idxs = np.argmin(D, axis=1).tolist()
                dists = [dict(zip(self._names, row)) for row in D.tolist()]

        out: list[QueryResult] = []
        with self._timers.time("profile"):
            for k, st in enumerate(stores):
                name = self._names[int(idxs[k])]
                module = self.corpus.results[name].proxy.module
                self.stats["n_module_cache_hits"] += 1
                profile = self.predict_profile(name, chip)
                m = matched_all[ext[k]:ext[k + 1]]
                out.append(QueryResult(
                    name=name, distance=float(dists[k][name]),
                    distances=dists[k], module=module, profile=profile,
                    matched_frac=(float(m.mean()) if len(m) else 1.0)))
        self.stats.update(self._timers.snapshot_ms())
        return out

    def predict_profile(self, name: str, chip: str | None = None,
                        ) -> ProfilePrediction:
        """Memoized cross-chip roofline estimate for a corpus scenario's
        proxy module (the prediction is a pure function of the cached
        module, so one computation per (scenario, chip) serves every
        query)."""
        chip = chip or self.chip
        key = (name, chip)
        with self._lock:
            hit = self._profiles.get(key)
            if hit is None:
                self.stats["n_profile_cache_misses"] += 1
                hit = predict_profile(
                    self.corpus.results[name].proxy.module, chip)
                self._profiles[key] = hit
            else:
                self.stats["n_profile_cache_hits"] += 1
            return hit
