from repro.serve.ann import BallTree, brute_force_nearest  # noqa: F401
from repro.serve.engine import ServeEngine, StageTimers  # noqa: F401
from repro.serve.proxy_service import (  # noqa: F401
    ProxyService, QueryResult, StaleServiceError,
)
