from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.proxy_service import ProxyService, QueryResult  # noqa: F401
