"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention.  [arXiv:2401.04088]

8 experts cannot shard a 16-way model axis, so TP goes *inside* the expert
(expert_ffn → model); long_500k RUNS via the 4096-token SWA ring cache."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    rope_theta=1_000_000.0,
    window=4096,
    layer_pattern=("l",),
    n_experts=8,
    top_k=2,
    d_ff_expert=16384,
    rules_overrides=(("experts", None), ("expert_ffn", "model"),
                     ("embed", "data")),
    supports_long_decode=True,
)
