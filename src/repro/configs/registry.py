"""Architecture registry + dry-run input specs.

``get(arch_id)`` resolves the assigned ids; ``input_specs(cfg, shape, mesh)``
returns (args, in_shardings) of ShapeDtypeStructs for the step function of
the shape's kind — the no-allocation stand-ins the multi-pod dry-run lowers
against.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (  # noqa: F401
    ArchConfig, RunShape, SHAPES, applicable_shapes, smoke,
)
from repro.sharding.partition import LogicalRules, sharding_for_shape

_MODULES = {
    "gemma3-4b": "gemma3_4b",
    "qwen3-32b": "qwen3_32b",
    "qwen3-8b": "qwen3_8b",
    "llama3.2-3b": "llama32_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "mamba2-2.7b": "mamba2_27b",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-v0.1-52b": "jamba_v01_52b",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def rules_for(cfg: ArchConfig) -> LogicalRules:
    rules = LogicalRules()
    if cfg.rules_overrides:
        rules = rules.with_overrides(**dict(cfg.rules_overrides))
    return rules


def batch_specs(cfg: ArchConfig, shape: RunShape, mesh, rules=None) -> dict:
    """ShapeDtypeStruct batch for the given run shape (modalities stubbed)."""
    rules = rules or rules_for(cfg)
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    def sds(shp, dtype, axes):
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=sharding_for_shape(shp, axes, mesh, rules))

    if shape.kind == "train":
        out = {
            "tokens": sds((b, s), jnp.int32, ("batch", "seq")),
            "labels": sds((b, s), jnp.int32, ("batch", "seq")),
        }
    elif shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32, ("batch", "seq"))}
    else:  # decode: one new token
        out = {"tokens": sds((b, 1), jnp.int32, ("batch", None))}
    if cfg.n_vision_tokens and shape.kind != "decode":
        out["vision_embeds"] = sds((b, cfg.n_vision_tokens, cfg.d_model), dt,
                                   ("batch", "patches", "embed"))
    if cfg.n_audio_frames and shape.kind != "decode":
        out["audio_frames"] = sds((b, cfg.n_audio_frames, cfg.d_model), dt,
                                  ("batch", "frames", "embed"))
    return out


def param_specs(cfg: ArchConfig, mesh, rules=None):
    """Abstract, sharded parameter ShapeDtypeStructs."""
    from repro.models.model import init_abstract, logical_axes_tree
    rules = rules or rules_for(cfg)
    shapes = init_abstract(cfg)
    axes = logical_axes_tree(cfg)
    return jax.tree.map(
        lambda sd, ax: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype,
            sharding=sharding_for_shape(sd.shape, ax, mesh, rules)),
        shapes, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_specs(cfg: ArchConfig, shape: RunShape, mesh, rules=None):
    """Abstract, sharded decode-cache ShapeDtypeStructs."""
    from repro.models.model import abstract_cache, cache_logical_axes
    rules = rules or rules_for(cfg)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    axes = cache_logical_axes(cfg, shape.global_batch, shape.seq_len)
    return jax.tree.map(
        lambda sd, ax: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype,
            sharding=sharding_for_shape(sd.shape, ax, mesh, rules)),
        cache, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def input_specs(arch_id: str, shape_name: str, mesh, *, with_opt: bool = True):
    """Full argument specs for the dry-run step of (arch × shape).

    train  → (params, opt_state, batch)   for train_step
    prefill→ (params, batch)              for prefill_step
    decode → (params, cache, batch, pos)  for serve_step
    """
    cfg = get(arch_id)
    shape = SHAPES[shape_name]
    rules = rules_for(cfg)
    params = param_specs(cfg, mesh, rules)
    batch = batch_specs(cfg, shape, mesh, rules)
    if shape.kind == "train":
        if not with_opt:
            return cfg, (params, batch)
        from repro.train.optimizer import abstract_opt_state
        opt = abstract_opt_state(params, mesh, rules)
        return cfg, (params, opt, batch)
    if shape.kind == "prefill":
        return cfg, (params, batch)
    cache = cache_specs(cfg, shape, mesh, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cfg, (params, cache, batch, pos)
