"""Architecture registry + dry-run input specs + the scenario zoo.

``get(arch_id)`` resolves the assigned ids; ``input_specs(cfg, shape, mesh)``
returns (args, in_shardings) of ShapeDtypeStructs for the step function of
the shape's kind — the no-allocation stand-ins the multi-pod dry-run lowers
against.

``SCENARIOS``/:func:`build_scenario` register the **model-zoo workloads**
corpus-level synthesis runs over (``repro.core.synthesize.
synthesize_corpus``): one traced scenario per model family
(transformer / flash / ssm / moe / encdec), each combining real compute
costs — the jaxpr walker over the family's smoke-config step functions,
no allocation, no devices — with the family's canonical parallelism
schedule recorded through :class:`repro.core.tracer.TraceSession` (the
PMPI-interposition analog).  Builders return columnar
:class:`~repro.core.trace_ir.TraceStore` traces.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (  # noqa: F401
    ArchConfig, RunShape, SHAPES, applicable_shapes, smoke,
)
from repro.sharding.partition import LogicalRules, sharding_for_shape

_MODULES = {
    "gemma3-4b": "gemma3_4b",
    "qwen3-32b": "qwen3_32b",
    "qwen3-8b": "qwen3_8b",
    "llama3.2-3b": "llama32_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "mamba2-2.7b": "mamba2_27b",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-v0.1-52b": "jamba_v01_52b",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def rules_for(cfg: ArchConfig) -> LogicalRules:
    rules = LogicalRules()
    if cfg.rules_overrides:
        rules = rules.with_overrides(**dict(cfg.rules_overrides))
    return rules


def batch_specs(cfg: ArchConfig, shape: RunShape, mesh, rules=None) -> dict:
    """ShapeDtypeStruct batch for the given run shape (modalities stubbed)."""
    rules = rules or rules_for(cfg)
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    def sds(shp, dtype, axes):
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=sharding_for_shape(shp, axes, mesh, rules))

    if shape.kind == "train":
        out = {
            "tokens": sds((b, s), jnp.int32, ("batch", "seq")),
            "labels": sds((b, s), jnp.int32, ("batch", "seq")),
        }
    elif shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32, ("batch", "seq"))}
    else:  # decode: one new token
        out = {"tokens": sds((b, 1), jnp.int32, ("batch", None))}
    if cfg.n_vision_tokens and shape.kind != "decode":
        out["vision_embeds"] = sds((b, cfg.n_vision_tokens, cfg.d_model), dt,
                                   ("batch", "patches", "embed"))
    if cfg.n_audio_frames and shape.kind != "decode":
        out["audio_frames"] = sds((b, cfg.n_audio_frames, cfg.d_model), dt,
                                  ("batch", "frames", "embed"))
    return out


def param_specs(cfg: ArchConfig, mesh, rules=None):
    """Abstract, sharded parameter ShapeDtypeStructs."""
    from repro.models.model import init_abstract, logical_axes_tree
    rules = rules or rules_for(cfg)
    shapes = init_abstract(cfg)
    axes = logical_axes_tree(cfg)
    return jax.tree.map(
        lambda sd, ax: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype,
            sharding=sharding_for_shape(sd.shape, ax, mesh, rules)),
        shapes, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_specs(cfg: ArchConfig, shape: RunShape, mesh, rules=None):
    """Abstract, sharded decode-cache ShapeDtypeStructs."""
    from repro.models.model import abstract_cache, cache_logical_axes
    rules = rules or rules_for(cfg)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    axes = cache_logical_axes(cfg, shape.global_batch, shape.seq_len)
    return jax.tree.map(
        lambda sd, ax: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype,
            sharding=sharding_for_shape(sd.shape, ax, mesh, rules)),
        cache, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def input_specs(arch_id: str, shape_name: str, mesh, *, with_opt: bool = True):
    """Full argument specs for the dry-run step of (arch × shape).

    train  → (params, opt_state, batch)   for train_step
    prefill→ (params, batch)              for prefill_step
    decode → (params, cache, batch, pos)  for serve_step
    """
    cfg = get(arch_id)
    shape = SHAPES[shape_name]
    rules = rules_for(cfg)
    params = param_specs(cfg, mesh, rules)
    batch = batch_specs(cfg, shape, mesh, rules)
    if shape.kind == "train":
        if not with_opt:
            return cfg, (params, batch)
        from repro.train.optimizer import abstract_opt_state
        opt = abstract_opt_state(params, mesh, rules)
        return cfg, (params, opt, batch)
    if shape.kind == "prefill":
        return cfg, (params, batch)
    cache = cache_specs(cfg, shape, mesh, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cfg, (params, cache, batch, pos)


# ---------------------------------------------------------------------------
# scenario zoo (corpus-level synthesis targets)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One model-zoo workload: which architecture's step functions provide
    the (real, jaxpr-walked) compute costs, and which parallelism schedule
    shapes the recorded communication pattern."""
    name: str
    arch_id: str
    family: str          # transformer | flash | ssm | moe | encdec
    parallelism: str
    n_ranks: int         # default trace width
    steps: int           # default steps / microbatches / decode tokens


SCENARIOS: dict[str, ScenarioSpec] = {
    "transformer-dp": ScenarioSpec(
        "transformer-dp", "qwen3-8b", "transformer", "data_parallel", 8, 4),
    "flash-ring": ScenarioSpec(
        "flash-ring", "gemma3-4b", "flash", "ring_attention", 8, 2),
    "ssm-decode": ScenarioSpec(
        "ssm-decode", "mamba2-2.7b", "ssm", "tp_decode", 8, 6),
    "moe-ep": ScenarioSpec(
        "moe-ep", "deepseek-moe-16b", "moe", "expert_parallel", 8, 4),
    "encdec-pipeline": ScenarioSpec(
        "encdec-pipeline", "whisper-large-v3", "encdec", "pipeline", 8, 4),
}

SCENARIO_IDS = tuple(SCENARIOS)


def _batch_sds(cfg: ArchConfig, b: int, s: int, kind: str) -> dict:
    """Unsharded ShapeDtypeStruct batch (tracing needs shapes only).
    Modalities follow :func:`batch_specs`' rule: decode steps never carry
    them (prefill populated the cache)."""
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if kind == "loss":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    dt = jnp.dtype(cfg.dtype)
    if cfg.n_vision_tokens and kind != "decode":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), dt)
    if cfg.n_audio_frames and kind != "decode":
        out["audio_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), dt)
    return out


def _model_costs(cfg: ArchConfig, kinds=("train", "prefill", "decode"),
                 b: int = 2, s: int = 8) -> dict[str, tuple]:
    """Real 6-metric costs of the family's step functions: train
    (fwd+bwd), prefill, and one decode step — jaxpr-walked, no devices."""
    from repro.core.tracer import compute_cost
    from repro.models.model import abstract_cache, build_forward, init_abstract

    params = init_abstract(cfg)
    out: dict[str, tuple] = {}
    if "train" in kinds:
        loss = build_forward(cfg, "loss")
        out["train"] = tuple(compute_cost(
            lambda p, bt: jax.value_and_grad(lambda q: loss(q, bt, cfg))(p),
            params, _batch_sds(cfg, b, s, "loss")))
    if "prefill" in kinds:
        prefill = build_forward(cfg, "prefill")
        out["prefill"] = tuple(compute_cost(
            lambda p, bt: prefill(p, bt, cfg), params,
            _batch_sds(cfg, b, s, "prefill")))
    if "decode" in kinds:
        decode = build_forward(cfg, "decode")
        cache = abstract_cache(cfg, b, 4 * s)
        dbatch = dict(_batch_sds(cfg, b, 1, "decode"))
        out["decode"] = tuple(compute_cost(
            lambda p, c, bt, pos: decode(p, c, bt, pos, cfg),
            params, cache, dbatch, jax.ShapeDtypeStruct((), jnp.int32)))
    return out


def build_scenario(name: str, n_ranks: int | None = None,
                   steps: int | None = None):
    """Trace one zoo scenario into a columnar
    :class:`~repro.core.trace_ir.TraceStore`."""
    from repro.core.events import CommEvent, ComputeEvent
    from repro.core.tracer import TraceSession

    spec = SCENARIOS[name]
    n = spec.n_ranks if n_ranks is None else n_ranks
    steps = spec.steps if steps is None else steps
    cfg = smoke(get(spec.arch_id))
    kinds = {"transformer": ("train",), "flash": ("prefill",),
             "ssm": ("decode",), "moe": ("train", "prefill"),
             "encdec": ("prefill", "decode")}[spec.family]
    costs = _model_costs(cfg, kinds)
    d = cfg.d_model

    if spec.family == "transformer":
        # data-parallel training: step compute + bucketed gradient psums
        g1 = CommEvent("psum", (d, cfg.d_ff), "float32", ("dp",))
        g2 = CommEvent("psum", (cfg.padded_vocab, d), "float32", ("dp",))
        with TraceSession(n, {"dp": n}) as sess:
            for _ in range(steps):
                sess.emit(None, ComputeEvent(costs["train"]))
                sess.emit(None, g1)
                sess.emit(None, g2)
        return sess.to_store()

    if spec.family == "flash":
        # ring-attention prefill: per hop, one flash chunk + KV-block shift
        from repro.models.flash import flash_attention
        from repro.core.tracer import compute_cost
        b, s, h, g, hd = 2, 16, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = jax.ShapeDtypeStruct((b, s, h, hd), jnp.float32)
        kv = jax.ShapeDtypeStruct((b, s, g, hd), jnp.float32)
        chunk = tuple(compute_cost(
            lambda q, k, v: flash_attention(q, k, v, causal=False,
                                            q_chunk=8, kv_chunk=8),
            q, kv, kv))
        shift = CommEvent("ppermute", (b, s, g, hd), "float32", ("ring",),
                          ("shift", 1))
        with TraceSession(n, {"ring": n}) as sess:
            for _ in range(steps):
                for _hop in range(n - 1):
                    sess.emit(None, ComputeEvent(chunk))
                    sess.emit(None, shift)
                sess.emit(None, ComputeEvent(costs["prefill"]))
                sess.emit(None, CommEvent("all_gather", (b, s // 2 or 1, d),
                                          "float32", ("ring",), (0,)))
        return sess.to_store()

    if spec.family == "ssm":
        # tensor-parallel decode: one SSM decode step + logits psum per token
        logits = CommEvent("psum", (2, cfg.padded_vocab), "float32", ("mp",))
        with TraceSession(n, {"mp": n}) as sess:
            for _ in range(steps):
                sess.emit(None, ComputeEvent(costs["decode"]))
                sess.emit(None, logits)
        return sess.to_store()

    if spec.family == "moe":
        # expert-parallel training: token dispatch/combine all_to_alls
        # around the expert compute, then the gradient psum
        tok = (2 * 8 // n or 1, d)
        disp = CommEvent("all_to_all", tok, "float32", ("ep",), (0, 0))
        grads = CommEvent("psum", (d, cfg.d_ff_expert or cfg.d_ff),
                          "float32", ("ep",))
        with TraceSession(n, {"ep": n}) as sess:
            for _ in range(steps):
                sess.emit(None, ComputeEvent(costs["prefill"]))
                sess.emit(None, disp)
                sess.emit(None, ComputeEvent(costs["train"]))
                sess.emit(None, disp)
                sess.emit(None, grads)
        return sess.to_store()

    if spec.family == "encdec":
        # two-stage pipeline: encoder ranks prefill and ship activations to
        # their decoder peer; decoder ranks run decode steps (heterogeneous
        # per-rank mains — the Algorithm 1 clustering case)
        half = max(n // 2, 1)
        act = CommEvent("ppermute", (2, 8, d), "float32", ("stage",),
                        ("shift", half))
        with TraceSession(n, {"stage": n}) as sess:
            for _ in range(steps):
                for r in range(half):
                    peer = r + half
                    sess.emit([r], ComputeEvent(costs["prefill"]))
                    if peer < n:
                        sess.emit([r, peer], act)
                        sess.emit([peer], ComputeEvent(costs["decode"]))
            sess.emit(None, CommEvent("psum", (d,), "float32", ("stage",)))
        return sess.to_store()

    raise KeyError(f"unknown scenario family {spec.family!r}")


def ingest_scenarios(corpus_store, names=None, **build_kwargs) -> list[str]:
    """Stream zoo scenarios into a
    :class:`repro.core.corpus_store.CorpusStore` **one at a time** —
    each :func:`build_scenario` result is appended (and incrementally
    clustered) before the next is built, so the corpus never needs the
    whole zoo in memory.  Scenarios already in the store are skipped
    (re-running is an idempotent catch-up).  Returns the names added.
    """
    added = []
    for name in (SCENARIO_IDS if names is None else names):
        if name in corpus_store:
            continue
        corpus_store.add_scenario(name, build_scenario(name, **build_kwargs))
        added.append(name)
    return added
