"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attention 7:1 interleave, MoE 16 experts top-2 on
alternate layers.  [arXiv:2403.19887]

long_500k RUNS: 7/8 of layers are O(1)-state SSM; the 4 attention layers'
KV caches are seq-sharded over the model axis.  (Jamba uses Mamba-1 state
16; we keep the SSD mixer with that state size — DESIGN.md §Arch notes.)"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    rope_theta=10_000.0,
    layer_pattern=("m", "m", "m", "g", "m", "m", "m", "m"),
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_chunk=256,
    supports_long_decode=True,
    rules_overrides=(("embed", "data"),),
)
