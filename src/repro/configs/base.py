"""Architecture + run-shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; run shapes
(``train_4k`` …) are :class:`RunShape`s.  ``input_specs(cfg, shape, mesh)``
yields ShapeDtypeStruct stand-ins (weak-type-correct, sharded, no
allocation) for the dry-run; ``smoke()`` returns a reduced same-family
config for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

#: layer kind codes used in ``layer_pattern`` (the repeating unit):
#:   'g' global attention   'l' local (sliding-window) attention
#:   'm' mamba2 mixer       'x' cross-attention (+self for VLM: 's')
#:   's' self attention (VLM unit member, same as 'g')
LAYER_KINDS = ("g", "l", "m", "x", "s")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: int = 0                   # sliding window for 'l' layers
    layer_pattern: tuple[str, ...] = ("g",)
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1                # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # --- SSM ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # --- frontends (stubs provide precomputed embeddings) ---
    n_vision_tokens: int = 0          # VLM patch embeddings
    n_audio_frames: int = 0           # audio frame embeddings (enc input)
    enc_layers: int = 0               # encoder layers (enc-dec only)
    # --- numerics / impl ---
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512             # LM-head seq chunking (0 = off)
    attn_impl: str = "xla"            # xla | pallas
    rules_overrides: tuple[tuple[str, object], ...] = ()
    # long-context applicability (sub-quadratic decode path exists)
    supports_long_decode: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the LM head/embedding shard
        cleanly on any reasonable TP degree (standard framework practice)."""
        return ((self.vocab + 255) // 256) * 256

    def layer_kinds(self) -> list[str]:
        """Expanded per-layer kind list of length n_layers."""
        pat = self.layer_pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def is_moe_layer(self, i: int) -> bool:
        return (self.n_experts > 0 and i % self.moe_every == self.moe_offset)

    def approx_params(self) -> float:
        """Total parameter count (for 6ND model-flops accounting)."""
        d, v = self.d_model, self.padded_vocab
        n = v * d  # embeddings (tied head assumed in count)
        kinds = self.layer_kinds()
        hd = self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        for i, k in enumerate(kinds):
            if k == "m":
                d_in = self.ssm_expand * d
                h = d_in // self.ssm_head_dim
                gn = self.ssm_groups * self.ssm_state
                n += d * (2 * d_in + 2 * gn + h)       # in_proj
                n += d_in * d                           # out_proj
                n += 4 * (d_in + 2 * gn)                # conv
            else:
                n += attn
                if k == "x":
                    n += attn                           # cross-attn weights
            # feed-forward applies to every layer kind (incl. jamba mamba)
            if self.is_moe_layer(i):
                n += self.n_experts * 3 * d * self.d_ff_expert
                n += self.n_shared_experts * 3 * d * self.d_ff_expert
            elif self.d_ff:
                n += 3 * d * self.d_ff
        if self.enc_layers:
            n += self.enc_layers * (attn + 3 * d * self.d_ff)
        return float(n)

    def active_params(self) -> float:
        """Per-token active parameters (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.approx_params()
        d = self.d_model
        total = self.approx_params()
        kinds = self.layer_kinds()
        for i, _ in enumerate(kinds):
            if self.is_moe_layer(i):
                inactive = (self.n_experts - self.top_k) * 3 * d * self.d_ff_expert
                total -= inactive
        return float(total)


@dataclasses.dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, RunShape] = {
    "train_4k":    RunShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": RunShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  RunShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   RunShape("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """All shapes minus long_500k for pure full-attention archs (per spec)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_decode:
        out.append("long_500k")
    return out


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config: tiny widths, few layers, small tables."""
    unit = len(cfg.layer_pattern)
    n_layers = max(unit, 2)
    if cfg.family == "vlm":
        n_layers = unit
    d = 64
    heads = 4
    kv = min(cfg.n_kv_heads, 2) or 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 16) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        capacity_factor=8.0,   # no token dropping at smoke batch sizes
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        n_vision_tokens=8 if cfg.n_vision_tokens else 0,
        n_audio_frames=16 if cfg.n_audio_frames else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        dtype="float32",
        remat=False,
        loss_chunk=0,
    )
