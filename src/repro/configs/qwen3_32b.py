"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    # ZeRO-style: params/opt 2-D sharded (embed rows over data) — 32B dense
    # params + f32 moments do not fit at TP-16 alone
    rules_overrides=(("embed", "data"),),
)
