"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global interleave, 128k context.  [hf:google/gemma-3-1b-pt]

long_500k RUNS: 5/6 of layers are 1024-token sliding window (ring caches);
the global layers decode against the full 500k cache (seq-sharded)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    qk_norm=True,
    rope_theta=1_000_000.0,
    window=1024,
    layer_pattern=("l", "l", "l", "l", "l", "g"),
    supports_long_decode=True,
)
