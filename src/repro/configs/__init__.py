from repro.configs.base import ArchConfig, RunShape, SHAPES, applicable_shapes, smoke  # noqa: F401
from repro.configs.registry import ARCH_IDS, batch_specs, get, input_specs, rules_for  # noqa: F401
