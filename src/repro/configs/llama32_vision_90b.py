"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision family]

The vision frontend is a stub: ``input_specs`` provides precomputed patch
embeddings (b, 1601, d_model); cross-attn K/V are cached at prefill."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=500_000.0,
    layer_pattern=("s", "s", "s", "s", "x"),
    n_vision_tokens=1601,
    rules_overrides=(("embed", "data"),),
)
