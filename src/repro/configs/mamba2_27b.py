"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free SSD (state-space
duality), ssm_state=128, vocab=50280.  [arXiv:2405.21060]

long_500k RUNS: decode is O(1) in context (fixed-size SSM state)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,            # attention-free; placeholders
    n_kv_heads=1,
    d_ff=0,               # the SSD mixer is the whole block
    vocab=50280,
    layer_pattern=("m",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_chunk=256,
    supports_long_decode=True,
)
