"""whisper-large-v3 [audio]: enc-dec, 32+32L d_model=1280 20H d_ff=5120
vocab=51866.  [arXiv:2212.04356]

The conv/mel frontend is a stub: ``input_specs`` provides precomputed frame
embeddings (b, 1500, d_model).  Decode shapes exercise the decoder backbone
at the assigned KV lengths (performance cells — the real model caps at 448
positions; noted in DESIGN.md)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,           # decoder
    enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    rope_theta=10_000.0,
    n_audio_frames=1500,
)
