"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16, MHA) d_ff=1408
vocab=102400, 2 shared + 64 routed experts top-6 (fine-grained).
[arXiv:2401.06066]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    rope_theta=10_000.0,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    rules_overrides=(("embed", "data"),),
)
