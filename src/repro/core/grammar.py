"""Frozen grammar + terminal-table model (paper §2.5-2.6 data structures).

A :class:`Grammar` is the per-process result of intra-process compression:
an id-keyed rule set (rule 0 = main rule) over a :class:`TerminalTable` that
maps canonical event keys to small integer ids (the hash table of §2.5).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable

import numpy as np

from repro.core.events import CommEvent, ComputeEvent, Event, is_comm
from repro.core.sequitur import Sequitur

# A rule body entry: ("t", terminal_id, exp) or ("r", rule_id, exp)
Sym = tuple[str, int, int]

#: depth bins of :func:`rule_histogram` (the last bin absorbs deeper rules)
GRAMMAR_HIST_BINS = 8


def rule_histogram(rules: dict[int, list[Sym]], main_id: int = 0,
                   n_bins: int = GRAMMAR_HIST_BINS) -> np.ndarray:
    """Depth-binned rule occurrence/instantiation counts of a frozen rule
    set — the grammar's *shape* as a small integer vector of length
    ``2 * n_bins``.

    The first half sums, over every non-main rule of depth ``d`` (depth
    1 = all-terminal bodies; depths ``>= n_bins`` fold into the last
    bin), how many times the rule is instantiated in one full expansion
    of ``main_id`` (exponents multiply through the rule DAG); the second
    half counts the *distinct* reachable rules per depth.  Two streams
    with identical symbol mass but different schedules compress to
    different rule sets, so their histograms separate — the serve tier's
    sequence-aware embedding term.  Both halves ride along deliberately:
    after the serve tier's scale-invariant log-normalization a single
    vector would collapse scalar multiples (e.g. one depth-1 rule
    instantiated 6× vs two instantiated 6× each), while the pair keeps
    distinct log-magnitude ratios.  Pure dict/int work over the frozen
    ``{rid: [(kind, ref, exp), ...]}`` form (the
    :class:`~repro.core.corpus_store.GrammarCache` payload): no Sequitur,
    no terminal table.  int64 (exact counts), not normalized.
    """
    depths: dict[int, int] = {}

    def depth(r: int) -> int:
        if r in depths:
            return depths[r]
        depths[r] = 0  # cycle guard (well-formed grammars are acyclic)
        d = 1 + max((depth(ref) for k, ref, _ in rules[r] if k == "r"),
                    default=0)
        depths[r] = d
        return d

    for r in rules:
        depth(r)

    # transitive instantiation counts: parents (strictly deeper than any
    # rule they reference) propagate before children are read
    counts: dict[int, int] = {main_id: 1}
    for r in sorted(rules, key=lambda r: (-depths[r], r)):
        c = counts.get(r, 0)
        if not c:
            continue            # unreachable from main
        for kind, ref, exp in rules[r]:
            if kind == "r":
                counts[ref] = counts.get(ref, 0) + c * exp
    hist = np.zeros(2 * n_bins, dtype=np.int64)
    for r, d in depths.items():
        if r != main_id and counts.get(r, 0):
            hist[min(d, n_bins) - 1] += counts[r]
            hist[n_bins + min(d, n_bins) - 1] += 1
    return hist


class TerminalTable:
    """Event <-> id interning table (paper: 'events are stored in a hash
    table ... then the trace is represented by a sequence of ids')."""

    def __init__(self):
        self.by_key: dict[str, int] = {}
        self.events: list[Event] = []

    def intern(self, ev: Event) -> int:
        k = ev.key()
        tid = self.by_key.get(k)
        if tid is None:
            tid = len(self.events)
            self.by_key[k] = tid
            self.events.append(ev)
        return tid

    def __len__(self):
        return len(self.events)

    def __getitem__(self, tid: int) -> Event:
        return self.events[tid]


@dataclasses.dataclass
class Grammar:
    rules: dict[int, list[Sym]]     # rule 0 is the main rule
    table: TerminalTable
    main_id: int = 0

    # -- lossless expansion ---------------------------------------------------

    def expand_ids(self, rid: int | None = None) -> list[int]:
        rid = self.main_id if rid is None else rid
        out: list[int] = []
        self._expand(rid, 1, out)
        return out

    def _expand(self, rid: int, times: int, out: list[int]) -> None:
        body = self.rules[rid]
        for _ in range(times):
            for kind, ref, exp in body:
                if kind == "t":
                    out.extend([ref] * exp)
                else:
                    self._expand(ref, exp, out)

    def expand_events(self) -> list[Event]:
        return [self.table[i] for i in self.expand_ids()]

    def expanded_length(self, rid: int | None = None) -> int:
        """Number of events the grammar expands to, without expanding."""
        rid = self.main_id if rid is None else rid
        memo: dict[int, int] = {}

        def length(r: int) -> int:
            if r in memo:
                return memo[r]
            total = 0
            for kind, ref, exp in self.rules[r]:
                total += exp * (1 if kind == "t" else length(ref))
            memo[r] = total
            return total

        return length(rid)

    # -- size accounting (paper Table 3 'compressed size') --------------------

    def n_symbols(self) -> int:
        return sum(len(b) for b in self.rules.values())

    def encoded_size_bytes(self) -> int:
        """Serialized size: symbols (kind+ref+exp ~ 9B) + terminal table."""
        sym_bytes = 9 * self.n_symbols() + 4 * len(self.rules)
        table_bytes = sum(len(ev.key()) + 2 for ev in self.table.events)
        return sym_bytes + table_bytes

    def rule_depth(self, rid: int) -> int:
        """Tree height with terminals as leaves (paper §2.6.2)."""
        return self.rule_depths()[rid]

    def rule_depths(self) -> dict[int, int]:
        """Depths of every rule in one shared-memo pass — callers that need
        all depths (non-terminal merge, codegen lowering) pay O(symbols)
        total instead of O(rules * symbols)."""
        memo: dict[int, int] = {}

        def depth(r: int) -> int:
            if r in memo:
                return memo[r]
            memo[r] = 0  # cycle guard (well-formed grammars are acyclic)
            d = 1 + max((depth(ref) for k, ref, _ in self.rules[r] if k == "r"),
                        default=0)
            memo[r] = d
            return d

        for r in self.rules:
            depth(r)
        return memo

    def to_json(self) -> str:
        return json.dumps({
            "rules": {str(k): v for k, v in self.rules.items()},
            "terminals": [ev.key() for ev in self.table.events],
        })


def raw_trace_bytes(events: Iterable[Event]) -> int:
    """Uncompressed trace size estimate (paper Table 3 'trace size'):
    one record per event (key string, like a text trace line)."""
    return sum(len(ev.key()) + 1 for ev in events)


def from_sequitur(s: Sequitur, table: TerminalTable) -> Grammar:
    """Freeze a Sequitur run (flat kernel or reference — both expose
    ``grammar_rules`` over their pool) into a :class:`Grammar`."""
    return Grammar(rules=s.grammar_rules(), table=table)


def compress_events(events: Iterable[Event]) -> Grammar:
    """Intern + Sequitur-compress a flat event sequence.

    Interning runs first so the id stream feeds the kernel's batch entry
    point (``push_ids`` RLE-collapses internally) instead of a scalar
    push per event.
    """
    table = TerminalTable()
    ids = [table.intern(ev) for ev in events]
    s = Sequitur()
    s.push_ids(ids)
    return from_sequitur(s, table)
