"""Frozen grammar + terminal-table model (paper §2.5-2.6 data structures).

A :class:`Grammar` is the per-process result of intra-process compression:
an id-keyed rule set (rule 0 = main rule) over a :class:`TerminalTable` that
maps canonical event keys to small integer ids (the hash table of §2.5).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable

from repro.core.events import CommEvent, ComputeEvent, Event, is_comm
from repro.core.sequitur import Sequitur

# A rule body entry: ("t", terminal_id, exp) or ("r", rule_id, exp)
Sym = tuple[str, int, int]


class TerminalTable:
    """Event <-> id interning table (paper: 'events are stored in a hash
    table ... then the trace is represented by a sequence of ids')."""

    def __init__(self):
        self.by_key: dict[str, int] = {}
        self.events: list[Event] = []

    def intern(self, ev: Event) -> int:
        k = ev.key()
        tid = self.by_key.get(k)
        if tid is None:
            tid = len(self.events)
            self.by_key[k] = tid
            self.events.append(ev)
        return tid

    def __len__(self):
        return len(self.events)

    def __getitem__(self, tid: int) -> Event:
        return self.events[tid]


@dataclasses.dataclass
class Grammar:
    rules: dict[int, list[Sym]]     # rule 0 is the main rule
    table: TerminalTable
    main_id: int = 0

    # -- lossless expansion ---------------------------------------------------

    def expand_ids(self, rid: int | None = None) -> list[int]:
        rid = self.main_id if rid is None else rid
        out: list[int] = []
        self._expand(rid, 1, out)
        return out

    def _expand(self, rid: int, times: int, out: list[int]) -> None:
        body = self.rules[rid]
        for _ in range(times):
            for kind, ref, exp in body:
                if kind == "t":
                    out.extend([ref] * exp)
                else:
                    self._expand(ref, exp, out)

    def expand_events(self) -> list[Event]:
        return [self.table[i] for i in self.expand_ids()]

    def expanded_length(self, rid: int | None = None) -> int:
        """Number of events the grammar expands to, without expanding."""
        rid = self.main_id if rid is None else rid
        memo: dict[int, int] = {}

        def length(r: int) -> int:
            if r in memo:
                return memo[r]
            total = 0
            for kind, ref, exp in self.rules[r]:
                total += exp * (1 if kind == "t" else length(ref))
            memo[r] = total
            return total

        return length(rid)

    # -- size accounting (paper Table 3 'compressed size') --------------------

    def n_symbols(self) -> int:
        return sum(len(b) for b in self.rules.values())

    def encoded_size_bytes(self) -> int:
        """Serialized size: symbols (kind+ref+exp ~ 9B) + terminal table."""
        sym_bytes = 9 * self.n_symbols() + 4 * len(self.rules)
        table_bytes = sum(len(ev.key()) + 2 for ev in self.table.events)
        return sym_bytes + table_bytes

    def rule_depth(self, rid: int) -> int:
        """Tree height with terminals as leaves (paper §2.6.2)."""
        return self.rule_depths()[rid]

    def rule_depths(self) -> dict[int, int]:
        """Depths of every rule in one shared-memo pass — callers that need
        all depths (non-terminal merge, codegen lowering) pay O(symbols)
        total instead of O(rules * symbols)."""
        memo: dict[int, int] = {}

        def depth(r: int) -> int:
            if r in memo:
                return memo[r]
            memo[r] = 0  # cycle guard (well-formed grammars are acyclic)
            d = 1 + max((depth(ref) for k, ref, _ in self.rules[r] if k == "r"),
                        default=0)
            memo[r] = d
            return d

        for r in self.rules:
            depth(r)
        return memo

    def to_json(self) -> str:
        return json.dumps({
            "rules": {str(k): v for k, v in self.rules.items()},
            "terminals": [ev.key() for ev in self.table.events],
        })


def raw_trace_bytes(events: Iterable[Event]) -> int:
    """Uncompressed trace size estimate (paper Table 3 'trace size'):
    one record per event (key string, like a text trace line)."""
    return sum(len(ev.key()) + 1 for ev in events)


def from_sequitur(s: Sequitur, table: TerminalTable) -> Grammar:
    """Freeze a Sequitur run (flat kernel or reference — both expose
    ``grammar_rules`` over their pool) into a :class:`Grammar`."""
    return Grammar(rules=s.grammar_rules(), table=table)


def compress_events(events: Iterable[Event]) -> Grammar:
    """Intern + Sequitur-compress a flat event sequence.

    Interning runs first so the id stream feeds the kernel's batch entry
    point (``push_ids`` RLE-collapses internally) instead of a scalar
    push per event.
    """
    table = TerminalTable()
    ids = [table.intern(ev) for ev in events]
    s = Sequitur()
    s.push_ids(ids)
    return from_sequitur(s, table)
