"""Pre-columnar per-event synthesis front end — kept as the parity oracle.

This module preserves, verbatim, the event-loop implementations that
:mod:`repro.core.trace_ir` replaced: per-event log-space clustering and the
per-rank intern+Sequitur grammar build.  It exists for two reasons:

* **bit-exactness tests** — the columnar pipeline must produce the same
  grammar rules, terminal keys, compression ratio, and δ̄ as this code on
  every workload (tests/test_trace_ir.py pins that);
* **benchmarking** — ``benchmarks/synthesize_time.py`` times the columnar
  front end against this baseline.

Do not use it in production paths; it is O(python) per event.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from repro.core.events import ComputeEvent, Event, is_comm
from repro.core.grammar import Grammar, TerminalTable, from_sequitur
from repro.core.interproc import MergedProgram, merge_grammars
# the reference front end runs on the reference Sequitur: both oracles
# stay per-event/object-graph implementations, independent of the flat
# kernel they pin
from repro.core.sequitur_reference import Sequitur


def _quantize(vec: np.ndarray, rel_tol: float) -> tuple[int, ...]:
    """Per-event log-space bucketing (the scalar original)."""
    width = math.log1p(rel_tol)
    out = []
    for v in vec:
        if v <= 0:
            out.append(-1)
        else:
            out.append(int(math.floor(math.log(v + 1.0) / width)))
    return tuple(out)


def cluster_compute_events_reference(
    events: Iterable[ComputeEvent], rel_tol: float = 0.05
) -> tuple[list[ComputeEvent], dict[int, np.ndarray]]:
    """The per-event clustering loop (pre-columnar original)."""
    buckets: dict[tuple[int, ...], int] = {}
    sums: dict[int, np.ndarray] = {}
    counts: dict[int, int] = {}
    assigned: list[tuple[ComputeEvent, int]] = []
    for ev in events:
        q = _quantize(ev.vector, rel_tol)
        if q not in buckets:
            buckets[q] = len(buckets)
        bid = buckets[q]
        sums[bid] = sums.get(bid, 0) + ev.vector
        counts[bid] = counts.get(bid, 0) + 1
        assigned.append((ev, bid))

    bids = sorted(sums)
    bucket_rep = {b: sums[b] / counts[b] for b in bids}
    remap: dict[int, int] = {}
    cluster_reps: list[np.ndarray] = []
    cluster_w: list[int] = []
    for b in bids:
        v = bucket_rep[b]
        placed = False
        for cid, rep in enumerate(cluster_reps):
            denom = np.maximum(np.maximum(np.abs(rep), np.abs(v)), 1e-30)
            if np.all(np.abs(rep - v) / denom <= rel_tol):
                w = cluster_w[cid]
                cluster_reps[cid] = (rep * w + v * counts[b]) / (w + counts[b])
                cluster_w[cid] = w + counts[b]
                remap[b] = cid
                placed = True
                break
        if not placed:
            remap[b] = len(cluster_reps)
            cluster_reps.append(v.copy())
            cluster_w.append(counts[b])

    out = [dataclasses.replace(ev, cluster_id=remap[bid])
           for ev, bid in assigned]
    reps = {cid: rep for cid, rep in enumerate(cluster_reps)}
    return out, reps


def compress_rank_traces_reference(
    rank_traces: Sequence[Sequence[Event]],
    rel_tol: float = 0.05,
    threshold: float = 0.5,
) -> tuple[list[Grammar], MergedProgram, list[list[int]], dict[int, np.ndarray]]:
    """The per-event intern+push grammar build (pre-columnar original):
    one TerminalTable/Sequitur per rank, one ``intern``+``push`` per event.
    """
    flat: list[ComputeEvent] = []
    index: list[list[int]] = []
    for tr in rank_traces:
        idx = []
        for ev in tr:
            if not is_comm(ev):
                idx.append(len(flat))
                flat.append(ev)
            else:
                idx.append(-1)
        index.append(idx)
    clustered, reps = cluster_compute_events_reference(flat, rel_tol)

    grammars: list[Grammar] = []
    rank_ids: list[list[int]] = []
    for tr, idx in zip(rank_traces, index):
        table = TerminalTable()
        seq = Sequitur()
        ids = []
        for ev, fi in zip(tr, idx):
            ev2 = clustered[fi] if fi >= 0 else ev
            tid = table.intern(ev2)
            ids.append(tid)
            seq.push(tid)
        grammars.append(from_sequitur(seq, table))
        rank_ids.append(ids)
    merged = merge_grammars(grammars, threshold)
    return grammars, merged, rank_ids, reps
