"""Space-optimized Sequitur (paper §2.5.2) — flat-array kernel.

Classic Sequitur [Nevill-Manning & Witten 1997] maintains two constraints over
an online-constructed context-free grammar:

  (1) digram uniqueness -- any adjacent symbol pair occurs at most once;
  (2) rule utility      -- every rule (except the main rule) is used >= twice.

The paper adds the Omnisc'IO-style run-length constraint:

  (3) adjacent equal symbols a^i a^j are merged into a^{i+j},

which turns the O(log n) encoding of a loop that repeats n times into O(1).

**Flat layout.**  The original implementation (preserved verbatim as the
parity oracle in :mod:`repro.core.sequitur_reference`) kept one Python
``Node`` object per symbol occurrence in doubly-linked ``Rule`` bodies and
hashed 4-deep nested tuples per digram.  This kernel stores the symbol pool
as five index-linked columns, so a "node" is an integer index and every
structural step is a column read/write:

* ``_sym[i] >= 0`` — terminal id; ``_sym[i] < 0`` — rule reference encoding
  rule id ``-sym - 1``; ``_sym[i] is None`` — a rule's guard (the guard's
  ``_exp`` slot holds the owning rule id, the analog of ``Node.owner``);
* ``_prev``/``_next`` hold pool indices; ``None`` marks an unlinked
  (poisoned) node exactly where the reference poisons ``Node.prev``;
* ``_reg[i]`` caches the digram-table key node ``i`` is currently
  registered under (None when unregistered) — see the invariant below;
* the digram table maps flat ``(sym1, exp1, sym2, exp2)`` int keys to pool
  indices — the encoded ``sym`` already distinguishes terminal from rule,
  so the reference's nested ``("t"/"r", ref)`` ident tuples disappear.

The columns are deliberately Python lists, not numpy arrays: the kernel is
a scalar pointer-chasing loop, and per-element ``ndarray`` access measures
~3x slower than list indexing on the floor CPython (numpy views of the
pool are available via :meth:`Sequitur.columns` for vectorized export).

**The registration invariant.**  In the reference, ``_remove_digram(n)``
rebuilds n's digram key and drops the table entry only if it maps to n.
Three facts make that probe equivalent to an O(1) column access:

* a table entry always reflects a *current* adjacency — every link change
  goes through a join/delete that first probes the left node's digram, so
  a registered key never goes stale (equivalently: a node is registered
  under at most one key, and it is its current digram's key);
* entries are never overwritten while their owner is live — every
  registration site first misses on a lookup of the same key;
* equal-symbol digrams are never registered (the run-length merge branch
  fires before the registration branch), so a node whose exponent just
  changed is provably unregistered.

Hence ``_remove_digram(n)`` == ``if _reg[n] is not None: del digrams[
_reg[n]]; _reg[n] = None``, and the reference's probes of freshly-created
adjacencies (e.g. ``(p, n2)`` right after both deletions in
``_substitute``) are provably no-ops and elided.  Every elision below is
annotated with the reference call it collapses.  The parity fuzz suite
(tests/test_sequitur_kernel.py) is the enforcement mechanism for this
reasoning: any violation diverges the emitted grammar from the reference.

The kernel enforces the same three constraints in the same online order as
the reference, so the emitted grammar is **bit-identical**
(``Grammar.to_json`` equality — pinned by tests/test_sequitur_kernel.py
and the CI grammar-parity step).

**Recycling.**  Freed indices go to a limbo list and only become
allocatable at the next push boundary: within one push's constraint
cascade a freed index stays poisoned — never recycled — so an index
captured before churn behaves exactly like the reference's poisoned
``Node`` object instead of aliasing a new allocation.

**Batch entry points.**  ``push_runs(ids, counts)`` ingests an
RLE-collapsed stream and is bit-identical to the scalar push loop over the
expanded stream: run increments replay the reference's merge branch with
one dict probe instead of a full push (alloc + link + cascade), and a run
pushed right after a guard collapses to a single exponent addition.
``push_ids`` RLE-collapses (:func:`rle_runs`) and delegates.  ``push_run``
keeps the reference's O(1) bulk-repetition semantics -- used by the tracer
for collective-free ``lax.scan`` bodies with huge trip counts (note it is
*not* equivalent to ``count`` scalar pushes: a mid-run digram match that
scalar pushes would take is deliberately skipped, exactly as the reference
skips it).

Terminal ids must be >= 0 (negative ids are the rule-reference encoding).
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np


def rle_runs(ids) -> tuple[list[int], list[int]]:
    """Collapse equal-adjacent ids into an RLE ``(ids, counts)`` pair.

    Vectorized pre-pass shared by :meth:`Sequitur.push_ids` and the
    columnar front end (``trace_ir.compress_store``): one
    ``np.flatnonzero(np.diff(...))`` instead of a per-token Python loop.
    """
    arr = np.asarray(ids, dtype=np.int64)
    if arr.size == 0:
        return [], []
    starts = np.concatenate(
        [np.zeros(1, dtype=np.int64), np.flatnonzero(np.diff(arr)) + 1])
    counts = np.diff(np.concatenate([starts, [arr.size]]))
    return arr[starts].tolist(), counts.tolist()


class Sequitur:
    """Online grammar builder enforcing constraints (1)-(3) on the flat pool."""

    KERNEL = "flat"

    __slots__ = ("_sym", "_exp", "_prev", "_next", "_reg", "_free", "_limbo",
                 "_rules", "_users", "digrams", "_next_rid")

    def __init__(self):
        # pool slot 0 is the main rule's guard (links to itself: empty body)
        self._sym: list = [None]
        self._exp: list = [0]          # guard exp slot = owning rule id
        self._prev: list = [0]
        self._next: list = [0]
        self._reg: list = [None]       # current digram-table key per node
        self._free: list[int] = []
        # freed during the current push's cascade; drained into _free at
        # the next push boundary (deferred recycling — see module docs)
        self._limbo: list[int] = []
        self._rules: dict[int, int] = {0: 0}       # rid -> guard index
        self._users: dict[int, set[int]] = {0: set()}
        self.digrams: dict[tuple, int] = {}
        self._next_rid = 1

    # -- public API ---------------------------------------------------------

    def push(self, sym: int) -> None:
        self.push_run(sym, 1)

    def push_run(self, sym: int, count: int) -> None:
        """Append an already run-length-compressed repetition in O(1)."""
        if count <= 0:
            return
        if sym < 0:
            raise ValueError(f"terminal ids must be >= 0, got {sym}")
        limbo = self._limbo
        if limbo:
            self._free.extend(limbo)
            del limbo[:]
        i = self._alloc(sym, count)
        prv, nxt = self._prev, self._next
        last = prv[0]
        nxt[last] = i
        prv[i] = last
        nxt[i] = 0
        prv[0] = i
        self._check(last)

    def push_many(self, syms: Iterable[int]) -> None:
        for s in syms:
            self.push_run(s, 1)

    def push_ids(self, ids) -> None:
        """Ingest a pre-interned terminal-id sequence (numpy array or list).

        RLE-collapses equal-adjacent ids (:func:`rle_runs`) and feeds
        :meth:`push_runs`; the grammar produced is bit-identical to
        ``push_many`` over the same sequence.
        """
        run_ids, counts = rle_runs(ids)
        self.push_runs(run_ids, counts)

    def push_runs(self, ids: Sequence[int], counts: Sequence[int]) -> None:
        """Push an RLE ``(ids, counts)`` stream, bit-identical to the
        scalar push loop over the expanded stream.

        This is the shared fast entry point for the columnar front end.
        The scalar push and its digram check are inlined (the new node's
        digram is always ``(tail, new)`` with exponent 1, so the general
        :meth:`_check` guard tests collapse away), and within a run each
        repetition replays exactly the reference's merge branch
        (constraint 3) — drop the tail's left-digram registration, bump
        the tail exponent, re-probe the left digram — without allocating
        and immediately freeing a pool node.  A mid-run digram match falls
        back to the general machinery, so matches fire in the same online
        order as scalar pushes.
        """
        sym, exp = self._sym, self._exp
        prv, nxt = self._prev, self._next
        reg, free = self._reg, self._free
        dig, limbo = self.digrams, self._limbo
        for a, k in zip(ids, counts):
            if a < 0:
                raise ValueError(f"terminal ids must be >= 0, got {a}")
            while k > 0:
                # scalar push of one `a` (the reference push_run(a, 1))
                if limbo:
                    free.extend(limbo)
                    del limbo[:]
                if free:
                    i = free.pop()
                    sym[i] = a
                    exp[i] = 1
                else:
                    i = len(sym)
                    sym.append(a)
                    exp.append(1)
                    prv.append(None)
                    nxt.append(None)
                    reg.append(None)
                last = prv[0]
                nxt[last] = i
                prv[i] = last
                nxt[i] = 0
                prv[0] = i
                k -= 1
                # inline _check(last): next[last] is the fresh node (a, 1)
                s1 = sym[last]
                if s1 is not None:          # last == guard -> nothing to do
                    if s1 == a:
                        self._check(last)   # rare: tail merged after churn
                    else:
                        key = (s1, exp[last], a, 1)
                        m = dig.get(key)
                        if m is None:
                            dig[key] = last
                            reg[last] = key
                        elif m != last and nxt[m] != last and m != i:
                            self._process_match(last, m)
                if k == 0:
                    break
                t = prv[0]
                if sym[t] != a:
                    continue            # tail restructured; push scalar again
                # fast increments: each iteration is the reference's merge
                # branch for (tail a^e, new a^1) + _check(tail.prev).  The
                # reference's probes of the (a^e', a^1) keys are elided —
                # equal-symbol digrams are never registered.
                p = prv[t]
                sp = sym[p]
                if sp is None:
                    # guard before tail: no left digram to maintain — the
                    # whole remaining run is one exponent addition
                    exp[t] += k
                    k = 0
                    continue
                ep = exp[p]
                while k > 0:
                    rk = reg[p]                 # _remove_digram(tail.prev)
                    if rk is not None:
                        del dig[rk]
                        reg[p] = None
                    e = exp[t] + 1
                    exp[t] = e
                    k -= 1
                    # _check(tail.prev) on the digram (p, tail)
                    key = (sp, ep, a, e)
                    m = dig.get(key)
                    if m is None:
                        dig[key] = p
                        reg[p] = key
                    elif m == p or nxt[m] == p or m == t:
                        pass                    # identical / overlapping
                    else:
                        self._process_match(p, m)
                        break           # structure changed; back to scalar

    def expand(self) -> list[int]:
        """Expand the grammar back into the original sequence (lossless)."""
        out: list[int] = []
        self._expand_rule(0, 1, out)
        return out

    def grammar_rules(self) -> dict[int, list[tuple]]:
        """Freeze to ``{rid: [(kind, ref, exp), ...]}`` with kind in {t, r}."""
        sym, exp, nxt = self._sym, self._exp, self._next
        out: dict[int, list[tuple]] = {}
        for rid, g in self._rules.items():
            body = []
            n = nxt[g]
            while n != g:
                s = sym[n]
                if s < 0:
                    body.append(("r", -s - 1, exp[n]))
                else:
                    body.append(("t", s, exp[n]))
                n = nxt[n]
            out[rid] = body
        return out

    def size(self) -> int:
        """Total number of symbol occurrences across all rules."""
        nxt = self._next
        total = 0
        for g in self._rules.values():
            n = nxt[g]
            while n != g:
                total += 1
                n = nxt[n]
        return total

    def columns(self) -> dict[str, np.ndarray]:
        """Numpy snapshot of the pool columns (``None`` -> -2**31 in
        ``sym``, -1 in the link columns) for vectorized inspection."""
        def col(xs, null):
            return np.asarray([null if x is None else x for x in xs],
                              dtype=np.int64)
        return {"sym": col(self._sym, -2**31),
                "exp": col(self._exp, 0),
                "prev": col(self._prev, -1),
                "next": col(self._next, -1)}

    # -- internals ----------------------------------------------------------
    #
    # Each mutation method performs the same digram-table operations, in
    # the same order, as the corresponding reference method — with removal
    # probes replaced by _reg accesses per the registration invariant, and
    # probes of freshly-created adjacencies elided (annotated inline).

    def _alloc(self, s, e) -> int:
        free = self._free
        if free:
            i = free.pop()
            self._sym[i] = s
            self._exp[i] = e
            # links stay poisoned (None) until joined, like a fresh Node
        else:
            i = len(self._sym)
            self._sym.append(s)
            self._exp.append(e)
            self._prev.append(None)
            self._next.append(None)
            self._reg.append(None)
        return i

    def _expand_rule(self, rid: int, times: int, out: list) -> None:
        sym, exp, nxt = self._sym, self._exp, self._next
        g = self._rules[rid]
        for _ in range(times):
            n = nxt[g]
            while n != g:
                s = sym[n]
                if s < 0:
                    self._expand_rule(-s - 1, exp[n], out)
                else:
                    out.extend([s] * exp[n])
                n = nxt[n]

    def _check(self, i) -> bool:
        """Enforce constraints on the digram (i, next[i]).

        Returns True if the grammar was modified.
        """
        if i is None:
            return False
        sym = self._sym
        s1 = sym[i]
        if s1 is None:                  # guard
            return False
        prv, nxt = self._prev, self._next
        j = nxt[i]
        if j is None:
            return False
        s2 = sym[j]
        if s2 is None:                  # next is guard
            return False

        exp, reg = self._exp, self._reg
        dig = self.digrams
        if s1 == s2:
            # constraint (3): run-length merge of adjacent equal symbols.
            # Reference sequence: _remove_digram(i.prev); _remove_digram(j);
            # i.exp += j.exp; _delete_node(j) — whose probes of (i, j)
            # under the merged exponent are elided (equal-symbol digrams
            # are never registered, so i is provably unregistered);
            # re-check both sides.
            p = prv[i]
            rk = reg[p]
            if rk is not None:
                del dig[rk]
                reg[p] = None
            rk = reg[j]
            if rk is not None:
                del dig[rk]
                reg[j] = None
            exp[i] += exp[j]
            n2 = nxt[j]
            nxt[i] = n2
            prv[n2] = i
            if s2 < 0:
                self._users[-s2 - 1].discard(j)
            prv[j] = nxt[j] = None      # poison
            self._limbo.append(j)
            # digrams around the merged node changed; re-check both sides
            self._check(p)
            self._check(i)
            return True

        key = (s1, exp[i], s2, exp[j])
        m = dig.get(key)
        if m is None:
            dig[key] = i
            reg[i] = key
            return False
        if m == i or nxt[m] == i or j == m:
            return False                # identical or overlapping occurrence
        self._process_match(i, m)
        return True

    def _process_match(self, node: int, match: int) -> None:
        sym, exp, prv, nxt = self._sym, self._exp, self._prev, self._next
        # _is_full_rule_body(match), inlined: prev is a guard and
        # next.next is a guard; the guard's exp slot is the owning rule id
        # (0 = main, which never substitutes).
        p = prv[match]
        if sym[p] is None and sym[nxt[nxt[match]]] is None and exp[p] != 0:
            self._substitute(node, exp[p])
            return
        p = prv[node]
        if sym[p] is None and sym[nxt[nxt[node]]] is None and exp[p] != 0:
            # the *new* digram is itself a full rule body; reuse it for the
            # match occurrence instead.
            self._substitute(match, exp[p])
            return
        new_rid = self._next_rid
        self._next_rid = new_rid + 1
        j = nxt[node]
        sn, en = sym[node], exp[node]
        sj, ej = sym[j], exp[j]
        reg, free = self._reg, self._free
        # three inline allocations: the new rule's guard + copies of the
        # matched digram's two symbols
        if free:
            g = free.pop()
            sym[g] = None
            exp[g] = new_rid
        else:
            g = len(sym)
            sym.append(None)
            exp.append(new_rid)
            prv.append(None)
            nxt.append(None)
            reg.append(None)
        self._rules[new_rid] = g
        self._users[new_rid] = set()
        if free:
            a = free.pop()
            sym[a] = sn
            exp[a] = en
        else:
            a = len(sym)
            sym.append(sn)
            exp.append(en)
            prv.append(None)
            nxt.append(None)
            reg.append(None)
        if free:
            b = free.pop()
            sym[b] = sj
            exp[b] = ej
        else:
            b = len(sym)
            sym.append(sj)
            exp.append(ej)
            prv.append(None)
            nxt.append(None)
            reg.append(None)
        # _insert_after(guard, a) + _insert_after(a, b), inlined: joins
        # against a guard or a fresh node never probe the digram table
        # (fresh nodes have poisoned links; guard digrams are skipped).
        if sn < 0:
            self._users[-sn - 1].add(a)
        if sj < 0:
            self._users[-sj - 1].add(b)
        nxt[a] = b
        prv[b] = a
        nxt[b] = g
        prv[g] = b
        nxt[g] = a
        prv[a] = g
        self._substitute(match, new_rid)
        self._substitute(node, new_rid)
        # Register the rule-body digram.  NB: a rule-utility inline during
        # the substitutions above may have spliced new bodies into the new
        # rule (poisoning ``a``), so consult the live body rather than the
        # captured indices.
        first = nxt[g]
        if first != g:
            second = nxt[first]
            if second != g:
                key = (sym[first], exp[first], sym[second], exp[second])
                dig = self.digrams
                cur = dig.get(key)
                if cur is None or prv[cur] is None:
                    dig[key] = first
                    reg[first] = key

    def _substitute(self, node: int, rid: int) -> None:
        """Replace the digram starting at ``node`` with one rule-use node.

        Reference sequence: _delete_node(node.next); _delete_node(node);
        insert a fresh rule use after the old prev; rule-utility checks on
        the removed symbols; boundary re-checks.  Registration drops, in
        reference probe order:

        * node (its digram is (node, j)) — _delete_node(j)'s
          _remove_digram(j.prev); join(node, j.next)'s re-probe elided;
        * j (digram (j, n2)) — _delete_node(j)'s _remove_digram(j);
        * p (digram (p, node)) — _delete_node(node)'s
          _remove_digram(node.prev); join(p, n2)'s re-probe elided;
        * _delete_node(node)'s probe of (node, n2) and join(p, use)'s
          probe of (p, n2) are elided: both adjacencies were created
          within this call, so neither node is registered for them.
        """
        sym, exp, prv, nxt = self._sym, self._exp, self._prev, self._next
        reg, free = self._reg, self._free
        dig, limbo = self.digrams, self._limbo
        p = prv[node]
        j = nxt[node]
        n2 = nxt[j]
        s1 = sym[node]
        s2 = sym[j]
        # -- _delete_node(j)
        rk = reg[node]
        if rk is not None:
            del dig[rk]
            reg[node] = None
        rk = reg[j]
        if rk is not None:
            del dig[rk]
            reg[j] = None
        nxt[node] = n2
        prv[n2] = node
        if s2 < 0:
            self._users[-s2 - 1].discard(j)
        prv[j] = nxt[j] = None
        limbo.append(j)
        # -- _delete_node(node)
        rk = reg[p]
        if rk is not None:
            del dig[rk]
            reg[p] = None
        nxt[p] = n2
        prv[n2] = p
        if s1 < 0:
            self._users[-s1 - 1].discard(node)
        prv[node] = nxt[node] = None
        limbo.append(node)
        # -- use = Node(rule, 1); _insert_after(p, use)
        ref = -rid - 1
        if free:
            use = free.pop()
            sym[use] = ref
            exp[use] = 1
        else:
            use = len(sym)
            sym.append(ref)
            exp.append(1)
            prv.append(None)
            nxt.append(None)
            reg.append(None)
        self._users[rid].add(use)
        nxt[use] = n2
        prv[n2] = use
        nxt[p] = use
        prv[use] = p
        # rule-utility bookkeeping for symbols we just removed (the
        # rid-membership and single-user gates of _maybe_inline are
        # pre-checked here so the common no-op skips the call)
        if s1 < 0:
            r1 = -s1 - 1
            if r1 != rid and r1 in self._rules \
                    and len(self._users[r1]) == 1:
                self._maybe_inline(r1)
        if s2 < 0:
            r2 = -s2 - 1
            if r2 != rid and r2 in self._rules \
                    and len(self._users[r2]) == 1:
                self._maybe_inline(r2)
        # -- if not _check(p): _check(use), with _check's common
        # miss-register branch inlined.  The inline calls above may have
        # restructured around p (deleted it, spliced between p and use);
        # specialize only when p's digram is still exactly (p, use),
        # otherwise take the general path the reference takes.
        sp = sym[p]
        if nxt[p] != use or sp is None or sp == sym[use]:
            if not self._check(p):
                self._check(use)
            return
        su, eu = sym[use], exp[use]
        key = (sp, exp[p], su, eu)
        m = dig.get(key)
        if m is None:
            dig[key] = p
            reg[p] = key
        elif m == p or nxt[m] == p or m == use:
            pass
        else:
            self._process_match(p, m)
            return
        # _check(use) on the digram (use, next[use]), same specialization
        nu = nxt[use]
        s3 = sym[nu]
        if s3 is None:
            return
        if su == s3:
            self._check(use)
            return
        k5 = (su, eu, s3, exp[nu])
        m2 = dig.get(k5)
        if m2 is None:
            dig[k5] = use
            reg[use] = k5
        elif m2 != use and nxt[m2] != use and nxt[use] != m2:
            self._process_match(use, m2)

    def _maybe_inline(self, rid: int) -> None:
        """Constraint (2): a rule used once with exponent 1 is inlined."""
        if rid == 0 or rid not in self._rules:
            return
        users = self._users[rid]
        if len(users) != 1:
            return
        (use,) = users
        sym, exp, prv, nxt = self._sym, self._exp, self._prev, self._next
        if prv[use] is None:            # poisoned node awaiting recycling
            users.discard(use)
            return
        if exp[use] != 1:
            return                      # keeps a loop body alive (RLE)
        reg, dig, limbo = self._reg, self.digrams, self._limbo
        p = prv[use]
        n = nxt[use]
        g = self._rules[rid]
        first, last = nxt[g], prv[g]
        # -- _delete_node(use): drop p's (p, use) and use's (use, n)
        # registrations; join(p, n)'s re-probe of (p, use) elided
        rk = reg[p]
        if rk is not None:
            del dig[rk]
            reg[p] = None
        rk = reg[use]
        if rk is not None:
            del dig[rk]
            reg[use] = None
        nxt[p] = n
        prv[n] = p
        users.discard(use)
        prv[use] = nxt[use] = None
        limbo.append(use)
        if first == g:                  # empty rule body; just drop the use
            del self._rules[rid]
            prv[g] = nxt[g] = None
            limbo.append(g)
            return
        # -- splice the body in place (nodes keep their digram
        # registrations).  join(p, first)'s probe of (p, n) is elided —
        # that adjacency was created by the delete above, so p is
        # unregistered; join(last, n)'s probe of (last, guard) is a guard
        # digram, never registered.
        nxt[p] = first
        prv[first] = p
        nxt[last] = n
        prv[n] = last
        del self._rules[rid]
        prv[g] = nxt[g] = None
        limbo.append(g)
        # boundary digrams are new
        if not self._check(p):
            self._check(last)

    # -- debugging ----------------------------------------------------------

    def dump(self) -> str:
        sym, exp, nxt = self._sym, self._exp, self._next
        lines = []
        for rid in sorted(self._rules):
            g = self._rules[rid]
            parts = []
            n = nxt[g]
            while n != g:
                s = sym[n]
                rep = f"R{-s - 1}" if s < 0 else str(s)
                parts.append(f"{rep}^{exp[n]}" if exp[n] != 1 else rep)
                n = nxt[n]
            lines.append(f"R{rid} -> {' '.join(parts)}")
        return "\n".join(lines)


def compress(seq: Iterable[int]) -> Sequitur:
    s = Sequitur()
    s.push_many(seq)
    return s
