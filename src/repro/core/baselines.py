"""Baseline proxy synthesizers the paper compares against (§3.4-3.5).

* :func:`minime_fit` — MINIME-style iterative greedy block matching
  [Deniz et al. 2015].  MINIME targets ratio metrics (IPC, cache-miss rate,
  branch-misprediction rate); the TPU analogs here are arithmetic intensity,
  gather rate and serialization rate.  Greedy chunked addition, no global
  optimization — the paper's Figs. 5-6 show (and our benchmarks reproduce)
  that it fits a single aggregate event acceptably but drifts when every
  inter-collective segment must be matched separately.

* :class:`ScalaBenchProxy` — ScalaBench-style lossy compression [Wu et al.
  2012]: communication parameters are approximated by per-kind log2
  histograms (replay draws the bucket mean), computation is recorded as a
  *time interval* and replayed by sleeping — so its replay cannot track
  platform changes (paper §3.5.4, Figs. 9-11).
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Sequence

import numpy as np

from repro.core import blocks as B
from repro.core.events import CommEvent, Event, N_METRICS, is_comm
from repro.core.metrics import (
    I_BYTES, I_GATHER, I_MXU, I_SCAN, I_TRANS, I_VPU, comm_seconds,
    roofline_seconds,
)

# ---------------------------------------------------------------------------
# MINIME-style greedy
# ---------------------------------------------------------------------------


def minime_ratios(vec: np.ndarray) -> np.ndarray:
    """MINIME's 3 ratio metrics, TPU-adapted: (AI, gather rate, scan rate)."""
    ops = vec[I_MXU] + vec[I_VPU]
    return np.array([
        ops / max(vec[I_BYTES], 1.0),            # IPC  -> arithmetic intensity
        vec[I_GATHER] / max(ops, 1.0),           # CMR  -> gather rate
        vec[I_SCAN] / max(ops, 1.0),             # BMR  -> serialization rate
    ])


def _ratio_err(x: np.ndarray, b: np.ndarray, t: np.ndarray) -> float:
    """Symmetric log-ratio error on MINIME's 3 ratios + total-ops size term
    (log form keeps the greedy landscape smooth far from the optimum)."""
    vec = b @ x
    rt, rv = minime_ratios(t), minime_ratios(vec)
    eps = 1e-9
    ratio_err = float(np.mean(np.abs(np.log((rv + eps) / (rt + eps)))))
    ops_t = t[I_MXU] + t[I_VPU]
    ops_v = vec[I_MXU] + vec[I_VPU]
    size_err = abs(np.log((ops_v + 1.0) / (ops_t + 1.0)))
    return ratio_err + size_err


@dataclasses.dataclass
class GreedyFit:
    x: np.ndarray
    predicted: np.ndarray
    target: np.ndarray
    per_metric_rel_err: np.ndarray
    iters: int


def minime_fit(t: np.ndarray, b: np.ndarray | None = None,
               max_iter: int = 4000) -> GreedyFit:
    """Iterative greedy: repeatedly add the chunk of one block that most
    reduces the ratio+size error; halve the chunk when stuck; stop when the
    unit chunk no longer improves (MINIME's iterative code-block addition)."""
    t = np.asarray(t, dtype=np.float64)
    if b is None:
        b = B.calibration_matrix()
    n = b.shape[1]
    x = np.zeros(n)
    chunk = 1 << 16
    err = _ratio_err(x, b, t)
    ops_t = t[I_MXU] + t[I_VPU]
    it = 0
    while it < max_iter and chunk >= 1:
        best_j, best_err = -1, err
        for j in range(n):
            x[j] += chunk
            vec = b @ x
            # additions are irreversible: never overshoot the size budget
            if vec[I_MXU] + vec[I_VPU] > 1.2 * max(ops_t, 1.0):
                x[j] -= chunk
                continue
            e = _ratio_err(x, b, t)
            x[j] -= chunk
            if e < best_err - 1e-15:
                best_err, best_j = e, j
        if best_j < 0:
            chunk //= 2
            continue
        x[best_j] += chunk
        err = best_err
        it += 1
    x = np.rint(x).astype(np.int64)
    x[10] = max(x[10], int(np.sum(x[:9])))  # keep replayable
    pred = b @ x
    rel = np.abs(pred - t) / np.maximum(np.abs(t), 1e-30)
    rel = np.where(t > 0, rel, 0.0)
    return GreedyFit(x=x, predicted=pred, target=t,
                     per_metric_rel_err=rel, iters=it)


# ---------------------------------------------------------------------------
# ScalaBench-style histogram + sleep proxy
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScalaBenchProxy:
    """Lossy comm histogram + fixed sleep compute replay."""
    op_sequence: list[tuple[str, int]]       # (kind, histogram bucket) per event
    bucket_means: dict[tuple[str, int], float]  # mean payload bytes per bucket
    sleep_seconds: list[float]               # per compute event, recorded on A
    n_ranks: int

    def replayed_comm_bytes(self) -> float:
        return sum(self.bucket_means[(k, bk)] for k, bk in self.op_sequence)

    def predicted_time(self, flops_rate_scale: float = 1.0,
                       n_devices: int = 2) -> float:
        """Replay wall time on a platform whose compute speed differs by
        ``flops_rate_scale`` from the recording platform: the sleeps do NOT
        scale (that is the point), only communication does."""
        t = sum(self.sleep_seconds)
        t += sum(comm_seconds(self.bucket_means[(k, bk)], n_devices)
                 for k, bk in self.op_sequence)
        return t


def _bucket(nbytes: int) -> int:
    return int(math.log2(max(nbytes, 1)))


def scalabench_compress(rank_trace: Sequence[Event], n_ranks: int = 1,
                        ) -> ScalaBenchProxy:
    sums: dict[tuple[str, int], float] = defaultdict(float)
    counts: dict[tuple[str, int], int] = defaultdict(int)
    op_seq: list[tuple[str, int]] = []
    sleeps: list[float] = []
    for ev in rank_trace:
        if is_comm(ev):
            key = (ev.kind, _bucket(ev.payload_bytes))
            sums[key] += ev.payload_bytes
            counts[key] += 1
            op_seq.append(key)
        else:
            sleeps.append(roofline_seconds(ev.vector))
    means = {k: sums[k] / counts[k] for k in sums}
    return ScalaBenchProxy(op_sequence=op_seq, bucket_means=means,
                           sleep_seconds=sleeps, n_ranks=n_ranks)


def siesta_predicted_time(combos: Sequence[tuple],
                          comm_events: Sequence[CommEvent],
                          flops_rate_scale: float = 1.0,
                          n_devices: int = 2) -> float:
    """Siesta replay time on a scaled platform: the block mixes re-execute,
    so compute time scales with the platform (paper §3.5.4 portability).

    ``combos``: (x, unroll) pairs as produced by synthesize."""
    t = 0.0
    for x, unroll in combos:
        vec = B.combo_cost(x, unroll)
        t += roofline_seconds(vec) / flops_rate_scale
    t += sum(comm_seconds(ev.payload_bytes, n_devices) for ev in comm_events)
    return t


def original_time(rank_trace: Sequence[Event], flops_rate_scale: float = 1.0,
                  n_devices: int = 2) -> float:
    t = 0.0
    for ev in rank_trace:
        if is_comm(ev):
            t += comm_seconds(ev.payload_bytes, n_devices)
        else:
            t += roofline_seconds(ev.vector) / flops_rate_scale
    return t
