"""Event tracing front-ends (paper §2.2–2.3, DESIGN.md §2).

The paper intercepts MPI calls with PMPI and reads PAPI counters around them.
Our programs are staged JAX, so tracing needs no runtime interposition at all:

* :func:`trace_fn` walks the jaxpr of a step function.  Collective primitives
  (``psum``/``all_gather``/``reduce_scatter``/``all_to_all``/``ppermute`` …,
  visible inside ``shard_map`` bodies) become :class:`CommEvent`s; every
  equation between two collectives accumulates into the pending 6-metric
  vector of a :class:`ComputeEvent` (the virtual ``MPI_Compute`` call).

* :class:`TraceSession` is the host-level recorder for multi-step drivers
  (pipeline schedules, serving engines) whose per-rank behaviour differs in
  Python, not in the jaxpr.  The collective wrappers in
  :mod:`repro.sharding.collectives` record into the active session — the
  literal PMPI-interposition analog.

``lax.scan`` bodies that contain collectives are walked once per iteration so
the event sequence is exact; Sequitur's run-length constraint collapses the
repetition back to O(1) grammar space.  Collective-free bodies are costed
``length`` times in O(1) and charged ``length`` scan steps (the serialization
hazard metric).

Handle canonicalization (paper: MPI_Request/MPI_Comm pools): distinct
``axis_index_groups`` values are renumbered in first-use order, so traces stay
low-entropy and compressible.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Callable, Iterable, Sequence

import jax
import numpy as np

from repro.core.events import (
    CommEvent, ComputeEvent, Event, N_METRICS, encode_relative_perm, is_comm,
)
from repro.core.metrics import (
    COLLECTIVE_PRIMS, I_SCAN, collective_event_info, eqn_cost,
)

try:  # jax >= 0.4.x exposes Literal via jax.extend; older via jax.core
    from jax.extend.core import Literal as _Literal
except ImportError:  # pragma: no cover - old JAX fallback
    from jax.core import Literal as _Literal

#: primitives never constant-folded by the exact walker: higher-order (their
#: sub-jaxprs are walked structurally) and anything with host side effects
_NO_FOLD_PRIMS = frozenset({
    "scan", "while", "cond", "pjit", "closed_call", "core_call", "custom_lin",
    "remat", "remat2", "checkpoint", "shard_map", "custom_jvp_call",
    "custom_vjp_call", "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr",
})
_FOLD_SIZE_CAP = 1 << 16   # skip folding on large operands (opcode arrays ok)


@dataclasses.dataclass
class Trace:
    """A template trace: one SPMD event stream plus mesh-axis metadata.

    ``ppermute`` events carry their raw permutation; :func:`per_rank_traces`
    specializes them into per-rank relative-encoded events.
    """
    events: list[Event]
    axis_sizes: dict[str, int]

    def comm_events(self) -> list[CommEvent]:
        return [e for e in self.events if is_comm(e)]

    def compute_events(self) -> list[ComputeEvent]:
        return [e for e in self.events if not is_comm(e)]

    def total_compute(self) -> np.ndarray:
        vec = np.zeros(N_METRICS)
        for e in self.compute_events():
            vec += e.vector
        return vec

    def total_comm_bytes(self) -> int:
        return sum(e.payload_bytes for e in self.comm_events())

    def compute_metrics_array(self) -> np.ndarray:
        """``(n_compute_events, 6)`` float64 metric rows in stream order —
        the per-event variance the noise calibrator consumes (the columnar
        twin of ``TraceStore.metrics`` for a single template trace)."""
        rows = [e.metrics for e in self.compute_events()]
        if not rows:
            return np.zeros((0, N_METRICS))
        return np.asarray(rows, dtype=np.float64)


class JaxprWalker:
    """Recursive jaxpr walk producing the template event stream.

    ``exact_cond=True`` switches on constant-propagated control-flow
    resolution: jaxpr constants (and scan-carried constants / per-iteration
    xs slices) flow through an environment, ``cond`` equations with a
    resolved scalar index walk **only the selected branch**, and equations
    whose inputs are fully constant fold to zero cost (they are program-
    counter bookkeeping — e.g. the ``clamp`` a ``lax.switch`` inserts — not
    workload).  This is how grammar-compiled proxy modules (scan-over-
    opcodes + switch dispatch, :mod:`repro.core.progtable`) measure
    bit-identically to the unrolled reference.  Default off: original-
    program traces (which may use data-dependent ``lax.cond``) keep the
    legacy branch-0 / max-cost semantics, so fidelity baselines are
    untouched.
    """

    def __init__(self, axis_sizes: dict[str, int] | None = None,
                 exact_cond: bool = False):
        self.events: list[Event] = []
        self.pending = np.zeros(N_METRICS, dtype=np.float64)
        self.axis_sizes: dict[str, int] = dict(axis_sizes or {})
        self.exact_cond = bool(exact_cond)
        self._group_pool: dict[tuple, int] = {}   # handle canonicalization

    # -- event emission -------------------------------------------------------

    def flush(self) -> None:
        if self.pending.any():
            self.events.append(ComputeEvent(tuple(self.pending)))
            self.pending = np.zeros(N_METRICS, dtype=np.float64)

    def _emit_comm(self, eqn) -> None:
        self.flush()
        info = collective_event_info(eqn)
        # canonicalize axis_index_groups handles through a first-use pool
        detail = info["detail"]
        if detail and detail[0] == "groups" or (len(detail) > 2 and "groups" in detail):
            detail = self._canon_groups(detail)
        elif "groups" in detail:
            detail = self._canon_groups(detail)
        info["detail"] = detail
        self.events.append(CommEvent(**info))

    def _canon_groups(self, detail: tuple) -> tuple:
        out = []
        i = 0
        while i < len(detail):
            if detail[i] == "groups" and i + 1 < len(detail):
                gid = self._group_pool.setdefault(detail[i + 1],
                                                  len(self._group_pool))
                out.extend(["groups", gid])
                i += 2
            else:
                out.append(detail[i])
                i += 1
        return tuple(out)

    # -- recursion ------------------------------------------------------------

    def walk(self, jaxpr, env: dict | None = None) -> None:
        """Walk a (possibly Closed) jaxpr, emitting events in program order.

        ``env`` (exact mode only) maps jaxpr Vars to known host values;
        the closed jaxpr's own constants are merged in."""
        inner = getattr(jaxpr, "jaxpr", jaxpr)
        if self.exact_cond:
            env = dict(env or {})
            for var, val in zip(inner.constvars, getattr(jaxpr, "consts", ())):
                env.setdefault(var, np.asarray(val))
        else:
            env = None
        for eqn in inner.eqns:
            self._walk_eqn(eqn, env)

    # -- constant environment (exact mode) --------------------------------------

    @staticmethod
    def _val(v, env):
        """Known host value of an atom, or None."""
        if isinstance(v, _Literal):
            return np.asarray(v.val)
        return None if env is None else env.get(v)

    def _walk_sub(self, closed, invars, env) -> None:
        """Walk a sub-jaxpr, mapping resolved outer invars onto its invars."""
        if not self.exact_cond:
            self.walk(closed)
            return
        inner = getattr(closed, "jaxpr", closed)
        sub: dict = {}
        if invars is not None:
            for ivar, outer in zip(inner.invars, invars):
                val = self._val(outer, env)
                if val is not None:
                    sub[ivar] = val
        self.walk(closed, sub)

    def _try_fold(self, eqn, env) -> bool:
        """Eagerly evaluate a fully-constant equation; record its outputs in
        ``env`` and treat it as free.  Constant equations in generated
        modules are dispatch bookkeeping (switch index clamps, opcode
        casts), not replayed workload — costing them would break δ̄ parity
        with the unrolled reference, which has no dispatch machinery."""
        name = eqn.primitive.name
        if name in _NO_FOLD_PRIMS or name in COLLECTIVE_PRIMS \
                or "callback" in name:
            return False
        for v in eqn.params.values():
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                return False
        vals = []
        for v in eqn.invars:
            val = self._val(v, env)
            if val is None or np.size(val) > _FOLD_SIZE_CAP:
                return False
            vals.append(val)
        try:
            out = eqn.primitive.bind(*[np.asarray(v) for v in vals],
                                     **eqn.params)
        except Exception:
            return False
        outs = out if eqn.primitive.multiple_results else [out]
        for var, val in zip(eqn.outvars, outs):
            env[var] = np.asarray(val)
        return True

    def _walk_eqn(self, eqn, env: dict | None = None) -> None:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            self._emit_comm(eqn)
            return
        if name in ("pjit", "closed_call", "core_call", "custom_lin"):
            self._walk_sub(eqn.params["jaxpr"], eqn.invars, env)
            return
        if name in ("remat2", "remat", "checkpoint"):
            self._walk_sub(eqn.params["jaxpr"], eqn.invars, env)
            return
        if name in ("custom_jvp_call", "custom_vjp_call", "custom_jvp_call_jaxpr",
                    "custom_vjp_call_jaxpr"):
            inner = eqn.params.get("call_jaxpr", eqn.params.get("fun_jaxpr"))
            if inner is not None:
                self.walk(inner)
            return
        if name == "shard_map":
            mesh = eqn.params.get("mesh")
            if mesh is not None:
                for ax, sz in zip(mesh.axis_names, mesh.shape.values()
                                  if hasattr(mesh.shape, "values") else mesh.shape):
                    self.axis_sizes[str(ax)] = int(sz)
            self.walk(eqn.params["jaxpr"])
            return
        if name == "scan":
            self._walk_scan(eqn, env)
            return
        if name == "while":
            self._walk_while(eqn)
            return
        if name == "cond":
            self._walk_cond(eqn, env)
            return
        if env is not None and self._try_fold(eqn, env):
            return
        self.pending += eqn_cost(eqn)

    # -- higher-order handling --------------------------------------------------

    def _scan_layout(self, eqn):
        nc = int(eqn.params.get("num_consts", 0))
        ncar = int(eqn.params.get("num_carry", 0))
        return nc, ncar

    def _scan_iter_env(self, body, invals, t: int) -> dict | None:
        """Body-invar environment for scan iteration ``t``: scan constants
        pass through whole, xs operands are sliced per iteration, carries
        stay unknown."""
        if invals is None:
            return None
        nc, ncar, vals = invals
        inner = getattr(body, "jaxpr", body)
        bvars = inner.invars
        env: dict = {}
        for var, val in zip(bvars[:nc], vals[:nc]):
            if val is not None:
                env[var] = val
        for var, val in zip(bvars[nc + ncar:], vals[nc + ncar:]):
            if val is not None:
                env[var] = np.asarray(val)[t]
        return env

    def _walk_scan(self, eqn, env: dict | None = None) -> None:
        body = eqn.params["jaxpr"]
        length = int(eqn.params["length"])
        invals = None
        if self.exact_cond:
            nc, ncar = self._scan_layout(eqn)
            invals = (nc, ncar, [self._val(v, env) for v in eqn.invars])
        has_cond = self.exact_cond and _contains_cond(body)
        xs_known = (invals is not None
                    and len(invals[2]) > invals[0] + invals[1]
                    and all(v is not None
                            for v in invals[2][invals[0] + invals[1]:]))
        if _contains_collective(body) or (has_cond and xs_known):
            # exact event sequence; Sequitur's RLE makes this O(1) in grammar.
            # cond-bearing bodies with known xs (switch dispatch over a
            # constant opcode array) also walk per-iteration: each step
            # resolves to exactly the branch the reference emitted inline,
            # and no scan-step serialization is charged — the reference's
            # straight-line statements charge none either.
            for t in range(length):
                self.walk(body, self._scan_iter_env(body, invals, t))
            return
        if has_cond:
            # rolled rule body (cond nested below an exponent scan): cost one
            # exact iteration with the loop-invariant constants, like the
            # reference's rep()-scan of the same body
            self.pending += self._exact_body_cost(body, invals) * length
            self.pending[I_SCAN] += length
            return
        cost = _subtree_cost(body)
        self.pending += cost * length
        self.pending[I_SCAN] += length

    def _exact_body_cost(self, body, invals) -> np.ndarray:
        """One-iteration 6-metric cost of a comm-free scan body, walked in
        exact mode with the scan constants bound (xs/carries unknown)."""
        w = JaxprWalker(self.axis_sizes, exact_cond=True)
        env = self._scan_iter_env(body, invals, 0)
        if env is not None and invals is not None:
            nc, ncar, _ = invals
            inner = getattr(body, "jaxpr", body)
            # xs slices are iteration-dependent: drop them from the cost env
            for var in inner.invars[nc + ncar:]:
                env.pop(var, None)
        w.walk(body, env)
        w.flush()
        vec = np.zeros(N_METRICS)
        for e in w.events:
            vec += e.vector
        return vec

    def _walk_while(self, eqn) -> None:
        body = eqn.params["body_jaxpr"]
        cond = eqn.params["cond_jaxpr"]
        # trip count is dynamic; cost one iteration and flag serialization.
        if _contains_collective(body):
            self.walk(cond)
            self.walk(body)
        else:
            self.pending += _subtree_cost(cond) + _subtree_cost(body)
            self.pending[I_SCAN] += 1

    def _walk_cond(self, eqn, env: dict | None = None) -> None:
        branches = eqn.params["branches"]
        if self.exact_cond:
            idx = self._val(eqn.invars[0], env)
            if idx is not None and np.ndim(idx) == 0:
                b = branches[min(max(int(idx), 0), len(branches) - 1)]
                self._walk_sub(b, eqn.invars[1:], env)
                return
        if any(_contains_collective(b) for b in branches):
            # SPMD safety requires identical collective skeletons; walk branch 0
            self.walk(branches[0])
            return
        costs = [_subtree_cost(b) for b in branches]
        self.pending += np.max(np.stack(costs), axis=0)


def _contains_collective(jaxpr) -> bool:
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            return True
        for v in eqn.params.values():
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                if _contains_collective(v):
                    return True
            elif isinstance(v, (tuple, list)):
                for b in v:
                    if (hasattr(b, "eqns") or hasattr(b, "jaxpr")) and _contains_collective(b):
                        return True
    return False


def _contains_cond(jaxpr) -> bool:
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "cond":
            return True
        for v in eqn.params.values():
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                if _contains_cond(v):
                    return True
            elif isinstance(v, (tuple, list)):
                for b in v:
                    if (hasattr(b, "eqns") or hasattr(b, "jaxpr")) and _contains_cond(b):
                        return True
    return False


def _subtree_cost(jaxpr) -> np.ndarray:
    """Total 6-metric cost of a collective-free jaxpr subtree."""
    w = JaxprWalker()
    w.walk(jaxpr)
    w.flush()
    vec = np.zeros(N_METRICS)
    for e in w.events:
        vec += e.vector
    return vec


# ---------------------------------------------------------------------------
# public front-end: trace a function
# ---------------------------------------------------------------------------


def trace_fn(fn: Callable, *args, axis_sizes: dict[str, int] | None = None,
             exact_cond: bool = False, **kwargs) -> Trace:
    """Trace ``fn(*args, **kwargs)`` into a template event stream.

    Works on any JAX-traceable callable; args may be ShapeDtypeStructs
    (no allocation — the "binary only" analog is "staged artifact only").

    ``exact_cond=True`` enables the walker's constant-propagated control-
    flow resolution (see :class:`JaxprWalker`) — used when measuring
    generated proxy modules, whose switch dispatch is driven entirely by
    constant opcode arrays.
    """
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    w = JaxprWalker(axis_sizes, exact_cond=exact_cond)
    w.walk(jaxpr)
    w.flush()
    return Trace(w.events, w.axis_sizes)


def trace_fn_store(fn: Callable, *args,
                   axis_sizes: dict[str, int] | None = None, **kwargs):
    """Trace ``fn`` straight into a columnar :class:`~repro.core.trace_ir.
    TraceStore`: the template is walked once and specialized per rank in
    array form (no per-rank Event lists) — the fast path ``synthesize``
    uses.  Equivalent to ``TraceStore.from_rank_traces(per_rank_traces(
    trace_fn(...)))``."""
    from repro.core.trace_ir import TraceStore
    template = trace_fn(fn, *args, axis_sizes=axis_sizes, **kwargs)
    sizes = dict(template.axis_sizes if axis_sizes is None else axis_sizes)
    return TraceStore.from_template(template, sizes)


def compute_cost(fn: Callable, *args, **kwargs) -> np.ndarray:
    """Total 6-metric cost of a collective-free callable (block calibration)."""
    t = trace_fn(fn, *args, **kwargs)
    return t.total_compute()


# ---------------------------------------------------------------------------
# per-rank specialization (paper §2.2 relative ranks, §2.6 SPMD merging input)
# ---------------------------------------------------------------------------


def per_rank_traces(trace: Trace, axis_sizes: dict[str, int] | None = None,
                    ) -> list[list[Event]]:
    """Specialize the SPMD template to one event list per rank.

    Ranks are the row-major flattening of the mesh axes in ``axis_sizes``
    order.  ``ppermute`` events become relative-encoded events present only on
    participating ranks (paper Fig. 2: a shift permutation collapses to one
    shared terminal; boundary ranks of a non-periodic halo drop out, which is
    exactly what drives rank-set branches in the merged main rule).
    """
    axis_sizes = dict(axis_sizes or trace.axis_sizes)
    axes = list(axis_sizes)
    sizes = [axis_sizes[a] for a in axes]
    n_ranks = int(np.prod(sizes)) if sizes else 1

    def coords(rank: int) -> dict[str, int]:
        out = {}
        rem = rank
        for a, s in zip(reversed(axes), reversed(sizes)):
            out[a] = rem % s
            rem //= s
        return out

    traces: list[list[Event]] = []
    for rank in range(n_ranks):
        c = coords(rank)
        evs: list[Event] = []
        for ev in trace.events:
            if is_comm(ev) and ev.kind == "ppermute":
                ev2 = _specialize_ppermute(ev, c, axis_sizes)
                if ev2 is not None:
                    evs.append(ev2)
            else:
                evs.append(ev)
        traces.append(evs)
    return traces


def _specialize_ppermute(ev: CommEvent, coords: dict[str, int],
                         axis_sizes: dict[str, int]) -> CommEvent | None:
    if not ev.detail or ev.detail[0] != "rawperm":
        return ev
    perm = ev.detail[1]
    axis = ev.axes[0] if ev.axes else None
    size = axis_sizes.get(axis, max((max(s, d) for s, d in perm), default=0) + 1)
    me = coords.get(axis, 0)
    srcs = {s for s, _ in perm}
    dsts = {d for _, d in perm}
    if me not in srcs and me not in dsts:
        return None  # this rank does not participate
    rel = encode_relative_perm([tuple(p) for p in perm], size)
    return dataclasses.replace(ev, detail=rel)


# ---------------------------------------------------------------------------
# host-level interposition recorder (PMPI analog for multi-step drivers)
# ---------------------------------------------------------------------------

_TLS = threading.local()


class TraceSession:
    """Record events emitted by instrumented wrappers in host-driver code.

    ``rank_streams[r]`` is rank r's event list.  Wrappers use
    :func:`record_event`; compute segments are costed with
    :func:`record_compute`.  Nested sessions are not supported.
    """

    def __init__(self, n_ranks: int, axis_sizes: dict[str, int] | None = None):
        self.n_ranks = n_ranks
        self.axis_sizes = dict(axis_sizes or {})
        self.rank_streams: list[list[Event]] = [[] for _ in range(n_ranks)]

    def __enter__(self):
        if getattr(_TLS, "session", None) is not None:
            raise RuntimeError("TraceSession already active")
        _TLS.session = self
        return self

    def __exit__(self, *exc):
        _TLS.session = None
        return False

    def emit(self, ranks: Iterable[int] | None, ev: Event) -> None:
        ranks = range(self.n_ranks) if ranks is None else ranks
        for r in ranks:
            self.rank_streams[r].append(ev)

    def to_store(self):
        """Freeze the recorded streams into a columnar
        :class:`~repro.core.trace_ir.TraceStore`."""
        from repro.core.trace_ir import TraceStore
        return TraceStore.from_rank_traces(self.rank_streams, self.axis_sizes)


def active_session() -> TraceSession | None:
    return getattr(_TLS, "session", None)


def record_event(ev: Event, ranks: Iterable[int] | None = None) -> None:
    s = active_session()
    if s is not None:
        s.emit(ranks, ev)


def record_compute(fn: Callable, *args, ranks: Iterable[int] | None = None,
                   **kwargs) -> None:
    """Cost ``fn`` with the jaxpr walker and record one ComputeEvent."""
    s = active_session()
    if s is None:
        return
    vec = compute_cost(fn, *args, **kwargs)
    s.emit(ranks, ComputeEvent(tuple(vec)))
