"""Program-table lowering: grammar-shaped executables (paper §2.7).

The unrolled emitter (:mod:`repro.core.codegen_reference`) turns every
grammar symbol into one Python statement, so jaxpr size, compile time, and
host memory scale with the *trace*.  :class:`ProgramTable` is the compiled
alternative: the generated module ships the grammar itself — terminal
descriptors plus rule bodies as ``(opcode, ref, exponent)`` tuples — and
this lowering maps it onto rolled JAX control flow:

* a symbol with exponent ``n`` replays through :func:`repro.core.replay.rep`
  — unrolled up to :data:`~repro.core.replay.REP_UNROLL_THRESHOLD`, a rolled
  ``fori_loop``/``scan`` above it (one body trace regardless of n);
* a long heterogeneous symbol sequence becomes one ``lax.scan`` over a
  constant int32 opcode array whose step is a ``lax.switch`` over the
  sequence's *distinct* ``(callee, exponent)`` pairs — same-signature
  symbols share one switch branch, so the scan body is sized by the
  distinct-symbol count, not the sequence length;
* nested rules lower children-first, so rule exponents become nested scans.

Executable size is therefore O(grammar): comm terminals keep their exact
traced parameters (the collective schedule stays lossless), while the jaxpr
equation count stops depending on how many times the trace repeats them.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np
from jax import lax
import jax.numpy as jnp

from repro.core import blocks
from repro.core import noise as noise_mod
from repro.core.replay import REP_UNROLL_THRESHOLD, rep

#: Symbol sequences shorter than this stay straight-line: a switch-scan
#: needs the opcode array + dispatch machinery, which only pays for itself
#: once the sequence is meaningfully longer than its distinct-symbol set.
SWITCH_MIN_LEN = 6


def topo_order(rules: Mapping[int, Sequence]) -> list[int]:
    """Children-first ordering of rule ids (deterministic)."""
    seen: set[int] = set()
    out: list[int] = []

    def visit(rid: int) -> None:
        if rid in seen:
            return
        seen.add(rid)
        for kind, ref, _ in rules[rid]:
            if kind == "r":
                visit(ref)
        out.append(rid)

    for rid in sorted(rules):
        visit(rid)
    return out


def expand_symbols(seq: Sequence, rules: Mapping[int, Sequence]) -> list[int]:
    """Symbolic expansion of a symbol sequence to its terminal-id stream.

    This is the comm-sequence oracle for compiled modules: expanding the
    emitted tables must reproduce ``MergedProgram.expand_rank`` exactly
    (losslessness survives the lowering), without executing anything.
    """
    out: list[int] = []

    def go(symbols: Sequence) -> None:
        for kind, ref, exp in symbols:
            if kind == "t":
                out.extend([int(ref)] * int(exp))
            else:
                for _ in range(int(exp)):
                    go(rules[ref])

    go(seq)
    return out


class ProgramTable:
    """Executable lowering of a generated module's grammar tables.

    ``terminals[gid]`` is ``("comm", buf_name, params_dict)`` or
    ``("compute", x_tuple, unroll)``; ``rules[rid]`` is a tuple of
    ``(kind, ref, exp)`` symbols; ``programs[gi]`` is signature group
    ``gi``'s flattened (guard-resolved) symbol sequence.  All lowered
    callables take ``(st, comm)`` and return the new state, exactly like
    the unrolled emitter's functions — the replay engine cannot tell the
    flavors apart.
    """

    def __init__(self, terminals: Sequence, rules: Mapping[int, Sequence],
                 programs: Sequence, noise: Sequence | None = None):
        self.terminals = tuple(tuple(t) for t in terminals)
        self.rules = {int(rid): tuple(tuple(s) for s in body)
                      for rid, body in dict(rules).items()}
        self.programs = tuple(tuple(tuple(s) for s in seq)
                              for seq in programs)
        # Per-terminal (sigma, shift) noise params (the module's
        # NOISE_MODELS table).  Lowered once through the shared
        # repro.core.noise helpers; the wrappers are trace-time no-ops
        # unless the replay state carries the noise key, so pre-noise
        # modules (noise=None) and noise-disabled replay trace identical
        # jaxprs.
        if noise is not None:
            self._noise = noise_mod.lower_params(noise, self.terminals)
        else:
            self._noise = (None,) * len(self.terminals)
        self._term_fns = [self._lower_terminal(t, nz) for t, nz
                          in zip(self.terminals, self._noise)]
        self._rule_fns: dict[int, object] = {}
        for rid in topo_order(self.rules):
            self._rule_fns[rid] = self._lower_seq(self.rules[rid])
        self._prog_fns = [self._lower_seq(seq) for seq in self.programs]

    # -- terminal lowering -----------------------------------------------------

    @staticmethod
    def _lower_terminal(desc, nz=None):
        kind = desc[0]
        if kind == "comm":
            _, buf, params = desc
            params = dict(params)

            def comm_fn(st, comm, _buf=buf, _p=params, _nz=nz):
                return noise_mod.perturb(comm.do(st, _buf, **_p), _nz)

            return comm_fn
        if kind == "compute":
            _, x, unroll = desc
            x = tuple(int(v) for v in x)
            unroll = int(unroll)

            def compute_fn(st, comm, _x=x, _u=unroll, _nz=nz):
                return noise_mod.perturb(blocks.run_combo(st, _x, unroll=_u),
                                         _nz)

            return compute_fn
        raise ValueError(f"unknown terminal kind: {kind!r}")

    # -- sequence lowering -----------------------------------------------------

    def _callee(self, kind: str, ref: int):
        return self._term_fns[ref] if kind == "t" else self._rule_fns[ref]

    def _lower_seq(self, seq: Sequence):
        """Lower one symbol sequence to a ``(st, comm) -> st`` callable.

        Distinct ``(kind, ref, exp)`` symbols dedupe into switch branches;
        the sequence itself survives only as a constant int32 opcode array,
        so trace size is O(distinct symbols) + O(1) for the scan."""
        if not seq:
            return lambda st, comm: st
        keys: list[tuple] = []
        index: dict[tuple, int] = {}
        for kind, ref, exp in seq:
            k = (kind, int(ref), int(exp))
            if k not in index:
                index[k] = len(keys)
                keys.append(k)
        entries = [(self._callee(kind, ref), exp) for kind, ref, exp in keys]
        if len(seq) < SWITCH_MIN_LEN or len(keys) < 2 \
                or len(keys) == len(seq):
            run = tuple((self._callee(kind, ref), int(exp))
                        for kind, ref, exp in seq)

            def straight(st, comm, _run=run):
                for fn, e in _run:
                    st = rep(fn, e, st, comm)
                return st

            return straight

        opcodes = np.asarray([index[(k, int(r), int(e))] for k, r, e in seq],
                             dtype=np.int32)

        def switched(st, comm, _entries=entries, _ops=opcodes):
            branches = [
                (lambda s, _fn=fn, _e=e: rep(_fn, _e, s, comm))
                for fn, e in _entries
            ]

            def step(carry, op):
                return lax.switch(op, branches, carry), None

            st, _ = lax.scan(step, st, jnp.asarray(_ops))
            return st

        return switched

    # -- execution + introspection ---------------------------------------------

    def run(self, gi: int, st: dict, comm) -> dict:
        """Execute signature group ``gi``'s program."""
        return self._prog_fns[gi](st, comm)

    def expand(self, gi: int) -> list[int]:
        """Terminal-id stream of group ``gi`` (symbolic, no execution)."""
        return expand_symbols(self.programs[gi], self.rules)


# ---------------------------------------------------------------------------
# executable-size accounting
# ---------------------------------------------------------------------------


def jaxpr_eqn_count(jaxpr) -> int:
    """Total equation count of a jaxpr, recursing into sub-jaxprs carried by
    higher-order primitives (each scan/cond body is counted once — exactly
    the traced-program size a rolled lowering keeps O(grammar))."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for v in eqn.params.values():
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                n += jaxpr_eqn_count(v)
            elif isinstance(v, (tuple, list)):
                for b in v:
                    if hasattr(b, "eqns") or hasattr(b, "jaxpr"):
                        n += jaxpr_eqn_count(b)
    return n
