"""Code generation (paper §2.7, Algorithm 2): grammar → executable source.

The merged grammar is emitted as a self-contained Python module:

  * communication terminals → ``comm.do(...)`` calls carrying the exact
    traced parameters (kind, payload shape/dtype, mesh axes, permute detail)
    — lossless, like the paper's direct MPI-call emission;
  * computation terminals → ``blocks.run_combo(st, x)`` with the QP-searched
    block counts (paper: "combine the code blocks into a function");
  * non-terminals → Python functions; run-length exponents → ``fori_loop``
    via :func:`repro.core.replay.rep` (the O(1) loop replay of a^i symbols);
  * main rules → per-cluster driver functions with rank-set branch guards,
    consecutive symbols sharing a guard are grouped (paper: "compare and
    merge the same rank lists to reduce redundant branch statements").

The module executes under any comm backend: ``LocalSim`` on one host, or
``DeviceComm`` inside ``shard_map`` on a real mesh, where its lowered HLO
reproduces the original program's collective schedule.
"""
from __future__ import annotations

import textwrap
from typing import Mapping, Sequence

from repro.core.events import CommEvent, ComputeEvent, is_comm
from repro.core.interproc import MergedProgram


def _fmt_rankset(rs: frozenset, n_ranks: int) -> str:
    """Compact literal: ALL / range / strided range / explicit set."""
    if len(rs) == n_ranks:
        return "ALL"
    s = sorted(rs)
    if len(s) == 1:
        return f"frozenset(({s[0]},))"
    step = s[1] - s[0]
    if step > 0 and all(b - a == step for a, b in zip(s, s[1:])):
        return f"frozenset(range({s[0]}, {s[-1] + 1}, {step}))" if step > 1 \
            else f"frozenset(range({s[0]}, {s[-1] + 1}))"
    return "frozenset((" + ", ".join(map(str, s)) + ",))"


def generate_source(merged: MergedProgram,
                    combos: Mapping[int, tuple],
                    name: str = "proxy",
                    axis_sizes: Mapping[str, int] | None = None,
                    count_scale: float = 1.0) -> str:
    """Emit the proxy-app module source.

    ``combos[gid]`` is ``(x, unroll)`` — the 11-int loop-turn vector and the
    block-instances-per-turn factor — for the compute terminal with global
    id ``gid`` (one per compute-event cluster, paper §2.4).

    ``count_scale`` is the time-dilation factor the block counts were
    fitted with; the per-group device hints in ``SIGNATURE_GROUPS`` scale
    with it (see :func:`group_device_hint`), so a 1/20-dilated proxy does
    not claim the full traced collective span per group.
    """
    axis_sizes = dict(axis_sizes or {})
    L: list[str] = []
    w = L.append

    w(f'"""Auto-generated performance proxy ({name}).')
    w("")
    w("Synthesized by repro.core (Siesta-JAX): the collective skeleton is a")
    w("lossless replay of the traced program; compute segments are QP-fitted")
    w("block combinations.  Do not edit."  '"""')
    w("from jax import lax  # noqa: F401")
    w("from repro.core import blocks as _blocks")
    w("from repro.core.replay import rep as _rep")
    w("")
    w(f"N_RANKS = {merged.n_ranks}")
    w(f"AXIS_SIZES = {dict(axis_sizes)!r}")

    # -- comm buffer pool (one per distinct payload shape/dtype) --------------
    bufs: dict[tuple, str] = {}
    for ev in merged.table.events:
        if is_comm(ev):
            key = (ev.shape, ev.dtype)
            if key not in bufs:
                bufs[key] = f"buf{len(bufs)}"
    w("COMM_BUFFERS = {")
    for (shape, dtype), bname in bufs.items():
        w(f"    {bname!r}: ({shape!r}, {dtype!r}),")
    w("}")
    w("ALL = frozenset(range(N_RANKS))")
    w("")

    # -- terminals -------------------------------------------------------------
    for gid, ev in enumerate(merged.table.events):
        if is_comm(ev):
            bname = bufs[(ev.shape, ev.dtype)]
            w(f"def t{gid}(st, comm):  # {ev.kind} {ev.dtype}{list(ev.shape)} over {ev.axes}")
            w(f"    return comm.do(st, {bname!r}, kind={ev.kind!r}, "
              f"axes={ev.axes!r}, detail={ev.detail!r}, "
              f"shape={ev.shape!r}, dtype={ev.dtype!r})")
        else:
            combo = combos.get(gid)
            if combo is None:
                raise KeyError(f"no block combo for compute terminal {gid}")
            x, unroll = combo
            w(f"def t{gid}(st, comm):  # MPI_Compute proxy, cluster {ev.cluster_id}")
            w(f"    return _blocks.run_combo(st, {tuple(int(v) for v in x)!r}, "
              f"unroll={int(unroll)})")
        w("")

    # -- non-terminals (children before parents) -------------------------------
    order = _topo_order(merged.rules)
    for rid in order:
        w(f"def r{rid}(st, comm):")
        body = merged.rules[rid]
        if not body:
            w("    return st")
            w("")
            continue
        for kind, ref, exp in body:
            fn = f"t{ref}" if kind == "t" else f"r{ref}"
            if exp == 1:
                w(f"    st = {fn}(st, comm)")
            else:
                w(f"    st = _rep({fn}, {exp}, st, comm)")
        w("    return st")
        w("")

    # -- main rules with rank-set guards ----------------------------------------
    guards_meta: list[list[str]] = []
    cluster_runs: list[list[frozenset | None]] = []   # None == unguarded run
    cluster_run_syms: list[list[tuple[frozenset, list]]] = []  # runs w/ symbols
    for ci, (main, cranks) in enumerate(zip(merged.mains, merged.cluster_ranks)):
        w(f"def main{ci}(st, comm, rank):")
        if not main:
            w("    return st")
            w("")
            guards_meta.append([])
            cluster_runs.append([])
            cluster_run_syms.append([])
            continue
        meta = []
        # group consecutive symbols sharing a rank set (Alg. 2 lines 15-18)
        runs: list[tuple[frozenset, list]] = []
        for kind, ref, exp, rs in main:
            if runs and runs[-1][0] == rs:
                runs[-1][1].append((kind, ref, exp))
            else:
                runs.append((rs, [(kind, ref, exp)]))
        for rs, syms in runs:
            full = rs >= cranks
            indent = "    "
            if not full:
                w(f"    if rank in {_fmt_rankset(rs, merged.n_ranks)}:")
                indent = "        "
            for kind, ref, exp in syms:
                fn = f"t{ref}" if kind == "t" else f"r{ref}"
                if exp == 1:
                    w(f"{indent}st = {fn}(st, comm)")
                else:
                    w(f"{indent}st = _rep({fn}, {exp}, st, comm)")
            meta.append("None" if full else _fmt_rankset(rs, merged.n_ranks))
        w("    return st")
        w("")
        guards_meta.append(meta)
        cluster_runs.append([None if rs >= cranks else rs for rs, _ in runs])
        cluster_run_syms.append(runs)

    # -- driver + signature -------------------------------------------------------
    w("CLUSTER_RANKS = (")
    for cr in merged.cluster_ranks:
        w(f"    {_fmt_rankset(cr, merged.n_ranks)},")
    w(")")
    w("_MAINS = (" + ", ".join(f"main{i}" for i in range(len(merged.mains)))
      + ("," if len(merged.mains) == 1 else "") + ")")
    w("_GUARDS = (")
    for meta in guards_meta:
        w("    (" + ", ".join(meta) + ("," if len(meta) == 1 else "") + "),")
    w(")")
    w("")

    # -- signature-group metadata (batched replay, §3.3) -----------------------
    # Ranks sharing a control-flow signature execute byte-identical programs,
    # so the replay engine can stack their states and run one compiled
    # executable for the whole group.  Precomputed here so replay never has
    # to probe program_signature rank by rank.  Each group also carries a
    # device-count hint: the number of mesh devices that fully reproduces the
    # collective span of the group's program (product of the traced sizes of
    # every mesh axis its comm terminals touch; 1 for comm-free groups).  The
    # mesh sweep scheduler in repro.core.replay partitions devices
    # proportionally to these hints.
    sig_groups = compute_signature_groups(merged.cluster_ranks, cluster_runs,
                                          merged.n_ranks)
    run_axes = [[_syms_comm_axes(syms, merged.rules, merged.table)
                 for _, syms in runs] for runs in cluster_run_syms]
    w("#: (signature, ranks, device_hint) triples; every rank appears in")
    w("#: exactly one group.")
    w("SIGNATURE_GROUPS = (")
    for sig, ranks in sig_groups:
        hint = group_device_hint(sig, run_axes, axis_sizes, count_scale)
        w(f"    ({sig!r}, {_fmt_ranktuple(ranks)}, {hint}),")
    w(")")
    w("")
    w(textwrap.dedent("""\
        def run_rank(st, comm, rank):
            \"\"\"Execute rank ``rank``'s proxy program (host-level dispatch).\"\"\"
            for ranks, fn in zip(CLUSTER_RANKS, _MAINS):
                if rank in ranks:
                    st = fn(st, comm, rank)
            return st


        def program_signature(rank):
            \"\"\"Hashable per-rank control-flow signature (jit dedupe key).\"\"\"
            sig = []
            for ci, (ranks, guards) in enumerate(zip(CLUSTER_RANKS, _GUARDS)):
                if rank in ranks:
                    sig.append((ci, tuple(i for i, g in enumerate(guards)
                                          if g is None or rank in g)))
            return tuple(sig)
    """))
    return "\n".join(L)


def _fmt_ranktuple(s: Sequence[int]) -> str:
    """Compact ordered-tuple literal: arithmetic progressions (the common
    SPMD group shape) render as ``tuple(range(...))`` so a thousand-rank
    group costs O(1) generated source, not O(n)."""
    s = list(s)
    if len(s) >= 3:
        step = s[1] - s[0]
        if step > 0 and all(b - a == step for a, b in zip(s, s[1:])):
            return (f"tuple(range({s[0]}, {s[-1] + 1}))" if step == 1
                    else f"tuple(range({s[0]}, {s[-1] + 1}, {step}))")
    return repr(tuple(s))


def _syms_comm_axes(syms: Sequence[tuple], rules: Mapping[int, list],
                    table) -> frozenset:
    """Mesh axes touched by the comm terminals reachable from ``syms``
    (transitively through non-terminal references)."""
    axes: set[str] = set()
    seen: set[int] = set()

    def visit_rule(rid: int) -> None:
        if rid in seen:
            return
        seen.add(rid)
        for kind, ref, _ in rules[rid]:
            if kind == "t":
                visit_term(ref)
            else:
                visit_rule(ref)

    def visit_term(gid: int) -> None:
        ev = table.events[gid]
        if is_comm(ev):
            axes.update(ev.axes)

    for kind, ref, _ in syms:
        if kind == "t":
            visit_term(ref)
        else:
            visit_rule(ref)
    return frozenset(axes)


def group_device_hint(sig: tuple, cluster_run_axes: Sequence[Sequence[frozenset]],
                      axis_sizes: Mapping[str, int],
                      count_scale: float = 1.0) -> int:
    """Devices that fully reproduce the collective span of a signature group:
    the product of the traced sizes of every mesh axis the group's comm
    terminals touch (1 for comm-free groups, or when an axis size is
    unknown).

    ``count_scale`` < 1 scales the hint down proportionally (floor 1): a
    time-dilated proxy replays 1/count_scale of the traced work, so tiny
    groups should share sub-meshes instead of idling devices sized for the
    full span (the sweep scheduler packs unit-hint groups together — see
    :func:`repro.core.replay.plan_mesh_sweep`)."""
    axes: set[str] = set()
    for ci, run_ids in sig:
        for i in run_ids:
            axes |= cluster_run_axes[ci][i]
    hint = 1
    for a in sorted(axes):
        hint *= max(int(axis_sizes.get(a, 1)), 1)
    hint = max(hint, 1)
    if count_scale < 1.0:
        hint = max(1, int(round(hint * count_scale)))
    return hint


def compute_signature_groups(cluster_ranks: Sequence[frozenset],
                             cluster_runs: Sequence[Sequence[frozenset | None]],
                             n_ranks: int,
                             ) -> list[tuple[tuple, list[int]]]:
    """Group ranks by control-flow signature (mirrors ``program_signature``).

    A rank's signature is the tuple of ``(cluster_id, matched_guard_runs)``
    over the clusters containing it — the exact per-rank trace key of the
    generated module.  Groups preserve rank order; signatures are ordered by
    first rank seen, so output is deterministic.
    """
    groups: dict[tuple, list[int]] = {}
    for rank in range(n_ranks):
        sig = []
        for ci, (cranks, runs) in enumerate(zip(cluster_ranks, cluster_runs)):
            if rank in cranks:
                sig.append((ci, tuple(i for i, rs in enumerate(runs)
                                      if rs is None or rank in rs)))
        groups.setdefault(tuple(sig), []).append(rank)
    return list(groups.items())


def _topo_order(rules: dict[int, list]) -> list[int]:
    """Children-first ordering of non-terminal definitions."""
    seen: set[int] = set()
    out: list[int] = []

    def visit(rid: int):
        if rid in seen:
            return
        seen.add(rid)
        for kind, ref, _ in rules[rid]:
            if kind == "r":
                visit(ref)
        out.append(rid)

    for rid in sorted(rules):
        visit(rid)
    return out
