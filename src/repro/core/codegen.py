"""Code generation (paper §2.7, Algorithm 2): grammar → executable source.

The merged grammar is emitted as a self-contained Python module carrying a
**program table** — the grammar itself, not an unrolled statement per
symbol — so the traced executable is sized O(grammar), not O(trace):

  * communication terminals → ``('comm', buf, dict(kind=..., ...))``
    descriptors carrying the exact traced parameters (kind, payload
    shape/dtype, mesh axes, permute detail) — lossless, like the paper's
    direct MPI-call emission;
  * computation terminals → ``('compute', x, unroll)`` descriptors with the
    QP-searched block counts (paper: "combine the code blocks into a
    function");
  * non-terminals → ``RULES[rid]`` bodies of ``(kind, ref, exp)`` symbols;
  * signature groups → ``GROUP_PROGRAMS[gi]``, the flattened guard-resolved
    symbol sequence each group executes.

:class:`repro.core.progtable.ProgramTable` lowers the tables at import
time: run-length exponents become rolled ``fori_loop``/``scan`` via
:func:`repro.core.replay.rep`, nested rules become nested scans, and long
heterogeneous sequences dispatch through ``lax.switch`` over the distinct
symbols (same-signature terminals share one branch).

The unrolled per-symbol emitter is preserved verbatim as
:mod:`repro.core.codegen_reference` — the parity oracle: both flavors must
produce bit-identical δ̄ and per-rank comm sequences (pinned by tests and
the CI parity step).  The module executes under any comm backend:
``LocalSim`` on one host, or ``DeviceComm`` inside ``shard_map`` on a real
mesh, where its lowered HLO reproduces the original program's collective
schedule.
"""
from __future__ import annotations

import textwrap
from typing import Mapping, Sequence

from repro.core.events import CommEvent, ComputeEvent, is_comm
from repro.core.interproc import MergedProgram


def _fmt_rankset(rs: frozenset, n_ranks: int) -> str:
    """Compact literal: ALL / range / strided range / explicit set.

    The range form needs >= 3 elements (mirroring :func:`_fmt_ranktuple`):
    a 2-element set like ``{0, 5}`` is an arithmetic progression too, but
    ``frozenset(range(0, 6, 5))`` is opaque where ``frozenset((0, 5,))``
    is obvious, and saves nothing."""
    if len(rs) == n_ranks:
        return "ALL"
    s = sorted(rs)
    if len(s) >= 3:
        step = s[1] - s[0]
        if step > 0 and all(b - a == step for a, b in zip(s, s[1:])):
            return f"frozenset(range({s[0]}, {s[-1] + 1}, {step}))" if step > 1 \
                else f"frozenset(range({s[0]}, {s[-1] + 1}))"
    return "frozenset((" + ", ".join(map(str, s)) + ",))"


# ---------------------------------------------------------------------------
# shared structural computation (table emitter + unrolled reference)
# ---------------------------------------------------------------------------


def _comm_buffers(merged: MergedProgram) -> dict[tuple, str]:
    """Comm buffer pool: one buffer per distinct payload (shape, dtype)."""
    bufs: dict[tuple, str] = {}
    for ev in merged.table.events:
        if is_comm(ev):
            key = (ev.shape, ev.dtype)
            if key not in bufs:
                bufs[key] = f"buf{len(bufs)}"
    return bufs


def _main_runs(merged: MergedProgram) -> list[list[tuple[frozenset, list]]]:
    """Per-cluster guard runs: consecutive main symbols sharing a rank set
    are grouped (Alg. 2 lines 15-18), preserving symbol order."""
    out: list[list[tuple[frozenset, list]]] = []
    for main in merged.mains:
        runs: list[tuple[frozenset, list]] = []
        for kind, ref, exp, rs in main:
            if runs and runs[-1][0] == rs:
                runs[-1][1].append((kind, ref, exp))
            else:
                runs.append((rs, [(kind, ref, exp)]))
        out.append(runs)
    return out


def generate_source(merged: MergedProgram,
                    combos: Mapping[int, tuple],
                    name: str = "proxy",
                    axis_sizes: Mapping[str, int] | None = None,
                    count_scale: float = 1.0,
                    noise_models: Sequence[tuple[float, float]] | None = None,
                    ) -> str:
    """Emit the grammar-compiled proxy-app module source.

    ``combos[gid]`` is ``(x, unroll)`` — the 11-int loop-turn vector and the
    block-instances-per-turn factor — for the compute terminal with global
    id ``gid`` (one per compute-event cluster, paper §2.4).

    ``count_scale`` is the time-dilation factor the block counts were
    fitted with; the per-group device hints in ``SIGNATURE_GROUPS`` scale
    with it (see :func:`group_device_hint`), so a 1/20-dilated proxy does
    not claim the full traced collective span per group.

    ``noise_models`` is the per-terminal ``(sigma, shift)`` table from
    :meth:`repro.core.noise.NoiseModel.terminal_params` (aligned with
    ``TERMINALS``); ``None`` emits an all-zeros table (unit factors).
    The table is inert unless replay opts in with ``noise=NoiseConfig``.
    """
    axis_sizes = dict(axis_sizes or {})
    L: list[str] = []
    w = L.append

    w(f'"""Auto-generated performance proxy ({name}).')
    w("")
    w("Synthesized by repro.core (Siesta-JAX): the collective skeleton is a")
    w("lossless replay of the traced program; compute segments are QP-fitted")
    w("block combinations.  Grammar-compiled flavor: the tables below ARE the")
    w("merged grammar; repro.core.progtable lowers them to rolled scan/switch")
    w("nests sized O(grammar).  Do not edit."  '"""')
    w("from repro.core.progtable import ProgramTable as _ProgramTable")
    w("from repro.core.progtable import expand_symbols as _expand_symbols")
    w("")
    w("CODEGEN = 'table'")
    w(f"N_RANKS = {merged.n_ranks}")
    w(f"AXIS_SIZES = {dict(axis_sizes)!r}")

    bufs = _comm_buffers(merged)
    w("COMM_BUFFERS = {")
    for (shape, dtype), bname in bufs.items():
        w(f"    {bname!r}: ({shape!r}, {dtype!r}),")
    w("}")
    w("ALL = frozenset(range(N_RANKS))")
    w("")

    # -- terminal descriptors --------------------------------------------------
    w("#: terminal descriptors, indexed by global terminal id; comm terminals")
    w("#: keep their exact traced parameters (lossless collective skeleton)")
    w("TERMINALS = (")
    for gid, ev in enumerate(merged.table.events):
        if is_comm(ev):
            bname = bufs[(ev.shape, ev.dtype)]
            w(f"    # t{gid}: {ev.kind} {ev.dtype}{list(ev.shape)} over {ev.axes}")
            w(f"    ('comm', {bname!r}, dict(kind={ev.kind!r}, "
              f"axes={ev.axes!r}, detail={ev.detail!r}, "
              f"shape={ev.shape!r}, dtype={ev.dtype!r})),")
        else:
            combo = combos.get(gid)
            if combo is None:
                raise KeyError(f"no block combo for compute terminal {gid}")
            x, unroll = combo
            w(f"    # t{gid}: MPI_Compute proxy, cluster {ev.cluster_id}")
            w(f"    ('compute', {tuple(int(v) for v in x)!r}, {int(unroll)}),")
    w(")")
    w("")
    w(_noise_models_block(merged, noise_models))
    w("")

    # -- rule bodies (children before parents, for readability) ---------------
    w("#: non-terminal bodies as (kind, ref, exp) symbol tuples")
    w("RULES = {")
    for rid in merged.rule_topo_order():
        body = tuple((k, int(r), int(e)) for k, r, e in merged.rules[rid])
        w(f"    {rid}: {body!r},")
    w("}")
    w("")

    # -- cluster / guard metadata (program_signature support) ------------------
    runs_per_cluster = _main_runs(merged)
    guards_meta: list[list[str]] = []
    cluster_runs: list[list[frozenset | None]] = []
    for runs, cranks in zip(runs_per_cluster, merged.cluster_ranks):
        guards_meta.append(["None" if rs >= cranks
                            else _fmt_rankset(rs, merged.n_ranks)
                            for rs, _ in runs])
        cluster_runs.append([None if rs >= cranks else rs for rs, _ in runs])
    w("CLUSTER_RANKS = (")
    for cr in merged.cluster_ranks:
        w(f"    {_fmt_rankset(cr, merged.n_ranks)},")
    w(")")
    w("_GUARDS = (")
    for meta in guards_meta:
        w("    (" + ", ".join(meta) + ("," if len(meta) == 1 else "") + "),")
    w(")")
    w("")

    # -- signature-group metadata (batched replay, §3.3) -----------------------
    # Ranks sharing a control-flow signature execute byte-identical programs,
    # so the replay engine can stack their states and run one compiled
    # executable for the whole group.  Each group carries a device-count
    # hint (see codegen_reference for the unrolled twin of this block) and —
    # table flavor only — its flattened guard-resolved symbol sequence in
    # GROUP_PROGRAMS, which ProgramTable lowers to one rolled executable.
    sig_groups = compute_signature_groups(merged.cluster_ranks, cluster_runs,
                                          merged.n_ranks)
    run_axes = [[_syms_comm_axes(syms, merged.rules, merged.table)
                 for _, syms in runs] for runs in runs_per_cluster]
    w("#: (signature, ranks, device_hint) triples; every rank appears in")
    w("#: exactly one group.")
    w("SIGNATURE_GROUPS = (")
    for sig, ranks in sig_groups:
        hint = group_device_hint(sig, run_axes, axis_sizes, count_scale)
        w(f"    ({sig!r}, {_fmt_ranktuple(ranks)}, {hint}),")
    w(")")
    w("#: GROUP_PROGRAMS[gi]: signature group gi's flattened symbol sequence")
    w("GROUP_PROGRAMS = (")
    for sig, _ranks in sig_groups:
        prog: list[tuple] = []
        for ci, run_ids in sig:
            for i in run_ids:
                prog.extend((k, int(r), int(e))
                            for k, r, e in runs_per_cluster[ci][i][1])
        w(f"    {tuple(prog)!r},")
    w(")")
    w("")
    w("_PT = _ProgramTable(TERMINALS, RULES, GROUP_PROGRAMS, "
      "noise=NOISE_MODELS)")
    w("_GROUP_INDEX = {r: gi for gi, g in enumerate(SIGNATURE_GROUPS)")
    w("                for r in g[1]}")
    w("")
    w(textwrap.dedent("""\
        def run_rank(st, comm, rank):
            \"\"\"Execute rank ``rank``'s proxy program (grammar-compiled).\"\"\"
            return _PT.run(_GROUP_INDEX[rank], st, comm)


        def expand_rank_ids(rank):
            \"\"\"Terminal-id stream rank ``rank`` replays (symbolic, no
            execution) — the lossless-expansion oracle of this module.\"\"\"
            return _expand_symbols(GROUP_PROGRAMS[_GROUP_INDEX[rank]], RULES)


        def program_signature(rank):
            \"\"\"Hashable per-rank control-flow signature (jit dedupe key).\"\"\"
            sig = []
            for ci, (ranks, guards) in enumerate(zip(CLUSTER_RANKS, _GUARDS)):
                if rank in ranks:
                    sig.append((ci, tuple(i for i, g in enumerate(guards)
                                          if g is None or rank in g)))
            return tuple(sig)
    """))
    return "\n".join(L)


def _noise_models_block(merged: MergedProgram,
                        noise_models: Sequence[tuple[float, float]] | None,
                        ) -> str:
    """``NOISE_MODELS`` table source, shared by both codegen flavors.

    One ``(sigma, shift)`` float pair per terminal, aligned with the
    terminal table; ``repr`` floats round-trip exactly, which the noise
    property suite pins.  All-zeros (unit factors) when no model was
    calibrated, so pre-noise pipelines emit a well-formed table too.
    """
    events = merged.table.events
    if noise_models is None:
        noise_models = ((0.0, 0.0),) * len(events)
    if len(noise_models) != len(events):
        raise ValueError("noise_models length does not match terminal table: "
                         f"{len(noise_models)} vs {len(events)}")
    L = ["#: per-terminal calibrated (sigma, shift) noise params — mean-one",
         "#: multiplicative factors lowered by repro.core.noise; inert unless",
         "#: replay opts in (ProxyProgram.*(noise=NoiseConfig(...)))",
         "NOISE_MODELS = ("]
    for gid, (sigma, shift) in enumerate(noise_models):
        L.append(f"    ({float(sigma)!r}, {float(shift)!r}),  # t{gid}")
    L.append(")")
    return "\n".join(L)


def _fmt_ranktuple(s: Sequence[int]) -> str:
    """Compact ordered-tuple literal: arithmetic progressions (the common
    SPMD group shape) render as ``tuple(range(...))`` so a thousand-rank
    group costs O(1) generated source, not O(n)."""
    s = list(s)
    if len(s) >= 3:
        step = s[1] - s[0]
        if step > 0 and all(b - a == step for a, b in zip(s, s[1:])):
            return (f"tuple(range({s[0]}, {s[-1] + 1}))" if step == 1
                    else f"tuple(range({s[0]}, {s[-1] + 1}, {step}))")
    return repr(tuple(s))


def _syms_comm_axes(syms: Sequence[tuple], rules: Mapping[int, list],
                    table) -> frozenset:
    """Mesh axes touched by the comm terminals reachable from ``syms``
    (transitively through non-terminal references)."""
    axes: set[str] = set()
    seen: set[int] = set()

    def visit_rule(rid: int) -> None:
        if rid in seen:
            return
        seen.add(rid)
        for kind, ref, _ in rules[rid]:
            if kind == "t":
                visit_term(ref)
            else:
                visit_rule(ref)

    def visit_term(gid: int) -> None:
        ev = table.events[gid]
        if is_comm(ev):
            axes.update(ev.axes)

    for kind, ref, _ in syms:
        if kind == "t":
            visit_term(ref)
        else:
            visit_rule(ref)
    return frozenset(axes)


def group_device_hint(sig: tuple, cluster_run_axes: Sequence[Sequence[frozenset]],
                      axis_sizes: Mapping[str, int],
                      count_scale: float = 1.0) -> int:
    """Devices that fully reproduce the collective span of a signature group:
    the product of the traced sizes of every mesh axis the group's comm
    terminals touch (1 for comm-free groups, or when an axis size is
    unknown).

    ``count_scale`` < 1 scales the hint down proportionally (floor 1): a
    time-dilated proxy replays 1/count_scale of the traced work, so tiny
    groups should share sub-meshes instead of idling devices sized for the
    full span (the sweep scheduler packs unit-hint groups together — see
    :func:`repro.core.replay.plan_mesh_sweep`)."""
    axes: set[str] = set()
    for ci, run_ids in sig:
        for i in run_ids:
            axes |= cluster_run_axes[ci][i]
    hint = 1
    for a in sorted(axes):
        hint *= max(int(axis_sizes.get(a, 1)), 1)
    hint = max(hint, 1)
    if count_scale < 1.0:
        hint = max(1, int(round(hint * count_scale)))
    return hint


def compute_signature_groups(cluster_ranks: Sequence[frozenset],
                             cluster_runs: Sequence[Sequence[frozenset | None]],
                             n_ranks: int,
                             ) -> list[tuple[tuple, list[int]]]:
    """Group ranks by control-flow signature (mirrors ``program_signature``).

    A rank's signature is the tuple of ``(cluster_id, matched_guard_runs)``
    over the clusters containing it — the exact per-rank trace key of the
    generated module.  Groups preserve rank order; signatures are ordered by
    first rank seen, so output is deterministic.
    """
    groups: dict[tuple, list[int]] = {}
    for rank in range(n_ranks):
        sig = []
        for ci, (cranks, runs) in enumerate(zip(cluster_ranks, cluster_runs)):
            if rank in cranks:
                sig.append((ci, tuple(i for i, rs in enumerate(runs)
                                      if rs is None or rank in rs)))
        groups.setdefault(tuple(sig), []).append(rank)
    return list(groups.items())


def _topo_order(rules: dict[int, list]) -> list[int]:
    """Children-first ordering of non-terminal definitions."""
    seen: set[int] = set()
    out: list[int] = []

    def visit(rid: int):
        if rid in seen:
            return
        seen.add(rid)
        for kind, ref, _ in rules[rid]:
            if kind == "r":
                visit(ref)
        out.append(rid)

    for rid in sorted(rules):
        visit(rid)
    return out
