"""Proxy replay engine + fidelity measurement (paper §3.3).

``rep`` is the run-length replay primitive used by generated code: small
exponents unroll (cheap trace), large exponents become ``lax.fori_loop`` so
a loop that executed 10^6 times costs O(1) code and O(1) trace — mirroring
the grammar's a^i symbols.

:class:`ProxyProgram` wraps a generated module:
  * ``run_local(rank)`` executes the proxy on this host (LocalSim comm),
    jit-compiling once per distinct control-flow signature;
  * ``rank_metrics(rank)`` re-traces the generated code with the *same*
    jaxpr cost walker used on the original program — the measurement behind
    the paper's Table 3 relative-error columns;
  * ``fidelity(original)`` computes δ̄ = mean_{m,p} |A-B|/A (paper eq. 8).
"""
from __future__ import annotations

import dataclasses
import importlib.util
import sys
import tempfile
import time
from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import blocks
from repro.core.events import Event, METRIC_NAMES, N_METRICS, is_comm
from repro.core.tracer import trace_fn
from repro.sharding.collectives import LocalSim

_UNROLL_LIMIT = 4


def rep(fn, n: int, st: dict, comm) -> dict:
    """Repeat ``fn`` n times: unrolled when small, ``fori_loop`` otherwise."""
    if n <= _UNROLL_LIMIT:
        for _ in range(n):
            st = fn(st, comm)
        return st
    return lax.fori_loop(0, n, lambda i, s: fn(s, comm), st)


def load_module(source: str, name: str = "generated_proxy",
                out_dir: str | Path | None = None):
    """Write generated source to a file and import it as a module."""
    out_dir = Path(out_dir) if out_dir else Path(tempfile.mkdtemp(prefix="proxy_"))
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{name}.py"
    path.write_text(source)
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    mod.__proxy_path__ = str(path)
    return mod


def init_replay_state(module, seed: int = 0) -> dict:
    """Block state + the generated module's comm buffer pool."""
    st = blocks.init_state(seed)
    for bname, (shape, dtype) in module.COMM_BUFFERS.items():
        st[bname] = jnp.full(shape, 0.5, dtype=dtype)
    return st


@dataclasses.dataclass
class FidelityReport:
    """Per-(metric, rank) relative errors (paper Table 3 / Fig. 4)."""
    delta: np.ndarray          # (n_metrics, n_ranks)
    comm_lossless: bool        # event-id sequences reproduced exactly
    mean: float                # δ̄, paper eq. 8

    def heatmap_csv(self) -> str:
        lines = ["metric," + ",".join(f"rank{p}" for p in range(self.delta.shape[1]))]
        for m, name in enumerate(METRIC_NAMES):
            lines.append(name + "," + ",".join(f"{v:.4f}" for v in self.delta[m]))
        return "\n".join(lines)


class ProxyProgram:
    """A synthesized proxy-app: source + module + replay/fidelity methods."""

    def __init__(self, source: str, module, merged, combos,
                 axis_sizes: dict[str, int] | None = None):
        self.source = source
        self.module = module
        self.merged = merged
        self.combos = combos
        self.axis_sizes = dict(axis_sizes or {})
        self._compiled: dict = {}

    # -- execution -------------------------------------------------------------

    def _fn_for_rank(self, rank: int, comm):
        sig = self.module.program_signature(rank)
        key = (sig, id(comm))
        if key not in self._compiled:
            mod = self.module
            self._compiled[key] = jax.jit(
                lambda st: mod.run_rank(st, comm, rank))
        return self._compiled[key]

    def run_local(self, ranks: Sequence[int] | None = None, seed: int = 0,
                  comm=None) -> dict:
        """Execute ranks sequentially on this host; returns final state of
        the last rank (values are meaningless — this is a performance proxy)."""
        comm = comm or LocalSim()
        ranks = range(self.merged.n_ranks) if ranks is None else ranks
        st = init_replay_state(self.module, seed)
        out = st
        for r in ranks:
            out = self._fn_for_rank(r, comm)(st)
        jax.block_until_ready(out)
        return out

    def time_local(self, rank: int = 0, iters: int = 1, seed: int = 0) -> float:
        """Wall-clock seconds of one rank's replay (compiled, warm)."""
        comm = LocalSim()
        fn = self._fn_for_rank(rank, comm)
        st = init_replay_state(self.module, seed)
        jax.block_until_ready(fn(st))  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(st))
        return (time.perf_counter() - t0) / iters

    # -- measurement -------------------------------------------------------------

    def rank_metrics(self, rank: int) -> np.ndarray:
        """Walker-measured 6-metric total of this rank's generated program."""
        st = jax.eval_shape(lambda: init_replay_state(self.module))
        comm = LocalSim()
        tr = trace_fn(lambda s: self.module.run_rank(s, comm, rank), st)
        return tr.total_compute()

    def expand_rank_ids(self, rank: int) -> list[int]:
        return self.merged.expand_rank(rank)

    def fidelity(self, original_rank_traces: Sequence[Sequence[Event]],
                 original_rank_keys: Sequence[Sequence[str]] | None = None,
                 sample_ranks: int | None = None) -> FidelityReport:
        """Compare proxy vs original per rank (paper §3.3.1).

        Compute metrics: walker totals of generated code vs the original
        trace's compute totals.  Communication: the merged grammar must
        expand to the original event *key* sequence exactly (losslessness;
        keys, not local ids — heterogeneous ranks intern in different
        orders).
        """
        n_ranks = len(original_rank_traces)
        ranks = list(range(n_ranks))
        if sample_ranks and n_ranks > sample_ranks:
            step = max(n_ranks // sample_ranks, 1)
            ranks = ranks[::step][:sample_ranks]
        lossless = True
        if original_rank_keys is not None:
            for r in range(n_ranks):
                got = [self.merged.table[i].key()
                       for i in self.expand_rank_ids(r)]
                if list(original_rank_keys[r]) != got:
                    lossless = False
                    break
        delta = np.zeros((N_METRICS, len(ranks)))
        for col, r in enumerate(ranks):
            a = np.zeros(N_METRICS)
            for ev in original_rank_traces[r]:
                if not is_comm(ev):
                    a += ev.vector
            b = self.rank_metrics(r)
            delta[:, col] = np.abs(a - b) / np.maximum(np.abs(a), 1e-30)
            delta[a <= 0, col] = 0.0  # metric absent in original and (near) proxy
        return FidelityReport(delta=delta, comm_lossless=lossless,
                              mean=float(delta.mean()))
