"""Proxy replay engine + fidelity measurement (paper §3.3).

``rep`` is the run-length replay primitive used by generated code: small
exponents unroll (cheap trace), large exponents become ``lax.fori_loop`` so
a loop that executed 10^6 times costs O(1) code and O(1) trace — mirroring
the grammar's a^i symbols.

:class:`ProxyProgram` wraps a generated module:

  * ``run_local(rank)`` executes ranks one at a time on this host (LocalSim
    comm), jit-compiling once per distinct control-flow signature;
  * ``run_all(ranks)`` is the **batched multi-rank engine**: ranks are
    grouped by control-flow signature (the generated module precomputes
    ``SIGNATURE_GROUPS``), per-rank states are stacked along a leading rank
    axis, and one ``vmap``-ed compiled executable replays a whole group at
    once — one trace + one dispatch per group instead of per rank;
  * ``rank_metrics(rank)`` re-traces the generated code with the *same*
    jaxpr cost walker used on the original program — the measurement behind
    the paper's Table 3 relative-error columns.  Results are cached per
    (signature, state shapes): ranks in a group are byte-identical programs,
    so one walker trace covers them all;
  * ``fidelity(original)`` computes δ̄ = mean_{m,p} |A-B|/A (paper eq. 8),
    vectorized across all ranks in one pass.

Compile caching: every compiled executable (per-rank and batched) is keyed
by (signature, comm backend, batch size, state shapes) and kept on the
instance, so repeated ``run_all`` / ``fidelity`` / ``rank_metrics`` calls
never re-trace.  ``cache_stats()`` exposes trace/hit counters for tests and
benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import sys
import tempfile
import time
from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat  # noqa: F401  (registers vmap rules on old JAX)
from repro.core import blocks
from repro.core import proxy_search
from repro.core.events import Event, METRIC_NAMES, N_METRICS, is_comm
from repro.core.tracer import trace_fn
from repro.sharding.collectives import LocalSim

_UNROLL_LIMIT = 4


def rep(fn, n: int, st: dict, comm) -> dict:
    """Repeat ``fn`` n times: unrolled when small, ``fori_loop`` otherwise."""
    if n <= _UNROLL_LIMIT:
        for _ in range(n):
            st = fn(st, comm)
        return st
    return lax.fori_loop(0, n, lambda i, s: fn(s, comm), st)


def load_module(source: str, name: str = "generated_proxy",
                out_dir: str | Path | None = None):
    """Write generated source to a file and import it as a module."""
    out_dir = Path(out_dir) if out_dir else Path(tempfile.mkdtemp(prefix="proxy_"))
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{name}.py"
    path.write_text(source)
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    mod.__proxy_path__ = str(path)
    return mod


def init_replay_state(module, seed: int = 0) -> dict:
    """Block state + the generated module's comm buffer pool."""
    st = blocks.init_state(seed)
    for bname, (shape, dtype) in module.COMM_BUFFERS.items():
        st[bname] = jnp.full(shape, 0.5, dtype=dtype)
    return st


@dataclasses.dataclass
class FidelityReport:
    """Per-(metric, rank) relative errors (paper Table 3 / Fig. 4)."""
    delta: np.ndarray          # (n_metrics, n_ranks)
    comm_lossless: bool        # event-id sequences reproduced exactly
    mean: float                # δ̄, paper eq. 8

    def heatmap_csv(self) -> str:
        lines = ["metric," + ",".join(f"rank{p}" for p in range(self.delta.shape[1]))]
        for m, name in enumerate(METRIC_NAMES):
            lines.append(name + "," + ",".join(f"{v:.4f}" for v in self.delta[m]))
        return "\n".join(lines)


class ProxyProgram:
    """A synthesized proxy-app: source + module + replay/fidelity methods."""

    def __init__(self, source: str, module, merged, combos,
                 axis_sizes: dict[str, int] | None = None):
        self.source = source
        self.module = module
        self.merged = merged
        self.combos = combos
        self.axis_sizes = dict(axis_sizes or {})
        self._compiled: dict = {}          # (sig, comm, shapes) -> per-rank fn
        self._compiled_batched: dict = {}  # (sig, comm, n, shapes) -> vmapped fn
        self._metrics_cache: dict = {}     # (sig, shapes) -> np.ndarray
        self._sig_by_rank: dict | None = None
        self._shapes_key_cache = None      # filled by _shapes_key()
        self._counters = {"jit_traces": 0, "metric_traces": 0,
                          "batch_cache_hits": 0, "batch_cache_misses": 0}

    # -- signature grouping ----------------------------------------------------

    def signature_of(self, rank: int):
        """Control-flow signature of ``rank`` (hashable jit/cache key)."""
        if self._sig_by_rank is None:
            groups = getattr(self.module, "SIGNATURE_GROUPS", None) or ()
            self._sig_by_rank = {r: sig for sig, ranks in groups for r in ranks}
        sig = self._sig_by_rank.get(rank)
        if sig is None:
            sig = self.module.program_signature(rank)
            self._sig_by_rank[rank] = sig
        return sig

    def _validate_ranks(self, ranks: Sequence[int]) -> None:
        bad = [r for r in ranks if not 0 <= r < self.merged.n_ranks]
        if bad:
            raise ValueError(f"ranks out of range: {bad} "
                             f"(proxy has {self.merged.n_ranks} ranks)")

    def signature_groups(self, ranks: Sequence[int] | None = None,
                         ) -> list[tuple[tuple, list[int]]]:
        """(signature, ranks) pairs covering ``ranks`` (default: all).

        Uses the generation-time ``SIGNATURE_GROUPS`` constant when the
        module has one; falls back to probing ``program_signature`` so
        pre-metadata modules keep working.
        """
        groups = getattr(self.module, "SIGNATURE_GROUPS", None)
        if groups is None:
            by_sig: dict[tuple, list[int]] = {}
            all_ranks = range(self.merged.n_ranks) if ranks is None else ranks
            for r in all_ranks:
                by_sig.setdefault(self.module.program_signature(r), []).append(r)
            return list(by_sig.items())
        if ranks is None:
            return [(sig, list(rs)) for sig, rs in groups]
        want = set(ranks)
        out = [(sig, [r for r in rs if r in want]) for sig, rs in groups]
        out = [(sig, rs) for sig, rs in out if rs]
        missing = want - {r for _, rs in out for r in rs}
        if missing:
            raise ValueError(
                f"ranks not in any signature group: {sorted(missing)} "
                f"(proxy has {self.merged.n_ranks} ranks)")
        return out

    def _shapes_key(self) -> tuple:
        """State-shape fingerprint: part of every compile-cache key.

        Constant for this instance today (block geometry and COMM_BUFFERS
        are module-level), but kept in the key as the contract guard for
        the §3.3 cache spec — (signature, block shapes) — so a future
        configurable block geometry invalidates instead of aliasing."""
        if self._shapes_key_cache is None:
            st = jax.eval_shape(lambda: init_replay_state(self.module))
            self._shapes_key_cache = tuple(
                sorted((k, tuple(v.shape), str(v.dtype)) for k, v in st.items()))
        return self._shapes_key_cache

    # -- execution -------------------------------------------------------------

    @staticmethod
    def _comm_key(comm):
        """Compile-cache component for the comm backend.  A plain LocalSim
        is stateless at execution time, so all instances share compiled
        programs — the fresh ``LocalSim()`` each ``run_local``/``fidelity``
        call constructs must not force a re-trace.  Anything else (DeviceComm,
        counting subclasses) is keyed by identity."""
        return LocalSim if type(comm) is LocalSim else id(comm)

    def _fn_for_rank(self, rank: int, comm):
        sig = self.signature_of(rank)
        key = (sig, self._comm_key(comm), self._shapes_key())
        if key not in self._compiled:
            mod = self.module
            counters = self._counters

            def traced(st):
                counters["jit_traces"] += 1   # trace-time side effect
                return mod.run_rank(st, comm, rank)

            self._compiled[key] = jax.jit(traced)
        return self._compiled[key]

    def _fn_for_group(self, sig, rep_rank: int, n: int, comm):
        """Compiled executable replaying ``n`` stacked ranks of one group."""
        key = (sig, self._comm_key(comm), n, self._shapes_key())
        fn = self._compiled_batched.get(key)
        if fn is None:
            self._counters["batch_cache_misses"] += 1
            mod = self.module
            counters = self._counters

            def traced(stacked):
                counters["jit_traces"] += 1   # trace-time side effect
                return jax.vmap(lambda st: mod.run_rank(st, comm, rep_rank))(stacked)

            fn = jax.jit(traced)
            self._compiled_batched[key] = fn
        else:
            self._counters["batch_cache_hits"] += 1
        return fn

    def run_local(self, ranks: Sequence[int] | None = None, seed: int = 0,
                  comm=None) -> dict:
        """Execute ranks sequentially on this host; returns final state of
        the last rank (values are meaningless — this is a performance proxy)."""
        comm = comm or LocalSim()
        if ranks is None:
            ranks = range(self.merged.n_ranks)
        else:
            self._validate_ranks(ranks)
        st = init_replay_state(self.module, seed)
        out = st
        for r in ranks:
            out = self._fn_for_rank(r, comm)(st)
        jax.block_until_ready(out)
        return out

    def run_all(self, ranks: Sequence[int] | None = None, seed: int = 0,
                comm=None, batched: bool = True,
                per_rank_seeds: bool = False) -> dict[int, dict]:
        """Replay every rank; returns ``{rank: final state}``.

        ``batched=True`` (default) replays one signature group per compiled
        call instead of one rank at a time:

        * with the default shared seed, every rank of a group is a
          byte-identical execution (same program, same initial state — the
          SPMD redundancy that made the grammars mergeable in the first
          place), so the group's program runs **once** and the result is
          shared by all its ranks;
        * with ``per_rank_seeds=True`` each rank gets a distinct initial
          state (``seed + rank``); states are stacked on a leading rank
          axis and the group program is ``vmap``-ed over it — still one
          trace + one dispatch per group.

        ``batched=False`` is the per-rank baseline path (identical results;
        benchmarked against in benchmarks/replay_time.py).
        """
        comm = comm or LocalSim()
        if ranks is not None:
            self._validate_ranks(ranks)
        out = {}
        if not batched:
            st = None if per_rank_seeds else init_replay_state(self.module, seed)
            for r in (range(self.merged.n_ranks) if ranks is None else ranks):
                out[r] = self._fn_for_rank(r, comm)(
                    init_replay_state(self.module, seed + r)
                    if per_rank_seeds else st)
            for v in out.values():
                jax.block_until_ready(v)
            return out
        for fn, arg, grp in self._group_work(ranks, seed, comm, per_rank_seeds):
            res = fn(arg)
            if per_rank_seeds:
                for i, r in enumerate(grp):
                    out[r] = jax.tree.map(lambda a, i=i: a[i], res)
            else:
                for r in grp:   # identical input + program -> identical output
                    out[r] = dict(res)      # fresh dict: don't alias ranks
        for v in out.values():
            jax.block_until_ready(v)
        return out

    def _group_work(self, ranks, seed: int, comm, per_rank_seeds: bool,
                    ) -> list[tuple]:
        """One ``(compiled_fn, input_state, group_ranks)`` unit per signature
        group — the shared work plan of :meth:`run_all` and :meth:`time_all`."""
        st = None if per_rank_seeds else init_replay_state(self.module, seed)
        work = []
        for sig, grp in self.signature_groups(ranks):
            if per_rank_seeds:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[init_replay_state(self.module, seed + r) for r in grp])
                work.append((self._fn_for_group(sig, grp[0], len(grp), comm),
                             stacked, grp))
            else:
                work.append((self._fn_for_rank(grp[0], comm), st, grp))
        return work

    def time_local(self, rank: int = 0, iters: int = 1, seed: int = 0) -> float:
        """Wall-clock seconds of one rank's replay (compiled, warm)."""
        self._validate_ranks([rank])
        comm = LocalSim()
        fn = self._fn_for_rank(rank, comm)
        st = init_replay_state(self.module, seed)
        jax.block_until_ready(fn(st))  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(st))
        return (time.perf_counter() - t0) / iters

    def time_all(self, ranks: Sequence[int] | None = None, iters: int = 1,
                 seed: int = 0, batched: bool = True,
                 per_rank_seeds: bool = False) -> float:
        """Warm wall-clock seconds of one full multi-rank replay sweep.

        Mirrors :meth:`run_all`'s three modes: per-rank baseline
        (``batched=False``), group-deduplicated (default), and group-vmapped
        (``per_rank_seeds=True``).
        """
        comm = LocalSim()
        ranks = list(range(self.merged.n_ranks) if ranks is None else ranks)
        self._validate_ranks(ranks)
        if batched:
            work = [(fn, arg) for fn, arg, _ in
                    self._group_work(ranks, seed, comm, per_rank_seeds)]
        else:
            st = None if per_rank_seeds else init_replay_state(self.module, seed)
            work = [(self._fn_for_rank(r, comm),
                     init_replay_state(self.module, seed + r)
                     if per_rank_seeds else st) for r in ranks]

        def sweep():
            out = None
            for fn, arg in work:
                out = fn(arg)
            jax.block_until_ready(out)

        sweep()  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            sweep()
        return (time.perf_counter() - t0) / iters

    def cache_stats(self) -> dict[str, int]:
        """Trace/cache counters (jit_traces counts actual re-traces)."""
        return dict(self._counters,
                    compiled_per_rank=len(self._compiled),
                    compiled_batched=len(self._compiled_batched),
                    cached_metric_groups=len(self._metrics_cache))

    # -- measurement -------------------------------------------------------------

    def rank_metrics(self, rank: int, use_cache: bool = True) -> np.ndarray:
        """Walker-measured 6-metric total of this rank's generated program.

        Cached per (signature, state shapes): ranks sharing a control-flow
        signature run byte-identical programs, so repeated ``fidelity`` /
        ``rank_metrics`` calls never re-trace a group already measured.
        """
        key = (self.signature_of(rank), self._shapes_key())
        if use_cache and key in self._metrics_cache:
            return self._metrics_cache[key]
        st = jax.eval_shape(lambda: init_replay_state(self.module))
        comm = LocalSim()
        self._counters["metric_traces"] += 1
        tr = trace_fn(lambda s: self.module.run_rank(s, comm, rank), st)
        out = tr.total_compute()
        self._metrics_cache[key] = out
        return out

    def expand_rank_ids(self, rank: int) -> list[int]:
        return self.merged.expand_rank(rank)

    def fidelity(self, original_rank_traces: Sequence[Sequence[Event]],
                 original_rank_keys: Sequence[Sequence[str]] | None = None,
                 sample_ranks: int | None = None,
                 batched: bool = True) -> FidelityReport:
        """Compare proxy vs original per rank (paper §3.3.1).

        Compute metrics: walker totals of generated code vs the original
        trace's compute totals, assembled for all sampled ranks in one
        vectorized pass (proxy totals come from the per-signature metrics
        cache — one walker trace per group, not per rank).  Communication:
        the merged grammar must expand to the original event *key* sequence
        exactly (losslessness; keys, not local ids — heterogeneous ranks
        intern in different orders).  ``batched=False`` forces the original
        per-rank/per-trace path (the parity baseline in tests).
        """
        n_ranks = len(original_rank_traces)
        ranks = list(range(n_ranks))
        if sample_ranks and n_ranks > sample_ranks:
            step = max(n_ranks // sample_ranks, 1)
            ranks = ranks[::step][:sample_ranks]
        lossless = True
        if original_rank_keys is not None:
            for r in range(n_ranks):
                got = [self.merged.table[i].key()
                       for i in self.expand_rank_ids(r)]
                if list(original_rank_keys[r]) != got:
                    lossless = False
                    break
        a = np.zeros((N_METRICS, len(ranks)))
        for col, r in enumerate(ranks):
            for ev in original_rank_traces[r]:
                if not is_comm(ev):
                    a[:, col] += ev.vector
        b = np.stack([self.rank_metrics(r, use_cache=batched) for r in ranks],
                     axis=1)
        delta = proxy_search.rel_error_matrix(a, b)
        return FidelityReport(delta=delta, comm_lossless=lossless,
                              mean=float(delta.mean()))
