"""Proxy replay engine + fidelity measurement (paper §3.3).

``rep`` is the run-length replay primitive used by generated code: small
exponents unroll (cheap trace), large exponents become ``lax.fori_loop`` so
a loop that executed 10^6 times costs O(1) code and O(1) trace — mirroring
the grammar's a^i symbols.

:class:`ProxyProgram` wraps a generated module:

  * ``run_local(rank)`` executes ranks one at a time on this host (LocalSim
    comm), jit-compiling once per distinct control-flow signature;
  * ``run_all(ranks)`` is the **batched multi-rank engine**: ranks are
    grouped by control-flow signature (the generated module precomputes
    ``SIGNATURE_GROUPS``), per-rank states are stacked along a leading rank
    axis, and one ``vmap``-ed compiled executable replays a whole group at
    once — one trace + one dispatch per group instead of per rank;
  * ``run_all(ranks, mesh=...)`` is the **mesh-sharded sweep**: signature
    groups are placed on disjoint device subsets of a mesh
    (:func:`plan_mesh_sweep`, driven by the per-group device hints the
    generated module carries), each group replays its real collectives via
    ``DeviceComm`` inside a single ``shard_map`` dispatch with the rank axis
    ``vmap``-folded through them, and groups are dispatched asynchronously;
  * ``rank_metrics(rank)`` re-traces the generated code with the *same*
    jaxpr cost walker used on the original program — the measurement behind
    the paper's Table 3 relative-error columns.  Results are cached per
    (signature, state shapes): ranks in a group are byte-identical programs,
    so one walker trace covers them all;
  * ``fidelity(original)`` computes δ̄ = mean_{m,p} |A-B|/A (paper eq. 8),
    vectorized across all ranks in one pass.

Compile caching: every compiled executable (per-rank and batched) is keyed
by (signature, comm backend, batch size, state shapes) and kept on the
instance, so repeated ``run_all`` / ``fidelity`` / ``rank_metrics`` calls
never re-trace.  ``cache_stats()`` exposes trace/hit counters for tests and
benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import math
import sys
import tempfile
import time
from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec

from repro import compat  # noqa: F401  (registers vmap rules on old JAX)
from repro.core import blocks
from repro.core import noise as noise_mod
from repro.core import proxy_search
from repro.core.events import Event, METRIC_NAMES, N_METRICS, is_comm
from repro.core.noise import (FidelityDistribution, NoiseConfig,  # noqa: F401
                              parse_fidelity_csv)
from repro.core.tracer import trace_fn
from repro.sharding.collectives import DeviceComm, LocalSim

#: Exponents up to this unroll at trace time; above it ``rep`` emits a
#: rolled ``fori_loop`` (one body trace regardless of n).  Shared with the
#: program-table lowering in :mod:`repro.core.progtable`, so compiled and
#: unrolled modules make identical unroll-vs-loop decisions.
REP_UNROLL_THRESHOLD = 4


def rep(fn, n: int, st: dict, comm) -> dict:
    """Repeat ``fn`` n times: unrolled when small, ``fori_loop`` otherwise."""
    if n <= REP_UNROLL_THRESHOLD:
        for _ in range(n):
            st = fn(st, comm)
        return st
    return lax.fori_loop(0, n, lambda i, s: fn(s, comm), st)


def load_saved_module(path, name: str | None = None):
    """Re-import a previously generated proxy module from disk.

    Generated proxies are plain Python files (``module.__proxy_path__``);
    together with ``TraceStore.save``/``load`` this makes the pipeline
    fully offline: trace → store ``.npz`` → synthesize → proxy ``.py`` →
    reload and replay anywhere, no re-synthesis required."""
    path = Path(path)
    name = name or path.stem
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    mod.__proxy_path__ = str(path)
    return mod


def load_module(source: str, name: str = "generated_proxy",
                out_dir: str | Path | None = None):
    """Write generated source to a file and import it as a module."""
    out_dir = Path(out_dir) if out_dir else Path(tempfile.mkdtemp(prefix="proxy_"))
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{name}.py"
    path.write_text(source)
    return load_saved_module(path, name)


def init_replay_state(module, seed: int = 0) -> dict:
    """Block state + the generated module's comm buffer pool."""
    st = blocks.init_state(seed)
    for bname, (shape, dtype) in module.COMM_BUFFERS.items():
        st[bname] = jnp.full(shape, 0.5, dtype=dtype)
    return st


# ---------------------------------------------------------------------------
# mesh sweep scheduling (device-parallel signature-group replay)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupPlacement:
    """One signature group pinned to a mesh device subset.

    ``device_ids`` are flat indices into ``mesh.devices``; ``axis_sizes`` is
    the group's sub-mesh geometry (same axis names as the traced program,
    sizes shrunk to the subset).  Hashable: used as a compile-cache key
    component so executables are cached *per placement*."""
    sig: tuple
    ranks: tuple[int, ...]
    device_ids: tuple[int, ...]
    axis_sizes: tuple[tuple[str, int], ...]

    @property
    def n_devices(self) -> int:
        return len(self.device_ids)

    def key(self) -> tuple:
        return (self.device_ids, self.axis_sizes)


def submesh_axis_sizes(n_devices: int, axis_sizes: dict[str, int],
                       ) -> dict[str, int]:
    """Shrink a traced mesh geometry onto ``n_devices``.

    Keeps the axis names and order; each axis gets ``gcd(traced_size,
    devices_still_unassigned)`` so the product always divides ``n_devices``
    exactly and every collective still spans a nonempty axis.  A comm-free
    program (no traced axes) gets a single unit axis so ``shard_map`` has a
    mesh to run under.
    """
    out: dict[str, int] = {}
    rem = max(int(n_devices), 1)
    for a, s in axis_sizes.items():
        g = math.gcd(max(int(s), 1), rem)
        out[a] = g
        rem //= g
    if not out:
        out = {"x": 1}
    return out


def _proportional_alloc(want: Sequence[int], n_devices: int,
                        axis_sizes: dict[str, int],
                        ) -> tuple[list[int], list[int]]:
    """Hint-proportional contiguous device shares (requires
    ``len(want) <= n_devices``); returns (alloc, starts)."""
    total = sum(want)
    alloc = [min(w, max(1, (n_devices * w) // total)) for w in want]
    # bumping zero-share groups to 1 device can oversubscribe the mesh
    # (e.g. hints [100,1,1,1,1,1,1] on 8 devices); shave the largest
    # shares back until the plan fits (every group keeps >= 1)
    while sum(alloc) > n_devices:
        i = alloc.index(max(alloc))
        alloc[i] -= 1
    # hand leftovers to the groups furthest below their hint
    while sum(alloc) < n_devices:
        gaps = [w - a for w, a in zip(want, alloc)]
        if max(gaps) <= 0:
            break
        i = gaps.index(max(gaps))
        alloc[i] += 1
    # shrink each share to the largest realizable sub-mesh size (a
    # 7-device share of a 16-wide axis would otherwise collapse to 1)
    alloc = [_realizable(a, axis_sizes) for a in alloc]
    starts = []
    cur = 0
    for a in alloc:
        starts.append(cur)
        cur += a
    return alloc, starts


def plan_mesh_sweep(groups: Sequence[tuple[tuple, Sequence[int]]],
                    hints: dict[tuple, int],
                    axis_sizes: dict[str, int],
                    n_devices: int,
                    share_unit_groups: bool = False) -> list[GroupPlacement]:
    """Partition ``n_devices`` mesh devices among signature groups.

    Pure function of its inputs (deterministic; no jax state touched):

    * every group gets at least one device and never more than its hint —
      extra devices beyond the traced collective span would sit idle;
    * shares are proportional to the per-group device hints, leftovers go
      to the groups furthest below their hint;
    * device subsets are contiguous and disjoint while supply lasts; with
      more groups than devices, groups wrap round-robin onto single devices
      (dispatches then serialize per device, which is still correct);
    * each subset is trimmed to the realizable sub-mesh size
      (:func:`submesh_axis_sizes`), so the placement's geometry always
      multiplies out to exactly ``len(device_ids)``;
    * with ``share_unit_groups=True``, two or more unit-hint groups (the
      ``count_scale``-dilated tiny groups whose scaled hints collapsed to
      1) are packed onto **one shared device** instead of claiming one
      each — their dispatches serialize there while the freed devices go
      to groups still below their hint.
    """
    n_devices = max(int(n_devices), 1)
    groups = [(sig, list(rs)) for sig, rs in groups]
    if not groups:
        return []
    want = [max(int(hints.get(sig, 1)), 1) for sig, _ in groups]
    n = len(groups)
    if n >= n_devices:
        alloc = [1] * n
        starts = [i % n_devices for i in range(n)]
    else:
        unit = [i for i, w in enumerate(want) if w == 1]
        big = [i for i, w in enumerate(want) if w > 1]
        # pack only under device scarcity (demand above supply): with spare
        # devices, unit groups keep one each and run in parallel — packing
        # would serialize them for no one's benefit
        if share_unit_groups and len(unit) >= 2 and big \
                and n_devices >= 2 and sum(want) > n_devices:
            big_alloc, big_starts = _proportional_alloc(
                [want[i] for i in big], n_devices - 1, axis_sizes)
            alloc = [1] * n
            starts = [n_devices - 1] * n     # unit groups share the last dev
            for i, a, s0 in zip(big, big_alloc, big_starts):
                alloc[i] = a
                starts[i] = s0
        else:
            alloc, starts = _proportional_alloc(want, n_devices, axis_sizes)
    out = []
    for (sig, rs), a, s0 in zip(groups, alloc, starts):
        out.append(GroupPlacement(
            sig=sig, ranks=tuple(rs),
            device_ids=tuple(range(s0, s0 + a)),
            axis_sizes=tuple(submesh_axis_sizes(a, axis_sizes).items())))
    return out


def _realizable(n_devices: int, axis_sizes: dict[str, int]) -> int:
    """Largest ``v <= n_devices`` whose sub-mesh geometry multiplies out to
    exactly ``v`` (1 always qualifies)."""
    for v in range(max(int(n_devices), 1), 0, -1):
        p = 1
        for s in submesh_axis_sizes(v, axis_sizes).values():
            p *= s
        if p == v:
            return v
    return 1


@dataclasses.dataclass
class FidelityReport:
    """Per-(metric, rank) relative errors (paper Table 3 / Fig. 4)."""
    delta: np.ndarray          # (n_metrics, n_ranks)
    comm_lossless: bool        # event-id sequences reproduced exactly
    mean: float                # δ̄, paper eq. 8
    mesh_checked: bool = False  # a mesh-sharded sweep executed finitely
    seed: int = 0              # replay seed provenance (deterministic: 0)
    n_replicas: int = 1        # deterministic replay is one replica

    def heatmap_csv(self) -> str:
        lines = ["metric," + ",".join(f"rank{p}" for p in range(self.delta.shape[1]))]
        for m, name in enumerate(METRIC_NAMES):
            lines.append(name + "," + ",".join(f"{v:.4f}" for v in self.delta[m]))
        return "\n".join(lines)

    def to_csv(self) -> str:
        """Heatmap CSV with seed/replica provenance headers — the same
        parseable shape as :meth:`FidelityDistribution.to_csv`, so
        downstream consumers never have to guess which replay produced a
        bare float matrix (see :func:`repro.core.noise.parse_fidelity_csv`)."""
        return (f"# seed={self.seed}\n# n_replicas={self.n_replicas}\n"
                + self.heatmap_csv())


class ProxyProgram:
    """A synthesized proxy-app: source + module + replay/fidelity methods."""

    def __init__(self, source: str, module, merged, combos,
                 axis_sizes: dict[str, int] | None = None):
        self.source = source
        self.module = module
        self.merged = merged
        self.combos = combos
        self.axis_sizes = dict(axis_sizes or {})
        self._compiled: dict = {}          # (sig, comm, shapes) -> per-rank fn
        self._compiled_batched: dict = {}  # (sig, comm, n, shapes) -> vmapped fn
        self._metrics_cache: dict = {}     # (sig, shapes) -> np.ndarray
        self._mesh_comms: dict = {}        # placement key -> DeviceComm
        self._submeshes: dict = {}         # (mesh id, placement key) -> Mesh
        self._sig_by_rank: dict | None = None
        self._shapes_key_cache = None      # filled by _shapes_key()
        self._counters = {"jit_traces": 0, "metric_traces": 0,
                          "batch_cache_hits": 0, "batch_cache_misses": 0}

    # -- signature grouping ----------------------------------------------------

    def signature_of(self, rank: int):
        """Control-flow signature of ``rank`` (hashable jit/cache key)."""
        if self._sig_by_rank is None:
            groups = getattr(self.module, "SIGNATURE_GROUPS", None) or ()
            # entries are (sig, ranks) or (sig, ranks, device_hint)
            self._sig_by_rank = {r: g[0] for g in groups for r in g[1]}
        sig = self._sig_by_rank.get(rank)
        if sig is None:
            sig = self.module.program_signature(rank)
            self._sig_by_rank[rank] = sig
        return sig

    def _validate_ranks(self, ranks: Sequence[int]) -> None:
        bad = [r for r in ranks if not 0 <= r < self.merged.n_ranks]
        if bad:
            raise ValueError(f"ranks out of range: {bad} "
                             f"(proxy has {self.merged.n_ranks} ranks)")

    def signature_groups(self, ranks: Sequence[int] | None = None,
                         ) -> list[tuple[tuple, list[int]]]:
        """(signature, ranks) pairs covering ``ranks`` (default: all).

        Uses the generation-time ``SIGNATURE_GROUPS`` constant when the
        module has one (entries may be ``(sig, ranks)`` or
        ``(sig, ranks, device_hint)``); falls back to probing
        ``program_signature`` so pre-metadata modules keep working.
        """
        groups = getattr(self.module, "SIGNATURE_GROUPS", None)
        if groups is None:
            by_sig: dict[tuple, list[int]] = {}
            all_ranks = range(self.merged.n_ranks) if ranks is None else ranks
            for r in all_ranks:
                by_sig.setdefault(self.module.program_signature(r), []).append(r)
            return list(by_sig.items())
        if ranks is None:
            return [(g[0], list(g[1])) for g in groups]
        want = set(ranks)
        out = [(g[0], [r for r in g[1] if r in want]) for g in groups]
        out = [(sig, rs) for sig, rs in out if rs]
        missing = want - {r for _, rs in out for r in rs}
        if missing:
            raise ValueError(
                f"ranks not in any signature group: {sorted(missing)} "
                f"(proxy has {self.merged.n_ranks} ranks)")
        return out

    def _shapes_key(self) -> tuple:
        """State-shape fingerprint: part of every compile-cache key.

        Constant for this instance today (block geometry and COMM_BUFFERS
        are module-level), but kept in the key as the contract guard for
        the §3.3 cache spec — (signature, block shapes) — so a future
        configurable block geometry invalidates instead of aliasing."""
        if self._shapes_key_cache is None:
            st = jax.eval_shape(lambda: init_replay_state(self.module))
            self._shapes_key_cache = tuple(
                sorted((k, tuple(v.shape), str(v.dtype)) for k, v in st.items()))
        return self._shapes_key_cache

    # -- execution -------------------------------------------------------------

    @staticmethod
    def _comm_key(comm):
        """Compile-cache component for the comm backend.  A plain LocalSim
        is stateless at execution time, so all instances share compiled
        programs — the fresh ``LocalSim()`` each ``run_local``/``fidelity``
        call constructs must not force a re-trace.  Anything else (DeviceComm,
        counting subclasses) is keyed by identity."""
        return LocalSim if type(comm) is LocalSim else id(comm)

    def _fn_for_rank(self, rank: int, comm):
        sig = self.signature_of(rank)
        key = (sig, self._comm_key(comm), self._shapes_key())
        if key not in self._compiled:
            mod = self.module
            counters = self._counters

            def traced(st):
                counters["jit_traces"] += 1   # trace-time side effect
                return mod.run_rank(st, comm, rank)

            self._compiled[key] = jax.jit(traced)
        return self._compiled[key]

    def _fn_for_group(self, sig, rep_rank: int, n: int, comm,
                      tag: str | None = None):
        """Compiled executable replaying ``n`` stacked states of one group.

        ``tag`` disambiguates batched entries whose stacked state carries a
        different pytree structure at the same ``n`` (the noisy-replica
        states add the noise leaves) so the cache counters stay honest."""
        key = (sig, self._comm_key(comm), n, tag, self._shapes_key())
        fn = self._compiled_batched.get(key)
        if fn is None:
            self._counters["batch_cache_misses"] += 1
            mod = self.module
            counters = self._counters

            def traced(stacked):
                counters["jit_traces"] += 1   # trace-time side effect
                return jax.vmap(lambda st: mod.run_rank(st, comm, rep_rank))(stacked)

            fn = jax.jit(traced)
            self._compiled_batched[key] = fn
        else:
            self._counters["batch_cache_hits"] += 1
        return fn

    # -- mesh-sharded sweep (device-parallel signature groups) -----------------

    def group_device_hints(self) -> dict[tuple, int]:
        """Per-signature device-count hints from the generated module.

        Modules generated before the hint metadata (2-tuple groups) fall
        back to the full traced mesh size — the span every collective would
        need in the worst case."""
        default = 1
        for s in self.axis_sizes.values():
            default *= max(int(s), 1)
        out: dict[tuple, int] = {}
        for g in getattr(self.module, "SIGNATURE_GROUPS", None) or ():
            out[g[0]] = int(g[2]) if len(g) > 2 else default
        return out

    def mesh_sweep_plan(self, mesh, ranks: Sequence[int] | None = None,
                        share_unit_groups: bool = True,
                        ) -> list[GroupPlacement]:
        """Deterministic placement of signature groups onto ``mesh``'s
        devices (see :func:`plan_mesh_sweep`).  Unit-hint groups —
        typically ``count_scale``-dilated tiny groups — share one device
        by default instead of idling devices each."""
        return plan_mesh_sweep(self.signature_groups(ranks),
                               self.group_device_hints(), self.axis_sizes,
                               int(np.asarray(mesh.devices).size),
                               share_unit_groups=share_unit_groups)

    def _submesh_for(self, mesh, placement: GroupPlacement):
        devs = list(np.asarray(mesh.devices).flat)
        # keyed by the actual devices, not id(mesh): two Mesh objects over
        # the same device set share sub-meshes, and a recycled object id
        # can never resurrect a stale placement
        key = (tuple(d.id for d in devs), placement.key())
        sub = self._submeshes.get(key)
        if sub is None:
            sizes = dict(placement.axis_sizes)
            sub = compat.make_mesh(
                tuple(sizes.values()), tuple(sizes),
                devices=[devs[i] for i in placement.device_ids])
            self._submeshes[key] = sub
        return sub

    def _mesh_comm(self, placement: GroupPlacement) -> DeviceComm:
        """One DeviceComm per placement: its ``axis_sizes`` are the sub-mesh
        geometry, and reusing the instance keeps the identity-keyed compile
        cache warm across sweeps."""
        comm = self._mesh_comms.get(placement.key())
        if comm is None:
            comm = DeviceComm(dict(placement.axis_sizes))
            self._mesh_comms[placement.key()] = comm
        return comm

    def _fn_for_group_mesh(self, sig, rep_rank: int, n: int | None,
                           placement: GroupPlacement, mesh,
                           noise: bool = False):
        """Compiled ``shard_map`` executable for one placed group.

        ``n`` is the stacked rank count (``None`` = unbatched: one rank's
        state, the sequential-mesh baseline).  Cached per (signature, mesh
        devices, placement, n, state shapes) — a group moved to a different
        mesh, device subset, or sub-mesh geometry compiles afresh instead
        of aliasing.  ``noise=True`` stacks ``n`` seeded replicas instead
        of ranks: the shard_map in/out specs must then cover the extra
        noise leaves, so the entry is keyed (and traced) separately.
        """
        mesh_ids = tuple(d.id for d in np.asarray(mesh.devices).flat)
        key = (sig, "mesh", n, noise, mesh_ids, placement.key(),
               self._shapes_key())
        fn = self._compiled_batched.get(key)
        if fn is None:
            self._counters["batch_cache_misses"] += 1
            mod = self.module
            counters = self._counters
            comm = self._mesh_comm(placement)
            submesh = self._submesh_for(mesh, placement)

            def state_proto():
                st = init_replay_state(mod)
                if noise:   # spec must mirror the noise-attached pytree
                    st = noise_mod.attach(st, jax.random.PRNGKey(0))
                return st

            spec = jax.tree.map(lambda _: PartitionSpec(),
                                jax.eval_shape(state_proto))

            def traced(st):
                counters["jit_traces"] += 1   # trace-time side effect
                if n is None:
                    return mod.run_rank(st, comm, rep_rank)
                return jax.vmap(lambda s: mod.run_rank(s, comm, rep_rank))(st)

            fn = jax.jit(compat.shard_map(
                traced, mesh=submesh, in_specs=(spec,), out_specs=spec,
                check_vma=False))
            self._compiled_batched[key] = fn
        else:
            self._counters["batch_cache_hits"] += 1
        return fn

    def _noise_group_state(self, rep_rank: int, cfg: "NoiseConfig",
                           seed: int = 0) -> dict:
        """``n_replicas`` noise-attached copies of one group's initial state,
        stacked on a leading replica axis.  Replica keys derive only from
        ``(cfg.seed, group representative, replica index)`` — never from
        placement — so LocalSim and mesh replay draw identical streams."""
        base = init_replay_state(self.module, seed)
        sts = [noise_mod.attach(base,
                                noise_mod.replica_key(cfg.seed, rep_rank, j))
               for j in range(cfg.n_replicas)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *sts)

    def _group_work_mesh(self, ranks, seed: int, per_rank_seeds: bool,
                         mesh, batched: bool = True,
                         noise: "NoiseConfig | None" = None) -> list[tuple]:
        """``(fn, input_state, group_ranks, stacked)`` units for a mesh sweep.

        ``batched=True`` emits exactly one unit — one ``shard_map``
        dispatch — per signature group: the group's ranks are stacked on a
        leading axis and ``vmap``-ed through the real collectives (or, with
        a shared seed, the byte-identical program runs once and the result
        is shared).  ``batched=False`` is the sequential mesh baseline: one
        dispatch per rank on the *same* placement, so results are
        comparable bit-for-bit.  ``noise=`` stacks seeded replicas instead
        of ranks (one unit per group; ranks of a group share the replica
        results, the run-level-platform-state reading of the noise model)."""
        work = []
        for pl in self.mesh_sweep_plan(mesh, ranks):
            grp = list(pl.ranks)
            if noise is not None:
                fn = self._fn_for_group_mesh(pl.sig, grp[0], noise.n_replicas,
                                             pl, mesh, noise=True)
                work.append((fn, self._noise_group_state(grp[0], noise, seed),
                             grp, False))
            elif batched and per_rank_seeds:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[init_replay_state(self.module, seed + r) for r in grp])
                work.append((self._fn_for_group_mesh(pl.sig, grp[0], len(grp),
                                                     pl, mesh),
                             stacked, grp, True))
            elif batched:
                work.append((self._fn_for_group_mesh(pl.sig, grp[0], None,
                                                     pl, mesh),
                             init_replay_state(self.module, seed), grp, False))
            else:
                fn = self._fn_for_group_mesh(pl.sig, grp[0], None, pl, mesh)
                for r in grp:
                    st = init_replay_state(
                        self.module, seed + r if per_rank_seeds else seed)
                    work.append((fn, st, [r], False))
        return work

    def run_local(self, ranks: Sequence[int] | None = None, seed: int = 0,
                  comm=None) -> dict:
        """Execute ranks sequentially on this host; returns final state of
        the last rank (values are meaningless — this is a performance proxy)."""
        comm = comm or LocalSim()
        if ranks is None:
            ranks = range(self.merged.n_ranks)
        else:
            self._validate_ranks(ranks)
        st = init_replay_state(self.module, seed)
        out = st
        for r in ranks:
            out = self._fn_for_rank(r, comm)(st)
        jax.block_until_ready(out)
        return out

    def run_all(self, ranks: Sequence[int] | None = None, seed: int = 0,
                comm=None, batched: bool = True,
                per_rank_seeds: bool = False, mesh=None,
                noise: "NoiseConfig | None" = None) -> dict[int, dict]:
        """Replay every rank; returns ``{rank: final state}``.

        ``batched=True`` (default) replays one signature group per compiled
        call instead of one rank at a time:

        * with the default shared seed, every rank of a group is a
          byte-identical execution (same program, same initial state — the
          SPMD redundancy that made the grammars mergeable in the first
          place), so the group's program runs **once** and the result is
          shared by all its ranks.  Each rank gets its own result *dict*,
          but the leaf arrays of a group deliberately alias (one buffer, n
          references): ``jax.Array`` leaves are immutable — rebinding one
          rank's entry never touches its siblings, and ``np.asarray`` views
          of them are read-only — so the sharing is observable only as
          reduced memory, not as cross-rank mutation;
        * with ``per_rank_seeds=True`` each rank gets a distinct initial
          state (``seed + rank``); states are stacked on a leading rank
          axis and the group program is ``vmap``-ed over it — still one
          trace + one dispatch per group.

        ``batched=False`` is the per-rank baseline path (identical results;
        benchmarked against in benchmarks/replay_time.py).

        ``mesh=`` switches to the **mesh-sharded sweep**: signature groups
        are placed on disjoint device subsets of ``mesh`` (see
        :meth:`mesh_sweep_plan`), each group executes its real collectives
        via :class:`DeviceComm` inside one ``shard_map`` dispatch, and all
        groups are dispatched asynchronously before any result is gathered.
        ``comm`` is ignored in mesh mode (the backend is derived from the
        placement); ``batched=False`` gives the sequential mesh baseline
        (one dispatch per rank on the same placement).

        ``noise=NoiseConfig(...)`` replays ``n_replicas`` seeded noisy
        replicas per signature group as ONE extra vmapped axis (the
        default ``noise=None`` path is byte-identical to a build without
        the noise layer).  Every leaf of a rank's result then carries a
        leading replica axis; ranks of a group share the replica results
        (the noise models run-level platform state, not per-rank jitter),
        and the :data:`~repro.core.noise.NOISE_COMPUTE` /
        :data:`~repro.core.noise.NOISE_COMM` leaves hold the perturbed
        cost accumulators :meth:`fidelity` summarizes.
        """
        if ranks is not None:
            self._validate_ranks(ranks)
        if noise is not None and per_rank_seeds:
            raise ValueError("noise= and per_rank_seeds are mutually "
                             "exclusive (both own the stacked batch axis)")
        if noise is not None and not batched:
            raise ValueError("noise= requires the batched replay path "
                             "(replicas ride the vmapped group axis)")
        if mesh is not None:
            return self._run_all_mesh(ranks, seed, batched, per_rank_seeds,
                                      mesh, noise)
        comm = comm or LocalSim()
        if noise is not None:
            out = {}
            for fn, arg, grp in self._group_work(ranks, seed, comm,
                                                 False, noise=noise):
                res = fn(arg)
                for r in grp:   # replicas are group-level, shared by ranks
                    out[r] = dict(res)
            for v in out.values():
                jax.block_until_ready(v)
            return out
        out = {}
        if not batched:
            st = None if per_rank_seeds else init_replay_state(self.module, seed)
            for r in (range(self.merged.n_ranks) if ranks is None else ranks):
                out[r] = self._fn_for_rank(r, comm)(
                    init_replay_state(self.module, seed + r)
                    if per_rank_seeds else st)
            for v in out.values():
                jax.block_until_ready(v)
            return out
        for fn, arg, grp in self._group_work(ranks, seed, comm, per_rank_seeds):
            res = fn(arg)
            if per_rank_seeds:
                for i, r in enumerate(grp):
                    out[r] = jax.tree.map(lambda a, i=i: a[i], res)
            else:
                for r in grp:   # identical input + program -> identical output
                    # fresh dict per rank; leaves alias on purpose (immutable)
                    out[r] = dict(res)
        for v in out.values():
            jax.block_until_ready(v)
        return out

    def _run_all_mesh(self, ranks, seed: int, batched: bool,
                      per_rank_seeds: bool, mesh,
                      noise: "NoiseConfig | None" = None) -> dict[int, dict]:
        """Mesh-sharded sweep body: dispatch every placed group first (jax
        dispatch is asynchronous — groups on disjoint device subsets overlap),
        gather/unstack after, block once at the end."""
        pending = []
        for fn, arg, grp, stacked in self._group_work_mesh(
                ranks, seed, per_rank_seeds, mesh, batched, noise):
            pending.append((fn(arg), grp, stacked))
        out: dict[int, dict] = {}
        for res, grp, stacked in pending:
            if stacked:
                for i, r in enumerate(grp):
                    out[r] = jax.tree.map(lambda a, i=i: a[i], res)
            else:
                for r in grp:
                    out[r] = dict(res)
        jax.block_until_ready(out)
        return out

    def _group_work(self, ranks, seed: int, comm, per_rank_seeds: bool,
                    noise: "NoiseConfig | None" = None) -> list[tuple]:
        """One ``(compiled_fn, input_state, group_ranks)`` unit per signature
        group — the shared work plan of :meth:`run_all` and :meth:`time_all`.

        With ``noise=``, each unit stacks ``n_replicas`` seeded noisy
        replicas of the group's (shared) initial state on a leading axis —
        the same one-vmapped-axis shape as ``per_rank_seeds``, so the
        sweep scheduler and compile caches are reused as-is."""
        if noise is not None:
            work = []
            for sig, grp in self.signature_groups(ranks):
                fn = self._fn_for_group(sig, grp[0], noise.n_replicas, comm,
                                        tag="noise")
                work.append((fn, self._noise_group_state(grp[0], noise, seed),
                             grp))
            return work
        st = None if per_rank_seeds else init_replay_state(self.module, seed)
        work = []
        for sig, grp in self.signature_groups(ranks):
            if per_rank_seeds:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[init_replay_state(self.module, seed + r) for r in grp])
                work.append((self._fn_for_group(sig, grp[0], len(grp), comm),
                             stacked, grp))
            else:
                work.append((self._fn_for_rank(grp[0], comm), st, grp))
        return work

    def time_local(self, rank: int = 0, iters: int = 1, seed: int = 0) -> float:
        """Wall-clock seconds of one rank's replay (compiled, warm)."""
        self._validate_ranks([rank])
        comm = LocalSim()
        fn = self._fn_for_rank(rank, comm)
        st = init_replay_state(self.module, seed)
        jax.block_until_ready(fn(st))  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(st))
        return (time.perf_counter() - t0) / iters

    def time_all(self, ranks: Sequence[int] | None = None, iters: int = 1,
                 seed: int = 0, batched: bool = True,
                 per_rank_seeds: bool = False, mesh=None,
                 noise: "NoiseConfig | None" = None) -> float:
        """Warm wall-clock seconds of one full multi-rank replay sweep.

        Mirrors :meth:`run_all`'s modes: per-rank baseline
        (``batched=False``), group-deduplicated (default), group-vmapped
        (``per_rank_seeds=True``), noisy-replica (``noise=NoiseConfig``,
        one vmapped replica axis per group), and — with ``mesh=`` — the
        mesh-sharded sweep (real collectives, one dispatch per placed
        group; the ``batched=False`` variant times the sequential mesh
        baseline).
        """
        ranks = list(range(self.merged.n_ranks) if ranks is None else ranks)
        self._validate_ranks(ranks)
        if noise is not None and (per_rank_seeds or not batched):
            raise ValueError("noise= requires the batched path and is "
                             "mutually exclusive with per_rank_seeds")
        comm = LocalSim()
        if mesh is not None:
            work = [(fn, arg) for fn, arg, _, _ in self._group_work_mesh(
                ranks, seed, per_rank_seeds, mesh, batched, noise)]
        elif batched:
            work = [(fn, arg) for fn, arg, _ in
                    self._group_work(ranks, seed, comm, per_rank_seeds,
                                     noise=noise)]
        else:
            st = None if per_rank_seeds else init_replay_state(self.module, seed)
            work = [(self._fn_for_rank(r, comm),
                     init_replay_state(self.module, seed + r)
                     if per_rank_seeds else st) for r in ranks]

        def sweep():
            out = None
            for fn, arg in work:
                out = fn(arg)
            jax.block_until_ready(out)

        sweep()  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            sweep()
        return (time.perf_counter() - t0) / iters

    def cache_stats(self) -> dict[str, int]:
        """Trace/cache counters (jit_traces counts actual re-traces)."""
        return dict(self._counters,
                    compiled_per_rank=len(self._compiled),
                    compiled_batched=len(self._compiled_batched),
                    cached_metric_groups=len(self._metrics_cache))

    # -- measurement -------------------------------------------------------------

    def rank_metrics(self, rank: int, use_cache: bool = True) -> np.ndarray:
        """Walker-measured 6-metric total of this rank's generated program.

        Cached per (signature, state shapes): ranks sharing a control-flow
        signature run byte-identical programs, so repeated ``fidelity`` /
        ``rank_metrics`` calls never re-trace a group already measured.
        """
        key = (self.signature_of(rank), self._shapes_key())
        if use_cache and key in self._metrics_cache:
            return self._metrics_cache[key]
        st = jax.eval_shape(lambda: init_replay_state(self.module))
        comm = LocalSim()
        self._counters["metric_traces"] += 1
        # exact_cond: generated modules' control flow is driven entirely by
        # constant opcode tables, so the walker resolves every switch to the
        # branch actually replayed — grammar-compiled and unrolled modules
        # measure bit-identically (the codegen_reference parity bar)
        tr = trace_fn(lambda s: self.module.run_rank(s, comm, rank), st,
                      exact_cond=True)
        out = tr.total_compute()
        self._metrics_cache[key] = out
        return out

    def group_eqn_counts(self, comm=None) -> dict[tuple, int]:
        """Traced-executable size per signature group: total jaxpr equation
        count of one representative rank's ``run_rank``.  For grammar-
        compiled modules this is O(grammar); for the unrolled reference it
        grows with the trace — the size bar the CI guard pins."""
        from repro.core.progtable import jaxpr_eqn_count
        comm = comm or LocalSim()
        st = jax.eval_shape(lambda: init_replay_state(self.module))
        out: dict[tuple, int] = {}
        for sig, grp in self.signature_groups():
            jaxpr = jax.make_jaxpr(
                lambda s, _r=grp[0]: self.module.run_rank(s, comm, _r))(st)
            out[sig] = jaxpr_eqn_count(jaxpr)
        return out

    def expand_rank_ids(self, rank: int) -> list[int]:
        return self.merged.expand_rank(rank)

    def _noise_totals(self, ranks: Sequence[int], cfg: "NoiseConfig",
                      mesh=None) -> tuple[dict, dict]:
        """Executed perturbed cost totals per rank.

        Returns ``(compute, comm_bytes)`` dicts: ``compute[r]`` is the
        ``(n_replicas, 6)`` float64 noise-accumulator matrix, ``comm[r]``
        the ``(n_replicas,)`` perturbed collective-byte totals.  δ̄ is
        normally measured by the static jaxpr walker, which runtime
        randomness cannot reach — the noisy path instead *executes* the
        replicas (LocalSim or mesh) and reads the accumulators the
        perturb wrappers summed during replay."""
        if mesh is not None:
            units = [(fn, arg, grp) for fn, arg, grp, _ in
                     self._group_work_mesh(ranks, 0, False, mesh, True,
                                           noise=cfg)]
        else:
            units = self._group_work(ranks, 0, LocalSim(), False, noise=cfg)
        pending = [(fn(arg), grp) for fn, arg, grp in units]
        compute: dict[int, np.ndarray] = {}
        comm_bytes: dict[int, np.ndarray] = {}
        for res, grp in pending:
            acc = np.asarray(jax.device_get(res[noise_mod.NOISE_COMPUTE]),
                             dtype=np.float64)
            cb = np.asarray(jax.device_get(res[noise_mod.NOISE_COMM]),
                            dtype=np.float64)
            for r in grp:       # replicas are group-level; ranks share them
                compute[r] = acc
                comm_bytes[r] = cb
        return compute, comm_bytes

    def fidelity(self, original_rank_traces: Sequence[Sequence[Event]],
                 original_rank_keys: Sequence[Sequence[str]] | None = None,
                 sample_ranks: int | None = None,
                 batched: bool = True, mesh=None,
                 noise: "NoiseConfig | None" = None,
                 ) -> "FidelityReport | FidelityDistribution":
        """Compare proxy vs original per rank (paper §3.3.1).

        ``original_rank_traces`` is either per-rank Event lists or a
        columnar :class:`~repro.core.trace_ir.TraceStore` (preferred: the
        original totals then come from one vectorized pass with no Event
        materialization).  Compute metrics: walker totals of generated
        code vs the original trace's compute totals, assembled for all
        sampled ranks in one vectorized pass (proxy totals come from the
        per-signature metrics cache — one walker trace per group, not per
        rank).  Communication:
        the merged grammar must expand to the original event *key* sequence
        exactly (losslessness; keys, not local ids — heterogeneous ranks
        intern in different orders).  ``batched=False`` forces the original
        per-rank/per-trace path (the parity baseline in tests).

        ``mesh=`` additionally executes one mesh-sharded sweep (real
        collectives via :class:`DeviceComm`, reusing the placement-keyed
        compile cache) and records whether every pool buffer came back
        finite in ``report.mesh_checked``.  δ̄ itself is placement-invariant
        by construction — walker metrics are keyed by (signature, state
        shapes) only — so mesh and local reports carry bit-identical deltas.

        ``noise=NoiseConfig(...)`` returns a
        :class:`~repro.core.noise.FidelityDistribution` instead: the proxy
        side becomes the *executed* perturbed-cost accumulators over
        ``n_replicas`` seeded replicas (one vmapped axis per group,
        LocalSim by default, ``mesh=`` for the sharded sweep), each
        replica's δ matrix computed against the same original totals.
        Fixed ``(seed, n_replicas)`` is reproducible bit-for-bit and
        identical between LocalSim and mesh (replica keys are
        placement-invariant and the accumulator math never reads buffer
        values).  Note the σ→0 limit of the executed totals tracks — but
        is not bit-equal to — the float64 walker totals (float32
        execution; rolled-loop scan-step accounting), so the bit-parity
        contract binds only the untouched ``noise=None`` walker path.
        """
        if hasattr(original_rank_traces, "compute_totals"):
            # columnar TraceStore: per-rank totals in one vectorized pass,
            # bit-identical to the per-event accumulation (np.add.at sums
            # in stream order) — no Event materialization
            totals = original_rank_traces.compute_totals()
            n_ranks = original_rank_traces.n_ranks
        else:
            totals = None
            n_ranks = len(original_rank_traces)
        ranks = list(range(n_ranks))
        if sample_ranks and n_ranks > sample_ranks:
            step = max(n_ranks // sample_ranks, 1)
            ranks = ranks[::step][:sample_ranks]
        lossless = True
        if original_rank_keys is not None:
            for r in range(n_ranks):
                got = [self.merged.table[i].key()
                       for i in self.expand_rank_ids(r)]
                if list(original_rank_keys[r]) != got:
                    lossless = False
                    break
        if totals is not None:
            a = totals[ranks].T
        else:
            a = np.zeros((N_METRICS, len(ranks)))
            for col, r in enumerate(ranks):
                for ev in original_rank_traces[r]:
                    if not is_comm(ev):
                        a[:, col] += ev.vector
        if noise is not None:
            compute, comm_b = self._noise_totals(ranks, noise, mesh)
            bn = np.stack([compute[r] for r in ranks], axis=2)
            replica_delta = np.stack(
                [proxy_search.rel_error_matrix(a, bn[j])
                 for j in range(noise.n_replicas)])
            cb = np.stack([comm_b[r] for r in ranks], axis=1)
            mesh_checked = mesh is not None and \
                bool(np.isfinite(bn).all() and np.isfinite(cb).all())
            return FidelityDistribution(
                replica_delta=replica_delta, comm_bytes=cb,
                ranks=tuple(ranks), seed=noise.seed,
                n_replicas=noise.n_replicas, comm_lossless=lossless,
                mesh_checked=mesh_checked)
        b = np.stack([self.rank_metrics(r, use_cache=batched) for r in ranks],
                     axis=1)
        delta = proxy_search.rel_error_matrix(a, b)
        mesh_checked = False
        if mesh is not None:
            states = self._run_all_mesh(ranks, 0, True, False, mesh)
            mesh_checked = all(
                bool(np.isfinite(np.asarray(leaf, np.float32)).all())
                for st in states.values() for leaf in jax.tree.leaves(st))
        return FidelityReport(delta=delta, comm_lossless=lossless,
                              mean=float(delta.mean()),
                              mesh_checked=mesh_checked)
