"""Columnar trace IR: the array-of-events representation of multi-rank traces.

The per-event pipeline (``list[Event]`` per rank, one ``intern``/``push``/
dict-op per event) is O(python) in trace length.  :class:`TraceStore` keeps
the same information columnar:

* ``metrics``  — ``(n_compute_events, 6)`` float64, every compute event's
  metric vector across all ranks, rank-major in stream order;
* ``tokens``   — ``(n_events,)`` int64, the concatenated per-rank event
  streams: token ``t >= 0`` is the compute event stored in ``metrics[t]``,
  token ``t < 0`` is the interned communication event
  ``comm_pool[-t - 1]`` (comm events are deduplicated by canonical key);
* ``extents``  — ``(n_ranks + 1,)`` int64 rank offsets into ``tokens``;
* ``cluster_ids`` — ``(n_compute_events,)`` int64, the *ingested*
  ``ComputeEvent.cluster_id`` per row (``-1`` when unassigned).  Pipeline
  clustering never mutates the store; it returns fresh arrays.

The round trip to/from ``list[Event]`` is lossless (ppermute ``detail``
tuples, canonicalized ``axis_index_groups`` handles, pre-assigned cluster
ids all survive), and :meth:`TraceStore.save`/:meth:`TraceStore.load` make
traces offline ``.npz`` artifacts — trace once, synthesize anywhere.

:func:`compress_store` is the columnar rewrite of the grammar front half:
vectorized clustering (:func:`repro.core.events.cluster_vectors`),
vectorized terminal interning (first-appearance factorization per rank),
and **signature-deduplicated** grammar construction — ranks whose token
streams are byte-identical (the overwhelmingly common SPMD case, the same
redundancy the replay engine's SIGNATURE_GROUPS exploit) share one
Sequitur run instead of paying for one each.  Each run RLE-collapses the
interned stream and feeds the flat-array kernel's batch entry point
(:meth:`repro.core.sequitur.Sequitur.push_runs`), optionally consulting a
content-addressed grammar cache; per-stage timings land in an optional
``profile`` dict.  Output is bit-identical to the per-event reference
(:mod:`repro.core.frontend_reference`).
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Sequence

import numpy as np

from repro.core.events import (
    CommEvent, ComputeEvent, Event, N_METRICS, cluster_vectors,
    encode_relative_perm, is_comm,
)
from repro.core.grammar import Grammar, TerminalTable
from repro.core.interproc import MergedProgram, merge_grammars
from repro.core.sequitur import Sequitur, rle_runs

_NPZ_VERSION = 1


@dataclasses.dataclass
class TraceStore:
    """Columnar multi-rank event trace (see module docstring for layout)."""

    tokens: np.ndarray                 # (n_events,) int64
    extents: np.ndarray                # (n_ranks + 1,) int64
    metrics: np.ndarray                # (n_compute_events, 6) float64
    cluster_ids: np.ndarray            # (n_compute_events,) int64
    comm_pool: list[CommEvent]
    axis_sizes: dict[str, int]

    # -- shape accessors -------------------------------------------------------

    @property
    def n_ranks(self) -> int:
        return len(self.extents) - 1

    @property
    def n_events(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def n_compute_events(self) -> int:
        return int(self.metrics.shape[0])

    @property
    def n_comm_events(self) -> int:
        return self.n_events - self.n_compute_events

    def rank_tokens(self, rank: int) -> np.ndarray:
        return self.tokens[self.extents[rank]:self.extents[rank + 1]]

    # -- construction ----------------------------------------------------------

    @classmethod
    def from_rank_traces(cls, rank_traces: Sequence[Sequence[Event]],
                         axis_sizes: dict[str, int] | None = None,
                         ) -> "TraceStore":
        """Ingest per-rank event lists (one Python pass; everything after
        this is columnar)."""
        tokens: list[int] = []
        extents = [0]
        rows: list[tuple] = []
        cids: list[int] = []
        pool: list[CommEvent] = []
        by_key: dict[str, int] = {}
        for tr in rank_traces:
            for ev in tr:
                if is_comm(ev):
                    k = ev.key()
                    cid = by_key.get(k)
                    if cid is None:
                        cid = len(pool)
                        by_key[k] = cid
                        pool.append(ev)
                    tokens.append(-cid - 1)
                else:
                    tokens.append(len(rows))
                    rows.append(ev.metrics)
                    cids.append(ev.cluster_id)
            extents.append(len(tokens))
        metrics = (np.asarray(rows, dtype=np.float64) if rows
                   else np.zeros((0, N_METRICS), dtype=np.float64))
        return cls(tokens=np.asarray(tokens, dtype=np.int64),
                   extents=np.asarray(extents, dtype=np.int64),
                   metrics=metrics,
                   cluster_ids=np.asarray(cids, dtype=np.int64),
                   comm_pool=pool,
                   axis_sizes=dict(axis_sizes or {}))

    @classmethod
    def from_template(cls, trace, axis_sizes: dict[str, int] | None = None,
                      ) -> "TraceStore":
        """Specialize an SPMD template trace straight into columnar form.

        Equivalent to ``from_rank_traces(per_rank_traces(trace))`` — same
        tokens, same metrics layout (rank-major), same comm pool order —
        without materializing per-rank Event lists.  Per-rank variation
        comes only from ``rawperm`` ppermute participation, so ranks are
        grouped into participation classes and each class's token stream
        is built once.
        """
        axis_sizes = dict(trace.axis_sizes if axis_sizes is None
                          else axis_sizes)
        axes = list(axis_sizes)
        sizes = [axis_sizes[a] for a in axes]
        n_ranks = int(np.prod(sizes)) if sizes else 1

        pool: list[CommEvent] = []
        by_key: dict[str, int] = {}

        def intern(ev: CommEvent) -> int:
            k = ev.key()
            cid = by_key.get(k)
            if cid is None:
                cid = len(pool)
                by_key[k] = cid
                pool.append(ev)
            return cid

        base: list[int] = []            # template tokens (compute rows local)
        trows: list[tuple] = []
        tcids: list[int] = []
        cond: list[tuple[int, str | None, frozenset]] = []
        for ev in trace.events:
            if not is_comm(ev):
                base.append(len(trows))
                trows.append(ev.metrics)
                tcids.append(ev.cluster_id)
                continue
            if ev.kind == "ppermute" and ev.detail \
                    and ev.detail[0] == "rawperm":
                perm = [tuple(p) for p in ev.detail[1]]
                axis = ev.axes[0] if ev.axes else None
                size = axis_sizes.get(
                    axis, max((max(s, d) for s, d in perm), default=0) + 1)
                rel = encode_relative_perm(perm, size)
                parts = frozenset({s for s, _ in perm}
                                  | {d for _, d in perm})
                cond.append((len(base), axis, parts))
                base.append(-intern(dataclasses.replace(ev, detail=rel)) - 1)
            else:
                base.append(-intern(ev) - 1)

        base_arr = np.asarray(base, dtype=np.int64)
        n_comp = len(trows)

        # per-rank mesh coordinates, vectorized (row-major rank flattening,
        # mirroring repro.core.tracer.per_rank_traces)
        ranks = np.arange(n_ranks)
        coord: dict[str, np.ndarray] = {}
        stride = 1
        for a, s in zip(reversed(axes), reversed(sizes)):
            coord[a] = (ranks // stride) % s
            stride *= s
        zero = np.zeros(n_ranks, dtype=np.int64)

        if cond:
            bits = np.stack(
                [np.isin(coord.get(a, zero),
                         np.fromiter(parts, dtype=np.int64, count=len(parts)))
                 for (_, a, parts) in cond], axis=1)
        else:
            bits = np.zeros((n_ranks, 0), dtype=bool)

        class_tokens: dict[bytes, np.ndarray] = {}
        rank_chunks: list[np.ndarray] = []
        extents = [0]
        total = 0
        for r in range(n_ranks):
            key = bits[r].tobytes()
            toks = class_tokens.get(key)
            if toks is None:
                keep = np.ones(len(base_arr), dtype=bool)
                for (pos, _, _), b in zip(cond, bits[r]):
                    if not b:
                        keep[pos] = False
                toks = base_arr[keep]
                class_tokens[key] = toks
            tr = toks.copy()
            comp = tr >= 0
            tr[comp] += r * n_comp
            rank_chunks.append(tr)
            total += len(tr)
            extents.append(total)

        tmetrics = (np.asarray(trows, dtype=np.float64) if trows
                    else np.zeros((0, N_METRICS), dtype=np.float64))
        return cls(
            tokens=(np.concatenate(rank_chunks) if rank_chunks
                    else np.zeros(0, dtype=np.int64)),
            extents=np.asarray(extents, dtype=np.int64),
            metrics=np.tile(tmetrics, (n_ranks, 1)),
            cluster_ids=np.tile(np.asarray(tcids, dtype=np.int64), n_ranks),
            comm_pool=pool,
            axis_sizes=axis_sizes)

    # -- lossless expansion ----------------------------------------------------

    def _event_pool(self) -> np.ndarray:
        """Object array mapping token keys to Event instances: slot ``c``
        is comm event ``comm_pool[c]``, slot ``n_comms + t`` the
        ComputeEvent of metrics row ``t``.

        Compute rows are interned by value — one ComputeEvent per distinct
        (metrics, cluster_id) row, gathered back over the row index — so
        SPMD-tiled stores materialize one object per template event, not
        one per occurrence.  Cached on the store (stores are immutable
        once built)."""
        cached = getattr(self, "_event_pool_cache", None)
        if cached is not None:
            return cached
        n_comms = len(self.comm_pool)
        pool = np.empty(n_comms + self.n_compute_events, dtype=object)
        for c, ev in enumerate(self.comm_pool):
            pool[c] = ev
        if self.n_compute_events:
            keyed = np.concatenate(
                [self.metrics, self.cluster_ids[:, None].astype(np.float64)],
                axis=1)
            uq, inv = np.unique(keyed, axis=0, return_inverse=True)
            uniq_events = np.empty(len(uq), dtype=object)
            for u, row in enumerate(uq):
                uniq_events[u] = ComputeEvent(tuple(row[:N_METRICS].tolist()),
                                              cluster_id=int(row[N_METRICS]))
            pool[n_comms:] = uniq_events[inv.reshape(-1)]
        self._event_pool_cache = pool
        return pool

    def rank_events(self, rank: int) -> list[Event]:
        """Materialize rank ``rank``'s event list (lossless round trip) in
        one interned-key gather over the token stream (value-equal
        ComputeEvents alias one instance; events are frozen)."""
        toks = self.rank_tokens(rank)
        n_comms = len(self.comm_pool)
        idx = np.where(toks < 0, -toks - 1, toks + n_comms)
        return self._event_pool()[idx].tolist()

    def to_rank_traces(self) -> list[list[Event]]:
        return [self.rank_events(r) for r in range(self.n_ranks)]

    # -- size accounting (vectorized raw_trace_bytes) --------------------------

    def raw_trace_bytes(self) -> int:
        """Uncompressed trace-size estimate, identical to summing
        ``len(ev.key()) + 1`` over every materialized event."""
        total = 0
        comm_toks = self.tokens[self.tokens < 0]
        if len(comm_toks):
            comm_lens = np.asarray([len(ev.key()) + 1 for ev in self.comm_pool],
                                   dtype=np.int64)
            total += int(comm_lens[-comm_toks - 1].sum())
        if self.n_compute_events:
            uq, inv = np.unique(self.metrics, axis=0, return_inverse=True)
            base = np.asarray(
                [len("X|" + "|".join(f"{m:.6g}" for m in row)) + 1
                 for row in uq], dtype=np.int64)
            row_lens = base[inv.reshape(-1)]
            pre = self.cluster_ids >= 0
            if pre.any():
                row_lens = row_lens.copy()
                row_lens[pre] = [len(f"X|{c}") + 1
                                 for c in self.cluster_ids[pre].tolist()]
            comp_toks = self.tokens[self.tokens >= 0]
            total += int(row_lens[comp_toks].sum())
        return total

    def comm_occurrence_counts(self) -> np.ndarray:
        """Per-comm-pool-entry occurrence counts across all ranks,
        ``(len(comm_pool),)`` int64 — the weights the noise calibrator
        uses so a collective repeated 10⁴ times dominates its kind's
        payload-spread estimate over a one-off of the same kind."""
        ct = self.tokens[self.tokens < 0]
        return np.bincount(-ct - 1, minlength=len(self.comm_pool))

    def compute_totals(self) -> np.ndarray:
        """Per-rank compute-metric totals, ``(n_ranks, 6)`` (the original
        side of the fidelity comparison), in one vectorized pass."""
        out = np.zeros((self.n_ranks, N_METRICS))
        if self.n_compute_events:
            rank_of = np.repeat(np.arange(self.n_ranks),
                                np.diff(self.extents))
            comp = self.tokens >= 0
            np.add.at(out, rank_of[comp], self.metrics[self.tokens[comp]])
        return out

    # -- content identity ------------------------------------------------------

    def content_hash(self) -> str:
        """Deterministic sha256 over the full store content (tokens,
        extents, metrics, ingested cluster ids, comm keys, axis sizes).

        Two stores with equal content hash synthesize identically; the
        corpus store keys its manifest entries and fit caches on it.
        """
        h = hashlib.sha256()
        for arr in (self.tokens, self.extents, self.metrics,
                    self.cluster_ids):
            h.update(np.ascontiguousarray(arr).tobytes())
        for ev in self.comm_pool:
            h.update(ev.key().encode())
            h.update(b"\x00")
        h.update(json.dumps(self.axis_sizes, sort_keys=True).encode())
        return h.hexdigest()

    # -- offline artifacts (.npz) ----------------------------------------------

    def save(self, path) -> Path:
        """Write the store as a ``.npz`` artifact; returns the actual path."""
        path = Path(path)
        if path.suffix != ".npz":
            path = path.with_suffix(path.suffix + ".npz")
        path.parent.mkdir(parents=True, exist_ok=True)
        comm = [repr((ev.kind, ev.shape, ev.dtype, ev.axes, ev.detail))
                for ev in self.comm_pool]
        comm_arr = (np.asarray(comm) if comm
                    else np.zeros(0, dtype="<U1"))
        meta = json.dumps({"version": _NPZ_VERSION,
                           "axis_sizes": self.axis_sizes})
        with open(path, "wb") as f:
            np.savez(f, tokens=self.tokens, extents=self.extents,
                     metrics=self.metrics, cluster_ids=self.cluster_ids,
                     comm=comm_arr, meta=np.asarray(meta))
        return path

    @staticmethod
    def load_columns(path, names: Sequence[str]) -> dict[str, np.ndarray]:
        """Partial load: read only the named arrays (``tokens`` /
        ``extents`` / ``metrics`` / ``cluster_ids``) from a saved store
        without materializing the comm pool (``ast.literal_eval`` per comm
        event is the slow part of a full :meth:`load`).  The cluster-index
        rebuild path reads just ``metrics`` this way.
        """
        valid = {"tokens", "extents", "metrics", "cluster_ids"}
        bad = set(names) - valid
        if bad:
            raise ValueError(f"unknown store columns {sorted(bad)}")
        with np.load(path) as z:
            meta = json.loads(str(z["meta"]))
            version = meta.get("version")
            if version != _NPZ_VERSION:
                raise ValueError(
                    f"unsupported trace store version {version!r} in {path}"
                    f" (this build reads version {_NPZ_VERSION})")
            dtypes = {"metrics": np.float64}
            return {n: z[n].astype(dtypes.get(n, np.int64)) for n in names}

    @classmethod
    def load(cls, path) -> "TraceStore":
        with np.load(path) as z:
            meta = json.loads(str(z["meta"]))
            version = meta.get("version")
            if version != _NPZ_VERSION:
                raise ValueError(
                    f"unsupported trace store version {version!r} in {path}"
                    f" (this build reads version {_NPZ_VERSION})")
            pool = []
            for s in z["comm"].tolist():
                kind, shape, dtype, axes, detail = ast.literal_eval(s)
                pool.append(CommEvent(kind, tuple(shape), dtype,
                                      tuple(axes), tuple(detail)))
            return cls(tokens=z["tokens"].astype(np.int64),
                       extents=z["extents"].astype(np.int64),
                       metrics=z["metrics"].astype(np.float64),
                       cluster_ids=z["cluster_ids"].astype(np.int64),
                       comm_pool=pool,
                       axis_sizes={str(k): int(v) for k, v in
                                   meta["axis_sizes"].items()})


# ---------------------------------------------------------------------------
# columnar grammar front half
# ---------------------------------------------------------------------------


def _first_appearance_factorize(sym: np.ndarray,
                                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Map a symbol stream to local ids numbered by first appearance.

    Returns ``(local_ids, uniq_syms, first_pos)`` where ``uniq_syms[k]`` is
    the symbol assigned local id ``k`` and ``first_pos[k]`` its first
    occurrence index — exactly the order a per-event ``TerminalTable``
    intern loop would have produced.
    """
    uq, first, inv = np.unique(sym, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")
    lid = np.empty(len(uq), dtype=np.int64)
    lid[order] = np.arange(len(uq))
    return lid[inv], uq[order], first[order]


def rank_symbol_streams(store: TraceStore, cluster_ids: np.ndarray,
                        ) -> np.ndarray:
    """Global symbol per token for every rank's stream, concatenated:
    comm id ``c`` -> ``c``, compute cluster ``k`` -> ``n_comms + k``
    (slice with ``store.extents`` for per-rank views).  Shared by
    :func:`compress_store` and the grammar benchmarks."""
    n_comms = len(store.comm_pool)
    toks = store.tokens
    if store.n_compute_events:
        comp_sym = n_comms + cluster_ids[np.maximum(toks, 0)]
    else:
        comp_sym = np.zeros(len(toks), dtype=np.int64)
    return np.where(toks < 0, -toks - 1, comp_sym)


def compress_store(store: TraceStore,
                   rel_tol: float = 0.05,
                   threshold: float = 0.5,
                   *,
                   cluster_ids: np.ndarray | None = None,
                   reps: dict[int, np.ndarray] | None = None,
                   grammar_cache=None,
                   profile: dict | None = None,
                   ) -> tuple[list[Grammar], MergedProgram,
                              list[list[int]], dict[int, np.ndarray]]:
    """Columnar replacement for the per-event ``compress_rank_traces``.

    Clusters compute events jointly across ranks (vectorized), interns
    terminals by first-appearance factorization of each rank's symbol
    stream, runs the flat Sequitur kernel once per *distinct* stream
    (ranks with byte-identical streams share the resulting grammar
    object) after an RLE pre-pass (:func:`repro.core.sequitur.rle_runs`),
    and merges (Algorithm 1).  Pass precomputed ``cluster_ids``/``reps``
    (aligned to ``store.metrics`` rows) to reuse a corpus-level joint
    clustering.

    ``grammar_cache`` (any object with the
    :class:`repro.core.corpus_store.GrammarCache` interface) memoizes the
    frozen Sequitur rules content-addressed by (local-id stream, threshold)
    — a hit skips grammar inference entirely; the terminal table is still
    built per stream (it binds store-local events).  Cached rule dicts
    alias across hits, read-only downstream like the per-class grammar
    aliasing below.

    ``profile`` (a dict) accumulates per-stage wall-clock and cache
    counters: ``cluster_ms``/``intern_ms``/``grammar_ms``/``merge_ms``,
    ``n_distinct_streams``/``n_sequitur_runs``, and
    ``grammar_cache_hits``/``grammar_cache_misses``.  Keys add onto
    existing values so one dict can aggregate across scenarios.
    """
    from time import perf_counter

    t0 = perf_counter()
    if cluster_ids is None:
        cluster_ids, reps = cluster_vectors(store.metrics, rel_tol)
    else:
        cluster_ids = np.asarray(cluster_ids, dtype=np.int64)
        if reps is None:
            raise ValueError("cluster_ids without reps")
    t_cluster = perf_counter() - t0

    n_comms = len(store.comm_pool)
    toks = store.tokens
    sym_all = rank_symbol_streams(store, cluster_ids)

    grammars: list[Grammar] = []
    rank_ids: list[list[int]] = []
    cache: dict[bytes, tuple[Grammar, list[int]]] = {}
    t_intern = t_grammar = 0.0
    n_runs = n_hits = n_misses = 0
    for r in range(store.n_ranks):
        sl = slice(int(store.extents[r]), int(store.extents[r + 1]))
        sym = sym_all[sl]
        key = sym.tobytes()
        hit = cache.get(key)
        if hit is None:
            t1 = perf_counter()
            local_ids, uniq, first = _first_appearance_factorize(sym)
            table = TerminalTable()
            rtoks = toks[sl]
            for s, fi in zip(uniq.tolist(), first.tolist()):
                if s < n_comms:
                    table.intern(store.comm_pool[s])
                else:
                    row = int(rtoks[fi])
                    table.intern(ComputeEvent(
                        tuple(store.metrics[row].tolist()),
                        cluster_id=int(s - n_comms)))
            t2 = perf_counter()
            t_intern += t2 - t1
            rules = gkey = None
            if grammar_cache is not None:
                gkey = grammar_cache.key(local_ids, threshold)
                rules = grammar_cache.get(gkey)
            if rules is None:
                if gkey is not None:
                    n_misses += 1
                seq = Sequitur()
                seq.push_runs(*rle_runs(local_ids))
                rules = seq.grammar_rules()
                n_runs += 1
                if gkey is not None:
                    grammar_cache.put(gkey, rules)
            else:
                n_hits += 1
            t_grammar += perf_counter() - t2
            hit = (Grammar(rules=rules, table=table), local_ids.tolist())
            cache[key] = hit
        grammars.append(hit[0])
        # grammars deliberately alias across a signature class (read-only
        # downstream, tested); id lists get a per-rank copy so in-place
        # edits by callers can't corrupt sibling ranks
        rank_ids.append(list(hit[1]))
    t3 = perf_counter()
    merged = merge_grammars(grammars, threshold)
    if profile is not None:
        for k, v in (("cluster_ms", t_cluster * 1e3),
                     ("intern_ms", t_intern * 1e3),
                     ("grammar_ms", t_grammar * 1e3),
                     ("merge_ms", (perf_counter() - t3) * 1e3),
                     ("n_distinct_streams", len(cache)),
                     ("n_sequitur_runs", n_runs),
                     ("grammar_cache_hits", n_hits),
                     ("grammar_cache_misses", n_misses)):
            profile[k] = profile.get(k, 0) + v
    return grammars, merged, rank_ids, reps
