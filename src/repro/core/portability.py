"""Cross-hardware profile prediction for synthesized proxies (paper §5).

A proxy fitted on one chip carries everything needed to *predict* its
profile on another: the terminal table pins exact per-occurrence costs
(compute metric vectors, collective payload bytes), and the roofline
model turns those costs into time bounds per chip.  ``predict_profile``
rescales the fitted terminal costs by the target chip's roofline ratios
— peak FLOP/s, HBM bandwidth, ICI bandwidth — and returns a per-rank
step-time bound with error bars, on hardware the scenario was never
traced on.

Error bars come from the module's ``NOISE_MODELS`` table: each terminal
occurrence's cost is modelled as its fitted value times an independent
mean-one factor with variance :func:`repro.core.noise.factor_variance`,
so the per-rank time variance is the count-weighted sum of squared
terminal times times factor variances (delta method on the bottleneck
roofline term).

Only imports the light ``launch.hlo_cost`` module — the reference-chip
constants are defined here (``CHIPS['v5e']``) and mirror
``repro.launch.roofline``; keeping them local avoids pulling the heavy
``repro.configs`` chain into ``repro.core``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import noise as noise_mod
from repro.launch.hlo_cost import HloCost


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Roofline envelope of one accelerator generation."""

    name: str
    peak_flops: float   # peak matmul FLOP/s (bf16)
    hbm_bw: float       # HBM bytes/s
    ici_bw: float       # per-link interconnect bytes/s

    def terms(self, flops: float, mem_bytes: float,
              coll_bytes: float) -> tuple[float, float, float]:
        """(t_compute, t_memory, t_collective) seconds for one rank."""
        return (flops / self.peak_flops, mem_bytes / self.hbm_bw,
                coll_bytes / self.ici_bw)


#: Known chip envelopes.  ``v5e`` is the reference generation the block
#: catalog was calibrated against; its numbers intentionally match the
#: constants in ``repro.launch.roofline``.
CHIPS: Mapping[str, ChipSpec] = {
    "v5e": ChipSpec("v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9),
    "v5p": ChipSpec("v5p", peak_flops=459e12, hbm_bw=2765e9, ici_bw=100e9),
    "v4": ChipSpec("v4", peak_flops=275e12, hbm_bw=1228e9, ici_bw=50e9),
}

REFERENCE_CHIP = "v5e"

_TERM_NAMES = ("compute", "memory", "collective")


@dataclasses.dataclass(frozen=True)
class ProfilePrediction:
    """Predicted per-rank roofline profile of a proxy on one chip.

    All arrays are float64 of shape ``(n_ranks,)``; ``t_total`` is the
    max-of-terms step-time bound (same convention as
    ``repro.launch.roofline.step_time_bound``) and ``t_std`` its noise
    standard deviation from the module's ``NOISE_MODELS`` table.
    """

    chip: str
    t_compute: np.ndarray
    t_memory: np.ndarray
    t_collective: np.ndarray
    t_total: np.ndarray
    t_std: np.ndarray
    bottleneck: tuple[str, ...]     # per rank: compute|memory|collective
    speedup_vs_ref: float           # ref-chip step bound / this chip's

    @property
    def step_time(self) -> float:
        """Scalar step-time bound: the slowest rank gates the step."""
        return float(self.t_total.max())

    def band(self, z: float = 1.96) -> tuple[np.ndarray, np.ndarray]:
        """Per-rank ``(lo, hi)`` confidence band, clipped at zero."""
        half = z * self.t_std
        return np.maximum(self.t_total - half, 0.0), self.t_total + half

    def as_dict(self) -> dict:
        """JSON-ready summary row (benchmark artifact schema)."""
        lo, hi = self.band()
        return {
            "chip": self.chip,
            "step_time_s": self.step_time,
            "step_std_s": float(self.t_std[int(self.t_total.argmax())]),
            "band_lo_s": float(lo.max()),
            "band_hi_s": float(hi.max()),
            "speedup_vs_ref": self.speedup_vs_ref,
            "bottleneck": self.bottleneck[int(self.t_total.argmax())],
            "t_compute_s": float(self.t_compute.max()),
            "t_memory_s": float(self.t_memory.max()),
            "t_collective_s": float(self.t_collective.max()),
        }


def _terminal_costs(module) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-terminal ``(flops, mem_bytes, coll_bytes)`` float64 arrays.

    Compute terminals map through :meth:`HloCost.from_metric_vector`
    (the fitted block-combo metric vector); comm terminals contribute
    their exact traced payload bytes to the collective term.
    """
    terms = getattr(module, "TERMINALS", None)
    if terms is None:
        raise ValueError(
            "predict_profile needs a table-flavor module (TERMINALS); "
            "re-synthesize with codegen='table'")
    n = len(terms)
    flops = np.zeros(n)
    mem = np.zeros(n)
    coll = np.zeros(n)
    for gid, desc in enumerate(terms):
        cost_vec, comm_bytes = noise_mod._desc_cost(desc)
        if cost_vec is not None:
            hc = HloCost.from_metric_vector(cost_vec)
            flops[gid] = hc.flops
            mem[gid] = hc.bytes
        else:
            coll[gid] = comm_bytes
    return flops, mem, coll


def _rank_counts(module) -> dict[int, np.ndarray]:
    """rank -> per-terminal occurrence counts (grouped: one expansion per
    signature group, shared by all its ranks)."""
    n = len(module.TERMINALS)
    counts: dict[int, np.ndarray] = {}
    for _sig, ranks, _hint in module.SIGNATURE_GROUPS:
        ct = np.bincount(np.asarray(module.expand_rank_ids(ranks[0]),
                                    dtype=np.int64), minlength=n)
        for r in ranks:
            counts[r] = ct
    return counts


def predict_profile(module, chip: str | ChipSpec,
                    ref_chip: str | ChipSpec = REFERENCE_CHIP,
                    ) -> ProfilePrediction:
    """Predict ``module``'s roofline profile on ``chip``.

    Rescales the proxy's fitted per-terminal costs by the target chip's
    roofline ratios; error bars propagate the module's calibrated
    ``NOISE_MODELS`` variance through the bottleneck term.
    """
    if isinstance(chip, str):
        chip = CHIPS[chip]
    if isinstance(ref_chip, str):
        ref_chip = CHIPS[ref_chip]
    flops, mem, coll = _terminal_costs(module)
    nm = getattr(module, "NOISE_MODELS", None) or ((0.0, 0.0),) * len(flops)
    fvar = np.array([noise_mod.factor_variance(s, sh) for s, sh in nm])
    counts = _rank_counts(module)
    ranks = sorted(counts)

    tc = np.empty(len(ranks))
    tm = np.empty(len(ranks))
    tl = np.empty(len(ranks))
    var = np.empty(len(ranks))
    ref_total = np.empty(len(ranks))
    bottleneck = []
    # Per-terminal seconds on the target chip, one row per roofline term.
    term_secs = np.stack([flops / chip.peak_flops, mem / chip.hbm_bw,
                          coll / chip.ici_bw])
    for i, r in enumerate(ranks):
        ct = counts[r]
        tc[i], tm[i], tl[i] = term_secs @ ct
        which = int(np.argmax((tc[i], tm[i], tl[i])))
        bottleneck.append(_TERM_NAMES[which])
        # Delta method: Var[Σ count·t·f] = Σ count·t²·Var[f] on the
        # bottleneck term (independent mean-one factors per occurrence).
        var[i] = float(ct @ (term_secs[which] ** 2 * fvar))
        ref_total[i] = max(ref_chip.terms(float(flops @ ct), float(mem @ ct),
                                          float(coll @ ct)))
    total = np.maximum(np.maximum(tc, tm), tl)
    speedup = float(ref_total.max() / total.max()) if total.max() > 0 else 1.0
    return ProfilePrediction(chip=chip.name, t_compute=tc, t_memory=tm,
                             t_collective=tl, t_total=total,
                             t_std=np.sqrt(var),
                             bottleneck=tuple(bottleneck),
                             speedup_vs_ref=speedup)


def predict_all(module, chips: Sequence[str | ChipSpec] = tuple(CHIPS),
                ) -> dict[str, ProfilePrediction]:
    """``predict_profile`` over a chip list, keyed by chip name."""
    out = {}
    for c in chips:
        pred = predict_profile(module, c)
        out[pred.chip] = pred
    return out
