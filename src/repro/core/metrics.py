"""Per-equation TPU cost model -- the PAPI-counter analog (DESIGN.md §2).

The paper reads 6 hardware counters around every MPI call.  On TPU the staged
jaxpr gives *exact* op counts without any runtime interference, so each jaxpr
equation is mapped to a 6-metric cost vector:

    mxu_flops, vpu_elems, hbm_bytes, transcendentals, gather_elems, scan_steps

``hbm_bytes`` is deliberately fusion-agnostic (operands + results per
equation): the same convention is applied to the target program and to the
proxy basic blocks, so the QP fit (paper eq. 6-7) is self-consistent.  The
roofline analysis uses XLA's own ``cost_analysis`` instead -- see
:mod:`repro.launch.roofline`.
"""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.core.events import METRIC_NAMES, N_METRICS, dtype_bytes

# --- metric indices ---------------------------------------------------------
I_MXU, I_VPU, I_BYTES, I_TRANS, I_GATHER, I_SCAN = range(N_METRICS)

#: primitives whose elementwise application hits the VPU slow path
TRANSCENDENTAL_PRIMS = {
    "exp", "exp2", "expm1", "log", "log1p", "log2", "tanh", "tan", "sin",
    "cos", "asin", "acos", "atan", "atan2", "sinh", "cosh", "asinh", "acosh",
    "atanh", "erf", "erfc", "erf_inv", "logistic", "pow", "integer_pow",
    "rsqrt", "sqrt", "cbrt", "digamma", "lgamma", "regularized_incomplete_beta",
}

#: irregular-address primitives (the L1_DCM analog)
GATHER_PRIMS = {"gather", "scatter", "scatter_add", "scatter_mul", "scatter_min",
                "scatter_max", "dynamic_slice", "dynamic_update_slice",
                "take", "take_along_axis", "argsort", "sort", "top_k"}

#: primitives that move data without arithmetic (count bytes only)
DATA_MOVEMENT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "concatenate",
    "slice", "pad", "rev", "convert_element_type", "bitcast_convert_type",
    "copy", "device_put", "iota", "split", "expand_dims",
    "pvary", "sharding_constraint", "reshard",
}

#: zero-cost bookkeeping primitives.  ``pbroadcast`` is the pre-0.5 spelling
#: of ``pvary`` — the replication marker shard_map's check_rep machinery
#: inserts after collectives; it lowers to a no-op and must not be recorded
#: as a communication event (version drift handled like repro.compat).
FREE_PRIMS = {
    "stop_gradient", "axis_index", "sharding_cast", "pvary", "pbroadcast",
    "symbolic_zeros", "empty", "debug_callback", "name",
    "optimization_barrier",
}

#: jaxpr collective primitive name -> CommEvent kind
COLLECTIVE_PRIMS = {
    "psum": "psum",
    "psum_invariant": "psum",
    "psum2": "psum",
    "pmax": "pmax",
    "pmin": "pmin",
    "all_gather": "all_gather",
    "all_gather_invariant": "all_gather",
    "reduce_scatter": "reduce_scatter",
    "all_to_all": "all_to_all",
    "ppermute": "ppermute",
}

#: higher-order primitives carrying sub-jaxprs that the walker must enter
HIGHER_ORDER_PRIMS = {
    "pjit", "closed_call", "core_call", "remat", "checkpoint", "remat2",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "custom_jvp_call_jaxpr", "shard_map", "scan", "while", "cond",
    "custom_lin", "custom_transpose_call",
}


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _aval_bytes(aval) -> int:
    try:
        return _aval_size(aval) * dtype_bytes(aval.dtype)
    except Exception:
        return 0


def eqn_io_bytes(eqn) -> int:
    """Fusion-agnostic bytes: all operands + all results."""
    total = 0
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            total += _aval_bytes(aval)
    for v in eqn.outvars:
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            total += _aval_bytes(aval)
    return total


def dot_general_flops(eqn) -> int:
    """2*M*N*K*batch flops for a dot_general from its dimension numbers."""
    (lhs_c, rhs_c), (lhs_b, rhs_b) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    batch = math.prod(lhs[d] for d in lhs_b) if lhs_b else 1
    k = math.prod(lhs[d] for d in lhs_c) if lhs_c else 1
    m = math.prod(lhs[d] for d in range(len(lhs)) if d not in lhs_b and d not in lhs_c)
    n = math.prod(rhs[d] for d in range(len(rhs)) if d not in rhs_b and d not in rhs_c)
    return 2 * batch * m * n * k


def conv_flops(eqn) -> int:
    """2 * out_elems * (in_channels/groups) * prod(kernel_spatial)."""
    out = eqn.outvars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    dnums = eqn.params["dimension_numbers"]
    groups = eqn.params.get("feature_group_count", 1)
    # rhs layout: (out_ch, in_ch/groups, *spatial) permuted by dnums.rhs_spec
    rhs_spec = dnums.rhs_spec  # (out_ch_dim, in_ch_dim, *spatial_dims)
    in_ch = rhs[rhs_spec[1]]
    kernel_spatial = math.prod(rhs[d] for d in rhs_spec[2:])
    return 2 * math.prod(out) * in_ch * kernel_spatial // max(groups, 1)


def eqn_cost(eqn) -> np.ndarray:
    """6-metric cost vector for a single *first-order* equation."""
    c = np.zeros(N_METRICS, dtype=np.float64)
    name = eqn.primitive.name
    if name in FREE_PRIMS:
        return c
    out_elems = sum(_aval_size(v.aval) for v in eqn.outvars
                    if hasattr(getattr(v, "aval", None), "shape"))
    c[I_BYTES] = eqn_io_bytes(eqn)
    if name == "dot_general":
        c[I_MXU] = dot_general_flops(eqn)
    elif name == "conv_general_dilated":
        c[I_MXU] = conv_flops(eqn)
    elif name in TRANSCENDENTAL_PRIMS:
        c[I_TRANS] = out_elems
        c[I_VPU] = out_elems
    elif name in GATHER_PRIMS:
        c[I_GATHER] = out_elems
        c[I_VPU] = out_elems  # address computation
    elif name in DATA_MOVEMENT_PRIMS:
        pass  # bytes only
    elif name.startswith("reduce_") or name in ("argmax", "argmin", "reduce"):
        in_elems = sum(_aval_size(v.aval) for v in eqn.invars
                       if hasattr(getattr(v, "aval", None), "shape"))
        c[I_VPU] = in_elems
    elif name == "cumsum" or name.startswith("cum"):
        in_elems = sum(_aval_size(v.aval) for v in eqn.invars
                       if hasattr(getattr(v, "aval", None), "shape"))
        c[I_VPU] = in_elems
    else:
        # generic elementwise (add/mul/select/compare/min/max/...)
        c[I_VPU] = out_elems
    return c


def collective_event_info(eqn) -> dict[str, Any]:
    """Extract CommEvent fields from a collective equation."""
    name = eqn.primitive.name
    kind = COLLECTIVE_PRIMS[name]
    aval = eqn.invars[0].aval
    shape = tuple(int(s) for s in aval.shape)
    dtype = str(np.dtype(aval.dtype).name) if hasattr(aval, "dtype") else "float32"
    ax = eqn.params.get("axis_name", eqn.params.get("axes", ()))
    if isinstance(ax, str):
        ax = (ax,)
    axes = tuple(str(a) for a in ax)
    detail: tuple = ()
    if kind == "ppermute":
        detail = ("rawperm", tuple(tuple(p) for p in eqn.params.get("perm", ())))
    elif kind == "all_to_all":
        detail = (int(eqn.params.get("split_axis", 0)), int(eqn.params.get("concat_axis", 0)))
    elif kind == "all_gather":
        detail = (int(eqn.params.get("all_gather_dimension", 0)),)
    elif kind == "reduce_scatter":
        detail = (int(eqn.params.get("scatter_dimension", 0)),)
    groups = eqn.params.get("axis_index_groups")
    if groups is not None:
        detail = detail + ("groups", tuple(tuple(g) for g in groups))
    return dict(kind=kind, shape=shape, dtype=dtype, axes=axes, detail=detail)


# ---------------------------------------------------------------------------
# Roofline-style time estimate for one event (used to apportion measured wall
# time over compute events, and by the ScalaBench-style baseline).
# ---------------------------------------------------------------------------

# TPU v5e-class chip constants (per the assignment):
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
VPU_RATE = 4e12              # elem-ops/s (8x128 lanes * ~4 GHz, order-of-magnitude)
TRANS_RATE = 0.5e12          # transcendental ops/s (slow path)
GATHER_RATE = 0.25e12        # irregular elems/s
SCAN_OVERHEAD = 1e-7         # s per sequential step (amortized TPU loop bookkeeping)


def roofline_seconds(vec: np.ndarray) -> float:
    """max-of-terms execution-time estimate for a 6-metric vector."""
    return max(
        vec[I_MXU] / PEAK_FLOPS_BF16,
        vec[I_BYTES] / HBM_BW,
        vec[I_VPU] / VPU_RATE,
        vec[I_TRANS] / TRANS_RATE,
        vec[I_GATHER] / GATHER_RATE,
        vec[I_SCAN] * SCAN_OVERHEAD,
    )


def comm_seconds(payload_bytes: int, n_devices: int = 2) -> float:
    """alpha-beta estimate for a collective (ring, bidirectional ICI)."""
    return 1e-6 + payload_bytes * max(n_devices - 1, 1) / (n_devices * ICI_BW)


def pretty_vector(vec: np.ndarray) -> str:
    return ", ".join(f"{n}={v:.3g}" for n, v in zip(METRIC_NAMES, vec))
