"""Inter-process compression (paper §2.6, Algorithm 1).

Input: one per-rank :class:`~repro.core.grammar.Grammar` each (own terminal
table, own rule ids).  Output: a :class:`MergedProgram` with

  * a single global terminal table          (§2.6.1, tree-merge semantics)
  * a global non-terminal rule set, merged bottom-up by rule depth (§2.6.2)
  * per-cluster merged main rules whose symbols carry rank sets (§2.6.3,
    Algorithm 1: normalized-edit-distance clustering + LCS merge)

The losslessness invariant — ``expand_rank(r)`` reproduces rank r's original
event-id sequence exactly, for every rank — is property-tested.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.events import is_comm
from repro.core.grammar import Grammar, Sym, TerminalTable

#: merged main-rule entry: (kind, ref, exp, ranks)
MainSym = tuple[str, int, int, frozenset]


@dataclasses.dataclass
class MergedProgram:
    table: TerminalTable
    rules: dict[int, list[Sym]]          # global non-terminals (no main here)
    mains: list[list[MainSym]]           # one merged main rule per cluster
    cluster_ranks: list[frozenset]       # ranks covered by each cluster
    n_ranks: int

    # -- lossless expansion ---------------------------------------------------

    def expand_rank(self, rank: int) -> list[int]:
        out: list[int] = []
        for main, ranks in zip(self.mains, self.cluster_ranks):
            if rank not in ranks:
                continue
            for kind, ref, exp, rset in main:
                if rank not in rset:
                    continue
                if kind == "t":
                    out.extend([ref] * exp)
                else:
                    self._expand(ref, exp, out)
        return out

    def _expand(self, rid: int, times: int, out: list[int]) -> None:
        body = self.rules[rid]
        for _ in range(times):
            for kind, ref, exp in body:
                if kind == "t":
                    out.extend([ref] * exp)
                else:
                    self._expand(ref, exp, out)

    # -- structure exposure (codegen lowering, §2.7) --------------------------
    #
    # Codegen lowers rule bodies into rolled loop nests; these accessors hand
    # it the structure it needs (evaluation order, nesting depth, per-rule
    # comm-axis footprints) so the emitter never re-derives grammar shape.

    def rule_topo_order(self) -> list[int]:
        """Children-first ordering of the global rules (deterministic: ids
        ascending within a level of readiness)."""
        seen: set[int] = set()
        out: list[int] = []

        def visit(rid: int) -> None:
            if rid in seen:
                return
            seen.add(rid)
            for kind, ref, _ in self.rules[rid]:
                if kind == "r":
                    visit(ref)
            out.append(rid)

        for rid in sorted(self.rules):
            visit(rid)
        return out

    def rule_depths(self) -> dict[int, int]:
        """Depth of every global rule (terminals = leaves), bottom-up."""
        depths: dict[int, int] = {}
        for rid in self.rule_topo_order():
            d = 1
            for kind, ref, _ in self.rules[rid]:
                if kind == "r":
                    d = max(d, 1 + depths[ref])
            depths[rid] = d
        return depths

    def max_rule_depth(self) -> int:
        """Deepest rule nesting — the scan-nest depth of compiled modules."""
        return max(self.rule_depths().values(), default=0)

    def rule_histogram(self, n_bins: int | None = None):
        """Depth-binned transitive rule-instantiation counts over the
        whole merged program (:func:`repro.core.grammar.rule_histogram`
        applied to a synthetic main that concatenates every merged main
        rule, each entry weighted by its rank-set size) — the program's
        shape as a small integer vector, rank-weighted so an SPMD rule
        executed by 64 ranks counts 64×."""
        from repro.core.grammar import GRAMMAR_HIST_BINS, rule_histogram
        n_bins = GRAMMAR_HIST_BINS if n_bins is None else n_bins
        synth = max(self.rules, default=-1) + 1
        body: list[Sym] = [(k, ref, exp * len(ranks))
                           for main in self.mains
                           for k, ref, exp, ranks in main]
        return rule_histogram({**self.rules, synth: body}, main_id=synth,
                              n_bins=n_bins)

    def rule_comm_axes(self) -> dict[int, frozenset]:
        """Mesh axes touched by comm terminals reachable from each rule,
        computed once bottom-up (drives per-group device hints)."""
        axes: dict[int, frozenset] = {}
        for rid in self.rule_topo_order():
            acc: set[str] = set()
            for kind, ref, _ in self.rules[rid]:
                if kind == "t":
                    ev = self.table.events[ref]
                    if is_comm(ev):
                        acc.update(ev.axes)
                else:
                    acc |= axes[ref]
            axes[rid] = frozenset(acc)
        return axes

    # -- size accounting -------------------------------------------------------

    def n_symbols(self) -> int:
        n = sum(len(b) for b in self.rules.values())
        n += sum(len(m) for m in self.mains)
        return n

    def encoded_size_bytes(self) -> int:
        """Symbols ~9B, rank sets ~4B+4B/rank-range, terminals by key size."""
        sym = 9 * self.n_symbols() + 4 * len(self.rules)
        ranks = sum(4 + 4 * _rankset_cost(s[3], self.n_ranks)
                    for m in self.mains for s in m)
        table = sum(len(ev.key()) + 2 for ev in self.table.events)
        return sym + ranks + table


def _rankset_cost(rs: frozenset, n_ranks: int) -> int:
    """Encoded cost of a rank set: 0 if all ranks, else #contiguous runs."""
    if len(rs) == n_ranks:
        return 0
    runs, prev = 0, None
    for r in sorted(rs):
        if prev is None or r != prev + 1:
            runs += 1
        prev = r
    return runs


# ---------------------------------------------------------------------------
# §2.6.1 terminal-table merge
# ---------------------------------------------------------------------------


def merge_terminal_tables(tables: Sequence[TerminalTable],
                          ) -> tuple[TerminalTable, list[dict[int, int]]]:
    """Union all per-rank tables into one global table.

    Deployed multi-controller this is the paper's log2(P)-round tree merge
    followed by a root broadcast; the result (global id per unique key,
    first-use order) is identical, so the host implementation is sequential.
    """
    glob = TerminalTable()
    maps: list[dict[int, int]] = []
    for tab in tables:
        m = {local: glob.intern(ev) for local, ev in enumerate(tab.events)}
        maps.append(m)
    return glob, maps


def corpus_terminal_table(programs: Sequence[MergedProgram],
                          ) -> tuple[TerminalTable, list[dict[int, int]]]:
    """§2.6.1 applied once more, across scenarios: union the merged tables
    of several synthesized programs into one corpus-level terminal table.

    Compute terminals keyed by joint cluster id (``X|<cid>``) and identical
    comm terminals unify across scenarios, so one block-combination fit per
    corpus terminal covers every scenario that uses it.  Returns the global
    table plus one per-scenario ``{scenario gid -> corpus gid}`` map.
    The union's identity (:func:`table_fingerprint`) versions downstream
    caches: a fit cached under one table version is only reusable while the
    terminal it fits still means the same thing.
    """
    return merge_terminal_tables([p.table for p in programs])


def compute_gid_index(table: TerminalTable) -> dict[int, int]:
    """``{joint cluster id -> corpus gid}`` over a corpus terminal
    table's compute terminals.

    The inverse lookup the serve tier needs: a query trace's metric rows
    map onto joint cluster ids (``ClusterIndex.match_clusters``), and
    this index maps those onto the corpus-gid-keyed fit coefficients
    (``CorpusResult.fits``) — pure dict work, no clustering or fitting.
    Cluster ids are unique across a corpus table's compute terminals by
    construction (they key the union, ``X|<cid>``)."""
    return {ev.cluster_id: gid for gid, ev in enumerate(table.events)
            if not is_comm(ev) and ev.cluster_id >= 0}


def table_fingerprint(table: TerminalTable) -> str:
    """Content version of a terminal table: sha256 over the ordered
    terminal keys.

    Two unions with the same fingerprint assign identical meanings to
    every gid prefix they share, so per-terminal artifacts (block-
    combination fits, codegen combos) keyed by ``(fingerprint-compatible
    terminal key, target)`` survive incremental re-unions; any semantic
    drift (a cluster id re-used for a different behaviour) changes the
    fingerprint and invalidates them.
    """
    import hashlib

    h = hashlib.sha256()
    for ev in table.events:
        h.update(ev.key().encode())
        h.update(b"\x00")
    return h.hexdigest()


# ---------------------------------------------------------------------------
# §2.6.2 non-terminal merge (bottom-up by depth, structural hashing)
# ---------------------------------------------------------------------------


def merge_nonterminals(grammars: Sequence[Grammar],
                       tmaps: Sequence[dict[int, int]],
                       ) -> tuple[dict[int, list[Sym]], list[dict[int, int]]]:
    """Merge rules across ranks: identical bodies (in global ids) unify.

    Processing by increasing depth guarantees child rules are canonical
    before parents are compared — the paper's observation that equal-depth
    comparison from the bottom is both necessary and sufficient.
    """
    sig2gid: dict[tuple, int] = {}
    glob: dict[int, list[Sym]] = {}
    rmaps: list[dict[int, int]] = []
    for g, tmap in zip(grammars, tmaps):
        depths = g.rule_depths()
        rmap: dict[int, int] = {}
        for rid in sorted((r for r in g.rules if r != g.main_id),
                          key=lambda r: depths[r]):
            body = []
            for kind, ref, exp in g.rules[rid]:
                gref = tmap[ref] if kind == "t" else rmap[ref]
                body.append((kind, gref, exp))
            sig = tuple(body)
            gid = sig2gid.get(sig)
            if gid is None:
                gid = len(sig2gid)
                sig2gid[sig] = gid
                glob[gid] = body
            rmap[rid] = gid
        rmaps.append(rmap)
    return glob, rmaps


def _globalize_main(g: Grammar, tmap: dict[int, int], rmap: dict[int, int],
                    ) -> tuple[Sym, ...]:
    out = []
    for kind, ref, exp in g.rules[g.main_id]:
        gref = tmap[ref] if kind == "t" else rmap[ref]
        out.append((kind, gref, exp))
    return tuple(out)


# ---------------------------------------------------------------------------
# §2.6.3 main-rule merge (Algorithm 1)
# ---------------------------------------------------------------------------


def levenshtein(a: Sequence, b: Sequence) -> int:
    """Classic O(len(a)*len(b)) token edit distance."""
    if len(a) < len(b):
        a, b = b, a
    prev = list(range(len(b) + 1))
    for i, ta in enumerate(a, 1):
        cur = [i]
        for j, tb in enumerate(b, 1):
            cur.append(min(prev[j] + 1, cur[-1] + 1,
                           prev[j - 1] + (ta != tb)))
        prev = cur
    return prev[-1]


def difference_degree(a: Sequence, b: Sequence) -> float:
    """Paper: Δ_{a,b} = d_{a,b} / max(l_a, l_b)."""
    m = max(len(a), len(b))
    return levenshtein(a, b) / m if m else 0.0


def _lcs_pairs(a: Sequence, b: Sequence) -> list[tuple[int, int]]:
    """Index pairs of one longest common subsequence."""
    la, lb = len(a), len(b)
    dp = [[0] * (lb + 1) for _ in range(la + 1)]
    for i in range(la - 1, -1, -1):
        for j in range(lb - 1, -1, -1):
            dp[i][j] = (dp[i + 1][j + 1] + 1 if a[i] == b[j]
                        else max(dp[i + 1][j], dp[i][j + 1]))
    out, i, j = [], 0, 0
    while i < la and j < lb:
        if a[i] == b[j]:
            out.append((i, j))
            i += 1
            j += 1
        elif dp[i + 1][j] >= dp[i][j + 1]:
            i += 1
        else:
            j += 1
    return out


def _merge_into(merged: list[MainSym], body: tuple[Sym, ...],
                ranks: frozenset) -> list[MainSym]:
    """LCS-merge one more rank-group's main-rule body into the running merge.

    LCS symbols get the union rank set; off-LCS symbols keep their own
    rank list, placed in order (paper §2.6.3 merge procedure steps 1-3).
    """
    a_toks = [(k, r, e) for k, r, e, _ in merged]
    pairs = _lcs_pairs(a_toks, list(body))
    out: list[MainSym] = []
    ai = bi = 0
    for ia, ib in pairs:
        out.extend(merged[ai:ia])
        out.extend((k, r, e, ranks) for k, r, e in body[bi:ib])
        k, r, e, rs = merged[ia]
        out.append((k, r, e, rs | ranks))
        ai, bi = ia + 1, ib + 1
    out.extend(merged[ai:])
    out.extend((k, r, e, ranks) for k, r, e in body[bi:])
    return out


def merge_main_rules(mains: Sequence[tuple[Sym, ...]],
                     threshold: float = 0.5,
                     ) -> tuple[list[list[MainSym]], list[frozenset]]:
    """Algorithm 1: dedupe -> Δ-threshold clustering -> LCS merge.

    ``mains[r]`` is rank r's globalized main-rule body.  Identical bodies are
    grouped first (the overwhelmingly common SPMD case), so the quadratic
    distance matrix is over *distinct* bodies only.
    """
    groups: dict[tuple, list[int]] = {}
    for r, body in enumerate(mains):
        groups.setdefault(body, []).append(r)
    distinct = list(groups)
    granks = [frozenset(groups[b]) for b in distinct]

    # Δ-threshold greedy clustering over distinct bodies (paper: "there is no
    # effect of merging in some cases" -> Δ above threshold starts a cluster)
    unmerged = list(range(len(distinct)))
    clusters: list[list[int]] = []
    while unmerged:
        leader = unmerged.pop(0)
        cluster = [leader]
        rest = []
        for j in unmerged:
            if difference_degree(distinct[leader], distinct[j]) <= threshold:
                cluster.append(j)
            else:
                rest.append(j)
        unmerged = rest
        clusters.append(cluster)

    merged_mains: list[list[MainSym]] = []
    cluster_ranks: list[frozenset] = []
    for cluster in clusters:
        lead = cluster[0]
        merged = [(k, r, e, granks[lead]) for k, r, e in distinct[lead]]
        ranks = granks[lead]
        for j in cluster[1:]:
            merged = _merge_into(merged, distinct[j], granks[j])
            ranks = ranks | granks[j]
        merged_mains.append(merged)
        cluster_ranks.append(ranks)
    return merged_mains, cluster_ranks


# ---------------------------------------------------------------------------
# top-level
# ---------------------------------------------------------------------------


def merge_grammars(grammars: Sequence[Grammar], threshold: float = 0.5,
                   ) -> MergedProgram:
    tables = [g.table for g in grammars]
    glob_table, tmaps = merge_terminal_tables(tables)
    glob_rules, rmaps = merge_nonterminals(grammars, tmaps)
    mains = [_globalize_main(g, tm, rm)
             for g, tm, rm in zip(grammars, tmaps, rmaps)]
    merged_mains, cluster_ranks = merge_main_rules(mains, threshold)
    return MergedProgram(table=glob_table, rules=glob_rules,
                         mains=merged_mains, cluster_ranks=cluster_ranks,
                         n_ranks=len(grammars))
