"""Event model for Siesta-JAX traces.

The paper (§2.2-2.3) records two event kinds:
  * communication events -- MPI calls with full parameter info (lossless), with
    relative-rank encoding for point-to-point targets and canonicalized handles;
  * computation events   -- everything between two communication events,
    characterized by a 6-metric hardware-counter vector (virtual ``MPI_Compute``).

This module is the TPU/JAX re-founding: communication events are mesh
collectives (psum / all_gather / reduce_scatter / all_to_all / ppermute), and
computation events carry the 6-metric TPU cost vector of
:mod:`repro.core.metrics`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable, Sequence

import numpy as np

# The 6 TPU performance metrics (the analog of the paper's Table 1).
# INS/CYC/LST/L1_DCM/BR_CN/MSP  ->  see DESIGN.md §2 for the mapping.
METRIC_NAMES: tuple[str, ...] = (
    "mxu_flops",        # MXU (dot/conv) floating point ops
    "vpu_elems",        # VPU elementwise/reduction element ops
    "hbm_bytes",        # fusion-agnostic memory traffic (operands + results)
    "transcendentals",  # exp/log/tanh/erf/... slow-path VPU ops
    "gather_elems",     # irregularly-addressed elements (gather/scatter/take)
    "scan_steps",       # sequential loop iterations (serialization hazard)
)
N_METRICS = len(METRIC_NAMES)

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8, "complex64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1, "int4": 1, "uint4": 1,
}


def dtype_bytes(dtype: Any) -> int:
    return _DTYPE_BYTES.get(str(np.dtype(dtype).name) if not isinstance(dtype, str) else dtype,
                            _DTYPE_BYTES.get(str(dtype), 4))


# ---------------------------------------------------------------------------
# Communication events
# ---------------------------------------------------------------------------

#: collective kinds we record.  ``ppermute`` is the point-to-point analog
#: (MPI_Send/Recv); the rest are MPI collectives.
COMM_KINDS = (
    "psum", "all_gather", "reduce_scatter", "all_to_all", "ppermute",
    "pmax", "pmin", "broadcast",
)


def encode_relative_perm(perm: Sequence[tuple[int, int]], axis_size: int):
    """Relative-rank encoding of a ppermute permutation (paper §2.2, Fig. 2).

    If every (src, dst) pair satisfies ``dst - src ≡ k (mod axis_size)`` the
    whole permutation compresses to the single offset ``k`` plus the
    participation set (stored as a canonical mask tuple only when not all
    ranks participate).  Otherwise the sorted pair tuple is kept verbatim
    (still lossless).
    """
    if not perm:
        return ("empty",)
    offsets = {(dst - src) % axis_size for src, dst in perm}
    srcs = sorted(src for src, _ in perm)
    full = len(perm) == axis_size and srcs == list(range(axis_size))
    if len(offsets) == 1:
        off = offsets.pop()
        if full:
            return ("shift", off)
        # partial participation: mask of source ranks (boundary effects --
        # the non-periodic stencil case of paper Fig. 2).
        return ("shift", off, tuple(srcs))
    return ("perm", tuple(sorted((s, d) for s, d in perm)))


def decode_relative_perm(detail: tuple, axis_size: int) -> list[tuple[int, int]]:
    """Inverse of :func:`encode_relative_perm` (losslessness guarantee)."""
    tag = detail[0]
    if tag == "empty":
        return []
    if tag == "shift":
        off = detail[1]
        srcs = detail[2] if len(detail) > 2 else range(axis_size)
        return [(s, (s + off) % axis_size) for s in srcs]
    return [tuple(p) for p in detail[1]]


@dataclasses.dataclass(frozen=True)
class CommEvent:
    """A lossless record of one collective (the MPI-call analog)."""
    kind: str                       # one of COMM_KINDS
    shape: tuple[int, ...]          # per-device payload shape
    dtype: str
    axes: tuple[str, ...]           # mesh axes the collective spans
    detail: tuple = ()              # e.g. relative-rank encoding for ppermute

    def __post_init__(self):
        if self.kind not in COMM_KINDS:
            raise ValueError(f"unknown collective kind {self.kind!r}")

    @property
    def payload_bytes(self) -> int:
        n = math.prod(self.shape) if self.shape else 1
        return n * dtype_bytes(self.dtype)

    def key(self) -> str:
        """Canonical string key (terminal-table identity, paper §2.5)."""
        return (f"C|{self.kind}|{'x'.join(map(str, self.shape))}|{self.dtype}"
                f"|{','.join(self.axes)}|{self.detail!r}")


# ---------------------------------------------------------------------------
# Computation events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ComputeEvent:
    """A virtual ``MPI_Compute`` call: the 6-metric cost of one compute span."""
    metrics: tuple[float, ...]      # aligned with METRIC_NAMES
    cluster_id: int = -1            # assigned by cluster_compute_events

    def __post_init__(self):
        if len(self.metrics) != N_METRICS:
            raise ValueError(f"expected {N_METRICS} metrics")

    @property
    def vector(self) -> np.ndarray:
        return np.asarray(self.metrics, dtype=np.float64)

    def key(self) -> str:
        if self.cluster_id >= 0:
            return f"X|{self.cluster_id}"
        return "X|" + "|".join(f"{m:.6g}" for m in self.metrics)


Event = Any  # CommEvent | ComputeEvent


def is_comm(ev: Event) -> bool:
    return isinstance(ev, CommEvent)


def is_compute(ev: Event) -> bool:
    return isinstance(ev, ComputeEvent)


# ---------------------------------------------------------------------------
# Computation-event clustering (paper §2.3: "we set a threshold to cluster
# similar computation events into one event")
# ---------------------------------------------------------------------------


def _quantize(vec: np.ndarray, rel_tol: float) -> tuple[int, ...]:
    """Log-space bucketing: two metric vectors land in the same bucket when
    every metric agrees within a multiplicative factor of ~(1 + rel_tol)."""
    width = math.log1p(rel_tol)
    out = []
    for v in vec:
        if v <= 0:
            out.append(-1)
        else:
            out.append(int(math.floor(math.log(v + 1.0) / width)))
    return tuple(out)


def cluster_compute_events(
    events: Iterable[ComputeEvent], rel_tol: float = 0.05
) -> tuple[list[ComputeEvent], dict[int, np.ndarray]]:
    """Assign cluster ids; each cluster's representative vector is the mean.

    Two passes: log-space bucketing (O(n)), then a greedy merge of buckets
    whose representatives agree within ``rel_tol`` on every metric — so
    near-identical events straddling a bucket boundary still unify (the
    paper's "threshold to cluster similar computation events").
    """
    buckets: dict[tuple[int, ...], int] = {}
    sums: dict[int, np.ndarray] = {}
    counts: dict[int, int] = {}
    assigned: list[tuple[ComputeEvent, int]] = []
    for ev in events:
        q = _quantize(ev.vector, rel_tol)
        if q not in buckets:
            buckets[q] = len(buckets)
        bid = buckets[q]
        sums[bid] = sums.get(bid, 0) + ev.vector
        counts[bid] = counts.get(bid, 0) + 1
        assigned.append((ev, bid))

    # merge close buckets (greedy, deterministic by bucket id)
    bids = sorted(sums)
    bucket_rep = {b: sums[b] / counts[b] for b in bids}
    remap: dict[int, int] = {}
    cluster_reps: list[np.ndarray] = []
    cluster_w: list[int] = []
    for b in bids:
        v = bucket_rep[b]
        placed = False
        for cid, rep in enumerate(cluster_reps):
            denom = np.maximum(np.maximum(np.abs(rep), np.abs(v)), 1e-30)
            if np.all(np.abs(rep - v) / denom <= rel_tol):
                w = cluster_w[cid]
                cluster_reps[cid] = (rep * w + v * counts[b]) / (w + counts[b])
                cluster_w[cid] = w + counts[b]
                remap[b] = cid
                placed = True
                break
        if not placed:
            remap[b] = len(cluster_reps)
            cluster_reps.append(v.copy())
            cluster_w.append(counts[b])

    out = [dataclasses.replace(ev, cluster_id=remap[bid])
           for ev, bid in assigned]
    reps = {cid: rep for cid, rep in enumerate(cluster_reps)}
    return out, reps
