"""Event model for Siesta-JAX traces.

The paper (§2.2-2.3) records two event kinds:
  * communication events -- MPI calls with full parameter info (lossless), with
    relative-rank encoding for point-to-point targets and canonicalized handles;
  * computation events   -- everything between two communication events,
    characterized by a 6-metric hardware-counter vector (virtual ``MPI_Compute``).

This module is the TPU/JAX re-founding: communication events are mesh
collectives (psum / all_gather / reduce_scatter / all_to_all / ppermute), and
computation events carry the 6-metric TPU cost vector of
:mod:`repro.core.metrics`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable, Sequence

import numpy as np

# The 6 TPU performance metrics (the analog of the paper's Table 1).
# INS/CYC/LST/L1_DCM/BR_CN/MSP  ->  see DESIGN.md §2 for the mapping.
METRIC_NAMES: tuple[str, ...] = (
    "mxu_flops",        # MXU (dot/conv) floating point ops
    "vpu_elems",        # VPU elementwise/reduction element ops
    "hbm_bytes",        # fusion-agnostic memory traffic (operands + results)
    "transcendentals",  # exp/log/tanh/erf/... slow-path VPU ops
    "gather_elems",     # irregularly-addressed elements (gather/scatter/take)
    "scan_steps",       # sequential loop iterations (serialization hazard)
)
N_METRICS = len(METRIC_NAMES)

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8, "complex64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1, "int4": 1, "uint4": 1,
}


def dtype_bytes(dtype: Any) -> int:
    """Payload bytes per element; unknown dtypes default to 4."""
    name = dtype if isinstance(dtype, str) else np.dtype(dtype).name
    return _DTYPE_BYTES.get(name, 4)


# ---------------------------------------------------------------------------
# Communication events
# ---------------------------------------------------------------------------

#: collective kinds we record.  ``ppermute`` is the point-to-point analog
#: (MPI_Send/Recv); the rest are MPI collectives.
COMM_KINDS = (
    "psum", "all_gather", "reduce_scatter", "all_to_all", "ppermute",
    "pmax", "pmin", "broadcast",
)


def encode_relative_perm(perm: Sequence[tuple[int, int]], axis_size: int):
    """Relative-rank encoding of a ppermute permutation (paper §2.2, Fig. 2).

    If every (src, dst) pair satisfies ``dst - src ≡ k (mod axis_size)`` the
    whole permutation compresses to the single offset ``k`` plus the
    participation set (stored as a canonical mask tuple only when not all
    ranks participate).  Otherwise the sorted pair tuple is kept verbatim
    (still lossless).
    """
    if not perm:
        return ("empty",)
    offsets = {(dst - src) % axis_size for src, dst in perm}
    srcs = sorted(src for src, _ in perm)
    full = len(perm) == axis_size and srcs == list(range(axis_size))
    if len(offsets) == 1:
        off = offsets.pop()
        if full:
            return ("shift", off)
        # partial participation: mask of source ranks (boundary effects --
        # the non-periodic stencil case of paper Fig. 2).
        return ("shift", off, tuple(srcs))
    return ("perm", tuple(sorted((s, d) for s, d in perm)))


def decode_relative_perm(detail: tuple, axis_size: int) -> list[tuple[int, int]]:
    """Inverse of :func:`encode_relative_perm` (losslessness guarantee)."""
    tag = detail[0]
    if tag == "empty":
        return []
    if tag == "shift":
        off = detail[1]
        srcs = detail[2] if len(detail) > 2 else range(axis_size)
        return [(s, (s + off) % axis_size) for s in srcs]
    return [tuple(p) for p in detail[1]]


@dataclasses.dataclass(frozen=True)
class CommEvent:
    """A lossless record of one collective (the MPI-call analog)."""
    kind: str                       # one of COMM_KINDS
    shape: tuple[int, ...]          # per-device payload shape
    dtype: str
    axes: tuple[str, ...]           # mesh axes the collective spans
    detail: tuple = ()              # e.g. relative-rank encoding for ppermute

    def __post_init__(self):
        if self.kind not in COMM_KINDS:
            raise ValueError(f"unknown collective kind {self.kind!r}")

    @property
    def payload_bytes(self) -> int:
        n = math.prod(self.shape) if self.shape else 1
        return n * dtype_bytes(self.dtype)

    def key(self) -> str:
        """Canonical string key (terminal-table identity, paper §2.5)."""
        return (f"C|{self.kind}|{'x'.join(map(str, self.shape))}|{self.dtype}"
                f"|{','.join(self.axes)}|{self.detail!r}")


# ---------------------------------------------------------------------------
# Computation events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ComputeEvent:
    """A virtual ``MPI_Compute`` call: the 6-metric cost of one compute span."""
    metrics: tuple[float, ...]      # aligned with METRIC_NAMES
    cluster_id: int = -1            # assigned by cluster_compute_events

    def __post_init__(self):
        if len(self.metrics) != N_METRICS:
            raise ValueError(f"expected {N_METRICS} metrics")

    @property
    def vector(self) -> np.ndarray:
        return np.asarray(self.metrics, dtype=np.float64)

    def key(self) -> str:
        if self.cluster_id >= 0:
            return f"X|{self.cluster_id}"
        return "X|" + "|".join(f"{m:.6g}" for m in self.metrics)


Event = Any  # CommEvent | ComputeEvent


def is_comm(ev: Event) -> bool:
    return isinstance(ev, CommEvent)


def is_compute(ev: Event) -> bool:
    return isinstance(ev, ComputeEvent)


# ---------------------------------------------------------------------------
# Computation-event clustering (paper §2.3: "we set a threshold to cluster
# similar computation events into one event")
# ---------------------------------------------------------------------------


def quantize_metrics(metrics: np.ndarray, rel_tol: float = 0.05,
                     ) -> np.ndarray:
    """Log-space quantization keys, ``(n, N_METRICS)`` int64.

    Each element quantizes to ``floor(log(v + 1) / log1p(rel_tol))``
    (``-1`` for non-positive metrics).  Pass 1 of the clustering; also the
    bucket identity the incremental :class:`repro.core.corpus_store.
    ClusterIndex` matches newly ingested events against.
    """
    metrics = np.asarray(metrics, dtype=np.float64)
    if metrics.ndim != 2 or metrics.shape[1] != N_METRICS:
        raise ValueError(f"expected (n, {N_METRICS}) metrics array")
    width = math.log1p(rel_tol)
    q = np.full(metrics.shape, -1, dtype=np.int64)
    pos = metrics > 0
    # np.log is assumed to agree with the scalar libm log the per-event
    # original used — true on every platform we run, and pinned per
    # platform by the frontend_reference parity tests (a 1-ULP divergence
    # at a bucket boundary would fail them loudly, not silently)
    q[pos] = np.floor(np.log(metrics[pos] + 1.0) / width).astype(np.int64)
    return q


def bucketize_keys(q: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Number quantization keys by first appearance in stream order.

    Returns ``(bucket_ids, uniq_keys)`` where ``uniq_keys[b]`` is the key
    of bucket ``b`` (buckets ordered by first appearance — the order the
    greedy merge pass consumes them in).
    """
    uq, first, inv = np.unique(q, axis=0, return_index=True,
                               return_inverse=True)
    inv = inv.reshape(-1)   # some numpy versions return (n, 1) for axis=0
    order = np.argsort(first, kind="stable")   # buckets by first appearance
    bucket_of = np.empty(len(uq), dtype=np.int64)
    bucket_of[order] = np.arange(len(uq))
    return bucket_of[inv], uq[order]


def merge_buckets(sums: np.ndarray, counts: np.ndarray,
                  rel_tol: float = 0.05,
                  ) -> tuple[np.ndarray, dict[int, np.ndarray]]:
    """Greedy merge of buckets whose mean vectors agree within ``rel_tol``
    on every metric, in bucket-id order — so near-identical events
    straddling a bucket boundary still unify (the paper's "threshold to
    cluster similar computation events").

    Pass 2 of the clustering, O(n_buckets²·6) — independent of trace
    length, which is what lets the incremental corpus index re-derive
    cluster representatives from its running bucket table without ever
    re-touching event data.  Returns ``(remap, reps)``: the bucket→cluster
    map and the weighted-mean representative per cluster.
    """
    n_buckets = len(counts)
    remap = np.empty(n_buckets, dtype=np.int64)
    cluster_reps: list[np.ndarray] = []
    cluster_w: list[int] = []
    for b in range(n_buckets):
        v = sums[b] / counts[b]
        placed = False
        for cid, rep in enumerate(cluster_reps):
            denom = np.maximum(np.maximum(np.abs(rep), np.abs(v)), 1e-30)
            if np.all(np.abs(rep - v) / denom <= rel_tol):
                w = cluster_w[cid]
                cluster_reps[cid] = (rep * w + v * counts[b]) / (w + counts[b])
                cluster_w[cid] = w + counts[b]
                remap[b] = cid
                placed = True
                break
        if not placed:
            remap[b] = len(cluster_reps)
            cluster_reps.append(np.array(v, dtype=np.float64, copy=True))
            cluster_w.append(int(counts[b]))
    reps = {cid: rep for cid, rep in enumerate(cluster_reps)}
    return remap, reps


def cluster_vectors(metrics: np.ndarray, rel_tol: float = 0.05,
                    ) -> tuple[np.ndarray, dict[int, np.ndarray]]:
    """Columnar clustering of 6-metric vectors: the vectorized hot path.

    ``metrics`` is ``(n_events, N_METRICS)`` float64.  Two passes, both
    deterministic in stream order:

    1. log-space bucketing (:func:`quantize_metrics` +
       :func:`bucketize_keys`) — buckets are numbered by first appearance,
       and per-bucket sums accumulate in stream order (``np.add.at`` is an
       unbuffered in-order accumulation, so the float64 addition order
       matches the per-event loop it replaced bit for bit);
    2. the greedy bucket merge (:func:`merge_buckets`).

    Returns ``(cluster_ids, reps)``: one cluster id per input row and the
    weighted-mean representative vector per cluster.
    """
    metrics = np.asarray(metrics, dtype=np.float64)
    if metrics.ndim != 2 or metrics.shape[1] != N_METRICS:
        raise ValueError(f"expected (n, {N_METRICS}) metrics array")
    n = metrics.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int64), {}

    bucket_ids, uq = bucketize_keys(quantize_metrics(metrics, rel_tol))
    n_buckets = len(uq)
    sums = np.zeros((n_buckets, N_METRICS), dtype=np.float64)
    np.add.at(sums, bucket_ids, metrics)
    counts = np.bincount(bucket_ids, minlength=n_buckets)

    remap, reps = merge_buckets(sums, counts, rel_tol)
    return remap[bucket_ids], reps


def scenario_bucket_table(metrics: np.ndarray, rel_tol: float = 0.05,
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray]:
    """Pass-1 bucket table of ONE scenario: ``(keys, psums, counts,
    local_ids)``.

    ``keys`` are the scenario's distinct quantization keys in
    first-appearance order, ``psums[b]`` the float64 sum of bucket ``b``'s
    rows accumulated *in the scenario's own event order* (``np.add.at``),
    ``counts[b]`` its row count, and ``local_ids`` the per-row bucket id.

    The partial sums are label-invariant — each bucket's value is the
    in-order sum of its own rows, regardless of how buckets are numbered —
    which is what lets :func:`combine_bucket_tables` renumber and refold
    them under corpus append *and* removal without re-touching event data.
    """
    metrics = np.asarray(metrics, dtype=np.float64)
    if metrics.ndim != 2 or metrics.shape[1] != N_METRICS:
        raise ValueError(f"expected (n, {N_METRICS}) metrics array")
    if metrics.shape[0] == 0:
        return (np.zeros((0, N_METRICS), dtype=np.int64),
                np.zeros((0, N_METRICS), dtype=np.float64),
                np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
    local_ids, uniq = bucketize_keys(quantize_metrics(metrics, rel_tol))
    psums = np.zeros((len(uniq), N_METRICS), dtype=np.float64)
    np.add.at(psums, local_ids, metrics)
    counts = np.bincount(local_ids, minlength=len(uniq)).astype(np.int64)
    return uniq, psums, counts, local_ids


def combine_bucket_tables(tables: Sequence[tuple], rel_tol: float = 0.05,
                          return_state: bool = False):
    """Fold per-scenario bucket tables (list order = manifest order) into
    the joint corpus clustering: ``(per-scenario cluster_ids, reps)``.

    Global buckets are numbered by first appearance across the tables —
    identical to the numbering ``bucketize_keys`` would assign over the
    concatenated event stream, because each scenario's local buckets are
    already in first-appearance order.  Each global bucket's float64 sum
    is the **ordered sum of per-scenario partial sums**: for a bucket
    touched by scenarios ``s1 < s2 < …`` the total is
    ``(psum_s1 + psum_s2) + …``, folded left-to-right in list order.

    This is *the* corpus clustering semantics (see
    :class:`repro.core.corpus_store.ClusterIndex`): a pure function of the
    ordered scenario list, exactly incremental under append (a new table
    folds in last), and sublinear under removal (drop a table, renumber,
    refold — no event data touched).  For a single table it is
    bit-identical to :func:`cluster_vectors`; for several it differs from
    event-order accumulation over the concatenation only in the float
    association at scenario boundaries (``(Σa + b1) + b2`` vs
    ``Σa + (b1 + b2)``) — the documented invariant change that bought
    O(remaining) removal.

    ``return_state=True`` additionally returns the derivation internals
    ``{"by_key", "remap", "reps", "n_buckets"}`` (key bytes → global
    bucket id, bucket → cluster remap) so the corpus index can answer
    nearest-cluster lookups without re-deriving.
    """
    by_key: dict[bytes, int] = {}
    gids_per: list[np.ndarray] = []
    for keys, _psums, _counts, _ids in tables:
        g = np.empty(len(keys), dtype=np.int64)
        for j, k in enumerate(np.ascontiguousarray(keys, dtype=np.int64)):
            kb = k.tobytes()
            gid = by_key.get(kb)
            if gid is None:
                gid = len(by_key)
                by_key[kb] = gid
            g[j] = gid
        gids_per.append(g)
    n_buckets = len(by_key)
    sums = np.zeros((n_buckets, N_METRICS), dtype=np.float64)
    counts = np.zeros(n_buckets, dtype=np.int64)
    for (_keys, psums, pcounts, _ids), g in zip(tables, gids_per):
        # one partial per (scenario, bucket): fancy += folds this
        # scenario's partials onto the running sums in list order
        sums[g] += psums
        counts[g] += pcounts
    if n_buckets == 0:
        remap, reps = np.zeros(0, dtype=np.int64), {}
    else:
        remap, reps = merge_buckets(sums, counts, rel_tol)
    ids_list = [remap[g[ids]] if len(ids) else np.zeros(0, dtype=np.int64)
                for (_k, _p, _c, ids), g in zip(tables, gids_per)]
    if return_state:
        return ids_list, reps, {"by_key": by_key, "remap": remap,
                                "reps": reps, "n_buckets": n_buckets}
    return ids_list, reps


def cluster_corpus(metrics_list: Sequence[np.ndarray],
                   rel_tol: float = 0.05,
                   ) -> tuple[list[np.ndarray], dict[int, np.ndarray]]:
    """Joint clustering of several scenarios' metric arrays, in order —
    the batch-path twin of the streaming
    :class:`repro.core.corpus_store.ClusterIndex` (both build on
    :func:`scenario_bucket_table` + :func:`combine_bucket_tables`, so the
    two stay bit-identical by construction)."""
    tables = [scenario_bucket_table(m, rel_tol) for m in metrics_list]
    return combine_bucket_tables(tables, rel_tol)


def cluster_compute_events(
    events: Iterable[ComputeEvent], rel_tol: float = 0.05
) -> tuple[list[ComputeEvent], dict[int, np.ndarray]]:
    """Assign cluster ids; each cluster's representative vector is the mean.

    Event-list front-end over :func:`cluster_vectors` (the columnar trace
    IR path in :mod:`repro.core.trace_ir` calls it directly on the stored
    metrics array and never materializes events).
    """
    events = list(events)
    if not events:
        return [], {}
    metrics = np.stack([ev.vector for ev in events])
    cids, reps = cluster_vectors(metrics, rel_tol)
    out = [dataclasses.replace(ev, cluster_id=int(c))
           for ev, c in zip(events, cids)]
    return out, reps
