"""Reference Sequitur — the preserved object-graph implementation.

This module keeps, verbatim, the linked-``Node``/``Rule`` Sequitur that
:mod:`repro.core.sequitur` (the flat-array kernel) replaced.  It is the
**parity oracle**, mirroring the :mod:`repro.core.frontend_reference`
convention: the flat kernel must emit ``to_json``-identical grammars to
this implementation on every stream (tests/test_sequitur_kernel.py and
the CI grammar-parity step pin that).  Keep it in sync with any grammar
*semantics* change; never "optimize" it.

Classic Sequitur [Nevill-Manning & Witten 1997] maintains two constraints over
an online-constructed context-free grammar:

  (1) digram uniqueness -- any adjacent symbol pair occurs at most once;
  (2) rule utility      -- every rule (except the main rule) is used >= twice.

The paper adds the Omnisc'IO-style run-length constraint:

  (3) adjacent equal symbols a^i a^j are merged into a^{i+j},

which turns the O(log n) encoding of a loop that repeats n times into O(1).

Symbols are integers (terminal ids) or :class:`Rule` references; every symbol
occurrence carries an exponent.  ``push_run`` lets a caller append an already
run-length-compressed repetition in O(1) -- used by the tracer for
collective-free ``lax.scan`` bodies with huge trip counts.
"""
from __future__ import annotations

from typing import Iterable, Iterator


class Rule:
    """A grammar rule: circular doubly-linked list of symbols with a guard."""
    __slots__ = ("rid", "guard", "users")
    _counter = 0

    def __init__(self, rid: int):
        self.rid = rid
        self.users: set["Node"] = set()   # symbol nodes referencing this rule
        g = Node(None, 0)
        g.owner = self
        g.prev = g.next = g
        self.guard = g

    @property
    def first(self) -> "Node":
        return self.guard.next

    @property
    def last(self) -> "Node":
        return self.guard.prev

    def symbols(self) -> Iterator["Node"]:
        n = self.guard.next
        while n is not self.guard:
            yield n
            n = n.next

    def __repr__(self):
        return f"R{self.rid}"


class Node:
    """One symbol occurrence: (sym, exp) in a doubly-linked rule body."""
    __slots__ = ("sym", "exp", "prev", "next", "owner")

    def __init__(self, sym, exp: int):
        self.sym = sym            # int terminal id, Rule, or None for guard
        self.exp = exp
        self.prev: "Node" = None  # type: ignore
        self.next: "Node" = None  # type: ignore
        self.owner = None         # set on guard nodes only

    @property
    def is_guard(self) -> bool:
        return self.sym is None

    def ident(self):
        if isinstance(self.sym, Rule):
            return ("r", self.sym.rid)
        return ("t", self.sym)

    def __repr__(self):
        s = f"R{self.sym.rid}" if isinstance(self.sym, Rule) else str(self.sym)
        return f"{s}^{self.exp}" if self.exp != 1 else s


class Sequitur:
    """Online grammar builder enforcing constraints (1)-(3)."""

    KERNEL = "reference"

    def __init__(self):
        self._next_rid = 1
        self.main = Rule(0)
        self.rules: dict[int, Rule] = {0: self.main}
        self.digrams: dict[tuple, Node] = {}

    # -- public API ---------------------------------------------------------

    def push(self, sym: int) -> None:
        self.push_run(sym, 1)

    def push_run(self, sym: int, count: int) -> None:
        if count <= 0:
            return
        node = Node(sym, count)
        self._link_rule_use(node)
        last = self.main.last
        self._join(last, node)
        self._join(node, self.main.guard)
        self._check(last)

    def push_many(self, syms: Iterable[int]) -> None:
        for s in syms:
            self.push(s)

    def push_ids(self, ids) -> None:
        """Ingest a pre-interned terminal-id array (the columnar trace IR
        hands sequences over as numpy int arrays).

        Ids are converted to plain Python ints in one bulk ``tolist()``
        call before the push loop: numpy scalars hash like ints but leak
        into digram keys and frozen rule bodies (breaking ``to_json`` and
        bit-exact rule comparisons), and per-element ``int()`` conversion
        is the slowest part of the loop.  The grammar produced is
        bit-identical to ``push_many`` over the same sequence.
        """
        if hasattr(ids, "tolist"):
            ids = ids.tolist()
        for s in ids:
            self.push(s)

    def expand(self) -> list[int]:
        """Expand the grammar back into the original sequence (lossless)."""
        out: list[int] = []
        self._expand_rule(self.main, 1, out)
        return out

    def grammar_rules(self) -> dict[int, list[tuple]]:
        """Freeze to ``{rid: [(kind, ref, exp), ...]}`` with kind in {t, r}."""
        out = {}
        for rid, rule in self.rules.items():
            body = []
            for n in rule.symbols():
                if isinstance(n.sym, Rule):
                    body.append(("r", n.sym.rid, n.exp))
                else:
                    body.append(("t", n.sym, n.exp))
            out[rid] = body
        return out

    def size(self) -> int:
        """Total number of symbol occurrences across all rules."""
        return sum(len(list(r.symbols())) for r in self.rules.values())

    # -- internals ----------------------------------------------------------

    def _expand_rule(self, rule: Rule, times: int, out: list) -> None:
        for _ in range(times):
            for n in rule.symbols():
                if isinstance(n.sym, Rule):
                    self._expand_rule(n.sym, n.exp, out)
                else:
                    out.extend([n.sym] * n.exp)

    def _link_rule_use(self, node: Node) -> None:
        if isinstance(node.sym, Rule):
            node.sym.users.add(node)

    def _unlink_rule_use(self, node: Node) -> None:
        if isinstance(node.sym, Rule):
            node.sym.users.discard(node)

    @staticmethod
    def _digram_key(node: Node) -> tuple:
        return (node.ident(), node.exp, node.next.ident(), node.next.exp)

    def _remove_digram(self, node: Node) -> None:
        """Drop the table entry for the digram starting at ``node`` if it is
        the registered occurrence."""
        if node.is_guard or node.next is None or node.next.is_guard:
            return
        key = self._digram_key(node)
        if self.digrams.get(key) is node:
            del self.digrams[key]

    def _join(self, left: Node, right: Node) -> None:
        if left.next is not None:
            self._remove_digram(left)
        left.next = right
        right.prev = left

    def _delete_node(self, node: Node) -> None:
        """Unlink ``node``; cleans its digrams and rule-use accounting."""
        self._remove_digram(node.prev)
        self._remove_digram(node)
        self._join(node.prev, node.next)
        self._unlink_rule_use(node)
        node.prev = node.next = None  # poison

    def _insert_after(self, where: Node, node: Node) -> None:
        self._link_rule_use(node)
        self._join(node, where.next)
        self._join(where, node)

    def _check(self, node: Node) -> bool:
        """Enforce constraints on the digram (node, node.next).

        Returns True if the grammar was modified.
        """
        if node is None or node.is_guard or node.next is None or node.next.is_guard:
            return False

        nxt = node.next
        # constraint (3): run-length merge of adjacent equal symbols
        if node.ident() == nxt.ident():
            self._remove_digram(node.prev)
            self._remove_digram(nxt)
            node.exp += nxt.exp
            self._delete_node(nxt)
            # digrams around the merged node changed; re-check both sides
            self._check(node.prev)
            self._check(node)
            return True

        key = self._digram_key(node)
        match = self.digrams.get(key)
        if match is None:
            self.digrams[key] = node
            return False
        if match is node or match.next is node or node.next is match:
            return False  # identical or overlapping occurrence
        self._process_match(node, match)
        return True

    def _is_full_rule_body(self, first: Node) -> Rule | None:
        """If (first, first.next) is the entire body of a rule, return it."""
        if first.prev.is_guard and first.next.next.is_guard:
            return first.prev.owner
        return None

    def _process_match(self, node: Node, match: Node) -> None:
        rule = self._is_full_rule_body(match)
        if rule is not None and rule is not self.main:
            self._substitute(node, rule)
        else:
            rule = self._is_full_rule_body(node)
            if rule is not None and rule is not self.main:
                # the *new* digram is itself a full rule body; reuse it for the
                # match occurrence instead.
                self._substitute(match, rule)
            else:
                new_rule = Rule(self._next_rid)
                self._next_rid += 1
                self.rules[new_rule.rid] = new_rule
                a = Node(node.sym, node.exp)
                b = Node(node.next.sym, node.next.exp)
                self._insert_after(new_rule.guard, a)
                self._insert_after(a, b)
                self._substitute(match, new_rule)
                self._substitute(node, new_rule)
                # Register the rule-body digram.  NB: a rule-utility inline
                # during the substitutions above may have spliced new bodies
                # into ``new_rule`` (poisoning ``a``), so consult the live
                # body rather than the captured nodes.
                first = new_rule.first
                if first is not new_rule.guard and first.next is not new_rule.guard:
                    key = self._digram_key(first)
                    cur = self.digrams.get(key)
                    if cur is None or cur.prev is None:
                        self.digrams[key] = first

    def _substitute(self, node: Node, rule: Rule) -> None:
        """Replace the digram starting at ``node`` with one ``rule`` symbol."""
        prev = node.prev
        first_sym, second_sym = node.sym, node.next.sym
        self._delete_node(node.next)
        self._delete_node(node)
        use = Node(rule, 1)
        self._insert_after(prev, use)
        # rule-utility bookkeeping for symbols we just removed
        for s in (first_sym, second_sym):
            if isinstance(s, Rule) and s is not rule:
                self._maybe_inline(s)
        if not self._check(prev):
            self._check(use)

    def _maybe_inline(self, rule: Rule) -> None:
        """Constraint (2): a rule used once with exponent 1 is inlined."""
        if rule is self.main or rule.rid not in self.rules:
            return
        if len(rule.users) != 1:
            return
        (use,) = tuple(rule.users)
        if use.prev is None:  # poisoned node awaiting GC
            rule.users.discard(use)
            return
        if use.exp != 1:
            return  # keeps a loop body alive (run-length semantics)
        prev = use.prev
        nxt = use.next
        first, last = rule.first, rule.last
        if first is rule.guard:  # empty rule body; just drop the use
            self._delete_node(use)
            del self.rules[rule.rid]
            return
        self._delete_node(use)
        # splice the body in place (nodes keep their digram registrations)
        self._join(prev, first)
        self._join(last, nxt)
        del self.rules[rule.rid]
        # boundary digrams are new
        if not self._check(prev):
            self._check(last)

    # -- debugging ----------------------------------------------------------

    def dump(self) -> str:
        lines = []
        for rid in sorted(self.rules):
            body = " ".join(map(repr, self.rules[rid].symbols()))
            lines.append(f"R{rid} -> {body}")
        return "\n".join(lines)


def compress(seq: Iterable[int]) -> Sequitur:
    s = Sequitur()
    s.push_many(seq)
    return s
