"""Streaming corpus store: append-only directory-of-npz + incremental
joint clustering.

ROADMAP's "stream the corpus" item: the scenario zoo grows continuously,
so the trace corpus must be an on-disk artifact that accumulates — not an
in-memory list re-clustered from scratch per added workload.  A
:class:`CorpusStore` is a directory::

    corpus/
      manifest.json            # ordered scenario entries + content hashes
      scenarios/<name>.npz     # one TraceStore artifact per scenario
      cluster_index.npz        # the running joint-clustering state
      fit_cache.npz            # content-addressed block-combination fits

**Incremental joint clustering with exact parity.**  The corpus-level
clustering (:func:`repro.core.events.cluster_vectors` over every
scenario's concatenated metrics) has two passes:

1. log-space bucketing — per-element quantization keys, buckets numbered
   by first appearance, per-bucket float64 sums accumulated in stream
   order.  Under *append* this pass is exactly incremental: a new
   scenario's events land after every existing event in the concatenated
   stream, so matching them against the persisted bucket keys and
   continuing the in-order ``np.add.at`` accumulation reproduces the
   one-shot sums bit for bit (new quantization keys get fresh buckets in
   first-appearance order — the "genuinely novel events spawn new
   clusters" path);
2. the greedy bucket merge (:func:`repro.core.events.merge_buckets`) —
   O(n_buckets²·6), independent of corpus length, so the
   :class:`ClusterIndex` re-derives cluster representatives from its
   running bucket table on demand instead of re-touching event data.

The load-bearing invariant (pinned by tests and the CI incremental job):
``synthesize_corpus(store=...)`` after any sequence of
:meth:`~CorpusStore.add_scenario` calls yields per-scenario δ̄
**bit-identical** to a from-scratch ``synthesize_corpus`` over the same
scenarios in manifest order.

``remove_scenario`` breaks append-only stream order, so it rebuilds the
index from the remaining scenarios' metrics (a partial ``.npz`` column
load — no comm-pool parse) in manifest order; the parity invariant then
holds for the reduced set.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Iterator, Sequence

import numpy as np

from repro.core.events import (
    N_METRICS, bucketize_keys, merge_buckets, quantize_metrics,
)
from repro.core.proxy_search import FitResult
from repro.core.trace_ir import TraceStore

_MANIFEST_VERSION = 1
_MANIFEST = "manifest.json"
_INDEX = "cluster_index.npz"
_FITS = "fit_cache.npz"
_GRAMMARS = "grammar_cache.json"
_SCENARIO_DIR = "scenarios"


def _atomic_npz_write(path: Path, writer) -> None:
    """Write-then-rename so a crash mid-write never truncates the live
    file (the same pattern the manifest uses)."""
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        writer(f)
    tmp.replace(path)


# ---------------------------------------------------------------------------
# incremental joint-clustering index
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterIndex:
    """Running corpus-clustering state: the pass-1 bucket table plus the
    per-scenario bucket assignments, in ingestion order."""

    rel_tol: float
    keys: np.ndarray                      # (n_buckets, 6) int64 quant keys
    sums: np.ndarray                      # (n_buckets, 6) float64 running
    counts: np.ndarray                    # (n_buckets,) int64
    buckets: dict[str, np.ndarray]        # scenario -> per-row bucket id

    def __post_init__(self):
        self._derived: tuple[np.ndarray, dict[int, np.ndarray]] | None = None

    @classmethod
    def empty(cls, rel_tol: float = 0.05) -> "ClusterIndex":
        return cls(rel_tol=rel_tol,
                   keys=np.zeros((0, N_METRICS), dtype=np.int64),
                   sums=np.zeros((0, N_METRICS), dtype=np.float64),
                   counts=np.zeros(0, dtype=np.int64),
                   buckets={})

    @property
    def n_buckets(self) -> int:
        return int(self.keys.shape[0])

    @property
    def n_clusters(self) -> int:
        return len(self.derive()[1])

    # -- ingest ----------------------------------------------------------------

    def ingest(self, name: str, metrics: np.ndarray) -> None:
        """Append one scenario's compute metrics to the running bucket
        table — the incremental half of ``cluster_vectors`` pass 1.

        Rows matching a persisted quantization key join that bucket (the
        float64 sum continues exactly where the one-shot accumulation
        would be); novel keys get fresh buckets numbered by first
        appearance, exactly as the concatenated stream would number them.
        """
        if name in self.buckets:
            raise ValueError(f"scenario {name!r} already in cluster index")
        metrics = np.asarray(metrics, dtype=np.float64)
        if metrics.shape[0] == 0:
            self.buckets[name] = np.zeros(0, dtype=np.int64)
            return
        local_ids, uniq = bucketize_keys(
            quantize_metrics(metrics, self.rel_tol))
        by_key = {k.tobytes(): i for i, k in enumerate(self.keys)}
        gids = np.empty(len(uniq), dtype=np.int64)
        novel: list[np.ndarray] = []
        for i, k in enumerate(uniq):
            kb = k.tobytes()
            gid = by_key.get(kb)
            if gid is None:
                gid = len(by_key)
                by_key[kb] = gid
                novel.append(k)
            gids[i] = gid
        if novel:
            self.keys = np.concatenate([self.keys, np.stack(novel)])
            self.sums = np.concatenate(
                [self.sums, np.zeros((len(novel), N_METRICS))])
            self.counts = np.concatenate(
                [self.counts, np.zeros(len(novel), dtype=np.int64)])
        bucket_ids = gids[local_ids]
        # np.add.at is an unbuffered in-order accumulation: continuing it
        # on the persisted sums reproduces the one-shot concatenated-stream
        # accumulation bit for bit (the appended rows come last either way)
        np.add.at(self.sums, bucket_ids, metrics)
        self.counts = self.counts + np.bincount(bucket_ids,
                                                minlength=self.n_buckets)
        self.buckets[name] = bucket_ids
        self._derived = None

    @classmethod
    def rebuild(cls, rel_tol: float,
                scenario_metrics: Sequence[tuple[str, np.ndarray]],
                ) -> "ClusterIndex":
        """Fresh index over the given scenarios in order — the one-shot
        semantics, used after removal."""
        idx = cls.empty(rel_tol)
        for name, metrics in scenario_metrics:
            idx.ingest(name, metrics)
        return idx

    # -- derivation ------------------------------------------------------------

    def derive(self) -> tuple[np.ndarray, dict[int, np.ndarray]]:
        """(bucket→cluster remap, cluster representatives) — pass 2 of
        ``cluster_vectors`` over the running bucket table.  Cached until
        the next ingest."""
        if self._derived is None:
            if self.n_buckets == 0:
                self._derived = (np.zeros(0, dtype=np.int64), {})
            else:
                self._derived = merge_buckets(self.sums, self.counts,
                                              self.rel_tol)
        return self._derived

    def assignments(self, name: str) -> np.ndarray:
        """Cluster id per compute row of one scenario (aligned with its
        ``TraceStore.metrics``)."""
        remap, _ = self.derive()
        return remap[self.buckets[name]]

    # -- persistence -----------------------------------------------------------

    def save(self, path, order: Sequence[str]) -> None:
        """Persist as npz (atomically: tmp + rename); per-scenario bucket
        arrays are concatenated in ``order`` (the manifest order) with an
        extents array."""
        order = list(order)
        chunks = [self.buckets[n] for n in order]
        extents = np.cumsum([0] + [len(c) for c in chunks])
        flat = (np.concatenate(chunks) if chunks
                else np.zeros(0, dtype=np.int64))
        meta = json.dumps({"rel_tol": self.rel_tol, "order": order})

        def write(f):
            np.savez(f, keys=self.keys, sums=self.sums, counts=self.counts,
                     bucket_ids=flat, bucket_extents=extents,
                     meta=np.asarray(meta))

        _atomic_npz_write(Path(path), write)

    @classmethod
    def load(cls, path) -> "ClusterIndex":
        with np.load(path) as z:
            meta = json.loads(str(z["meta"]))
            order = meta["order"]
            flat = z["bucket_ids"].astype(np.int64)
            extents = z["bucket_extents"].astype(np.int64)
            buckets = {n: flat[extents[i]:extents[i + 1]]
                       for i, n in enumerate(order)}
            return cls(rel_tol=float(meta["rel_tol"]),
                       keys=z["keys"].astype(np.int64),
                       sums=z["sums"].astype(np.float64),
                       counts=z["counts"].astype(np.int64),
                       buckets=buckets)


# ---------------------------------------------------------------------------
# content-addressed fit cache
# ---------------------------------------------------------------------------


class FitCache:
    """Persistent ``key -> FitResult`` map for block-combination fits.

    Keys are content hashes of the exact fit inputs (target vector bytes,
    count_scale, calibration-basis fingerprint, solver grid — built by
    ``repro.core.synthesize``), so a cached fit is valid wherever its key
    matches regardless of which table union or scenario produced it; the
    corpus terminal-table fingerprint is recorded in the manifest for
    observability and coarse invalidation."""

    def __init__(self):
        self._fits: dict[str, FitResult] = {}

    def __len__(self):
        return len(self._fits)

    def __contains__(self, key: str) -> bool:
        return key in self._fits

    def get(self, key: str) -> FitResult | None:
        return self._fits.get(key)

    def put(self, key: str, fr: FitResult) -> None:
        self._fits[key] = fr

    def save(self, path) -> None:
        keys = list(self._fits)
        if not keys:
            Path(path).unlink(missing_ok=True)
            return
        frs = [self._fits[k] for k in keys]

        def write(f):
            np.savez(
                f,
                keys=np.asarray(keys),
                x=np.stack([np.asarray(fr.x, dtype=np.int64) for fr in frs]),
                predicted=np.stack([fr.predicted for fr in frs]),
                target=np.stack([fr.target for fr in frs]),
                residual=np.asarray([fr.residual for fr in frs]),
                rel_err=np.stack([fr.per_metric_rel_err for fr in frs]),
                unroll=np.asarray([fr.unroll for fr in frs], dtype=np.int64))

        _atomic_npz_write(Path(path), write)

    @classmethod
    def load(cls, path) -> "FitCache":
        cache = cls()
        with np.load(path) as z:
            for i, k in enumerate(z["keys"].tolist()):
                cache._fits[str(k)] = FitResult(
                    x=z["x"][i].astype(np.int64),
                    predicted=z["predicted"][i].astype(np.float64),
                    target=z["target"][i].astype(np.float64),
                    residual=float(z["residual"][i]),
                    per_metric_rel_err=z["rel_err"][i].astype(np.float64),
                    unroll=int(z["unroll"][i]))
        return cache


# ---------------------------------------------------------------------------
# content-addressed grammar cache
# ---------------------------------------------------------------------------


class GrammarCache:
    """Persistent ``key -> frozen Sequitur rules`` map for rank-stream
    grammars, sibling to :class:`FitCache`.

    Keys are content hashes of the exact grammar-inference inputs: the
    interned local-id stream bytes plus the merge threshold (conservative
    — today's Sequitur rules depend only on the stream; keying on the
    threshold too keeps the cache valid if grammar semantics ever pick up
    threshold dependence).  A hit hands back the frozen
    ``{rid: [(kind, ref, exp), ...]}`` rules dict and skips the Sequitur
    run entirely — on a warm store, re-opened in a fresh process, every
    unchanged rank stream resolves from this cache, so grammar inference
    on incremental appends costs only the new scenario's novel streams.

    Rules are pure int/str structures, so unlike the in-memory front-half
    memo they persist (``grammar_cache.json``); rule dicts alias across
    hits and are read-only downstream (the same convention as grammar
    aliasing across a signature class).  ``hits``/``misses`` count
    :meth:`get` outcomes since construction; synthesis stats report the
    per-run delta.
    """

    def __init__(self):
        self._rules: dict[str, dict[int, list[tuple]]] = {}
        self.hits = 0
        self.misses = 0
        self.dirty = False

    def __len__(self):
        return len(self._rules)

    def __contains__(self, key: str) -> bool:
        return key in self._rules

    @staticmethod
    def key(local_ids: np.ndarray, threshold: float) -> str:
        h = hashlib.sha256(f"grammar|1|{threshold!r}|".encode())
        h.update(np.ascontiguousarray(local_ids, dtype=np.int64).tobytes())
        return h.hexdigest()

    def get(self, key: str) -> dict[int, list[tuple]] | None:
        rules = self._rules.get(key)
        if rules is None:
            self.misses += 1
        else:
            self.hits += 1
        return rules

    def put(self, key: str, rules: dict[int, list[tuple]]) -> None:
        self._rules[key] = rules
        self.dirty = True

    def save(self, path) -> None:
        path = Path(path)
        if not self._rules:
            path.unlink(missing_ok=True)
            self.dirty = False
            return
        # rid insertion order is part of the grammar identity (to_json
        # serializes rules in that order); JSON objects round-trip dict
        # order, so the frozen form persists it exactly
        payload = {"version": 1,
                   "entries": {k: {str(rid): [list(s) for s in body]
                                   for rid, body in rules.items()}
                               for k, rules in self._rules.items()}}
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(json.dumps(payload))
        tmp.replace(path)
        self.dirty = False

    @classmethod
    def load(cls, path) -> "GrammarCache":
        cache = cls()
        payload = json.loads(Path(path).read_text())
        if payload.get("version") != 1:
            raise ValueError(f"unsupported grammar cache version "
                             f"{payload.get('version')!r} in {path}")
        for k, rules in payload["entries"].items():
            cache._rules[k] = {
                int(rid): [(s[0], int(s[1]), int(s[2])) for s in body]
                for rid, body in rules.items()}
        return cache


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------


class CorpusStore:
    """Append-only on-disk trace corpus with incremental joint clustering.

    ::

        cs = CorpusStore("corpus/")            # opens or creates
        cs.add_scenario("transformer-dp", store)
        corp = synthesize_corpus(store=cs)     # incremental synthesis

    Scenario order is ingestion order (the manifest list); the clustering
    and the δ̄-parity invariant are defined relative to it.
    """

    def __init__(self, root, rel_tol: float | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        (self.root / _SCENARIO_DIR).mkdir(exist_ok=True)
        self._stores: dict[str, TraceStore] = {}
        #: in-memory front-half memo used by incremental synthesis
        #: (grammar objects are not persistable; the on-disk caches are
        #: the cluster index and the fit cache)
        self.memo: dict = {}

        mpath = self.root / _MANIFEST
        if mpath.exists():
            manifest = json.loads(mpath.read_text())
            if manifest.get("version") != _MANIFEST_VERSION:
                raise ValueError(
                    f"unsupported corpus manifest version "
                    f"{manifest.get('version')!r} in {mpath}")
            if rel_tol is not None and rel_tol != manifest["rel_tol"]:
                raise ValueError(
                    f"corpus at {self.root} was built with rel_tol="
                    f"{manifest['rel_tol']}, asked to open with {rel_tol}")
            self.manifest = manifest
        else:
            self.manifest = {"version": _MANIFEST_VERSION,
                             "rel_tol": 0.05 if rel_tol is None else rel_tol,
                             "scenarios": [],
                             "table_fingerprint": None}
            self._write_manifest()

        self.index = self._load_or_rebuild_index()
        fpath = self.root / _FITS
        try:
            self.fits = FitCache.load(fpath) if fpath.exists() else FitCache()
        except Exception:
            # fits are content-addressed pure derivations: a corrupt cache
            # costs a re-solve, never correctness — start empty
            self.fits = FitCache()
        gpath = self.root / _GRAMMARS
        try:
            self.grammars = (GrammarCache.load(gpath) if gpath.exists()
                             else GrammarCache())
        except Exception:
            # same contract as the fit cache: a corrupt grammar cache
            # costs a Sequitur re-run, never correctness
            self.grammars = GrammarCache()

    def _load_or_rebuild_index(self) -> ClusterIndex:
        """Load the persisted cluster index, validating it against the
        manifest (the source of truth).  A missing, corrupt, or stale
        index — e.g. a crash between the two persist writes — is rebuilt
        from the scenario artifacts, so the store self-heals instead of
        silently serving assignments inconsistent with its contents."""
        ipath = self.root / _INDEX
        names = self.names
        if ipath.exists():
            try:
                idx = ClusterIndex.load(ipath)
                if idx.rel_tol == self.rel_tol \
                        and set(idx.buckets) == set(names):
                    return idx
            except Exception:
                pass
        idx = ClusterIndex.rebuild(
            self.rel_tol, [(n, self._metrics_of(n)) for n in names])
        if names:
            idx.save(ipath, names)
        return idx

    # -- basic accessors -------------------------------------------------------

    @property
    def rel_tol(self) -> float:
        return float(self.manifest["rel_tol"])

    @property
    def names(self) -> list[str]:
        return [e["name"] for e in self.manifest["scenarios"]]

    def __len__(self) -> int:
        return len(self.manifest["scenarios"])

    def __contains__(self, name: str) -> bool:
        return any(e["name"] == name for e in self.manifest["scenarios"])

    def __iter__(self) -> Iterator[tuple[str, TraceStore]]:
        for name in self.names:
            yield name, self.load_scenario(name)

    def _entry(self, name: str) -> dict:
        for e in self.manifest["scenarios"]:
            if e["name"] == name:
                return e
        raise KeyError(f"scenario {name!r} not in corpus")

    def content_hash(self, name: str) -> str:
        return self._entry(name)["content_hash"]

    def noise_params(self, name: str):
        """The scenario-local calibrated noise model recorded at
        ``add_scenario`` time, or ``None`` for entries written before the
        noise layer existed (pre-noise manifests stay loadable)."""
        data = self._entry(name).get("noise")
        if data is None:
            return None
        from repro.core import noise as noise_mod
        return noise_mod.NoiseModel.from_json(data)

    def scenario_path(self, name: str) -> Path:
        return self.root / _SCENARIO_DIR / f"{name}.npz"

    # -- mutation --------------------------------------------------------------

    def add_scenario(self, name: str, store: TraceStore) -> str:
        """Append one scenario: write its npz, extend the cluster index
        incrementally, record its content hash.  Returns the hash."""
        if name in self:
            raise ValueError(f"scenario {name!r} already in corpus")
        if "/" in name or name in (".", ".."):
            raise ValueError(f"invalid scenario name {name!r}")
        path = store.save(self.scenario_path(name))
        chash = store.content_hash()
        self.index.ingest(name, store.metrics)
        from repro.core import noise as noise_mod
        self.manifest["scenarios"].append({
            "name": name,
            "file": str(path.relative_to(self.root)),
            "content_hash": chash,
            "n_ranks": store.n_ranks,
            "n_events": store.n_events,
            "n_compute_events": store.n_compute_events,
            # scenario-LOCAL noise calibration (this scenario's own
            # clustering at the store's rel_tol): an observability
            # artifact riding the manifest.  Synthesis recalibrates
            # against the JOINT cluster assignment so batch and
            # incremental paths emit identical NOISE_MODELS tables.
            "noise": noise_mod.calibrate(store,
                                         rel_tol=self.rel_tol).to_json(),
        })
        self._stores[name] = store
        self._persist()
        return chash

    def remove_scenario(self, name: str) -> None:
        """Drop a scenario and rebuild the cluster index over the
        remaining set (removal breaks append-only stream order, so the
        bucket table is re-accumulated from the survivors' metrics via a
        partial column load — still no comm-pool parse, no re-synthesis)."""
        entry = self._entry(name)
        self.manifest["scenarios"].remove(entry)
        self._stores.pop(name, None)
        self.scenario_path(name).unlink(missing_ok=True)
        self.index = ClusterIndex.rebuild(
            self.rel_tol,
            [(n, self._metrics_of(n)) for n in self.names])
        self._persist()

    def _metrics_of(self, name: str) -> np.ndarray:
        cached = self._stores.get(name)
        if cached is not None:
            return cached.metrics
        cols = TraceStore.load_columns(self.root / self._entry(name)["file"],
                                       ["metrics"])
        return cols["metrics"]

    def load_scenario(self, name: str) -> TraceStore:
        st = self._stores.get(name)
        if st is None:
            st = TraceStore.load(self.root / self._entry(name)["file"])
            self._stores[name] = st
        return st

    # -- clustering view -------------------------------------------------------

    def cluster_assignments(self) -> tuple[dict[str, np.ndarray],
                                           dict[int, np.ndarray]]:
        """Per-scenario cluster ids (aligned to each scenario's metrics
        rows) + the joint cluster representatives — bit-identical to
        ``cluster_vectors`` over the manifest-order concatenation."""
        ids = {n: self.index.assignments(n) for n in self.names}
        return ids, self.index.derive()[1]

    # -- persistence -----------------------------------------------------------

    def _write_manifest(self) -> None:
        tmp = self.root / (_MANIFEST + ".tmp")
        tmp.write_text(json.dumps(self.manifest, indent=1, sort_keys=True))
        tmp.replace(self.root / _MANIFEST)

    def _persist(self) -> None:
        self._write_manifest()
        self.index.save(self.root / _INDEX, self.names)

    def save_fits(self, table_fingerprint: str | None = None) -> None:
        """Persist the fit cache (called by incremental synthesis after a
        solve) and record the corpus table version in the manifest."""
        if table_fingerprint is not None:
            self.manifest["table_fingerprint"] = table_fingerprint
            self._write_manifest()
        self.fits.save(self.root / _FITS)

    def save_grammars(self) -> None:
        """Persist the grammar cache if it gained entries (called by
        incremental synthesis after the front half)."""
        if self.grammars.dirty:
            self.grammars.save(self.root / _GRAMMARS)
