"""Streaming corpus store: sharded directory-of-npz + incremental joint
clustering with per-scenario partial sums.

ROADMAP's "fleet-scale corpus" item: the scenario zoo grows continuously
and from several appender processes at once, so the trace corpus must be
an on-disk artifact whose appends are **commutative** — not a single
serial manifest re-clustered from scratch per added workload.  A
:class:`CorpusStore` is a directory::

    corpus/
      manifest.json              # header: version, rel_tol, n_shards, ...
      shards/shard-NN.json       # scenario entries, keyed by content hash
      scenarios/<name>.npz       # one TraceStore artifact per scenario
      scenarios/<name>.buckets.npz   # its pass-1 bucket table (sidecar)
      cluster_index.npz          # merged index cache (rebuildable)
      fit_cache.npz              # content-addressed block-combination fits
      grammar_cache.json         # content-addressed Sequitur rules
      locks/*.lock               # per-shard flock files

**Sharded manifests.**  Scenario entries live in per-shard manifests —
the shard is picked by content hash — and every shard write is a
file-locked read-modify-write followed by an atomic rename, so two
appender processes never corrupt a shard and never lose each other's
entries.  *Manifest order* is canonical: shards in index order, entries
within a shard sorted by ``(content_hash, name)``.  The store's state is
therefore a pure function of its scenario **set** — append order,
appender count, and worker scheduling all wash out, which is what makes
parallel ingest bit-identical to serial ingest by construction.

**Joint clustering with per-scenario partial sums.**  The corpus-level
clustering (see :func:`repro.core.events.combine_bucket_tables`) keeps
one pass-1 bucket table *per scenario*: distinct quantization keys,
float64 partial sums accumulated in the scenario's own event order, and
per-row bucket ids.  Deriving the joint clusters renumbers buckets by
first appearance in manifest order and folds the partial sums
left-to-right in that order — O(total distinct keys), never re-touching
event data.  Consequences:

* **append** is exactly incremental (a new scenario's partials fold in
  at its manifest position);
* **removal** is O(remaining events): drop the scenario's table,
  renumber, refold — no metrics reload, no full rebuild
  (:meth:`CorpusStore.remove_scenario`);
* the same fold implements the batch path
  (:func:`repro.core.events.cluster_corpus`), so incremental and
  from-scratch synthesis stay bit-identical by construction.

This replaced the v1 event-order global accumulation — a deliberate,
documented invariant change (float association differs at scenario
boundaries, at most one ulp per fold).  v1 stores migrate on first open:
the manifest is resharded and the index npz (now versioned) rebuilds
once from the scenario artifacts.

The load-bearing invariant (pinned by tests and the CI incremental job):
``synthesize_corpus(store=...)`` after any sequence of appends/removals
yields per-scenario δ̄ **bit-identical** to a from-scratch
``synthesize_corpus`` over the same scenarios in manifest order.

:meth:`CorpusStore.add_scenarios` fans the per-scenario ingest front
half (npz write, hashing, bucket table, noise calibration, grammar
warm-up — pure NumPy, no JAX dispatch) across a worker pool; workers
import no accelerator code, and the parent merges their bucket tables
deterministically in manifest order.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import tempfile
import threading
import time
from pathlib import Path
from typing import Iterator, Sequence

import numpy as np

from repro.core import faults
from repro.core.events import (
    N_METRICS, combine_bucket_tables, quantize_metrics,
    scenario_bucket_table,
)
from repro.core.trace_ir import TraceStore

try:                         # POSIX advisory locks; absent on some platforms
    import fcntl
except ImportError:          # pragma: no cover - non-POSIX fallback
    fcntl = None

_MANIFEST_VERSION = 2
_INDEX_VERSION = 2
_N_SHARDS_DEFAULT = 16
_MANIFEST = "manifest.json"
_INDEX = "cluster_index.npz"
_FITS = "fit_cache.npz"
_GRAMMARS = "grammar_cache.json"
_SCENARIO_DIR = "scenarios"
_SHARD_DIR = "shards"
_LOCK_DIR = "locks"
_QUARANTINE_DIR = "quarantine"
_META = "store.meta.json"

#: how long a shard/header lock acquisition retries (with exponential
#: backoff) before raising :class:`LockTimeoutError`
LOCK_TIMEOUT = 30.0


class ToleranceMismatchError(ValueError):
    """A persisted clustering artifact disagrees with the store's
    ``rel_tol``.  Raised loudly instead of silently re-clustering: a
    readable index built at a different tolerance means mixed store
    directories or hand-edited artifacts, not bit rot."""


class IndexFormatError(ValueError):
    """Unversioned/old-version index artifact — the store rebuilds it
    once (the v1 → v2 migration path)."""


class ScenarioCorruptError(RuntimeError):
    """A scenario artifact (``scenarios/<name>.npz`` or its bucket
    sidecar, where the metrics fallback is also unreadable) failed to
    load.  Typed so callers — and :meth:`CorpusStore.repair` — can
    identify the culprit instead of unwinding on a raw
    ``zipfile``/``OSError`` from deep inside iteration or synthesis."""

    def __init__(self, name: str, path, cause: BaseException):
        self.name = name
        self.path = str(path)
        self.cause = cause
        super().__init__(
            f"scenario {name!r} artifact {path} is unreadable "
            f"({type(cause).__name__}: {cause}); run "
            "CorpusStore.verify()/repair() to quarantine it")


class ShardCorruptError(RuntimeError):
    """A shard manifest file is unparseable (torn write / bit rot).  The
    store opens with the shard recorded in :attr:`CorpusStore.
    shard_errors` — synthesis and serving refuse to run until
    :meth:`CorpusStore.repair` reconstructs the shard's entries from the
    surviving scenario artifacts."""

    def __init__(self, path, cause: BaseException):
        self.path = str(path)
        self.cause = cause
        super().__init__(
            f"shard manifest {path} is unreadable "
            f"({type(cause).__name__}: {cause}); run "
            "CorpusStore.repair() to reconstruct it from the scenario "
            "artifacts")


class LockTimeoutError(TimeoutError):
    """Could not acquire a store lock inside the bounded retry window.
    Carries the lock path and attempt count so the diagnostic names the
    stuck writer's lock file instead of hanging forever."""

    def __init__(self, path, timeout: float, attempts: int):
        self.path = str(path)
        self.timeout = timeout
        self.attempts = attempts
        super().__init__(
            f"could not acquire corpus lock {path} within {timeout:.1f}s "
            f"({attempts} attempts with backoff) — another writer is "
            "stuck or died while holding it; if no writer process is "
            "alive the flock is already released and this indicates "
            "pathological contention")


@dataclasses.dataclass
class IngestItemError:
    """One scenario's typed ingest failure (after the serial retry)."""

    name: str
    error: BaseException
    retried: bool = False

    def __str__(self) -> str:
        return (f"{self.name}: {type(self.error).__name__}: {self.error}"
                + (" (after serial retry)" if self.retried else ""))


class IngestBatchError(RuntimeError):
    """Some items of an :meth:`CorpusStore.add_scenarios` batch failed —
    **after** the survivors committed.  Per-item fault isolation: a dead
    worker or one corrupt input costs that item, never the batch.
    ``hashes`` holds the committed scenarios, ``errors`` the typed
    per-item failures."""

    def __init__(self, errors: list[IngestItemError], hashes: dict):
        self.errors = list(errors)
        self.hashes = dict(hashes)
        names = [e.name for e in self.errors]
        super().__init__(
            f"{len(self.errors)} of {len(self.errors) + len(self.hashes)} "
            f"scenarios failed ingest: {names} "
            f"({len(self.hashes)} committed); see .errors for causes")


# ---------------------------------------------------------------------------
# crash-safe writes + cross-process locking
# ---------------------------------------------------------------------------


def _finish_atomic(tmp: str, path: Path, spec, site: str) -> None:
    """Shared tail of every atomic-write site: implement a ``torn_write``
    fault (the non-atomic clobber the renamer exists to prevent — injected
    anyway so fsck is exercised against real damage), commit the rename,
    then a ``crash_after`` fault."""
    if spec is not None and spec.kind == "torn_write":
        data = Path(tmp).read_bytes()
        os.unlink(tmp)
        faults.apply_torn_write(path, data, site, str(path))
    os.replace(tmp, path)
    if spec is not None and spec.kind == "crash_after":
        raise faults.InjectedCrash(site, f"after commit of {path}")


def _atomic_npz_write(path: Path, writer, site: str = "write.index") -> None:
    """Write-then-rename so a crash (or SIGKILL) mid-write never
    truncates the live file.  The tmp name is unique per writer
    (``mkstemp``), so two processes racing on the same target each
    rename a complete file — last one wins, both are valid.  ``site``
    names the registered fault point (:mod:`repro.core.faults`) this
    write arms — inert unless a plan is installed."""
    path = Path(path)
    spec = faults.arm(site, path)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                               prefix=path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            writer(f)
            f.flush()
            os.fsync(f.fileno())
        _finish_atomic(tmp, path, spec, site)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def _atomic_json_write(path: Path, obj, sort_keys: bool = True,
                       site: str = "write.manifest") -> None:
    """JSON twin of :func:`_atomic_npz_write` — same contract: readers
    (and reopeners after a kill) observe either the old or the new
    manifest, never a truncated one.  ``sort_keys=False`` for payloads
    whose dict order is semantic (the grammar cache)."""
    path = Path(path)
    spec = faults.arm(site, path)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                               prefix=path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=1, sort_keys=sort_keys)
            f.flush()
            os.fsync(f.fileno())
        _finish_atomic(tmp, path, spec, site)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def _atomic_scenario_write(path: Path, tstore: TraceStore) -> Path:
    """Atomic form of ``TraceStore.save`` for the corpus's scenario npz
    files: a killed ingest must never leave a truncated scenario behind
    a committed shard entry (the sidecar-before-entry ordering covers
    the entry; this covers the artifact itself)."""
    path = Path(path)
    spec = faults.arm("write.scenario_npz", path)
    # suffix keeps .npz so TraceStore.save doesn't append another one
    fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                               prefix=path.name + ".", suffix=".tmp.npz")
    os.close(fd)
    try:
        tstore.save(tmp)
        with open(tmp, "rb") as f:
            os.fsync(f.fileno())
        _finish_atomic(tmp, path, spec, "write.scenario_npz")
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
    return path


def _acquire_flock(f, path: Path, timeout: float) -> None:
    """Bounded lock acquisition: non-blocking attempts with exponential
    backoff instead of an unbounded ``LOCK_EX`` wait, so a writer that
    died (or hung) holding a lock surfaces as a
    :class:`LockTimeoutError` diagnostic, never an eternal hang."""
    deadline = time.monotonic() + timeout
    delay = 1e-3
    attempts = 0
    while True:
        attempts += 1
        spec = faults.arm("lock.acquire", path)
        try:
            if spec is not None and spec.kind == "slow_lock":
                raise BlockingIOError(
                    f"injected lock contention on {path}")
            fcntl.flock(f.fileno(), fcntl.LOCK_EX | fcntl.LOCK_NB)
            return
        except BlockingIOError:
            if time.monotonic() >= deadline:
                raise LockTimeoutError(path, timeout, attempts) from None
            time.sleep(delay)
            delay = min(delay * 2, 0.05)


@contextlib.contextmanager
def _file_lock(path: Path, timeout: float = LOCK_TIMEOUT):
    """Exclusive advisory lock serializing cross-process read-modify-
    write of one shard manifest (or the header), acquired with bounded
    retry + backoff (:func:`_acquire_flock`).  Degrades to no locking
    where ``fcntl`` is unavailable — single-appender only there."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a+") as f:
        if fcntl is not None:
            _acquire_flock(f, path, timeout)
        try:
            yield
        finally:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_UN)


# ---------------------------------------------------------------------------
# per-scenario bucket tables + the incremental joint-clustering index
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScenarioBuckets:
    """One scenario's pass-1 bucket table (the partial-sums unit the
    corpus clustering folds): distinct quantization keys in
    first-appearance order, per-key float64 sums accumulated in the
    scenario's own event order, row counts, and per-row local ids.

    Pure per-scenario data — computable in an ingest worker with no view
    of the rest of the corpus, persisted as a ``.buckets.npz`` sidecar so
    concurrent appenders never contend on a shared index file."""

    rel_tol: float
    keys: np.ndarray          # (k, 6) int64 quantization keys
    psums: np.ndarray         # (k, 6) float64 in-scenario partial sums
    counts: np.ndarray        # (k,) int64
    local_ids: np.ndarray     # (n_rows,) int64 → rows of ``keys``

    @classmethod
    def from_metrics(cls, metrics: np.ndarray, rel_tol: float,
                     ) -> "ScenarioBuckets":
        keys, psums, counts, local_ids = scenario_bucket_table(
            np.asarray(metrics, dtype=np.float64), rel_tol)
        return cls(rel_tol=rel_tol, keys=keys, psums=psums, counts=counts,
                   local_ids=local_ids)

    @property
    def n_rows(self) -> int:
        return int(self.local_ids.shape[0])

    def astuple(self) -> tuple:
        return (self.keys, self.psums, self.counts, self.local_ids)

    def save(self, path) -> None:
        meta = json.dumps({"version": _INDEX_VERSION,
                           "rel_tol": self.rel_tol})

        def write(f):
            np.savez(f, keys=self.keys, psums=self.psums,
                     counts=self.counts, local_ids=self.local_ids,
                     meta=np.asarray(meta))

        _atomic_npz_write(Path(path), write, site="write.sidecar")

    @classmethod
    def load(cls, path, expected_rel_tol: float | None = None,
             ) -> "ScenarioBuckets":
        faults.crash_point("read.sidecar", path)
        with np.load(path) as z:
            meta = json.loads(str(z["meta"]))
            if meta.get("version") != _INDEX_VERSION:
                raise IndexFormatError(
                    f"bucket sidecar {path} has version "
                    f"{meta.get('version')!r}, expected {_INDEX_VERSION}")
            rel_tol = float(meta["rel_tol"])
            if expected_rel_tol is not None and rel_tol != expected_rel_tol:
                raise ToleranceMismatchError(
                    f"bucket sidecar {path} was clustered at rel_tol="
                    f"{rel_tol}, the store expects {expected_rel_tol}; "
                    "refusing to silently re-cluster under a mismatched "
                    "tolerance")
            return cls(rel_tol=rel_tol,
                       keys=z["keys"].astype(np.int64),
                       psums=z["psums"].astype(np.float64),
                       counts=z["counts"].astype(np.int64),
                       local_ids=z["local_ids"].astype(np.int64))


#: one (6,) int64 quantization key as fixed-width bytes.  Distinct
#: equal-length byte strings stay distinct under the S-dtype trailing-null
#: stripping (same length → same stripped form ⇔ same raw bytes), so
#: sorting/searching this view is equality-exact.
_KEY_DTYPE = f"S{8 * N_METRICS}"


@dataclasses.dataclass(frozen=True)
class ClusterMatcher:
    """Immutable snapshot of the derived cluster-lookup state — the serve
    tier's hot-path matcher.

    Exact-key matching runs as one vectorized ``searchsorted`` over a
    sorted fixed-width byte view of the quantization keys (replacing the
    per-row ``dict.get`` loop, kept as
    :meth:`ClusterIndex.match_clusters_reference` — the parity oracle);
    unseen keys fall back to the nearest representative under the
    relative-max metric, unchanged.  Being frozen, a service can capture
    one and keep matching consistently while the owning index mutates
    underneath it."""

    rel_tol: float
    skeys: np.ndarray         # (k,) sorted quantization-key bytes
    scids: np.ndarray         # (k,) int64 cluster id per sorted key
    rep_ids: np.ndarray       # (c,) int64 cluster ids
    rep_mat: np.ndarray       # (c, 6) float64 representatives

    def match(self, metrics: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        metrics = np.asarray(metrics, dtype=np.float64)
        if metrics.ndim != 2 or metrics.shape[1] != N_METRICS:
            raise ValueError(f"expected (n, {N_METRICS}) metrics array")
        n = metrics.shape[0]
        if n == 0:
            return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=bool)
        if not len(self.skeys):
            raise ValueError("cannot match against an empty cluster index")
        q = np.ascontiguousarray(quantize_metrics(metrics, self.rel_tol))
        qs = q.view(_KEY_DTYPE).ravel()
        pos = np.minimum(np.searchsorted(self.skeys, qs),
                         len(self.skeys) - 1)
        matched = self.skeys[pos] == qs
        cids = np.zeros(n, dtype=np.int64)
        cids[matched] = self.scids[pos[matched]]
        if not matched.all():
            v = metrics[~matched][:, None, :]
            denom = np.maximum(
                np.maximum(np.abs(self.rep_mat[None]), np.abs(v)), 1e-30)
            dist = (np.abs(self.rep_mat[None] - v) / denom).max(axis=2)
            cids[~matched] = self.rep_ids[np.argmin(dist, axis=1)]
        return cids, matched


@dataclasses.dataclass
class ClusterIndex:
    """Running corpus-clustering state: one :class:`ScenarioBuckets` per
    scenario plus the derivation order (= manifest order).

    Derivation (:meth:`derive`) renumbers buckets by first appearance in
    ``order`` and folds partial sums left-to-right in that order — see
    :func:`repro.core.events.combine_bucket_tables`, the single shared
    implementation that keeps this index bit-identical to the batch
    path.  Mutations (:meth:`ingest`, :meth:`remove`, :meth:`set_order`)
    only touch per-scenario tables and invalidate the derived cache, so
    removal costs O(remaining events) at the next derive instead of a
    full rebuild from metrics."""

    rel_tol: float
    tables: dict[str, ScenarioBuckets]
    order: list[str]

    def __post_init__(self):
        self._derived: dict | None = None

    @classmethod
    def empty(cls, rel_tol: float = 0.05) -> "ClusterIndex":
        return cls(rel_tol=rel_tol, tables={}, order=[])

    @property
    def n_buckets(self) -> int:
        return int(self._derive_full()["n_buckets"])

    @property
    def n_clusters(self) -> int:
        return len(self.derive()[1])

    # -- mutation --------------------------------------------------------------

    def ingest(self, name: str, metrics: np.ndarray) -> None:
        """Append one scenario's compute metrics as a fresh bucket table
        (partial sums accumulated in the scenario's own event order)."""
        self.ingest_table(name,
                          ScenarioBuckets.from_metrics(metrics, self.rel_tol))

    def ingest_table(self, name: str, sb: ScenarioBuckets) -> None:
        """Merge a pre-computed bucket table (the parallel-ingest path:
        workers build tables, the parent folds them in manifest order)."""
        if name in self.tables:
            raise ValueError(f"scenario {name!r} already in cluster index")
        if sb.rel_tol != self.rel_tol:
            raise ToleranceMismatchError(
                f"bucket table for {name!r} was clustered at rel_tol="
                f"{sb.rel_tol}, index expects {self.rel_tol}")
        self.tables[name] = sb
        self.order.append(name)
        self._derived = None

    def remove(self, name: str) -> None:
        """Drop one scenario — O(1) now, O(remaining events) at the next
        :meth:`derive` (renumber + refold the surviving partial sums)."""
        if name not in self.tables:
            raise KeyError(f"scenario {name!r} not in cluster index")
        del self.tables[name]
        self.order.remove(name)
        self._derived = None

    def set_order(self, order: Sequence[str]) -> None:
        """Pin the derivation order (the store's canonical manifest
        order).  Must be a permutation of the ingested scenarios."""
        order = list(order)
        if set(order) != set(self.tables) or len(order) != len(self.tables):
            raise ValueError(
                f"order {order!r} is not a permutation of the indexed "
                f"scenarios {sorted(self.tables)!r}")
        if order != self.order:
            self.order = order
            self._derived = None

    @classmethod
    def rebuild(cls, rel_tol: float,
                scenario_metrics: Sequence[tuple[str, np.ndarray]],
                expected_rel_tol: float | None = None) -> "ClusterIndex":
        """Fresh index over the given scenarios in order — the from-
        scratch semantics, used for self-healing and as the timing
        baseline the partial-sums removal is measured against.

        ``expected_rel_tol`` guards miswired callers: rebuilding under a
        tolerance different from the store's raises instead of silently
        re-clustering."""
        if expected_rel_tol is not None and rel_tol != expected_rel_tol:
            raise ToleranceMismatchError(
                f"asked to rebuild the cluster index at rel_tol={rel_tol} "
                f"but the store expects {expected_rel_tol}; refusing to "
                "silently re-cluster under a mismatched tolerance")
        idx = cls.empty(rel_tol)
        for name, metrics in scenario_metrics:
            idx.ingest(name, metrics)
        return idx

    # -- derivation ------------------------------------------------------------

    def _derive_full(self) -> dict:
        if self._derived is None:
            ids_list, reps, state = combine_bucket_tables(
                [self.tables[n].astuple() for n in self.order],
                self.rel_tol, return_state=True)
            state["ids"] = dict(zip(self.order, ids_list))
            self._derived = state
        return self._derived

    def derive(self) -> tuple[dict[str, np.ndarray], dict[int, np.ndarray]]:
        """(per-scenario cluster ids, cluster representatives) — the
        joint clustering folded in ``order``.  Cached until the next
        mutation."""
        d = self._derive_full()
        return d["ids"], d["reps"]

    def assignments(self, name: str) -> np.ndarray:
        """Cluster id per compute row of one scenario (aligned with its
        ``TraceStore.metrics``)."""
        return self._derive_full()["ids"][name]

    def matcher(self) -> ClusterMatcher:
        """Frozen :class:`ClusterMatcher` snapshot of the derived lookup
        state (sorted key view + representatives).  Cached alongside the
        derived state, so it rebuilds only after mutations; serving
        callers capture it once per sync and stay immune to concurrent
        index mutation mid-match."""
        d = self._derive_full()
        m = d.get("matcher")
        if m is None:
            by_key, remap = d["by_key"], d["remap"]
            if by_key:
                # insertion position == global bucket id, so the joined
                # key bytes line up with ``remap`` by construction
                flat = np.frombuffer(b"".join(by_key), dtype=_KEY_DTYPE)
                order = np.argsort(flat, kind="stable")
                skeys = flat[order]
                scids = np.asarray(remap, dtype=np.int64)[order]
            else:
                skeys = np.zeros(0, dtype=_KEY_DTYPE)
                scids = np.zeros(0, dtype=np.int64)
            reps = d["reps"]
            rep_ids = np.fromiter(reps.keys(), dtype=np.int64,
                                  count=len(reps))
            rep_mat = (np.stack([reps[int(c)] for c in rep_ids])
                       if len(reps) else np.zeros((0, N_METRICS)))
            m = ClusterMatcher(rel_tol=self.rel_tol, skeys=skeys,
                               scids=scids, rep_ids=rep_ids, rep_mat=rep_mat)
            d["matcher"] = m
        return m

    def match_clusters(self, metrics: np.ndarray,
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Map arbitrary metric rows onto the derived corpus clusters
        *without* re-clustering: exact quantization-key lookup with a
        nearest-representative fallback for unseen keys.  Pure NumPy —
        the serve tier's hot path, vectorized via the sorted key view in
        :class:`ClusterMatcher` (bit-identical to the per-row loop kept
        as :meth:`match_clusters_reference`).  Returns ``(cluster_ids,
        matched)`` where ``matched[i]`` is False for fallback rows."""
        return self.matcher().match(metrics)

    def match_clusters_reference(self, metrics: np.ndarray,
                                 ) -> tuple[np.ndarray, np.ndarray]:
        """The per-row dict-lookup matcher the vectorized path replaced —
        preserved verbatim as the parity oracle (repo oracle discipline):
        tests pin ``match_clusters`` bit-identical to this on zoo + fuzz
        streams."""
        metrics = np.asarray(metrics, dtype=np.float64)
        if metrics.ndim != 2 or metrics.shape[1] != N_METRICS:
            raise ValueError(f"expected (n, {N_METRICS}) metrics array")
        d = self._derive_full()
        n = metrics.shape[0]
        cids = np.zeros(n, dtype=np.int64)
        matched = np.zeros(n, dtype=bool)
        if n == 0:
            return cids, matched
        if d["n_buckets"] == 0:
            raise ValueError("cannot match against an empty cluster index")
        by_key, remap = d["by_key"], d["remap"]
        q = np.ascontiguousarray(quantize_metrics(metrics, self.rel_tol))
        for i in range(n):
            gid = by_key.get(q[i].tobytes())
            if gid is not None:
                cids[i] = remap[gid]
                matched[i] = True
        if not matched.all():
            reps = d["reps"]
            rep_ids = np.fromiter(reps.keys(), dtype=np.int64,
                                  count=len(reps))
            rep_mat = np.stack([reps[int(c)] for c in rep_ids])
            v = metrics[~matched][:, None, :]
            denom = np.maximum(
                np.maximum(np.abs(rep_mat[None]), np.abs(v)), 1e-30)
            dist = (np.abs(rep_mat[None] - v) / denom).max(axis=2)
            cids[~matched] = rep_ids[np.argmin(dist, axis=1)]
        return cids, matched

    # -- persistence -----------------------------------------------------------

    def save(self, path, order: Sequence[str] | None = None) -> None:
        """Persist as a versioned npz (atomically: tmp + rename) — the
        merged cache of the per-scenario sidecars; always rebuildable."""
        names = list(self.order if order is None else order)
        if set(names) != set(self.tables):
            raise ValueError("save order must cover exactly the indexed "
                             "scenarios")
        tabs = [self.tables[n] for n in names]
        kext = np.cumsum([0] + [len(t.keys) for t in tabs])
        rext = np.cumsum([0] + [t.n_rows for t in tabs])
        cat = {
            "keys": (np.concatenate([t.keys for t in tabs]) if tabs
                     else np.zeros((0, N_METRICS), dtype=np.int64)),
            "psums": (np.concatenate([t.psums for t in tabs]) if tabs
                      else np.zeros((0, N_METRICS), dtype=np.float64)),
            "counts": (np.concatenate([t.counts for t in tabs]) if tabs
                       else np.zeros(0, dtype=np.int64)),
            "local_ids": (np.concatenate([t.local_ids for t in tabs]) if tabs
                          else np.zeros(0, dtype=np.int64)),
        }
        meta = json.dumps({"version": _INDEX_VERSION, "rel_tol": self.rel_tol,
                           "order": names})

        def write(f):
            np.savez(f, key_extents=kext, row_extents=rext,
                     meta=np.asarray(meta), **cat)

        _atomic_npz_write(Path(path), write, site="write.index")

    @classmethod
    def load(cls, path, expected_rel_tol: float | None = None,
             ) -> "ClusterIndex":
        """Load a persisted index.  Raises :class:`IndexFormatError` for
        pre-v2 artifacts (callers rebuild once — the migration path) and
        :class:`ToleranceMismatchError` when the artifact's ``rel_tol``
        disagrees with ``expected_rel_tol`` (loud, never a silent
        re-cluster)."""
        faults.crash_point("read.index", path)
        with np.load(path) as z:
            meta = json.loads(str(z["meta"]))
            if meta.get("version") != _INDEX_VERSION:
                raise IndexFormatError(
                    f"cluster index {path} has version "
                    f"{meta.get('version')!r}, expected {_INDEX_VERSION} "
                    "(pre-v2 stores rebuild once on open)")
            rel_tol = float(meta["rel_tol"])
            if expected_rel_tol is not None and rel_tol != expected_rel_tol:
                raise ToleranceMismatchError(
                    f"cluster index {path} was clustered at rel_tol="
                    f"{rel_tol}, the store expects {expected_rel_tol}; "
                    "refusing to silently re-cluster under a mismatched "
                    "tolerance")
            order = list(meta["order"])
            kext = z["key_extents"].astype(np.int64)
            rext = z["row_extents"].astype(np.int64)
            keys = z["keys"].astype(np.int64)
            psums = z["psums"].astype(np.float64)
            counts = z["counts"].astype(np.int64)
            ids = z["local_ids"].astype(np.int64)
            tables = {
                n: ScenarioBuckets(
                    rel_tol=rel_tol,
                    keys=keys[kext[i]:kext[i + 1]],
                    psums=psums[kext[i]:kext[i + 1]],
                    counts=counts[kext[i]:kext[i + 1]],
                    local_ids=ids[rext[i]:rext[i + 1]])
                for i, n in enumerate(order)
            }
            return cls(rel_tol=rel_tol, tables=tables, order=order)


# ---------------------------------------------------------------------------
# content-addressed fit cache
# ---------------------------------------------------------------------------


class FitCache:
    """Persistent ``key -> FitResult`` map for block-combination fits.

    Keys are content hashes of the exact fit inputs (target vector bytes,
    count_scale, calibration-basis fingerprint, solver grid — built by
    ``repro.core.synthesize``), so a cached fit is valid wherever its key
    matches regardless of which table union or scenario produced it; the
    corpus terminal-table fingerprint is recorded in the manifest for
    observability and coarse invalidation."""

    def __init__(self):
        self._fits: dict = {}

    def __len__(self):
        return len(self._fits)

    def __contains__(self, key: str) -> bool:
        return key in self._fits

    def get(self, key: str):
        return self._fits.get(key)

    def put(self, key: str, fr) -> None:
        self._fits[key] = fr

    def save(self, path) -> None:
        keys = list(self._fits)
        if not keys:
            Path(path).unlink(missing_ok=True)
            return
        frs = [self._fits[k] for k in keys]

        def write(f):
            np.savez(
                f,
                keys=np.asarray(keys),
                x=np.stack([np.asarray(fr.x, dtype=np.int64) for fr in frs]),
                predicted=np.stack([fr.predicted for fr in frs]),
                target=np.stack([fr.target for fr in frs]),
                residual=np.asarray([fr.residual for fr in frs]),
                rel_err=np.stack([fr.per_metric_rel_err for fr in frs]),
                unroll=np.asarray([fr.unroll for fr in frs], dtype=np.int64))

        _atomic_npz_write(Path(path), write, site="write.fit_cache")

    @classmethod
    def load(cls, path) -> "FitCache":
        # lazy: proxy_search pulls in jax, and the ingest worker pool
        # (which imports this module) never touches the fit cache
        from repro.core.proxy_search import FitResult
        cache = cls()
        with np.load(path) as z:
            for i, k in enumerate(z["keys"].tolist()):
                cache._fits[str(k)] = FitResult(
                    x=z["x"][i].astype(np.int64),
                    predicted=z["predicted"][i].astype(np.float64),
                    target=z["target"][i].astype(np.float64),
                    residual=float(z["residual"][i]),
                    per_metric_rel_err=z["rel_err"][i].astype(np.float64),
                    unroll=int(z["unroll"][i]))
        return cache


# ---------------------------------------------------------------------------
# content-addressed grammar cache
# ---------------------------------------------------------------------------


class GrammarCache:
    """Persistent ``key -> frozen Sequitur rules`` map for rank-stream
    grammars, sibling to :class:`FitCache`.

    Keys are content hashes of the exact grammar-inference inputs: the
    interned local-id stream bytes plus the merge threshold (conservative
    — today's Sequitur rules depend only on the stream; keying on the
    threshold too keeps the cache valid if grammar semantics ever pick up
    threshold dependence).  A hit hands back the frozen
    ``{rid: [(kind, ref, exp), ...]}`` rules dict and skips the Sequitur
    run entirely — on a warm store, re-opened in a fresh process, every
    unchanged rank stream resolves from this cache, so grammar inference
    on incremental appends costs only the new scenario's novel streams.

    The parallel-ingest workers warm this cache speculatively: each runs
    the scenario-local front half and returns its rules, so later joint
    synthesis hits whenever the joint cluster partition restricted to the
    scenario equals the local one (the common case; a miss just re-runs
    Sequitur — the cache is content-addressed, never a correctness risk).

    Rules are pure int/str structures, so unlike the in-memory front-half
    memo they persist (``grammar_cache.json``); rule dicts alias across
    hits and are read-only downstream (the same convention as grammar
    aliasing across a signature class).  ``hits``/``misses`` count
    :meth:`get` outcomes since construction; synthesis stats report the
    per-run delta.
    """

    def __init__(self):
        self._rules: dict[str, dict[int, list[tuple]]] = {}
        self.hits = 0
        self.misses = 0
        self.dirty = False

    def __len__(self):
        return len(self._rules)

    def __contains__(self, key: str) -> bool:
        return key in self._rules

    @staticmethod
    def key(local_ids: np.ndarray, threshold: float) -> str:
        h = hashlib.sha256(f"grammar|1|{threshold!r}|".encode())
        h.update(np.ascontiguousarray(local_ids, dtype=np.int64).tobytes())
        return h.hexdigest()

    def get(self, key: str) -> dict[int, list[tuple]] | None:
        rules = self._rules.get(key)
        if rules is None:
            self.misses += 1
        else:
            self.hits += 1
        return rules

    def put(self, key: str, rules: dict[int, list[tuple]]) -> None:
        self._rules[key] = rules
        self.dirty = True

    def merge(self, rules_by_key: dict[str, dict[int, list[tuple]]]) -> int:
        """Fold another cache's rule entries in (the parallel-ingest
        merge); existing keys win.  Returns the number added."""
        added = 0
        for k, rules in rules_by_key.items():
            if k not in self._rules:
                self.put(k, rules)
                added += 1
        return added

    def save(self, path) -> None:
        path = Path(path)
        if not self._rules:
            path.unlink(missing_ok=True)
            self.dirty = False
            return
        # rid insertion order is part of the grammar identity (to_json
        # serializes rules in that order); JSON objects round-trip dict
        # order, so the frozen form persists it exactly
        payload = {"version": 1,
                   "entries": {k: {str(rid): [list(s) for s in body]
                                   for rid, body in rules.items()}
                               for k, rules in self._rules.items()}}
        # sort_keys=False: rid order is semantic (see comment above)
        _atomic_json_write(path, payload, sort_keys=False,
                           site="write.grammar_cache")
        self.dirty = False

    @classmethod
    def load(cls, path) -> "GrammarCache":
        cache = cls()
        payload = json.loads(Path(path).read_text())
        if payload.get("version") != 1:
            raise ValueError(f"unsupported grammar cache version "
                             f"{payload.get('version')!r} in {path}")
        for k, rules in payload["entries"].items():
            cache._rules[k] = {
                int(rid): [(s[0], int(s[1]), int(s[2])) for s in body]
                for rid, body in rules.items()}
        return cache


# ---------------------------------------------------------------------------
# the ingest front half (worker-pool safe: pure NumPy, no JAX imports)
# ---------------------------------------------------------------------------


def _entry_sort_key(entry: dict) -> tuple[str, str]:
    return (entry["content_hash"], entry["name"])


def _ingest_front_half(root, name: str, src, rel_tol: float,
                       threshold: float = 0.5, warm_grammars: bool = False,
                       ) -> tuple[str, dict, ScenarioBuckets, dict]:
    """The per-scenario half of ingest with no view of the corpus: write
    the scenario npz + bucket sidecar, hash, calibrate noise, and
    (optionally) warm the grammar cache with the scenario-local front
    half.  Pure NumPy throughout — this is the function
    :meth:`CorpusStore.add_scenarios` fans across the worker pool, so it
    must not import any accelerator code.

    ``src`` is a :class:`TraceStore` or a path to one (paths are the
    fleet-scale case: workers load their own inputs, nothing large rides
    the pipe).  Returns ``(name, manifest_entry, buckets, grammar_rules)``
    for the parent to merge under the shard locks."""
    root = Path(root)
    faults.crash_point("worker.ingest", name)
    store = src if isinstance(src, TraceStore) else TraceStore.load(src)
    path = _atomic_scenario_write(root / _SCENARIO_DIR / f"{name}.npz", store)
    chash = store.content_hash()
    sb = ScenarioBuckets.from_metrics(store.metrics, rel_tol)
    sb.save(root / _SCENARIO_DIR / f"{name}.buckets.npz")
    from repro.core import noise as noise_mod   # lazy: numpy-only module
    entry = {
        "name": name,
        "file": f"{_SCENARIO_DIR}/{name}.npz",
        "content_hash": chash,
        "n_ranks": store.n_ranks,
        "n_events": store.n_events,
        "n_compute_events": store.n_compute_events,
        # scenario-LOCAL noise calibration (this scenario's own
        # clustering at the store's rel_tol): an observability artifact
        # riding the manifest.  Synthesis recalibrates against the JOINT
        # cluster assignment so batch and incremental paths emit
        # identical NOISE_MODELS tables.
        "noise": noise_mod.calibrate(store, rel_tol=rel_tol).to_json(),
    }
    rules: dict = {}
    if warm_grammars:
        from repro.core.trace_ir import compress_store   # numpy-only
        gc = GrammarCache()
        compress_store(store, rel_tol, threshold, grammar_cache=gc)
        rules = gc._rules
    return name, entry, sb, rules


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------


class CorpusStore:
    """Sharded on-disk trace corpus with incremental joint clustering.

    ::

        cs = CorpusStore("corpus/")            # opens or creates
        cs.add_scenario("transformer-dp", store)
        cs.add_scenarios(items, n_workers=4)   # parallel batch ingest
        corp = synthesize_corpus(store=cs)     # incremental synthesis

    Scenario order is **canonical**, not ingestion order: shards in index
    order, entries within a shard sorted by ``(content_hash, name)``.
    The clustering and the δ̄-parity invariant are defined relative to
    that order, which is a pure function of the scenario set — so any mix
    of appenders, worker counts, and append interleavings converges to
    the same bit-identical store state.
    """

    def __init__(self, root, rel_tol: float | None = None,
                 n_shards: int | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        (self.root / _SCENARIO_DIR).mkdir(exist_ok=True)
        (self.root / _SHARD_DIR).mkdir(exist_ok=True)
        self._stores: dict[str, TraceStore] = {}
        #: in-memory front-half memo used by incremental synthesis
        #: (grammar objects are not persistable; the on-disk caches are
        #: the cluster index and the fit/grammar caches)
        self.memo: dict = {}
        #: serializes this handle's mutators against in-process serving
        #: refreshes (cross-process safety stays with the shard flocks)
        self.lock = threading.RLock()
        self._subscribers: list = []
        #: scenarios whose artifacts failed to load at open (corrupt npz
        #: with no healthy sidecar): excluded from the cluster index,
        #: poison synthesis until :meth:`repair` quarantines them
        self.damaged: dict[str, ScenarioCorruptError] = {}
        #: shard manifests that failed to parse at open: entries absent
        #: from this handle's view until :meth:`repair` reconstructs them
        self.shard_errors: dict[int, ShardCorruptError] = {}
        #: operational counters (pool breaks, serial retries, ...)
        self.stats: dict[str, int] = {"n_pool_breaks": 0,
                                      "n_serial_retries": 0,
                                      "n_ingest_errors": 0}

        mpath = self.root / _MANIFEST
        if mpath.exists():
            manifest = self._read_header(mpath)
            version = manifest.get("version")
            if version not in (1, _MANIFEST_VERSION):
                raise ValueError(
                    f"unsupported corpus manifest version {version!r} "
                    f"in {mpath}")
            if rel_tol is not None and rel_tol != manifest["rel_tol"]:
                raise ValueError(
                    f"corpus at {self.root} was built with rel_tol="
                    f"{manifest['rel_tol']}, asked to open with {rel_tol}")
            if version == 1:
                self._migrate_v1(manifest)
            else:
                if n_shards is not None and n_shards != manifest["n_shards"]:
                    raise ValueError(
                        f"corpus at {self.root} has {manifest['n_shards']} "
                        f"shards, asked to open with {n_shards}")
                self.manifest = manifest
                self._shards = [self._read_shard_safe(i)
                                for i in range(self.n_shards)]
        else:
            self.manifest = {"version": _MANIFEST_VERSION,
                             "rel_tol": 0.05 if rel_tol is None else rel_tol,
                             "n_shards": n_shards or _N_SHARDS_DEFAULT,
                             "table_fingerprint": None}
            self._shards = [[] for _ in range(self.n_shards)]
            self._write_manifest()
        self._write_meta()

        seen: set[str] = set()
        for e in self._iter_entries():
            if e["name"] in seen:
                raise ValueError(
                    f"corpus at {self.root} lists scenario "
                    f"{e['name']!r} in more than one shard (two appenders "
                    "raced on the same name with different content)")
            seen.add(e["name"])

        self.index = self._load_or_rebuild_index()
        fpath = self.root / _FITS
        try:
            self.fits = FitCache.load(fpath) if fpath.exists() else FitCache()
        except Exception:
            # fits are content-addressed pure derivations: a corrupt cache
            # costs a re-solve, never correctness — start empty
            self.fits = FitCache()
        gpath = self.root / _GRAMMARS
        try:
            self.grammars = (GrammarCache.load(gpath) if gpath.exists()
                             else GrammarCache())
        except Exception:
            # same contract as the fit cache: a corrupt grammar cache
            # costs a Sequitur re-run, never correctness
            self.grammars = GrammarCache()

    # -- open-time migration / healing -----------------------------------------

    def _read_header(self, mpath: Path) -> dict:
        """Read the manifest header, recovering a torn one from the
        immutable ``store.meta.json`` twin (written at creation; holds
        only the never-changing fields, so recovery loses at most the
        ``table_fingerprint`` observability field)."""
        try:
            return json.loads(mpath.read_text())
        except ValueError as e:
            meta_path = self.root / _META
            if not meta_path.exists():
                raise ValueError(
                    f"corpus manifest {mpath} is unreadable "
                    f"({type(e).__name__}: {e}) and no {_META} recovery "
                    "twin exists (pre-robustness store?)") from e
            recovered = json.loads(meta_path.read_text())
            manifest = {"version": recovered["version"],
                        "rel_tol": recovered["rel_tol"],
                        "n_shards": recovered["n_shards"],
                        "table_fingerprint": None}
            _atomic_json_write(mpath, manifest)   # heal in place
            return manifest

    def _write_meta(self) -> None:
        """Persist (once) the immutable header twin used by
        :meth:`_read_header` to recover from a torn ``manifest.json``.
        Pre-existing stores heal it on first open.  Plain write, no
        fault point: it is write-once and recovery-only."""
        meta_path = self.root / _META
        if not meta_path.exists():
            tmp = meta_path.with_suffix(".tmp")
            tmp.write_text(json.dumps(
                {"version": _MANIFEST_VERSION, "rel_tol": self.rel_tol,
                 "n_shards": self.n_shards}, sort_keys=True))
            os.replace(tmp, meta_path)

    def _migrate_v1(self, manifest: dict) -> None:
        """One-time v1 → v2 migration: reshard the flat scenario list and
        adopt the canonical order.  The v1 index npz fails the version
        check downstream and rebuilds once (writing the sidecars), as
        documented — v1's event-order accumulation is superseded by the
        partial-sums semantics, so derived reps may move by an ulp."""
        entries = manifest.get("scenarios", [])
        self.manifest = {"version": _MANIFEST_VERSION,
                         "rel_tol": manifest["rel_tol"],
                         "n_shards": _N_SHARDS_DEFAULT,
                         "table_fingerprint":
                             manifest.get("table_fingerprint")}
        self._shards = [[] for _ in range(self.n_shards)]
        for e in entries:
            self._shards[self._shard_of(e["content_hash"])].append(e)
        for i, shard in enumerate(self._shards):
            shard.sort(key=_entry_sort_key)
            if shard:
                _atomic_json_write(self._shard_path(i),
                                   {"version": _MANIFEST_VERSION,
                                    "entries": shard},
                                   site="write.shard")
        self._write_manifest()

    def _load_or_rebuild_index(self) -> ClusterIndex:
        """Load the merged cluster-index cache, validating it against the
        manifest (the source of truth).  A missing, corrupt, stale, or
        pre-v2 index refreshes from the per-scenario bucket sidecars
        (falling back to a metrics column load where a sidecar is also
        gone), so the store self-heals instead of silently serving
        assignments inconsistent with its contents.  A *tolerance
        mismatch* is not healed: it raises
        :class:`ToleranceMismatchError` loudly.

        A scenario whose sidecar *and* npz are both unreadable cannot be
        healed: it is recorded in :attr:`damaged` and excluded from the
        index (synthesis refuses to run until :meth:`repair` quarantines
        it) — a double fault must not brick ``open``."""
        ipath = self.root / _INDEX
        names = self.names
        idx: ClusterIndex | None = None
        if ipath.exists():
            try:
                idx = ClusterIndex.load(ipath, expected_rel_tol=self.rel_tol)
            except ToleranceMismatchError:
                raise
            except Exception:
                idx = None            # corrupt or pre-v2: rebuild below
        if idx is not None and set(idx.tables) == set(names):
            idx.set_order(names)
            return idx
        tables: dict[str, ScenarioBuckets] = (
            {} if idx is None
            else {n: idx.tables[n] for n in names if n in idx.tables})
        for n in names:
            if n in tables:
                continue
            sb = self._sidecar_or_rebuild(n)
            if sb is not None:
                tables[n] = sb
        healthy = [n for n in names if n in tables]
        idx = ClusterIndex(rel_tol=self.rel_tol, tables=tables,
                           order=healthy)
        if healthy and not self.damaged:
            idx.save(ipath)
        return idx

    def _sidecar_or_rebuild(self, n: str) -> ScenarioBuckets | None:
        """One scenario's bucket table: load the sidecar, else rebuild it
        from the scenario's metrics (healing the sidecar on disk).  When
        the npz is also unreadable, record the scenario in
        :attr:`damaged` and return ``None``."""
        spath = self._sidecar_path(n)
        sb: ScenarioBuckets | None = None
        if spath.exists():
            try:
                sb = ScenarioBuckets.load(spath,
                                          expected_rel_tol=self.rel_tol)
            except ToleranceMismatchError:
                raise
            except Exception:
                sb = None
        if sb is None:
            try:
                metrics = self._metrics_of(n)
            except ScenarioCorruptError as e:
                self.damaged[n] = e
                return None
            sb = ScenarioBuckets.from_metrics(metrics, self.rel_tol)
            sb.save(spath)            # heal the sidecar
        return sb

    # -- basic accessors -------------------------------------------------------

    @property
    def rel_tol(self) -> float:
        return float(self.manifest["rel_tol"])

    @property
    def n_shards(self) -> int:
        return int(self.manifest["n_shards"])

    def _iter_entries(self) -> Iterator[dict]:
        for shard in self._shards:
            yield from shard

    @property
    def names(self) -> list[str]:
        """Scenario names in canonical manifest order (shard-major,
        content-hash sorted within a shard)."""
        return [e["name"] for e in self._iter_entries()]

    def __len__(self) -> int:
        return sum(len(s) for s in self._shards)

    def __contains__(self, name: str) -> bool:
        return any(e["name"] == name for e in self._iter_entries())

    def __iter__(self) -> Iterator[tuple[str, TraceStore]]:
        for name in self.names:
            yield name, self.load_scenario(name)

    def _entry(self, name: str) -> dict:
        for e in self._iter_entries():
            if e["name"] == name:
                return e
        raise KeyError(f"scenario {name!r} not in corpus")

    def content_hash(self, name: str) -> str:
        return self._entry(name)["content_hash"]

    def noise_params(self, name: str):
        """The scenario-local calibrated noise model recorded at
        ``add_scenario`` time, or ``None`` for entries written before the
        noise layer existed (pre-noise manifests stay loadable)."""
        data = self._entry(name).get("noise")
        if data is None:
            return None
        from repro.core import noise as noise_mod
        return noise_mod.NoiseModel.from_json(data)

    def scenario_path(self, name: str) -> Path:
        return self.root / _SCENARIO_DIR / f"{name}.npz"

    def _sidecar_path(self, name: str) -> Path:
        return self.root / _SCENARIO_DIR / f"{name}.buckets.npz"

    # -- shard plumbing --------------------------------------------------------

    def _shard_of(self, content_hash: str) -> int:
        return int(content_hash[:8], 16) % self.n_shards

    def _shard_path(self, i: int) -> Path:
        return self.root / _SHARD_DIR / f"shard-{i:02d}.json"

    def _lock_path(self, stem: str) -> Path:
        return self.root / _LOCK_DIR / f"{stem}.lock"

    @staticmethod
    def _read_shard(path: Path) -> list[dict]:
        if not path.exists():
            return []
        faults.crash_point("read.shard", path)
        try:
            data = json.loads(path.read_text())
        except (OSError, ValueError) as e:
            # torn write / bit rot: typed so open can record it and
            # repair() can reconstruct the shard from scenario artifacts
            raise ShardCorruptError(path, e) from e
        if data.get("version") != _MANIFEST_VERSION:
            raise ValueError(f"unsupported shard manifest version "
                             f"{data.get('version')!r} in {path}")
        return sorted(data["entries"], key=_entry_sort_key)

    def _read_shard_safe(self, i: int) -> list[dict]:
        """Open-time shard read that records (instead of raising) a
        :class:`ShardCorruptError` in :attr:`shard_errors`, so a torn
        shard manifest leaves the store openable — and repairable —
        rather than bricked."""
        try:
            return self._read_shard(self._shard_path(i))
        except ShardCorruptError as e:
            self.shard_errors[i] = e
            return []

    def _append_entry(self, entry: dict) -> None:
        """Commit one scenario entry to its shard: flock the shard,
        re-read it from disk (picking up concurrent appenders), insert in
        canonical position, atomic-rename the new shard file."""
        i = self._shard_of(entry["content_hash"])
        with _file_lock(self._lock_path(f"shard-{i:02d}")):
            cur = self._read_shard(self._shard_path(i))
            if any(e["name"] == entry["name"] for e in cur):
                raise ValueError(
                    f"scenario {entry['name']!r} already in corpus")
            cur.append(entry)
            cur.sort(key=_entry_sort_key)
            _atomic_json_write(self._shard_path(i),
                               {"version": _MANIFEST_VERSION, "entries": cur},
                               site="write.shard")
        self._shards[i] = cur

    def _remove_entry(self, entry: dict) -> None:
        i = self._shard_of(entry["content_hash"])
        with _file_lock(self._lock_path(f"shard-{i:02d}")):
            cur = [e for e in self._read_shard(self._shard_path(i))
                   if e["name"] != entry["name"]]
            _atomic_json_write(self._shard_path(i),
                               {"version": _MANIFEST_VERSION, "entries": cur},
                               site="write.shard")
        self._shards[i] = cur

    # -- mutation notifications ------------------------------------------------

    def subscribe(self, fn) -> None:
        """Register ``fn(event, names)`` to run after every mutation this
        handle commits (``event`` is ``"add"`` or ``"remove"``; ``names``
        a tuple of affected scenarios).  Callbacks fire after
        ``_finish_mutation`` under :attr:`lock`; they must be cheap and
        must not mutate the store (serving subscribers just flip a stale
        bit and refresh lazily)."""
        self._subscribers.append(fn)

    def unsubscribe(self, fn) -> None:
        """Drop a subscriber registered with :meth:`subscribe` (no-op if
        absent)."""
        with contextlib.suppress(ValueError):
            self._subscribers.remove(fn)

    def _notify(self, event: str, names) -> None:
        for fn in list(self._subscribers):
            fn(event, tuple(names))

    def manifest_fingerprint(self) -> str:
        """sha256 over this handle's canonical ``(name, content_hash)``
        entry list — the cheap drift probe serving caches compare against
        (a mutation through this handle always changes it)."""
        h = hashlib.sha256()
        for e in self._iter_entries():
            h.update(e["name"].encode())
            h.update(b"\x00")
            h.update(e["content_hash"].encode())
            h.update(b"\x00")
        return h.hexdigest()

    # -- mutation --------------------------------------------------------------

    @staticmethod
    def _validate_name(name: str) -> None:
        if "/" in name or name in (".", ".."):
            raise ValueError(f"invalid scenario name {name!r}")

    def add_scenario(self, name: str, store: TraceStore) -> str:
        """Append one scenario: write its npz + bucket sidecar, commit
        the shard entry under the shard lock, fold its bucket table into
        the cluster index.  Returns the content hash."""
        with self.lock:
            self._validate_name(name)
            if name in self:
                raise ValueError(f"scenario {name!r} already in corpus")
            _, entry, sb, _ = _ingest_front_half(self.root, name, store,
                                                 self.rel_tol)
            self._append_entry(entry)
            self.index.ingest_table(name, sb)
            self._stores[name] = store
            self._finish_mutation()
            self._notify("add", [name])
            return entry["content_hash"]

    def add_scenarios(self, items, n_workers: int = 0,
                      threshold: float = 0.5, warm_grammars: bool = True,
                      ) -> dict[str, str]:
        """Batch ingest; ``n_workers > 0`` fans the per-scenario front
        half (:func:`_ingest_front_half`: npz write, hashing, bucket
        table, noise calibration, grammar warm-up — all pure NumPy)
        across a process pool, then merges the returned bucket tables
        deterministically in canonical manifest order, so the final state
        is bit-identical to ``n_workers=0`` serial ingest.

        ``items`` is a sequence of ``(name, TraceStore | path)`` pairs —
        paths are the fleet-scale form (each worker loads its own input).
        ``warm_grammars`` runs the scenario-local Sequitur front half in
        the worker and folds the rules into the persistent grammar cache,
        so the first joint synthesis after ingest skips Sequitur for
        every stream whose joint partition matches the local one.
        Returns ``{name: content_hash}``."""
        with self.lock:
            return self._add_scenarios_locked(items, n_workers, threshold,
                                              warm_grammars)

    def _pool_front_half(self, items, n_workers, threshold, warm_grammars,
                         results: dict, errors: dict) -> None:
        """Fan :func:`_ingest_front_half` across a process pool with
        per-future fault isolation: one worker dying (a real
        ``BrokenProcessPool`` — e.g. OOM-killed) or one corrupt input
        fails only its own items, never the batch.  Failed items land in
        ``errors`` for the caller's serial retry."""
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor
        from concurrent.futures.process import BrokenProcessPool
        method = ("fork" if "fork" in mp.get_all_start_methods()
                  else "spawn")
        ctx = mp.get_context(method)
        pool_broke = False
        with ProcessPoolExecutor(
                max_workers=min(n_workers, len(items)),
                mp_context=ctx) as ex:
            futs = [(name, ex.submit(_ingest_front_half, str(self.root),
                                     name,
                                     src if isinstance(src, TraceStore)
                                     else str(src),
                                     self.rel_tol, threshold, warm_grammars))
                    for name, src in items]
            for name, fut in futs:
                try:
                    results[name] = fut.result()
                except BrokenProcessPool as e:
                    # the pool is dead: every unfinished future fails
                    # with this — count the break once, queue the items
                    # for the serial retry
                    if not pool_broke:
                        pool_broke = True
                        self.stats["n_pool_breaks"] += 1
                    errors[name] = e
                except (Exception, faults.InjectedCrash) as e:
                    # InjectedCrash here came over the pipe from a
                    # *child* — a worker crash, not this process's
                    errors[name] = e

    def _add_scenarios_locked(self, items, n_workers, threshold,
                              warm_grammars) -> dict[str, str]:
        items = [(name, src) for name, src in items]
        for name, _ in items:
            self._validate_name(name)
            if name in self:
                raise ValueError(f"scenario {name!r} already in corpus")
        if len({n for n, _ in items}) != len(items):
            raise ValueError("duplicate scenario names in batch")

        results: dict[str, tuple] = {}
        errors: dict[str, BaseException] = {}
        if n_workers and len(items) > 1:
            self._pool_front_half(items, n_workers, threshold, warm_grammars,
                                  results, errors)
        else:
            # serial path: Exception costs the item (retried below);
            # InjectedCrash propagates — it simulates THIS process dying
            for name, src in items:
                try:
                    results[name] = _ingest_front_half(
                        self.root, name, src, self.rel_tol, threshold,
                        warm_grammars)
                except Exception as e:
                    errors[name] = e

        # one serial retry per failed item (transient faults — a dead
        # worker, flaky EIO — clear; deterministic ones fail again and
        # are reported as typed per-item errors)
        item_errors: list[IngestItemError] = []
        by_name = dict(items)
        for name in list(errors):
            self.stats["n_serial_retries"] += 1
            try:
                results[name] = _ingest_front_half(
                    self.root, name, by_name[name], self.rel_tol, threshold,
                    warm_grammars)
                del errors[name]
            except Exception as e:
                item_errors.append(IngestItemError(name, e, retried=True))

        # commit the survivors (canonical order washes out which failed)
        hashes: dict[str, str] = {}
        for name, src in items:
            r = results.get(name)
            if r is None:
                continue
            _, entry, sb, rules = r
            self._append_entry(entry)
            self.index.ingest_table(name, sb)
            self.grammars.merge(rules)
            hashes[name] = entry["content_hash"]
            if isinstance(src, TraceStore):
                self._stores[name] = src
        self._finish_mutation()
        self.save_grammars()
        if hashes:
            self._notify("add", list(hashes))
        if item_errors:
            self.stats["n_ingest_errors"] += len(item_errors)
            # after commit: per-item fault isolation means the failures
            # cost their items, never the batch
            raise IngestBatchError(item_errors, hashes)
        return hashes

    def remove_scenario(self, name: str) -> None:
        """Drop a scenario in O(remaining events): delete its shard
        entry, npz, and bucket sidecar, and drop its partial-sum table
        from the cluster index — the survivors' buckets renumber and
        their partials refold (in manifest order) at the next derive.  No
        metrics reload, no full rebuild; post-removal clustering is
        bit-identical to a from-scratch index over the survivors."""
        with self.lock:
            entry = self._entry(name)
            self._remove_entry(entry)
            self._stores.pop(name, None)
            self.scenario_path(name).unlink(missing_ok=True)
            self._sidecar_path(name).unlink(missing_ok=True)
            self.index.remove(name)
            self._finish_mutation()
            self._notify("remove", [name])

    def _metrics_of(self, name: str) -> np.ndarray:
        cached = self._stores.get(name)
        if cached is not None:
            return cached.metrics
        path = self.root / self._entry(name)["file"]
        try:
            # fault point inside the try: an injected EIO is typed like a
            # real one (InjectedCrash is a BaseException and still escapes)
            faults.crash_point("read.scenario_npz", path)
            cols = TraceStore.load_columns(path, ["metrics"])
        except Exception as e:
            raise ScenarioCorruptError(name, path, e) from e
        return cols["metrics"]

    def load_scenario(self, name: str) -> TraceStore:
        st = self._stores.get(name)
        if st is None:
            path = self.root / self._entry(name)["file"]
            try:
                faults.crash_point("read.scenario_npz", path)
                st = TraceStore.load(path)
            except Exception as e:
                # typed: a truncated npz must name its scenario, not
                # unwind as a raw zipfile/OSError mid-synthesis
                raise ScenarioCorruptError(name, path, e) from e
            self._stores[name] = st
        return st

    # -- clustering view -------------------------------------------------------

    def cluster_assignments(self) -> tuple[dict[str, np.ndarray],
                                           dict[int, np.ndarray]]:
        """Per-scenario cluster ids (aligned to each scenario's metrics
        rows) + the joint cluster representatives — bit-identical to
        :func:`repro.core.events.cluster_corpus` over the manifest-order
        scenario metrics."""
        ids, reps = self.index.derive()
        return dict(ids), reps

    # -- persistence -----------------------------------------------------------

    def _write_manifest(self) -> None:
        _atomic_json_write(self.root / _MANIFEST, self.manifest)

    def _finish_mutation(self) -> None:
        """Sync the index with the manifest view and re-pin canonical
        order.  The locked shard re-read inside ``_append_entry`` makes
        concurrent appenders' commits visible to this handle, so fold in
        their sidecar tables (a scenario's sidecar is always written
        *before* its shard entry commits) and drop tables for scenarios
        removed elsewhere.  The merged index cache write is atomic but
        last-writer-wins across processes — harmless, because open-time
        validation refreshes any stale cache from the sidecars."""
        names = self.names
        current = set(names)
        for n in list(self.index.tables):
            if n not in current:
                self.index.remove(n)
        for n in names:
            if n in self.index.tables or n in self.damaged:
                continue
            sb = self._sidecar_or_rebuild(n)
            if sb is not None:
                self.index.ingest_table(n, sb)
        self.index.set_order([n for n in names
                              if n in self.index.tables])
        self.index.save(self.root / _INDEX)

    def save_fits(self, table_fingerprint: str | None = None) -> None:
        """Persist the fit cache (called by incremental synthesis after a
        solve) and record the corpus table version in the manifest header
        (under the header lock — concurrent synthesizers last-write-wins
        on this observability field, never on scenario entries)."""
        if table_fingerprint is not None:
            with _file_lock(self._lock_path("manifest")):
                mpath = self.root / _MANIFEST
                if mpath.exists():     # pick up concurrent header edits
                    on_disk = json.loads(mpath.read_text())
                    if on_disk.get("version") == _MANIFEST_VERSION:
                        self.manifest = on_disk
                self.manifest["table_fingerprint"] = table_fingerprint
                self._write_manifest()
        self.fits.save(self.root / _FITS)

    def save_grammars(self) -> None:
        """Persist the grammar cache if it gained entries (called by
        incremental synthesis after the front half)."""
        if self.grammars.dirty:
            self.grammars.save(self.root / _GRAMMARS)

    # -- integrity: fsck + quarantine ------------------------------------------

    def quarantine_dir(self) -> Path:
        """Where :meth:`repair` moves damaged scenario artifacts
        (created on first use)."""
        return self.root / _QUARANTINE_DIR

    def verify(self, deep: bool = True):
        """fsck: cross-check every shard entry against its scenario npz
        (existence, loadability, content-hash match), sidecar presence
        and coherence, index/manifest agreement, and cache readability.
        Returns a typed :class:`repro.core.fsck.VerifyReport`; mutates
        nothing.  ``deep=False`` skips re-hashing the scenario npz
        payloads (existence/metadata checks only)."""
        from repro.core.fsck import verify_store   # lazy: keeps ingest
        with self.lock:                            # workers import-light
            return verify_store(self, deep=deep)

    def repair(self):
        """Quarantine every damaged scenario (npz + sidecar moved to
        ``quarantine/`` with a JSON damage record), reconstruct corrupt
        shard manifests from the surviving scenario artifacts, and heal
        sidecars/index/caches — then re-derive.  Post-repair store state
        is bit-identical to a from-scratch store over the surviving
        scenario set (the chaos-sweep oracle).  Returns a
        :class:`repro.core.fsck.RepairReport`."""
        from repro.core.fsck import repair_store
        with self.lock:
            return repair_store(self)
