"""TPU proxy basic blocks (paper §2.4, Fig. 3 — DESIGN.md §2 re-founding).

The paper's 11 C blocks each excite ~1 hardware counter (IPC, LST/INS,
L1_DCM, BR_CN/MSP).  Our 11 JAX blocks each excite ~1 TPU metric axis:

  id  name          excites               paper analog
  --  ------------  --------------------  -------------------------------
   1  mxu_vmem      mxu_flops (high AI)   block1 simple add (high IPC)
   2  mxu_small     mxu_flops (low AI)    block2 add, low LST/INS
   3  hbm_stream    hbm_bytes (f32)       block7 cache-miss walk
   4  vpu_chain     vpu_elems (int8:      block1/2 ALU pressure
                    lowest bytes/elem)
   5  trans_chain   transcendentals       block3/4 div (low IPC slow path)
   6  gather_rand   gather_elems          block7-9 cache misses (irregular)
   7  reduce_long   vpu w/ bytes ratio 4  block8 cache miss + high ipc
   8  scan_seq      scan_steps + vpu      block5/6 msp loops (serialization)
   9  move_shift    hbm_bytes, zero vpu   block7 cache walk (pure movement)
  10  empty_loop    scan_steps only       block10 empty cycle for branch
  11  loop_turn     scan_steps (the       block11 loop achieving linear
                    combo-loop overhead)  combination of other blocks

Replay structure (faithful to the paper's "blocks 1-9 live inside block-11's
loop, x11 >= sum(x_1..9)"): each block i runs in its own ``fori_loop`` of
``x_i`` turns, followed by one padding loop of ``x11 - sum(x_i)`` empty turns.
Total loop turns = x11.  Hence one application of block i physically costs
(col_i + col_11), which is exactly the variable substitution that turns the
paper's coupled QP (eq. 6-7 + x11 constraint) into a plain NNLS — see
:mod:`repro.core.proxy_search`.

Calibration (the ``mini-proxy-app`` measurement producing matrix B, eq. 2)
runs the *same* jaxpr cost walker used to trace target programs, so the fit
is exactly self-consistent: the walker cost of generated proxy code equals
``B @ x`` by construction (tested in tests/test_blocks_qp.py).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.events import N_METRICS

BLOCK_NAMES: tuple[str, ...] = (
    "mxu_vmem", "mxu_small", "hbm_stream", "vpu_chain", "trans_chain",
    "gather_rand", "reduce_long", "scan_seq", "move_shift",
    "empty_loop", "loop_turn",
)
N_BLOCKS = len(BLOCK_NAMES)

# geometry constants (sized so working sets are VMEM-resident on TPU and
# replay on CPU stays fast; MXU dims are multiples of 128)
_MM = 128            # mxu_vmem tile
_MS = 8              # mxu_small M-dim (low arithmetic intensity)
_VEC = 1 << 15       # hbm_stream vector (128 KiB f32): small quanta limit
                     # integer-rounding error even for few-MB events; unroll
                     # absorbs the extra loop turns
_TILE = (32, 128)    # VPU tile
_TAB = 1 << 14       # gather table
_NIDX = 4096         # gather indices
_SCAN_LEN = 64       # scan_seq inner length


def init_state(seed: int = 0) -> dict:
    """Fixed-shape pytree threaded through every block (DCE-proof carry)."""
    rng = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(rng.uniform(-1, 1, (_MM, _MM)), jnp.bfloat16),
        # matmul operands carry the 1/128 contraction normalization baked in
        # so the MXU blocks emit *zero* VPU ops (pure-matmul targets must be
        # representable — see proxy_search feasibility notes)
        "b": jnp.asarray(rng.uniform(-1, 1, (_MM, _MM)) / _MM, jnp.bfloat16),
        "w": jnp.asarray(rng.uniform(-1, 1, (_MM, _MM)) / _MM, jnp.float32),
        "v": jnp.asarray(rng.uniform(0, 1, (_VEC,)), jnp.float32),
        "t": jnp.asarray(rng.uniform(-1, 1, _TILE), jnp.float32),
        "t8": jnp.asarray(rng.randint(-64, 64, _TILE), jnp.int8),
        "tab": jnp.asarray(rng.uniform(0, 1, (_TAB,)), jnp.float32),
        "idx": jnp.asarray(rng.randint(0, _TAB, (_NIDX,)), jnp.int32),
        "s": jnp.float32(0.0),
    }


# -- the block bodies (one "application" each) --------------------------------


def mxu_vmem(st: dict) -> dict:
    """128x128x128 bf16 matmul, VMEM-resident: high-AI MXU pressure."""
    st = dict(st)
    st["a"] = st["a"] @ st["b"]
    return st


def mxu_small(st: dict) -> dict:
    """8x128x128 f32 matmul: MXU flops at low arithmetic intensity."""
    st = dict(st)
    row = st["t"][:_MS]
    out = row @ st["w"]
    st["t"] = jnp.concatenate([out, st["t"][_MS:]], axis=0)
    return st


def hbm_stream(st: dict) -> dict:
    """Streaming f32 vector update: bytes/vpu ~ 8 (pure HBM pressure)."""
    st = dict(st)
    st["v"] = st["v"] * 0.999999 + 1e-6
    return st


def vpu_chain(st: dict) -> dict:
    """int8 ALU chain: lowest bytes-per-element VPU pressure (ratio ~2)."""
    st = dict(st)
    t = st["t8"]
    for _ in range(4):
        t = (t + jnp.int8(3)) ^ jnp.int8(21)
    st["t8"] = t
    return st


def trans_chain(st: dict) -> dict:
    """tanh chain: transcendental slow-path pressure."""
    st = dict(st)
    t = st["t"]
    for _ in range(2):
        t = jnp.tanh(t)
    st["t"] = t * 1.0009765625 	# escape the tanh fixed point at 0
    return st


def gather_rand(st: dict) -> dict:
    """random-index gather from a table: irregular-address pressure."""
    st = dict(st)
    g = st["tab"][st["idx"]]
    st["s"] = st["s"] * 0.5 + jnp.sum(g) * 1e-6
    return st


def reduce_long(st: dict) -> dict:
    """long reduction: vpu with bytes/elem ratio 4."""
    st = dict(st)
    st["s"] = st["s"] * 0.5 + jnp.sum(st["v"]) * 1e-9
    return st


def scan_seq(st: dict) -> dict:
    """sequential scalar scan: serialization hazard (scan_steps)."""
    st = dict(st)

    def body(c, _):
        return c * 0.9999 + 1e-7, None

    out, _ = lax.scan(body, st["s"], None, length=_SCAN_LEN)
    st["s"] = out
    return st


def move_shift(st: dict) -> dict:
    """pure data movement (slice+concat roll): bytes with zero element ops.

    TPU has no branch predictor, so the paper's msp blocks have no analogue
    (DESIGN.md §2); the freed slot covers the pure-copy segments real traces
    contain (layout changes, halo packing) that no ALU block can represent."""
    st = dict(st)
    v = st["v"]
    st["v"] = jnp.concatenate([v[_VEC // 2:], v[:_VEC // 2]])
    return st


BLOCK_FNS: dict[str, Callable[[dict], dict]] = {
    "mxu_vmem": mxu_vmem, "mxu_small": mxu_small, "hbm_stream": hbm_stream,
    "vpu_chain": vpu_chain, "trans_chain": trans_chain,
    "gather_rand": gather_rand, "reduce_long": reduce_long,
    "scan_seq": scan_seq, "move_shift": move_shift,
}


def repeat_block(name: str, n, st: dict, unroll: int = 1) -> dict:
    """Run block ``name`` for ``n`` loop turns of ``unroll`` inlined
    applications each (the paper places x_i block *instances* inside the
    block-11 loop body; unroll is that instance count — it decouples the
    application count from the loop-turn/serialization count)."""
    fn = BLOCK_FNS[name]

    def body(i, s):
        for _ in range(unroll):
            s = fn(s)
        return s

    return lax.fori_loop(0, n, body, st)


def empty_turns(n, st: dict) -> dict:
    """n empty loop turns (block10 / block11-padding)."""
    return lax.fori_loop(0, n, lambda i, s: s, st)


def run_combo(st: dict, x, unroll: int = 1) -> dict:
    """Execute the paper's block combination for count vector ``x`` (len 11).

    Blocks 1-9 run x_i loop turns of ``unroll`` applications each; then
    ``x11 - sum(x_1..9)`` empty padding turns (total combo-loop turns ==
    x11); then block10's standalone empty loop of x10 turns.  ``x`` entries
    must be static Python ints here (the generated code path);
    :func:`run_combo_dyn` takes a traced vector.
    """
    x = [int(v) for v in x]
    body = int(sum(x[:9]))
    if x[10] < body:
        raise ValueError(f"x11={x[10]} < sum(x1..9)={body}")
    for i, name in enumerate(BLOCK_NAMES[:9]):
        if x[i] > 0:
            st = repeat_block(name, x[i], st, unroll)
    pad = x[10] - body
    if pad > 0:
        st = empty_turns(pad, st)
    if x[9] > 0:
        st = empty_turns(x[9], st)
    return st


def run_combo_dyn(st: dict, x, unroll: int = 1) -> dict:
    """Traced-count variant (x: int32[11]); used by the jit replay engine."""
    x = jnp.asarray(x, jnp.int32)
    for i, name in enumerate(BLOCK_NAMES[:9]):
        st = repeat_block(name, x[i], st, unroll)
    pad = jnp.maximum(x[10] - jnp.sum(x[:9]), 0)
    st = empty_turns(pad, st)
    st = empty_turns(x[9], st)
    return st


# -- calibration: build matrix B (paper eq. 2) --------------------------------


@functools.lru_cache(maxsize=1)
def calibration_matrix() -> np.ndarray:
    """B[i, j]: metric i per single application of block j (walker-measured).

    Columns 1-9 are the *bare* block bodies (the loop turn each application
    carries at replay is column 11; proxy_search adds it via the constraint
    substitution).  Columns 10 and 11 are one empty loop turn each.
    """
    from repro.core.tracer import compute_cost  # local import: cycle-free

    st = jax.eval_shape(init_state)
    b = np.zeros((N_METRICS, N_BLOCKS))
    for j, name in enumerate(BLOCK_NAMES[:9]):
        b[:, j] = compute_cost(BLOCK_FNS[name], st)
    # one loop turn: fori_loop(0, K, identity) / K  ->  scan_steps == 1
    k = 1024
    turn = compute_cost(lambda s: empty_turns(k, s), st) / k
    b[:, 9] = turn
    b[:, 10] = turn
    return b


def combo_cost(x, unroll: int = 1) -> np.ndarray:
    """Predicted walker cost of ``run_combo(st, x, unroll)``: blocks 1-9
    contribute unroll applications per loop turn."""
    b = calibration_matrix()
    x = np.asarray(x, dtype=np.float64)
    scaled = b.copy()
    scaled[:, :9] *= unroll
    return scaled @ x
