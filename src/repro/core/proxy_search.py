"""Computation-proxy search (paper §2.4).

Problem (paper eq. 6-7 plus the loop-coupling constraint):

    min_x  f(x) = sum_i (1/t_i^2) (b_i . x - t_i)^2
    s.t.   x >= 0,      x_11 >= sum_{i=1..9} x_i

Exact reduction to NNLS: substitute x_11 = sum_{i=1..9} x_i + s with slack
s >= 0.  In the substituted basis y = (x_1..x_9, x_10, s) the columns become

    col'_i = col_i + col_11   (i = 1..9)     # each block turn also costs a loop turn
    col'_10 = col_10
    col'_s  = col_11

and the problem is a plain weighted non-negative least squares — which is
also the *physical* cost structure of the replay code (see blocks.py), so
the substitution is not merely algebraic convenience.

Two solvers:
  * :func:`fit_combination` — scipy NNLS (exact active-set), then integer
    rounding with constraint repair (paper: "rounded approximation at the end").
  * :func:`fit_batch_pgd` — pure-JAX projected gradient descent, ``vmap``-ed
    over many target vectors at once: all cluster representatives of a trace
    are fitted in one device call (beyond-paper optimization; the paper fits
    each event separately on host).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from repro.core import blocks as B
from repro.core.events import METRIC_NAMES, N_METRICS

_EPS = 1e-30


@dataclasses.dataclass
class FitResult:
    x: np.ndarray                 # integer loop-turn counts, len 11
    predicted: np.ndarray         # combo cost at (x, unroll)
    target: np.ndarray
    residual: float               # weighted objective value at the solution
    per_metric_rel_err: np.ndarray
    unroll: int = 1               # block applications per loop turn

    def summary(self) -> str:
        rows = [f"  {n:>16s}: target={t:12.4g} proxy={p:12.4g} err={e:7.2%}"
                for n, t, p, e in zip(METRIC_NAMES, self.target,
                                      self.predicted, self.per_metric_rel_err)]
        return "\n".join(rows)


def _weights(t: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row weights 1/t_i (relative error, paper eq. 6).  A zero target metric
    gets a small finite weight (vs. the mean block magnitude): the solver is
    softly discouraged from exciting metrics the target does not have, but
    unavoidable replay overhead (loop turns) must not crowd out real fits."""
    w = np.zeros_like(t)
    for i in range(len(t)):
        if t[i] > 0:
            w[i] = 1.0 / t[i]
        else:
            scale = float(np.mean(b[i, :9])) if np.any(b[i, :9] > 0) else 1.0
            w[i] = 0.01 / max(scale, _EPS)
    return w


def substituted_matrix(b: np.ndarray, unroll: int = 1) -> np.ndarray:
    """Map the 11-column block matrix to the substituted basis: one loop
    turn of block i = ``unroll`` applications + the turn overhead."""
    bs = b.copy()
    bs[:, :9] = b[:, :9] * unroll + b[:, 10:11]
    # col 9 (block10) unchanged; col 10 becomes the slack (pure loop turn)
    return bs


def _unsubstitute(y: np.ndarray) -> np.ndarray:
    x = y.copy()
    x[10] = float(np.sum(y[:9]) + y[10])
    return x


def _refine_integer(y: np.ndarray, a: np.ndarray, rhs: np.ndarray,
                    max_iter: int = 300) -> np.ndarray:
    """Greedy ±1 coordinate descent on the *integer* substituted solution.

    NNLS is exact over the reals, but block counts are integers (paper:
    "rounded approximation at the end") and naive rounding truncates
    sub-unit counts to zero when an event is smaller than one block
    application.  Steepest-descent unit moves recover the integer optimum
    in practice (objective is convex; the move set is the ±e_j lattice).
    """
    y = np.maximum(np.rint(y), 0).astype(np.int64)

    def obj(v):
        r = a @ v - rhs
        return float(r @ r)

    n = len(y)
    cur = obj(y)
    for _ in range(max_iter):
        best = None
        # single ±1 moves
        for j in range(n):
            for d in (1, -1):
                if y[j] + d < 0:
                    continue
                y[j] += d
                o = obj(y)
                y[j] -= d
                if o < cur - 1e-18 and (best is None or o < best[0]):
                    best = (o, ((j, d),))
        # paired swap moves (+1 on j, -1 on k): escapes block-substitution
        # local minima the axis moves cannot
        for j in range(n):
            for k in range(n):
                if j == k or y[k] < 1:
                    continue
                y[j] += 1
                y[k] -= 1
                o = obj(y)
                y[j] -= 1
                y[k] += 1
                if o < cur - 1e-18 and (best is None or o < best[0]):
                    best = (o, ((j, 1), (k, -1)))
        if best is None:
            break
        cur = best[0]
        for j, d in best[1]:
            y[j] += d
    return y


def _refine_integer_fast(y: np.ndarray, a: np.ndarray, rhs: np.ndarray,
                         max_iter: int = 300) -> np.ndarray:
    """Greedy ±1 / paired-swap descent with analytic objective deltas.

    Same move set as :func:`_refine_integer`, but the objective is
    quadratic, so every candidate move's exact Δobj comes from the
    gradient and Hessian in O(n²) vectorized ops instead of a full
    re-evaluation per move — the per-target polish of the batched-PGD
    path (:func:`fit_batch`), ~100× faster at the same move semantics.
    (:func:`fit_combination` keeps the original evaluator so the exact
    NNLS path stays bit-for-bit stable.)
    """
    y = np.maximum(np.rint(y), 0).astype(np.int64)
    n = len(y)
    h = a.T @ a
    hd = np.diag(h)
    g = a.T @ (a @ y.astype(np.float64) - rhs)
    jj, kk = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    for _ in range(max_iter):
        up = 2.0 * g + hd                       # +1 on j
        dn = np.where(y > 0, -2.0 * g + hd, np.inf)   # -1 on j
        # +1 on j, -1 on k (j != k, y_k >= 1)
        pair = (2.0 * (g[:, None] - g[None, :])
                + hd[:, None] + hd[None, :] - 2.0 * h)
        pair = np.where((jj != kk) & (y[None, :] > 0), pair, np.inf)
        cands = np.concatenate([up, dn, pair.reshape(-1)])
        i = int(np.argmin(cands))
        if not cands[i] < -1e-18:
            break
        if i < n:
            moves = ((i, 1),)
        elif i < 2 * n:
            moves = ((i - n, -1),)
        else:
            i -= 2 * n
            moves = ((i // n, 1), (i % n, -1))
        for j, d in moves:
            y[j] += d
            g = g + d * h[:, j]
    return y


_UNROLLS = (1, 8, 64, 512, 4096)


def _nnls_robust(a: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """NNLS that cannot fail: scipy's active-set solver with a generous
    iteration budget, falling back to bounded least squares when the
    weighted system is ill-conditioned enough to make it cycle (seen on
    tiny sub-block-sized targets).  The integer refinement downstream
    polishes either answer."""
    from scipy.optimize import lsq_linear, nnls

    try:
        try:
            y, _ = nnls(a, rhs, maxiter=max(30 * a.shape[1], 300))
        except TypeError:       # scipy < 1.12: no maxiter kwarg
            y, _ = nnls(a, rhs)
    except (RuntimeError, np.linalg.LinAlgError):
        # active-set cycling (RuntimeError) or a singular normal-equation
        # solve inside newer scipy's nnls (LinAlgError, seen on rank-
        # deficient weighted systems from large traced model steps)
        y = np.maximum(lsq_linear(a, rhs, bounds=(0.0, np.inf)).x, 0.0)
    return y


def fit_combination(t: np.ndarray, b: np.ndarray | None = None,
                    max_count: float = 2 ** 40) -> FitResult:
    """Exact weighted-NNLS fit + integer refinement with constraint repair.

    The loop-body unroll factor is searched over ``_UNROLLS``: large compute
    events need millions of block applications but only thousands of loop
    turns, so the turn count (= serialization metric) stays commensurate
    with the target's scan_steps (paper: multiple block instances share the
    block-11 loop body)."""
    t = np.asarray(t, dtype=np.float64)
    if b is None:
        b = B.calibration_matrix()
    w = _weights(t, b)
    best = None
    for u in _UNROLLS:
        bs = substituted_matrix(b, u)
        a = bs * w[:, None]
        rhs = t * w
        y = _nnls_robust(a, rhs)
        y = np.minimum(y, max_count)
        # integer projection in the substituted basis keeps coupling exact
        yi = _refine_integer(y, a, rhs)
        xi = np.zeros(len(yi), dtype=np.int64)
        xi[:10] = yi[:10]
        xi[10] = int(np.sum(yi[:9]) + yi[10])
        scaled = b.copy()
        scaled[:, :9] *= u
        pred = scaled @ xi
        res = float(np.sum((w * (pred - t)) ** 2))
        if best is None or res < best.residual - 1e-15:
            rel = np.abs(pred - t) / np.maximum(np.abs(t), _EPS)
            rel = np.where(t > 0, rel, np.abs(pred) * w * 10.0)
            best = FitResult(x=xi, predicted=pred, target=t, residual=res,
                             per_metric_rel_err=rel, unroll=u)
    return best


def fit_many(targets: np.ndarray, b: np.ndarray | None = None) -> list[FitResult]:
    return [fit_combination(t, b) for t in np.atleast_2d(targets)]


def fit_batch(targets: np.ndarray,
              b: np.ndarray | None = None,
              unrolls: Sequence[int] = _UNROLLS,
              iters: int = 400) -> list[FitResult]:
    """Fit every target row in **one** batched-PGD device call.

    The single-dispatch path behind ``synthesize(solver="pgd")`` and the
    corpus pipeline.  Like :func:`fit_combination`, the unroll factor is
    searched — but on device: the ``(n_targets × n_unrolls)`` grid solves
    in one ``jit(vmap)`` dispatch, then the best integer solution per
    target is picked by the same weighted objective, so large compute
    events get thousands of loop turns instead of millions (keeping the
    scan_steps metric commensurate with the target's)."""
    targets = np.atleast_2d(np.asarray(targets, dtype=np.float64))
    n = targets.shape[0]
    if n == 0:
        return []
    if b is None:
        b = B.calibration_matrix()
    unrolls = tuple(unrolls)
    bss = np.stack([substituted_matrix(b, u) for u in unrolls])
    grid_t = np.repeat(targets, len(unrolls), axis=0)
    grid_b = np.tile(bss, (n, 1, 1))
    ys = _pgd_grid(grid_t, grid_b, iters).reshape(n, len(unrolls), -1)

    out = []
    for i, t in enumerate(targets):
        w = _weights(t, b)
        rhs = t * w
        best = None
        for j, u in enumerate(unrolls):
            # same integer projection idea as fit_combination — greedy ±1
            # descent in the substituted basis rescues sub-block-sized
            # targets whose real-valued solution rounds to zero — but with
            # analytic move deltas (one quadratic, exact)
            a = bss[j] * w[:, None]
            yi = _refine_integer_fast(ys[i, j], a, rhs)
            xi = np.zeros(len(yi), dtype=np.int64)
            xi[:10] = yi[:10]
            xi[10] = int(np.sum(yi[:9]) + yi[10])
            scaled = b.copy()
            scaled[:, :9] *= u
            pred = scaled @ xi
            res = float(np.sum((w * (pred - t)) ** 2))
            if best is None or res < best.residual - 1e-15:
                # zero-target metrics get the same soft error treatment as
                # fit_combination (raw rel_error would divide by ~1e-30)
                rel = rel_error(t, pred)
                rel = np.where(t > 0, rel, np.abs(pred) * w * 10.0)
                best = FitResult(x=xi, predicted=pred, target=t,
                                 residual=res, per_metric_rel_err=rel,
                                 unroll=u)
        out.append(best)
    return out


# ---------------------------------------------------------------------------
# solver selection
# ---------------------------------------------------------------------------

#: Above this many distinct compute terminals the batched PGD solver is the
#: default: one vmapped device call beats that many sequential active-set
#: solves by orders of magnitude, and per-target accuracy differences wash
#: out in δ̄ at that scale.  At or below it, exact NNLS (+ integer
#: refinement + unroll search) wins on per-fit accuracy and is still cheap.
PGD_TERMINAL_THRESHOLD = 32


def choose_solver(n_targets: int, solver: str = "auto") -> str:
    """Resolve the block-combination solver for ``n_targets`` compute
    terminals: ``"auto"`` picks ``"pgd"`` above
    :data:`PGD_TERMINAL_THRESHOLD`, ``"nnls"`` otherwise; explicit names
    pass through unchanged."""
    if solver != "auto":
        return solver
    return "pgd" if n_targets > PGD_TERMINAL_THRESHOLD else "nnls"


# ---------------------------------------------------------------------------
# pure-JAX batched PGD solver (jit/vmap composable)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _pgd_solver(iters: int):
    """Memoized ``jit(vmap)`` PGD solver for a given iteration count.

    Built once per ``iters`` so repeated ``fit_batch`` calls (the
    incremental corpus path re-solves small miss batches per append) hit
    the jit executable cache instead of recompiling per call; column
    count and batch shape are read from the traced arguments."""
    import jax
    import jax.numpy as jnp

    def solve_one(t, bs):
        n_cols = bs.shape[-1]
        w = jnp.where(t > 0, 1.0 / jnp.maximum(t, _EPS),
                      0.1 / jnp.maximum(jnp.mean(bs[:, :9], axis=1), _EPS))
        a = bs * w[:, None]
        rhs = t * w
        ata = a.T @ a
        atb = a.T @ rhs
        # Lipschitz constant via 20 power-iteration steps
        v = jnp.ones((n_cols,)) / np.sqrt(n_cols)
        for _ in range(20):
            v = ata @ v
            v = v / jnp.maximum(jnp.linalg.norm(v), _EPS)
        lip = jnp.maximum(v @ ata @ v, _EPS)
        eta = 1.0 / lip

        def step(y, _):
            g = ata @ y - atb
            y = jnp.maximum(y - eta * g, 0.0)
            return y, None

        y0 = jnp.zeros((n_cols,))
        y, _ = jax.lax.scan(step, y0, None, length=iters)
        return y

    return jax.jit(jax.vmap(solve_one))


def _pgd_grid(targets: np.ndarray, bss: np.ndarray,
              iters: int = 400) -> np.ndarray:
    """Batched PGD over (target, substituted-matrix) pairs.

    One ``jit(vmap)`` device dispatch solves every row: ``targets`` is
    ``(n, 6)``, ``bss`` the matching ``(n, 6, 11)`` substituted block
    matrices (rows may repeat a matrix, e.g. the unroll grid).  Returns
    the real-valued substituted solutions ``(n, 11)``."""
    import jax.numpy as jnp

    ys = _pgd_solver(int(iters))(jnp.asarray(targets), jnp.asarray(bss))
    return np.asarray(ys, dtype=np.float64)


def fit_batch_pgd(targets: np.ndarray, b: np.ndarray | None = None,
                  iters: int = 400) -> np.ndarray:
    """Batched projected-gradient NNLS on device.

    targets: (n, 6) array of metric vectors. Returns (n, 11) integer counts.
    Objective per row matches :func:`fit_combination` at ``unroll=1``;
    accuracy is within a few percent of the exact active-set solution for
    well-scaled targets (tests assert parity), at ~1000x the throughput
    for large n.
    """
    if b is None:
        b = B.calibration_matrix()
    targets = np.atleast_2d(np.asarray(targets, dtype=np.float64))
    bs = substituted_matrix(b)
    ys = _pgd_grid(targets, np.broadcast_to(bs, (len(targets),) + bs.shape),
                   iters)
    xs = ys.copy()
    xs[:, 10] = np.sum(ys[:, :9], axis=1) + ys[:, 10]
    xi = np.maximum(np.rint(xs).astype(np.int64), 0)
    xi[:, 10] = np.maximum(xi[:, 10], np.sum(xi[:, :9], axis=1))
    return xi


def rel_error(t: np.ndarray, pred: np.ndarray) -> np.ndarray:
    t = np.asarray(t, dtype=np.float64)
    pred = np.asarray(pred, dtype=np.float64)
    return np.abs(pred - t) / np.maximum(np.abs(t), _EPS)


def rel_error_matrix(targets: np.ndarray, preds: np.ndarray) -> np.ndarray:
    """Batched δ matrix (paper eq. 8 numerator): ``|pred - t| / |t|`` over a
    (n_metrics, n_ranks) stack, with rows-by-column where the target metric
    is absent (t <= 0) defined as 0 — a metric the original never excites
    contributes no error.  Used by the vectorized fidelity path in
    :mod:`repro.core.replay`."""
    targets = np.asarray(targets, dtype=np.float64)
    delta = rel_error(targets, preds)
    delta[targets <= 0] = 0.0
    return delta
