"""Top-level proxy-app synthesis pipeline (paper Fig. 1).

    trace → cluster compute events → per-rank Sequitur grammars →
    inter-process merge → QP block-combination search → code generation

One call::

    result = synthesize(step_fn, *specs, axis_sizes={"data": 16})
    result.proxy.run_local()
    print(result.stats["compression_ratio"], result.fidelity.mean)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import proxy_search
from repro.core.events import (
    ComputeEvent, Event, cluster_compute_events, is_comm,
)
from repro.core.grammar import Grammar, TerminalTable, from_sequitur, raw_trace_bytes
from repro.core.interproc import MergedProgram, merge_grammars
from repro.core.codegen import generate_source
from repro.core.replay import FidelityReport, ProxyProgram, load_module
from repro.core.sequitur import Sequitur
from repro.core.tracer import Trace, per_rank_traces, trace_fn


@dataclasses.dataclass
class SynthesisResult:
    proxy: ProxyProgram
    merged: MergedProgram
    grammars: list[Grammar]
    rank_traces: list[list[Event]]
    rank_ids: list[list[int]]
    fits: dict[int, proxy_search.FitResult]
    stats: dict

    @property
    def source(self) -> str:
        return self.proxy.source

    def fidelity(self, sample_ranks: int | None = 16,
                 batched: bool = True) -> FidelityReport:
        """δ̄ report; ``batched`` uses the vectorized per-signature-group
        path (identical numbers, one walker trace per group)."""
        keys = [[g.table[i].key() for i in ids]
                for g, ids in zip(self.grammars, self.rank_ids)]
        return self.proxy.fidelity(self.rank_traces, keys,
                                   sample_ranks=sample_ranks, batched=batched)


def compress_rank_traces(rank_traces: Sequence[Sequence[Event]],
                         rel_tol: float = 0.05,
                         threshold: float = 0.5,
                         ) -> tuple[list[Grammar], MergedProgram,
                                    list[list[int]], dict[int, np.ndarray]]:
    """Cluster compute events jointly, build per-rank grammars, merge.

    Joint clustering across ranks is the paper's "inter-process merging of
    computing terminals has been completed in the process of processing
    computing events" (§2.6.1).
    """
    flat: list[ComputeEvent] = []
    index: list[list[int]] = []
    for tr in rank_traces:
        idx = []
        for ev in tr:
            if not is_comm(ev):
                idx.append(len(flat))
                flat.append(ev)
            else:
                idx.append(-1)
        index.append(idx)
    clustered, reps = cluster_compute_events(flat, rel_tol)

    grammars: list[Grammar] = []
    rank_ids: list[list[int]] = []
    for tr, idx in zip(rank_traces, index):
        table = TerminalTable()
        seq = Sequitur()
        ids = []
        for ev, fi in zip(tr, idx):
            ev2 = clustered[fi] if fi >= 0 else ev
            tid = table.intern(ev2)
            ids.append(tid)
            seq.push(tid)
        grammars.append(from_sequitur(seq, table))
        rank_ids.append(ids)
    merged = merge_grammars(grammars, threshold)
    return grammars, merged, rank_ids, reps


def synthesize(fn: Callable | None = None, *args,
               rank_traces: Sequence[Sequence[Event]] | None = None,
               axis_sizes: dict[str, int] | None = None,
               name: str = "proxy",
               rel_tol: float = 0.05,
               threshold: float = 0.5,
               solver: str = "auto",
               count_scale: float = 1.0,
               out_dir=None) -> SynthesisResult:
    """Synthesize a proxy-app from a step function or pre-recorded traces.

    ``solver="auto"`` (default) picks the block-combination solver by
    terminal count: exact NNLS for small traces, the batched-PGD device
    solver above :data:`repro.core.proxy_search.PGD_TERMINAL_THRESHOLD`
    distinct compute terminals (``"nnls"``/``"pgd"`` force either); the
    resolved name lands in ``stats["solver"]``.

    ``count_scale`` < 1 shrinks the fitted block counts (and hence replay
    time) proportionally — the proxy then represents a 1/count_scale
    time-dilated execution; useful to keep CPU-host replay benchmarks fast.
    """
    if rank_traces is None:
        if fn is None:
            raise ValueError("need fn or rank_traces")
        template: Trace = trace_fn(fn, *args, axis_sizes=axis_sizes)
        axis_sizes = dict(template.axis_sizes if axis_sizes is None
                          else axis_sizes)
        rank_traces = per_rank_traces(template, axis_sizes)
    n_events = sum(len(t) for t in rank_traces)
    trace_bytes = sum(raw_trace_bytes(t) for t in rank_traces)

    grammars, merged, rank_ids, reps = compress_rank_traces(
        rank_traces, rel_tol, threshold)

    # QP block-combination search, one fit per unique compute terminal
    fits: dict[int, proxy_search.FitResult] = {}
    combos: dict[int, tuple] = {}
    targets, gids = [], []
    for gid, ev in enumerate(merged.table.events):
        if not is_comm(ev):
            t = np.asarray(reps[ev.cluster_id] if ev.cluster_id >= 0
                           else ev.vector) * count_scale
            targets.append(t)
            gids.append(gid)
    solver = proxy_search.choose_solver(len(targets), solver)
    if solver == "pgd" and targets:
        xs = proxy_search.fit_batch_pgd(np.stack(targets))
        from repro.core.blocks import calibration_matrix
        b = calibration_matrix()
        for gid, t, x in zip(gids, targets, xs):
            pred = b @ x
            fits[gid] = proxy_search.FitResult(
                x=x, predicted=pred, target=t, residual=0.0,
                per_metric_rel_err=proxy_search.rel_error(t, pred), unroll=1)
            combos[gid] = (tuple(int(v) for v in x), 1)
    else:
        for gid, t in zip(gids, targets):
            fr = proxy_search.fit_combination(t)
            fits[gid] = fr
            combos[gid] = (tuple(int(v) for v in fr.x), fr.unroll)

    source = generate_source(merged, combos, name, axis_sizes)
    module = load_module(source, name=f"{name}_mod", out_dir=out_dir)
    proxy = ProxyProgram(source, module, merged, combos, axis_sizes)

    grammar_bytes = merged.encoded_size_bytes()
    fit_errs = [float(np.mean(f.per_metric_rel_err[f.target > 0]))
                for f in fits.values() if np.any(f.target > 0)]
    stats = {
        "n_ranks": len(rank_traces),
        "n_events": n_events,
        "n_signature_groups": len(module.SIGNATURE_GROUPS),
        "n_unique_terminals": len(merged.table),
        "n_rules": len(merged.rules),
        "trace_bytes": trace_bytes,
        "grammar_bytes": grammar_bytes,
        "compression_ratio": trace_bytes / max(grammar_bytes, 1),
        "source_lines": source.count("\n") + 1,
        "solver": solver,
        "mean_fit_rel_err": float(np.mean(fit_errs)) if fit_errs else 0.0,
        "max_fit_rel_err": float(np.max(fit_errs)) if fit_errs else 0.0,
    }
    return SynthesisResult(proxy=proxy, merged=merged, grammars=grammars,
                           rank_traces=list(map(list, rank_traces)),
                           rank_ids=rank_ids, fits=fits, stats=stats)
