"""Top-level proxy-app synthesis pipeline (paper Fig. 1).

    trace → columnar TraceStore → joint compute-event clustering →
    per-rank Sequitur grammars (signature-deduped) → inter-process merge →
    QP block-combination search → code generation

One call::

    result = synthesize(step_fn, *specs, axis_sizes={"data": 16})
    result.proxy.run_local()
    print(result.stats["compression_ratio"], result.fidelity.mean)

The front half runs on the columnar trace IR (:mod:`repro.core.trace_ir`):
compute metrics live in one ``(n_events, 6)`` array, comm events are
interned ids, and clustering/interning are vectorized — bit-identical to
the per-event reference (:mod:`repro.core.frontend_reference`) and
measured in ``benchmarks/synthesize_time.py``.

:func:`synthesize_corpus` lifts the pipeline to a *corpus* of scenarios
(the model-zoo workloads registered in :mod:`repro.configs.registry`):
compute events cluster jointly across scenarios, the per-scenario merged
tables union into one corpus terminal table, and every block-combination
fit solves in a single batched-PGD device call — one solve for the whole
zoo instead of one per scenario.
"""
from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path
from typing import Callable, Sequence

import numpy as np

from repro.core import noise as noise_mod
from repro.core import proxy_search
from repro.core.events import Event, cluster_corpus, is_comm
from repro.core.grammar import Grammar, TerminalTable
from repro.core.interproc import (
    MergedProgram, corpus_terminal_table, table_fingerprint,
)
from repro.core.codegen import generate_source
from repro.core.replay import FidelityReport, ProxyProgram, load_module
from repro.core.trace_ir import TraceStore, compress_store
from repro.core.tracer import trace_fn_store


@dataclasses.dataclass
class SynthesisResult:
    proxy: ProxyProgram
    merged: MergedProgram
    grammars: list[Grammar]
    store: TraceStore
    rank_ids: list[list[int]]
    fits: dict[int, proxy_search.FitResult]
    stats: dict

    @property
    def source(self) -> str:
        return self.proxy.source

    @property
    def rank_traces(self) -> list[list[Event]]:
        """Materialized per-rank event lists (lazy: the pipeline itself
        never needs them; tests and benchmarks do)."""
        cached = getattr(self, "_rank_traces_cache", None)
        if cached is None:
            cached = self.store.to_rank_traces()
            self._rank_traces_cache = cached
        return cached

    def fidelity(self, sample_ranks: int | None = 16,
                 batched: bool = True, mesh=None, noise=None):
        """δ̄ report; ``batched`` uses the vectorized per-signature-group
        path (identical numbers, one walker trace per group).  The
        original side reads straight from the columnar store — no Event
        materialization.  ``noise=NoiseConfig(...)`` returns the seeded
        :class:`~repro.core.noise.FidelityDistribution` instead (see
        :meth:`repro.core.replay.ProxyProgram.fidelity`)."""
        keys = [[g.table[i].key() for i in ids]
                for g, ids in zip(self.grammars, self.rank_ids)]
        return self.proxy.fidelity(self.store, keys,
                                   sample_ranks=sample_ranks, batched=batched,
                                   mesh=mesh, noise=noise)


def compress_rank_traces(rank_traces: Sequence[Sequence[Event]],
                         rel_tol: float = 0.05,
                         threshold: float = 0.5,
                         ) -> tuple[list[Grammar], MergedProgram,
                                    list[list[int]], dict[int, np.ndarray]]:
    """Cluster compute events jointly, build per-rank grammars, merge.

    Joint clustering across ranks is the paper's "inter-process merging of
    computing terminals has been completed in the process of processing
    computing events" (§2.6.1).  Thin wrapper: ingests the event lists
    into a :class:`TraceStore` and runs the columnar front half.
    """
    store = TraceStore.from_rank_traces(rank_traces)
    return compress_store(store, rel_tol, threshold)


def _fit_terminals(table: TerminalTable, reps: dict[int, np.ndarray],
                   solver: str, count_scale: float,
                   ) -> tuple[dict[int, proxy_search.FitResult],
                              dict[int, tuple], str]:
    """QP block-combination search, one fit per unique compute terminal.

    ``solver="pgd"`` solves every target in one batched device call;
    ``"nnls"`` runs the exact active-set solver per target."""
    targets, gids = [], []
    for gid, ev in enumerate(table.events):
        if not is_comm(ev):
            t = np.asarray(reps[ev.cluster_id] if ev.cluster_id >= 0
                           else ev.vector) * count_scale
            targets.append(t)
            gids.append(gid)
    solver = proxy_search.choose_solver(len(targets), solver)
    fits: dict[int, proxy_search.FitResult] = {}
    combos: dict[int, tuple] = {}
    if solver == "pgd" and targets:
        for gid, fr in zip(gids, proxy_search.fit_batch(np.stack(targets))):
            fits[gid] = fr
            combos[gid] = (tuple(int(v) for v in fr.x), fr.unroll)
    else:
        for gid, t in zip(gids, targets):
            fr = proxy_search.fit_combination(t)
            fits[gid] = fr
            combos[gid] = (tuple(int(v) for v in fr.x), fr.unroll)
    return fits, combos, solver


def _assemble_result(store: TraceStore, grammars, merged, rank_ids, fits,
                     combos, solver: str, name: str,
                     axis_sizes: dict[str, int], count_scale: float,
                     out_dir, codegen: str = "table",
                     noise_model: "noise_mod.NoiseModel | None" = None,
                     ) -> SynthesisResult:
    """Codegen + module load + stats: the shared back half of
    :func:`synthesize` and :func:`synthesize_corpus`.

    ``codegen`` picks the emitter: ``"table"`` (default) is the grammar-
    compiled program-table flavor (executables sized O(grammar));
    ``"unrolled"`` is the per-symbol reference oracle
    (:mod:`repro.core.codegen_reference`) — same δ̄ and comm sequences,
    trace-sized executables.

    ``noise_model`` is the calibrated :class:`~repro.core.noise.NoiseModel`
    whose per-terminal ``(σ, shift)`` pairs land in the emitted module's
    ``NOISE_MODELS`` table (both flavors; ``None`` emits unit factors)."""
    if codegen == "table":
        emit = generate_source
    elif codegen == "unrolled":
        from repro.core.codegen_reference import generate_source as emit
    else:
        raise ValueError(f"unknown codegen flavor: {codegen!r} "
                         "(expected 'table' or 'unrolled')")
    noise_models = (noise_model.terminal_params(merged.table.events)
                    if noise_model is not None else None)
    source = emit(merged, combos, name, axis_sizes,
                  count_scale=count_scale, noise_models=noise_models)
    module = load_module(source, name=f"{name}_mod", out_dir=out_dir)
    proxy = ProxyProgram(source, module, merged, combos, axis_sizes)

    trace_bytes = store.raw_trace_bytes()
    grammar_bytes = merged.encoded_size_bytes()
    fit_errs = [float(np.mean(f.per_metric_rel_err[f.target > 0]))
                for f in fits.values() if np.any(f.target > 0)]
    stats = {
        "n_ranks": store.n_ranks,
        "n_events": store.n_events,
        "n_signature_groups": len(module.SIGNATURE_GROUPS),
        "n_unique_terminals": len(merged.table),
        "n_rules": len(merged.rules),
        "trace_bytes": trace_bytes,
        "grammar_bytes": grammar_bytes,
        "compression_ratio": trace_bytes / max(grammar_bytes, 1),
        "source_lines": source.count("\n") + 1,
        "codegen": codegen,
        "solver": solver,
        "mean_fit_rel_err": float(np.mean(fit_errs)) if fit_errs else 0.0,
        "max_fit_rel_err": float(np.max(fit_errs)) if fit_errs else 0.0,
    }
    return SynthesisResult(proxy=proxy, merged=merged, grammars=grammars,
                           store=store, rank_ids=rank_ids, fits=fits,
                           stats=stats)


def synthesize(fn: Callable | None = None, *args,
               rank_traces: Sequence[Sequence[Event]] | None = None,
               store: TraceStore | None = None,
               axis_sizes: dict[str, int] | None = None,
               name: str = "proxy",
               rel_tol: float = 0.05,
               threshold: float = 0.5,
               solver: str = "auto",
               count_scale: float = 1.0,
               out_dir=None,
               codegen: str = "table") -> SynthesisResult:
    """Synthesize a proxy-app from a step function, pre-recorded traces,
    or a saved columnar :class:`TraceStore` (``TraceStore.load(path)`` —
    traces are offline artifacts).

    ``solver="auto"`` (default) picks the block-combination solver by
    terminal count: exact NNLS for small traces, the batched-PGD device
    solver above :data:`repro.core.proxy_search.PGD_TERMINAL_THRESHOLD`
    distinct compute terminals (``"nnls"``/``"pgd"`` force either); the
    resolved name lands in ``stats["solver"]``.

    ``count_scale`` < 1 shrinks the fitted block counts (and hence replay
    time) proportionally — the proxy then represents a 1/count_scale
    time-dilated execution; useful to keep CPU-host replay benchmarks
    fast.  The generated module's per-group device hints scale with it, so
    the mesh sweep scheduler packs time-dilated groups onto fewer devices.

    ``codegen="table"`` (default) emits the grammar-compiled program-table
    module; ``"unrolled"`` emits the per-symbol reference oracle — both
    replay the same program with bit-identical δ̄ and comm sequences.
    """
    if store is None:
        if rank_traces is not None:
            store = TraceStore.from_rank_traces(rank_traces, axis_sizes)
        elif fn is not None:
            store = trace_fn_store(fn, *args, axis_sizes=axis_sizes)
        else:
            raise ValueError("need fn, rank_traces, or store")
    axis_sizes = dict(store.axis_sizes if axis_sizes is None else axis_sizes)

    grammars, merged, rank_ids, reps = compress_store(store, rel_tol,
                                                      threshold)
    fits, combos, solver = _fit_terminals(merged.table, reps, solver,
                                          count_scale)
    # same rel_tol → same cluster assignment as compress_store, so the
    # calibrated σ keys line up with the merged table's cluster ids
    noise_model = noise_mod.calibrate(store, rel_tol=rel_tol)
    return _assemble_result(store, grammars, merged, rank_ids, fits, combos,
                            solver, name, axis_sizes, count_scale, out_dir,
                            codegen=codegen, noise_model=noise_model)


# ---------------------------------------------------------------------------
# corpus-level synthesis across the scenario zoo
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CorpusResult:
    """Per-scenario synthesis results plus the corpus-level shared state."""
    results: dict[str, SynthesisResult]
    table: TerminalTable               # corpus terminal table (shared)
    reps: dict[int, np.ndarray]        # joint cluster representatives
    stats: dict
    #: corpus-gid-keyed block-combination fits (one per compute terminal
    #: of ``table``) — the serve tier featurizes scenarios over these
    #: coefficients without touching per-scenario modules
    fits: dict[int, proxy_search.FitResult] = dataclasses.field(
        default_factory=dict)

    def report(self, sample_ranks: int | None = None) -> dict:
        """Aggregate fidelity/compression report: per-scenario δ̄ and
        compression ratio plus corpus totals (runs the walker-metric
        fidelity measurement per scenario)."""
        rows = {}
        for sname, res in self.results.items():
            fid = res.fidelity(sample_ranks=sample_ranks)
            rows[sname] = {
                "mean_delta": float(fid.mean),
                "comm_lossless": bool(fid.comm_lossless),
                "compression_ratio": float(res.stats["compression_ratio"]),
                "n_events": int(res.stats["n_events"]),
                "n_ranks": int(res.stats["n_ranks"]),
            }
        deltas = [r["mean_delta"] for r in rows.values()]
        return dict(self.stats, scenarios=rows,
                    mean_delta=float(np.mean(deltas)) if deltas else 0.0,
                    all_comm_lossless=all(r["comm_lossless"]
                                          for r in rows.values()))


def _corpus_scenario_results(stores: dict[str, TraceStore],
                             names: Sequence[str], per: dict[str, tuple],
                             corpus_fits: dict[int, proxy_search.FitResult],
                             gid_maps: Sequence[dict[int, int]],
                             count_scale: float, out_dir,
                             memo: dict | None = None,
                             id_of: dict[str, tuple] | None = None,
                             noise_models: dict | None = None,
                             ) -> tuple[dict[str, SynthesisResult], int]:
    """Back half shared by batch and incremental corpus synthesis: map
    corpus-level fits onto each scenario's merged table and assemble its
    proxy module.

    With ``memo``/``id_of`` (the incremental path), assembly itself is
    content-addressed: a scenario whose identity (content hash, cluster
    assignments, threshold) *and* fit inputs (per-terminal target/x/
    unroll) are unchanged reuses its previous :class:`SynthesisResult`
    wholesale — no re-codegen, no module reload.  Returns ``(results,
    n_reused)``.  ``noise_models[sname]`` is the scenario's calibrated
    :class:`~repro.core.noise.NoiseModel` (a pure function of the
    scenario content + joint cluster assignment, both already part of
    the memo identity, so memo hits stay valid).
    """
    results: dict[str, SynthesisResult] = {}
    n_reused = 0
    for i, sname in enumerate(names):
        grammars, merged, rank_ids = per[sname]
        gmap = gid_maps[i]
        fits, combos = {}, {}
        for gid, ev in enumerate(merged.table.events):
            if is_comm(ev):
                continue
            fr = corpus_fits[gmap[gid]]
            fits[gid] = fr
            combos[gid] = (tuple(int(v) for v in fr.x), fr.unroll)
        rkey = None
        if memo is not None:
            fit_id = tuple(
                (gid, fr.unroll, fr.x.tobytes(), fr.target.tobytes())
                for gid, fr in sorted(fits.items()))
            # sname is part of the key: assembly bakes the scenario name
            # into the module and the out_dir layout, so duplicate-content
            # scenarios must still assemble separately
            rkey = ("result", sname, id_of[sname], count_scale,
                    repr(out_dir), fit_id)
            hit = memo.get(rkey)
            if hit is not None:
                results[sname] = hit
                n_reused += 1
                continue
        sdir = Path(out_dir) / sname if out_dir else None
        results[sname] = _assemble_result(
            stores[sname], grammars, merged, rank_ids, fits, combos, "pgd",
            sname.replace("-", "_"), stores[sname].axis_sizes, count_scale,
            sdir, noise_model=(noise_models or {}).get(sname))
        if rkey is not None:
            memo[rkey] = results[sname]
    return results, n_reused


def _corpus_stats(names: Sequence[str], table: TerminalTable,
                  corpus_fits: dict, gid_maps: Sequence[dict[int, int]],
                  results: dict[str, SynthesisResult]) -> dict:
    from collections import Counter
    use = Counter()
    for m in gid_maps:
        use.update(set(m.values()))
    stats = {
        "n_scenarios": len(names),
        "n_corpus_terminals": len(table),
        "n_compute_terminals": len(corpus_fits),
        "n_shared_terminals": sum(1 for v in use.values() if v > 1),
        "n_solver_calls": 1 if corpus_fits else 0,
        "total_trace_bytes": sum(r.stats["trace_bytes"]
                                 for r in results.values()),
        "total_grammar_bytes": sum(r.stats["grammar_bytes"]
                                   for r in results.values()),
    }
    stats["corpus_compression_ratio"] = (
        stats["total_trace_bytes"] / max(stats["total_grammar_bytes"], 1))
    return stats


def synthesize_corpus(scenarios=None, *,
                      store=None,
                      rel_tol: float = 0.05,
                      threshold: float = 0.5,
                      count_scale: float = 1.0,
                      out_dir=None,
                      **scenario_kwargs) -> CorpusResult:
    """Synthesize proxies for a whole corpus of scenarios at once.

    ``scenarios`` entries are registry names (``repro.configs.registry.
    SCENARIOS``; ``None`` = the full zoo) or ``(name, TraceStore)`` pairs
    for pre-built/loaded traces.  Extra ``scenario_kwargs`` (``n_ranks``,
    ``steps``) forward to the registry builders.

    ``store=`` accepts a :class:`repro.core.corpus_store.CorpusStore`
    instead: synthesis then runs **incrementally** over everything the
    store holds, in canonical manifest order (shard-major, content-hash
    sorted — a pure function of the scenario set) — cluster assignments
    come from the store's persisted :class:`~repro.core.corpus_store.
    ClusterIndex`, unchanged scenarios reuse their memoized grammar front
    half, and only compute terminals without a content-addressed cached
    fit re-solve (still in one ``fit_batch`` dispatch).  Per-scenario δ̄
    is bit-identical to a from-scratch call on the same scenario set in
    the same order — the load-bearing invariant of the streaming corpus
    (pinned by tests/test_corpus_store.py and the CI incremental job).

    Versus a per-scenario :func:`synthesize` loop:

    * compute events cluster **jointly** across scenarios
      (:func:`cluster_corpus`: one pass-1 bucket table per scenario,
      partial sums folded in list order — the same semantics the
      streaming store derives incrementally), so a compute behaviour
      shared by two workloads is one terminal, not two;
    * the per-scenario merged tables union into one corpus terminal table
      (:func:`corpus_terminal_table`), and every block-combination fit
      solves in **one** batched-PGD device call;
    * each scenario still gets its own merged grammar, generated module,
      and :class:`SynthesisResult` (δ̄ measurable per scenario).
    """
    if store is not None:
        if scenarios is not None or scenario_kwargs:
            raise ValueError(
                "store= synthesizes everything the CorpusStore holds; "
                "pass scenarios/builder kwargs at add_scenario time")
        if rel_tol != store.rel_tol:
            raise ValueError(
                f"corpus store was clustered at rel_tol={store.rel_tol}; "
                f"got rel_tol={rel_tol}")
        return _synthesize_corpus_incremental(store, threshold, count_scale,
                                              out_dir)

    from repro.configs import registry   # lazy: configs pulls in models

    if scenarios is None:
        scenarios = list(registry.SCENARIOS)
    stores: dict[str, TraceStore] = {}
    for sc in scenarios:
        if isinstance(sc, str):
            stores[sc] = registry.build_scenario(sc, **scenario_kwargs)
        else:
            sname, st = sc
            stores[sname] = st
    names = list(stores)

    # joint clustering across every scenario's compute events: the
    # per-scenario partial-sums fold (one pass-1 bucket table per
    # scenario, folded in list order) — the same semantics the streaming
    # CorpusStore's ClusterIndex derives incrementally, which is what
    # keeps batch and incremental synthesis bit-identical
    cids_list, reps = cluster_corpus([stores[n].metrics for n in names],
                                     rel_tol)

    per: dict[str, tuple] = {}
    mergeds: list[MergedProgram] = []
    noise_models: dict[str, noise_mod.NoiseModel] = {}
    for i, sname in enumerate(names):
        cids = cids_list[i]
        grammars, merged, rank_ids, _ = compress_store(
            stores[sname], rel_tol, threshold, cluster_ids=cids, reps=reps)
        per[sname] = (grammars, merged, rank_ids)
        mergeds.append(merged)
        # calibrated against the JOINT assignment slice, so σ keys match
        # the merged table's (joint) cluster ids — and so the incremental
        # path, which calibrates from the persisted ClusterIndex's
        # identical assignment, emits identical NOISE_MODELS tables
        noise_models[sname] = noise_mod.calibrate(stores[sname],
                                                  cluster_ids=cids,
                                                  rel_tol=rel_tol)

    # one corpus table, one batched-PGD solve for every compute terminal
    table, gid_maps = corpus_terminal_table(mergeds)
    corpus_fits, _, _ = _fit_terminals(table, reps, "pgd", count_scale)

    results, _ = _corpus_scenario_results(stores, names, per, corpus_fits,
                                          gid_maps, count_scale, out_dir,
                                          noise_models=noise_models)
    stats = _corpus_stats(names, table, corpus_fits, gid_maps, results)
    return CorpusResult(results=results, table=table, reps=reps, stats=stats,
                        fits=corpus_fits)


# ---------------------------------------------------------------------------
# incremental corpus synthesis over a CorpusStore
# ---------------------------------------------------------------------------

_FIT_KEY_VERSION = 1
_basis_fp: str | None = None


def _fit_cache_key(target: np.ndarray) -> str:
    """Content address of one block-combination fit: the exact scaled
    target vector + the calibration-basis fingerprint + a solver-grid
    version (bump :data:`_FIT_KEY_VERSION` when ``fit_batch`` semantics
    change).  A fit is a pure function of these, so a cache hit is valid
    across table re-unions and scenario re-ingests."""
    global _basis_fp
    if _basis_fp is None:
        from repro.core import blocks as B
        _basis_fp = hashlib.sha256(
            np.ascontiguousarray(B.calibration_matrix()).tobytes()
        ).hexdigest()
    h = hashlib.sha256(f"fit|{_FIT_KEY_VERSION}|{_basis_fp}|".encode())
    h.update(np.ascontiguousarray(target, dtype=np.float64).tobytes())
    return h.hexdigest()


def _synthesize_corpus_incremental(cstore, threshold: float,
                                   count_scale: float, out_dir,
                                   ) -> CorpusResult:
    """The ``synthesize_corpus(store=...)`` path: same outputs as the
    batch path over the store's scenarios in manifest order, touching only
    what changed since the last synthesis."""
    # a damaged store must fail loudly here, not emit a proxy silently
    # missing scenarios: repair()/quarantine is an operator decision
    damaged = getattr(cstore, "damaged", None)
    if damaged:
        raise next(iter(damaged.values()))
    shard_errors = getattr(cstore, "shard_errors", None)
    if shard_errors:
        raise next(iter(shard_errors.values()))
    names = cstore.names
    ids_by_name, reps = cstore.cluster_assignments()

    per: dict[str, tuple] = {}
    id_of: dict[str, tuple] = {}
    mergeds: list[MergedProgram] = []
    n_front_reused = 0
    front_profile: dict = {}
    g_hits0 = cstore.grammars.hits
    g_miss0 = cstore.grammars.misses
    for sname in names:
        cids = ids_by_name[sname]
        ident = (cstore.content_hash(sname),
                 hashlib.sha256(cids.tobytes()).hexdigest(), threshold)
        id_of[sname] = ident
        key = ("front",) + ident
        hit = cstore.memo.get(key)
        if hit is None:
            # scenarios without an in-memory front-half memo (new content,
            # or a freshly opened store) still skip Sequitur for every
            # rank stream already in the persisted grammar cache
            grammars, merged, rank_ids, _ = compress_store(
                cstore.load_scenario(sname), cstore.rel_tol, threshold,
                cluster_ids=cids, reps=reps,
                grammar_cache=cstore.grammars, profile=front_profile)
            hit = (grammars, merged, rank_ids)
            cstore.memo[key] = hit
        else:
            n_front_reused += 1
        grammars, merged, rank_ids = hit
        # fresh per-rank id-list copies: memoized grammars/merged are
        # read-only downstream, but id lists are caller-mutable
        per[sname] = (grammars, merged, [list(ids) for ids in rank_ids])
        mergeds.append(merged)
    cstore.save_grammars()

    table, gid_maps = corpus_terminal_table(mergeds)
    table_fp = table_fingerprint(table)

    # content-addressed fits: only targets without a cached fit re-solve,
    # still in ONE fit_batch dispatch
    corpus_fits: dict[int, proxy_search.FitResult] = {}
    miss_gids: list[int] = []
    miss_keys: list[str] = []
    miss_targets: list[np.ndarray] = []
    for gid, ev in enumerate(table.events):
        if is_comm(ev):
            continue
        t = np.asarray(reps[ev.cluster_id] if ev.cluster_id >= 0
                       else ev.vector) * count_scale
        k = _fit_cache_key(t)
        cached = cstore.fits.get(k)
        if cached is None:
            miss_gids.append(gid)
            miss_keys.append(k)
            miss_targets.append(t)
        else:
            corpus_fits[gid] = cached
    if miss_targets:
        # pad the miss batch to a power-of-two bucket: per-row PGD results
        # are independent (the same invariance the fit cache itself relies
        # on), and bucketed shapes let successive appends reuse the jitted
        # PGD executable instead of recompiling per miss count
        batch = np.stack(miss_targets)
        n_miss = len(batch)
        padded = max(4, 1 << (n_miss - 1).bit_length())
        if padded > n_miss:
            batch = np.concatenate(
                [batch, np.repeat(batch[-1:], padded - n_miss, axis=0)])
        frs = proxy_search.fit_batch(batch)[:n_miss]
        for gid, k, fr in zip(miss_gids, miss_keys, frs):
            corpus_fits[gid] = fr
            cstore.fits.put(k, fr)
    if miss_targets or cstore.manifest.get("table_fingerprint") != table_fp:
        cstore.save_fits(table_fp)   # fully-cached runs stay read-only

    stores = {n: cstore.load_scenario(n) for n in names}
    # same joint cluster assignment (the persisted ClusterIndex is pinned
    # bit-identical to the batch path) + same metrics → identical noise
    # params, so batch and incremental emit identical NOISE_MODELS tables
    noise_models = {n: noise_mod.calibrate(stores[n],
                                           cluster_ids=ids_by_name[n],
                                           rel_tol=cstore.rel_tol)
                    for n in names}
    results, n_result_reused = _corpus_scenario_results(
        stores, names, per, corpus_fits, gid_maps, count_scale, out_dir,
        memo=cstore.memo, id_of=id_of, noise_models=noise_models)
    stats = _corpus_stats(names, table, corpus_fits, gid_maps, results)
    stats.update(
        incremental=True,
        table_fingerprint=table_fp,
        n_refit_terminals=len(miss_targets),
        n_cached_fits=len(corpus_fits) - len(miss_targets),
        n_front_reused=n_front_reused,
        n_result_reused=n_result_reused,
        n_solver_calls=1 if miss_targets else 0,
        n_grammar_cache_hits=cstore.grammars.hits - g_hits0,
        n_grammar_cache_misses=cstore.grammars.misses - g_miss0,
        grammar_ms=round(front_profile.get("grammar_ms", 0.0), 3),
    )
    return CorpusResult(results=results, table=table, reps=reps, stats=stats,
                        fits=corpus_fits)
