"""Reference unrolled code generator (paper §2.7, Algorithm 2) — oracle.

This is the original per-symbol emitter preserved verbatim when
:mod:`repro.core.codegen` switched to grammar-compiled program tables:
every grammar symbol becomes one Python statement, non-terminals become
functions, main rules become per-cluster drivers with rank-set guards.
The output is trivially auditable — the generated source *is* the grammar,
unrolled — which is exactly what makes it the parity oracle:

* compiled and unrolled modules must produce **bit-identical δ̄** and
  **identical per-rank comm sequences** (LocalSim and mesh replay) — pinned
  by tests/test_codegen_replay.py, tests/test_progtable.py, and the CI
  parity step (benchmarks/codegen_parity.py);
* any grammar-semantics change must update all three reference oracles
  (``sequitur_reference``, ``frontend_reference``, ``codegen_reference``)
  in the same commit.

Shared pure-metadata helpers (rank-set formatting, signature grouping,
device hints, guard-run computation) are imported from
:mod:`repro.core.codegen` so both flavors emit identical
``SIGNATURE_GROUPS`` / ``CLUSTER_RANKS`` / ``_GUARDS`` metadata by
construction.
"""
from __future__ import annotations

import textwrap
from typing import Mapping

from repro.core.codegen import (
    _comm_buffers, _fmt_rankset, _fmt_ranktuple, _main_runs,
    _noise_models_block, _syms_comm_axes, _topo_order,
    compute_signature_groups, group_device_hint,
)
from repro.core.events import is_comm
from repro.core.interproc import MergedProgram


def generate_source(merged: MergedProgram,
                    combos: Mapping[int, tuple],
                    name: str = "proxy",
                    axis_sizes: Mapping[str, int] | None = None,
                    count_scale: float = 1.0,
                    noise_models=None) -> str:
    """Emit the unrolled proxy-app module source (one statement/symbol)."""
    axis_sizes = dict(axis_sizes or {})
    L: list[str] = []
    w = L.append

    w(f'"""Auto-generated performance proxy ({name}).')
    w("")
    w("Synthesized by repro.core (Siesta-JAX): the collective skeleton is a")
    w("lossless replay of the traced program; compute segments are QP-fitted")
    w("block combinations.  Unrolled reference flavor (codegen_reference).")
    w("Do not edit."  '"""')
    w("from jax import lax  # noqa: F401")
    w("from repro.core import blocks as _blocks")
    w("from repro.core import noise as _noise")
    w("from repro.core.replay import rep as _rep")
    w("")
    w("CODEGEN = 'unrolled'")
    w(f"N_RANKS = {merged.n_ranks}")
    w(f"AXIS_SIZES = {dict(axis_sizes)!r}")

    # -- comm buffer pool (one per distinct payload shape/dtype) --------------
    bufs = _comm_buffers(merged)
    w("COMM_BUFFERS = {")
    for (shape, dtype), bname in bufs.items():
        w(f"    {bname!r}: ({shape!r}, {dtype!r}),")
    w("}")
    w("ALL = frozenset(range(N_RANKS))")
    w("")

    # -- noise params (shared table + this flavor's compact cost descs) --------
    # _NOISE_DESCS is the unrolled twin of the table flavor's TERMINALS for
    # noise lowering only: comm terminals carry just the payload bytes,
    # compute terminals their (x, unroll) combo — enough for
    # noise.lower_params to bind identical LoweredNoise records in both
    # flavors (the bit-parity prerequisite).
    w(_noise_models_block(merged, noise_models))
    w("_NOISE_DESCS = (")
    for gid, ev in enumerate(merged.table.events):
        if is_comm(ev):
            w(f"    ('comm', {int(ev.payload_bytes)}),  # t{gid}")
        else:
            combo = combos.get(gid)
            if combo is None:
                raise KeyError(f"no block combo for compute terminal {gid}")
            x, unroll = combo
            w(f"    ('compute', {tuple(int(v) for v in x)!r}, "
              f"{int(unroll)}),  # t{gid}")
    w(")")
    w("_NZ = _noise.lower_params(NOISE_MODELS, _NOISE_DESCS)")
    w("")

    # -- terminals -------------------------------------------------------------
    for gid, ev in enumerate(merged.table.events):
        if is_comm(ev):
            bname = bufs[(ev.shape, ev.dtype)]
            w(f"def t{gid}(st, comm):  # {ev.kind} {ev.dtype}{list(ev.shape)} over {ev.axes}")
            w(f"    st = comm.do(st, {bname!r}, kind={ev.kind!r}, "
              f"axes={ev.axes!r}, detail={ev.detail!r}, "
              f"shape={ev.shape!r}, dtype={ev.dtype!r})")
        else:
            combo = combos.get(gid)
            if combo is None:
                raise KeyError(f"no block combo for compute terminal {gid}")
            x, unroll = combo
            w(f"def t{gid}(st, comm):  # MPI_Compute proxy, cluster {ev.cluster_id}")
            w(f"    st = _blocks.run_combo(st, {tuple(int(v) for v in x)!r}, "
              f"unroll={int(unroll)})")
        w(f"    return _noise.perturb(st, _NZ[{gid}])")
        w("")

    # -- non-terminals (children before parents) -------------------------------
    order = _topo_order(merged.rules)
    for rid in order:
        w(f"def r{rid}(st, comm):")
        body = merged.rules[rid]
        if not body:
            w("    return st")
            w("")
            continue
        for kind, ref, exp in body:
            fn = f"t{ref}" if kind == "t" else f"r{ref}"
            if exp == 1:
                w(f"    st = {fn}(st, comm)")
            else:
                w(f"    st = _rep({fn}, {exp}, st, comm)")
        w("    return st")
        w("")

    # -- main rules with rank-set guards ----------------------------------------
    runs_per_cluster = _main_runs(merged)
    guards_meta: list[list[str]] = []
    cluster_runs: list[list[frozenset | None]] = []   # None == unguarded run
    for ci, (runs, cranks) in enumerate(zip(runs_per_cluster,
                                            merged.cluster_ranks)):
        w(f"def main{ci}(st, comm, rank):")
        if not runs:
            w("    return st")
            w("")
            guards_meta.append([])
            cluster_runs.append([])
            continue
        meta = []
        for rs, syms in runs:
            full = rs >= cranks
            indent = "    "
            if not full:
                w(f"    if rank in {_fmt_rankset(rs, merged.n_ranks)}:")
                indent = "        "
            for kind, ref, exp in syms:
                fn = f"t{ref}" if kind == "t" else f"r{ref}"
                if exp == 1:
                    w(f"{indent}st = {fn}(st, comm)")
                else:
                    w(f"{indent}st = _rep({fn}, {exp}, st, comm)")
            meta.append("None" if full else _fmt_rankset(rs, merged.n_ranks))
        w("    return st")
        w("")
        guards_meta.append(meta)
        cluster_runs.append([None if rs >= cranks else rs for rs, _ in runs])

    # -- driver + signature -------------------------------------------------------
    w("CLUSTER_RANKS = (")
    for cr in merged.cluster_ranks:
        w(f"    {_fmt_rankset(cr, merged.n_ranks)},")
    w(")")
    w("_MAINS = (" + ", ".join(f"main{i}" for i in range(len(merged.mains)))
      + ("," if len(merged.mains) == 1 else "") + ")")
    w("_GUARDS = (")
    for meta in guards_meta:
        w("    (" + ", ".join(meta) + ("," if len(meta) == 1 else "") + "),")
    w(")")
    w("")

    # -- signature-group metadata (batched replay, §3.3) -----------------------
    sig_groups = compute_signature_groups(merged.cluster_ranks, cluster_runs,
                                          merged.n_ranks)
    run_axes = [[_syms_comm_axes(syms, merged.rules, merged.table)
                 for _, syms in runs] for runs in runs_per_cluster]
    w("#: (signature, ranks, device_hint) triples; every rank appears in")
    w("#: exactly one group.")
    w("SIGNATURE_GROUPS = (")
    for sig, ranks in sig_groups:
        hint = group_device_hint(sig, run_axes, axis_sizes, count_scale)
        w(f"    ({sig!r}, {_fmt_ranktuple(ranks)}, {hint}),")
    w(")")
    w("")
    w(textwrap.dedent("""\
        def run_rank(st, comm, rank):
            \"\"\"Execute rank ``rank``'s proxy program (host-level dispatch).\"\"\"
            for ranks, fn in zip(CLUSTER_RANKS, _MAINS):
                if rank in ranks:
                    st = fn(st, comm, rank)
            return st


        def program_signature(rank):
            \"\"\"Hashable per-rank control-flow signature (jit dedupe key).\"\"\"
            sig = []
            for ci, (ranks, guards) in enumerate(zip(CLUSTER_RANKS, _GUARDS)):
                if rank in ranks:
                    sig.append((ci, tuple(i for i, g in enumerate(guards)
                                          if g is None or rank in g)))
            return tuple(sig)
    """))
    return "\n".join(L)
